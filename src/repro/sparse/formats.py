"""Sparse matrix storage formats as JAX pytrees.

Formats
-------
COO              (rows, cols, vals) unsorted triplets — interchange format.
CSR              classic compressed-sparse-row — canonical logical format.
GroupedCOO       row-sorted COO padded to a multiple of ``nnz_tile`` — the
                 feed format of the nnz-split (EB) segment-group kernel.
                 Padding uses ``val = 0`` so padded lanes are *zero
                 extension* in the paper's sense: they flow through the
                 vector/MXU datapath and contribute nothing.
ELL              per-row padded (blocked-ELL when viewed in row tiles) —
                 the feed format of the row-split (RB) kernel.

All formats carry their dense ``shape`` and padding parameters as static
metadata so they can cross ``jit`` boundaries.

``CSR`` memoizes its kernel-feed conversions per ``(format, tile)`` —
``csr.grouped(nnz_tile)`` / ``csr.ell(row_tile)`` / ``csr.tocoo()`` — so
training loops that call ``spmm`` on the same matrix every step don't
re-convert.  The cache only engages on concrete (non-traced) arrays; it is
deliberately not part of the pytree, so transformed copies start cold.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["COO", "CSR", "GroupedCOO", "ELL", "round_up"]


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _instance_cache(obj, arrays):
    """Per-instance conversion memo, or None while being traced (caching
    tracers would leak them across jit traces).  Deliberately not part of
    the pytree: transformed copies start cold."""
    if any(isinstance(x, jax.core.Tracer) for x in arrays):
        return None
    cache = obj.__dict__.get("_convcache")
    if cache is None:
        cache = {}
        object.__setattr__(obj, "_convcache", cache)
    return cache


def _memoized(obj, arrays, key, build):
    cache = _instance_cache(obj, arrays)
    if cache is None:
        return build()
    if key not in cache:
        cache[key] = build()
    return cache[key]


def _csr_scatter_index(indptr):
    """(row_ids, positions) int arrays: nnz t of CSR row r lands in ELL
    slot ``t - indptr[r]``.  Shared by ``ELL.fromcsr`` and
    ``CSR.ell_scatter_index``."""
    indptr = np.asarray(indptr).astype(np.int64)
    lengths = indptr[1:] - indptr[:-1]
    row_ids = np.repeat(np.arange(lengths.shape[0]), lengths)
    pos = np.arange(indptr[-1]) - np.repeat(indptr[:-1], lengths)
    return row_ids, pos


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "cols", "vals"],
    meta_fields=["shape"],
)
@dataclasses.dataclass(frozen=True)
class COO:
    """Unordered triplet format. ``shape`` is the dense (n_rows, n_cols)."""

    rows: jax.Array  # (nnz,) int32
    cols: jax.Array  # (nnz,) int32
    vals: jax.Array  # (nnz,) float
    shape: tuple

    @property
    def nnz(self) -> int:
        return self.vals.shape[0]

    def todense(self) -> jax.Array:
        out = jnp.zeros(self.shape, self.vals.dtype)
        return out.at[self.rows, self.cols].add(self.vals)

    @staticmethod
    def fromdense(mat) -> "COO":
        mat = np.asarray(mat)
        rows, cols = np.nonzero(mat)
        order = np.lexsort((cols, rows))
        return COO(
            rows=jnp.asarray(rows[order], jnp.int32),
            cols=jnp.asarray(cols[order], jnp.int32),
            vals=jnp.asarray(mat[rows[order], cols[order]]),
            shape=mat.shape,
        )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["indptr", "indices", "vals"],
    meta_fields=["shape"],
)
@dataclasses.dataclass(frozen=True)
class CSR:
    indptr: jax.Array  # (n_rows + 1,) int32
    indices: jax.Array  # (nnz,) int32 column ids
    vals: jax.Array  # (nnz,)
    shape: tuple

    @property
    def nnz(self) -> int:
        return self.vals.shape[0]

    def row_lengths(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    # -- conversion caching ------------------------------------------------

    def _cached(self, key, build):
        return _memoized(self, (self.indptr, self.indices, self.vals),
                         key, build)

    def tocoo(self) -> "COO":
        # expand indptr -> per-nnz row ids (format-time searchsorted: this
        # replaces the paper's per-thread taco_binarySearchBefore).
        def build():
            rows = jnp.searchsorted(
                self.indptr, jnp.arange(self.nnz, dtype=jnp.int32),
                side="right",
            ).astype(jnp.int32) - 1
            return COO(rows=rows, cols=self.indices, vals=self.vals,
                       shape=self.shape)

        return self._cached("coo", build)

    def grouped(self, nnz_tile: int) -> "GroupedCOO":
        """EB-kernel feed format, memoized per nnz_tile."""
        return self._cached(("grouped", nnz_tile),
                            lambda: GroupedCOO.fromcsr(self, nnz_tile))

    def ell(self, row_tile: int = 8, width: int | None = None) -> "ELL":
        """RB-kernel feed format, memoized per (row_tile, width)."""
        return self._cached(("ell", row_tile, width),
                            lambda: ELL.fromcsr(self, width=width,
                                                row_tile=row_tile))

    def ell_scatter_index(self):
        """(row_ids, positions) int32 arrays scattering the flat CSR value
        stream into the ELL (row, slot) layout — lets callers rebuild
        ``ELL.vals`` from fresh values (e.g. inside autodiff) without a
        Python loop.  Requires concrete arrays."""
        def build():
            row_ids, pos = _csr_scatter_index(self.indptr)
            return (jnp.asarray(row_ids, jnp.int32),
                    jnp.asarray(pos, jnp.int32))

        return self._cached("ell_scatter", build)

    def todense(self) -> jax.Array:
        return self.tocoo().todense()

    @staticmethod
    def fromdense(mat) -> "CSR":
        mat = np.asarray(mat)
        # np.nonzero is C-ordered: already sorted by (row, col).
        rows, cols = np.nonzero(mat)
        counts = np.bincount(rows, minlength=mat.shape[0])
        indptr = np.concatenate([[0], np.cumsum(counts)])
        return CSR(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(cols, jnp.int32),
            vals=jnp.asarray(mat[rows, cols]),
            shape=mat.shape,
        )

    @staticmethod
    def fromcoo(coo: COO) -> "CSR":
        rows = np.asarray(coo.rows)
        cols = np.asarray(coo.cols)
        vals = np.asarray(coo.vals)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        counts = np.bincount(rows, minlength=coo.shape[0])
        indptr = np.concatenate([[0], np.cumsum(counts)])
        return CSR(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(cols, jnp.int32),
            vals=jnp.asarray(vals),
            shape=coo.shape,
        )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "cols", "vals"],
    meta_fields=["shape", "nnz", "nnz_tile"],
)
@dataclasses.dataclass(frozen=True)
class GroupedCOO:
    """Row-sorted COO padded to a multiple of ``nnz_tile``.

    Feed format for the nnz-split segment-group kernel: a grid cell owns one
    ``nnz_tile`` slice; ``rows`` is the precomputed per-nnz row-id stream.
    Padded lanes have ``val == 0`` and ``row == shape[0] - 1`` (zero
    extension — they reduce into a live row but contribute nothing).
    """

    rows: jax.Array  # (nnz_padded,) int32, non-decreasing
    cols: jax.Array  # (nnz_padded,) int32
    vals: jax.Array  # (nnz_padded,)
    shape: tuple
    nnz: int  # true nnz (static)
    nnz_tile: int

    @property
    def nnz_padded(self) -> int:
        return self.vals.shape[0]

    @property
    def num_tiles(self) -> int:
        return self.nnz_padded // self.nnz_tile

    @staticmethod
    def fromcsr(csr: CSR, nnz_tile: int) -> "GroupedCOO":
        coo = csr.tocoo()
        nnz = csr.nnz
        padded = max(round_up(max(nnz, 1), nnz_tile), nnz_tile)
        pad = padded - nnz
        pad_row = csr.shape[0] - 1
        rows = jnp.concatenate(
            [coo.rows, jnp.full((pad,), pad_row, jnp.int32)])
        cols = jnp.concatenate([coo.cols, jnp.zeros((pad,), jnp.int32)])
        vals = jnp.concatenate([coo.vals, jnp.zeros((pad,), coo.vals.dtype)])
        return GroupedCOO(rows=rows, cols=cols, vals=vals, shape=csr.shape,
                          nnz=nnz, nnz_tile=nnz_tile)

    def regrouped(self, nnz_tile: int) -> "GroupedCOO":
        """This GroupedCOO re-padded to a different tile size, memoized
        per target tile (the same per-``(format, tile)`` conversion cache
        ``CSR`` has) — a serving loop whose tuned ``nnz_tile`` differs
        from the feed's converts once, not per call."""
        if nnz_tile == self.nnz_tile:
            return self

        def build():
            nnz = self.nnz
            padded = max(round_up(max(nnz, 1), nnz_tile), nnz_tile)
            pad = padded - nnz
            return GroupedCOO(
                rows=jnp.concatenate(
                    [self.rows[:nnz],
                     jnp.full((pad,), self.shape[0] - 1, jnp.int32)]),
                cols=jnp.concatenate(
                    [self.cols[:nnz], jnp.zeros((pad,), jnp.int32)]),
                vals=jnp.concatenate(
                    [self.vals[:nnz],
                     jnp.zeros((pad,), self.vals.dtype)]),
                shape=self.shape, nnz=nnz, nnz_tile=nnz_tile)

        return _memoized(self, (self.rows, self.cols, self.vals),
                         ("regrouped", nnz_tile), build)

    def todense(self) -> jax.Array:
        out = jnp.zeros(self.shape, self.vals.dtype)
        return out.at[self.rows, self.cols].add(self.vals)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["cols", "vals"],
    meta_fields=["shape", "width"],
)
@dataclasses.dataclass(frozen=True)
class ELL:
    """Per-row padded format (rows also padded to a row-tile multiple by the
    kernel wrapper). Feed format for the row-split kernel: a grid cell owns
    ``ROW_TILE`` whole rows. Padding cols point at column 0 with val 0."""

    cols: jax.Array  # (n_rows_padded, width) int32
    vals: jax.Array  # (n_rows_padded, width)
    shape: tuple
    width: int

    @property
    def n_rows_padded(self) -> int:
        return self.vals.shape[0]

    @staticmethod
    def fromcsr(csr: CSR, width: int | None = None, row_tile: int = 8) -> "ELL":
        indptr = np.asarray(csr.indptr).astype(np.int64)
        indices = np.asarray(csr.indices)
        vals = np.asarray(csr.vals)
        n_rows = csr.shape[0]
        lengths = indptr[1:] - indptr[:-1]
        w = int(lengths.max()) if len(lengths) and lengths.max() > 0 else 1
        if width is not None:
            if width < w:
                raise ValueError(f"width {width} < max row length {w}")
            w = width
        w = max(w, 1)
        n_pad = round_up(max(n_rows, 1), row_tile)
        ecols = np.zeros((n_pad, w), np.int32)
        evals = np.zeros((n_pad, w), vals.dtype if vals.size else np.float32)
        row_ids, pos = _csr_scatter_index(indptr)
        ecols[row_ids, pos] = indices
        evals[row_ids, pos] = vals
        return ELL(cols=jnp.asarray(ecols), vals=jnp.asarray(evals),
                   shape=csr.shape, width=w)

    def todense(self) -> jax.Array:
        n_rows, _ = self.shape
        rows = jnp.repeat(jnp.arange(self.n_rows_padded), self.width)
        out = jnp.zeros((self.n_rows_padded, self.shape[1]), self.vals.dtype)
        out = out.at[rows, self.cols.reshape(-1)].add(self.vals.reshape(-1))
        return out[:n_rows]
