"""Benchmark harness — one function per paper table (Sgap Tables 1-5) plus
beyond-paper benches. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--full]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger matrices (slower, closer to paper scale)")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table3,table4,table5,"
                         "moe,selector")
    args = ap.parse_args()
    quick = not args.full

    from . import beyond, tables

    benches = {
        "table1": lambda: tables.table1_group_size(quick),
        "table2": lambda: tables.table2_segment_vs_atomic(quick),
        "table3": lambda: tables.table3_new_vs_original(quick),
        "table4": lambda: tables.table4_tuning(quick),
        "table5": lambda: tables.table5_dynamic_choice(quick),
        "moe": lambda: beyond.moe_dispatch(quick),
        "selector": lambda: beyond.selector_quality(quick),
    }
    wanted = args.only.split(",") if args.only else list(benches)

    print("name,us_per_call,derived")
    ok = True
    for name in wanted:
        try:
            for row in benches[name]():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            ok = False
            print(f"{name},NaN,ERROR:{e!r}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
