"""End-to-end LM training driver: data pipeline -> sharded train step ->
checkpoints -> fault-tolerance hooks.

Default runs a ~10M-param model for 60 steps on CPU in a couple of
minutes; ``--size 100m --steps 300`` is the full exercise.

    PYTHONPATH=src python examples/train_lm.py [--size 100m] [--steps 300]
        [--arch qwen2-7b] [--microbatches 2] [--compress int8]
"""
import argparse

import jax

from repro.configs import ARCHS
from repro.data.synthetic import ShardedTokenStream
from repro.models import get_model
from repro.train.optimizer import AdamW, cosine_schedule
from repro.train.trainer import Trainer, TrainerConfig

SIZES = {
    # (layers, d_model, heads, kv, d_ff)  — ~param counts with 8k vocab
    "10m": (4, 256, 4, 2, 1024),
    "100m": (12, 768, 12, 4, 3072),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ARCHS))
    ap.add_argument("--size", default="10m", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", default=None, choices=[None, "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    n_layers, d_model, heads, kv, d_ff = SIZES[args.size]
    cfg = ARCHS[args.arch].scaled(
        n_layers=n_layers, d_model=d_model, n_heads=heads, n_kv_heads=kv,
        d_head=d_model // heads, d_ff=d_ff, vocab_size=8192,
        param_dtype="float32", compute_dtype="float32", remat=False,
        q_chunk=128, kv_chunk=128)
    if cfg.family == "moe":
        cfg = cfg.scaled(n_experts=8, experts_per_token=2, moe_d_ff=d_ff // 2)
    api = get_model(cfg)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(api.init, jax.random.PRNGKey(0))))
    print(f"arch family {cfg.family}; params {n_params / 1e6:.1f}M")

    data = ShardedTokenStream(cfg.vocab_size, args.seq, args.batch, seed=0)
    opt = AdamW(lr=cosine_schedule(3e-4, warmup=20, total=args.steps))
    trainer = Trainer(
        api, opt, iter(data), ckpt_dir=args.ckpt_dir,
        tcfg=TrainerConfig(total_steps=args.steps, ckpt_every=50,
                           log_every=10, microbatches=args.microbatches,
                           grad_compression=args.compress))
    state = trainer.init_or_restore(jax.random.PRNGKey(0))
    state = trainer.run(state)
    losses = trainer.losses()
    print(f"loss: first10 {losses[:10].mean():.4f} -> "
          f"last10 {losses[-10:].mean():.4f}")
    assert losses[-10:].mean() < losses[:10].mean(), "loss did not improve"
    print("train_lm complete ✓")


if __name__ == "__main__":
    main()
