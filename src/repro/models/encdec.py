"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, enc_len, D) directly. Positions are
sinusoidal on both sides (Whisper: sinusoidal encoder / learned decoder —
noted in DESIGN.md changed assumptions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import decode_attention, flash_attention
from .layers import (apply_dense, apply_mlp, apply_norm, embed,
                     init_embedding, init_mlp, init_norm, layer_scan,
                     lm_loss_from_features, unembed)
from .transformer import init_attn


def sinusoidal(n: int, d: int):
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = jnp.arange(n)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mha(cfg, p, xq, xkv, causal):
    b, sq, _ = xq.shape
    q = apply_dense(p["wq"], xq).reshape(b, sq, cfg.n_heads, cfg.d_head)
    k = apply_dense(p["wk"], xkv).reshape(b, xkv.shape[1], cfg.n_kv_heads,
                                          cfg.d_head)
    v = apply_dense(p["wv"], xkv).reshape(b, xkv.shape[1], cfg.n_kv_heads,
                                          cfg.d_head)
    o = flash_attention(q, k, v, causal, cfg.q_chunk, cfg.kv_chunk)
    return apply_dense(p["wo"], o.reshape(b, sq, cfg.attn_dim))


def init_enc_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg, cfg.d_model), "attn": init_attn(cfg, k1),
            "ln2": init_norm(cfg, cfg.d_model), "mlp": init_mlp(cfg, k2)}


def init_dec_layer(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg, cfg.d_model), "self_attn": init_attn(cfg, k1),
        "ln_x": init_norm(cfg, cfg.d_model), "cross_attn": init_attn(cfg, k2),
        "ln2": init_norm(cfg, cfg.d_model), "mlp": init_mlp(cfg, k3),
    }


def init_params(cfg, key):
    ke, kenc, kdec, kp = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_layer(cfg, k))(
        jax.random.split(kenc, cfg.n_encoder_layers))
    dec = jax.vmap(lambda k: init_dec_layer(cfg, k))(
        jax.random.split(kdec, cfg.n_layers))
    del kp
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                cfg.param_dtype),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": init_norm(cfg, cfg.d_model),
        "final_norm": init_norm(cfg, cfg.d_model),
    }


def encode(cfg, params, frames):
    """frames: (B, S_enc, D) stub embeddings -> (B, S_enc, D)."""
    x = frames.astype(cfg.compute_dtype)
    x = x + sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def layer(p_l, x):
        h = apply_norm(cfg, p_l["ln1"], x)
        x = x + _mha(cfg, p_l["attn"], h, h, causal=False)
        return x + apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    def step(x, p_l):
        return layer(p_l, x), None

    x, _ = layer_scan(cfg, step, x, params["enc_layers"])
    return apply_norm(cfg, params["enc_norm"], x)


def decode_train(cfg, params, tokens, enc_out):
    x = embed(params["embed"], tokens).astype(cfg.compute_dtype)
    x = x + sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)[None]

    def layer(p_l, x):
        h = apply_norm(cfg, p_l["ln1"], x)
        x = x + _mha(cfg, p_l["self_attn"], h, h, causal=True)
        h = apply_norm(cfg, p_l["ln_x"], x)
        x = x + _mha(cfg, p_l["cross_attn"], h, enc_out, causal=False)
        return x + apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    def step(x, p_l):
        return layer(p_l, x), None

    x, _ = layer_scan(cfg, step, x, params["dec_layers"])
    return apply_norm(cfg, params["final_norm"], x)


def forward(cfg, params, batch, ctx=None):
    del ctx
    enc_out = encode(cfg, params, batch["encoder_embeds"])
    x = decode_train(cfg, params, batch["tokens"], enc_out)
    return unembed(params["embed"], x)


def loss_fn(cfg, params, batch, ctx=None):
    del ctx
    enc_out = encode(cfg, params, batch["encoder_embeds"])
    x = decode_train(cfg, params, batch["tokens"], enc_out)
    return lm_loss_from_features(params["embed"], x[:, :-1],
                                 batch["tokens"][:, 1:], batch.get("mask"))


def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or cfg.compute_dtype
    kv = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.d_head)
    ckv = (cfg.n_layers, batch_size, cfg.encoder_seq, cfg.n_kv_heads,
           cfg.d_head)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "ck": jnp.zeros(ckv, dtype), "cv": jnp.zeros(ckv, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(cfg, params, batch, max_len, ctx=None):
    """Encode + cache cross-attention K/V + run the prompt tokens."""
    del ctx
    enc_out = encode(cfg, params, batch["encoder_embeds"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed(params["embed"], tokens).astype(cfg.compute_dtype)
    x = x + sinusoidal(s, cfg.d_model).astype(x.dtype)[None]

    def step(x, p_l):
        h = apply_norm(cfg, p_l["ln1"], x)
        q = apply_dense(p_l["self_attn"]["wq"], h).reshape(
            b, s, cfg.n_heads, cfg.d_head)
        k = apply_dense(p_l["self_attn"]["wk"], h).reshape(
            b, s, cfg.n_kv_heads, cfg.d_head)
        v = apply_dense(p_l["self_attn"]["wv"], h).reshape(
            b, s, cfg.n_kv_heads, cfg.d_head)
        o = flash_attention(q, k, v, True, cfg.q_chunk, cfg.kv_chunk)
        x = x + apply_dense(p_l["self_attn"]["wo"],
                            o.reshape(b, s, cfg.attn_dim))
        h = apply_norm(cfg, p_l["ln_x"], x)
        ck = apply_dense(p_l["cross_attn"]["wk"], enc_out).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.d_head)
        cv = apply_dense(p_l["cross_attn"]["wv"], enc_out).reshape(
            b, enc_out.shape[1], cfg.n_kv_heads, cfg.d_head)
        x = x + _mha(cfg, p_l["cross_attn"], h, enc_out, causal=False)
        x = x + apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))
        return x, (k, v, ck, cv)

    x, (ks, vs, cks, cvs) = layer_scan(cfg, step, x, params["dec_layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x[:, -1])
    pad = max_len - s
    return logits, {
        "k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "ck": cks, "cv": cvs,
        "pos": jnp.asarray(s, jnp.int32),
    }


def decode_step(cfg, params, cache, tokens, ctx=None):
    del ctx
    pos = cache["pos"]
    b = tokens.shape[0]
    x = embed(params["embed"], tokens)[:, None, :].astype(cfg.compute_dtype)
    x = x + jnp.take(sinusoidal(cache["k"].shape[2], cfg.d_model),
                     pos[None], axis=0).astype(x.dtype)[None]

    def step(x, inp):
        p_l, k_c, v_c, ck, cv = inp
        h = apply_norm(cfg, p_l["ln1"], x)
        q = apply_dense(p_l["self_attn"]["wq"], h).reshape(
            b, 1, cfg.n_heads, cfg.d_head)
        k = apply_dense(p_l["self_attn"]["wk"], h).reshape(
            b, 1, cfg.n_kv_heads, cfg.d_head)
        v = apply_dense(p_l["self_attn"]["wv"], h).reshape(
            b, 1, cfg.n_kv_heads, cfg.d_head)
        k_c = jax.lax.dynamic_update_slice(k_c, k, (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v, (0, pos, 0, 0))
        o = decode_attention(q[:, 0], k_c, v_c, pos)
        x = x + apply_dense(p_l["self_attn"]["wo"],
                            o.reshape(b, cfg.attn_dim))[:, None]
        h = apply_norm(cfg, p_l["ln_x"], x)
        cq = apply_dense(p_l["cross_attn"]["wq"], h).reshape(
            b, cfg.n_heads, cfg.d_head)
        co = decode_attention(cq, ck, cv, ck.shape[1] - 1)
        x = x + apply_dense(p_l["cross_attn"]["wo"],
                            co.reshape(b, cfg.attn_dim))[:, None]
        x = x + apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))
        return x, (k_c, v_c)

    x, (ks, vs) = layer_scan(
        cfg, step, x, (params["dec_layers"], cache["k"], cache["v"],
                       cache["ck"], cache["cv"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x[:, 0])
    return logits, {"k": ks, "v": vs, "ck": cache["ck"], "cv": cache["cv"],
                    "pos": pos + 1}
