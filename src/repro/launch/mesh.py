"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_reduction_mesh(axis_size: int | None = None, *,
                        axis: str = "shards"):
    """1-D mesh for the distributed reduction collectives (DESIGN.md §12:
    ``repro.sparse.dist_spmm`` / ``dist_attention_shard_map`` and the
    distributed tuner).  Unlike the production builders this avoids
    ``jax.sharding.AxisType`` (absent in older jax), so it works on the
    pinned toolchain and under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` in CI."""
    n = len(jax.devices())
    if axis_size is None:
        axis_size = n
    if n % axis_size:
        raise ValueError(
            f"axis_size={axis_size} does not divide device count {n}")
    return jax.make_mesh((axis_size,), (axis,))
