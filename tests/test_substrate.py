"""Substrate tests: optimizer, checkpoint manager, fault tolerance, data
pipeline, gradient compression.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import ShardedTokenStream
from repro.distributed.collectives import compress_tree, decompress_tree
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               make_elastic_plan, plan_remesh)
from repro.train.optimizer import AdamW, cosine_schedule, global_norm


# ------------------------------------------------------------- optimizer


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clipping():
    opt = AdamW(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.asarray([3.0, 4.0, 0.0])}, state,
                             params)
    assert abs(float(gnorm) - 5.0) < 1e-5


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) < 2e-4
    assert float(lr(jnp.asarray(5))) == pytest.approx(5e-4)


def test_global_norm():
    assert float(global_norm({"a": jnp.asarray([3.0]),
                              "b": jnp.asarray([4.0])})) == pytest.approx(5.0)


# ------------------------------------------------------------ compression


@pytest.mark.parametrize("method", ["bf16", "int8"])
def test_grad_compression_roundtrip(method):
    tree = {"a": jnp.asarray(np.random.default_rng(0).standard_normal(64),
                             jnp.float32) * 0.01,
            "b": {"c": jnp.ones((4, 4)) * 2.5}}
    out = decompress_tree(compress_tree(tree, method))
    tol = 1e-2 if method == "bf16" else 5e-2
    for k in ("a",):
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(tree[k]),
                                   rtol=tol, atol=tol * 0.01)


# ------------------------------------------------------------- checkpoint


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = _tree()
    mgr.save(100, tree)
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 100
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert int(restored["step"]) == 7


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_integrity_detection(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(5, _tree())
    # corrupt a payload file
    victim = next((tmp_path / "step_00000005").glob("arr_*.npy"))
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(_tree())


def test_checkpoint_restore_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    t = _tree()
    mgr.save(1, t)
    mgr.save(9, jax.tree.map(lambda x: x + 1, t))
    restored, step = mgr.restore(t)
    assert step == 9
    assert int(restored["step"]) == 8


# -------------------------------------------------------- fault tolerance


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_dead_host():
    clk = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10, clock=clk)
    mon.beat("h0", 1.0)
    mon.beat("h1", 1.0)
    clk.t = 5.0
    assert mon.dead_hosts() == []
    clk.t = 11.0
    mon.beat("h0", 1.0)
    assert mon.dead_hosts() == ["h1"]


def test_straggler_detection():
    clk = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1", "h2"], straggler_factor=1.5,
                           patience=3, clock=clk)
    for step in range(5):
        clk.t += 1
        mon.beat("h0", 1.0)
        mon.beat("h1", 1.0)
        mon.beat("h2", 3.0)  # consistently 3x slower
        mon.poll()
    assert mon.stragglers() == ["h2"]


def test_plan_remesh_and_elastic():
    assert plan_remesh(128, 4, 16) == (32, 16)
    assert plan_remesh(100, 4, 16) == (16, 16)  # power-of-two dp
    clk = FakeClock()
    mon = HeartbeatMonitor(["h0", "h1"], timeout_s=10, clock=clk)
    mon.beat("h0", 1.0)
    clk.t = 20.0
    mon.beat("h0", 1.0)
    plan = make_elastic_plan(mon, [100, 200], global_batch=256,
                             chips_per_host=4, model_parallel=2)
    assert plan is not None
    assert plan.restore_step == 200
    assert plan.mesh_shape == (2, 2)
    assert "h1" in plan.note


def test_no_plan_when_healthy():
    mon = HeartbeatMonitor(["h0"], clock=time.monotonic)
    mon.beat("h0", 1.0)
    assert make_elastic_plan(mon, [1], global_batch=8) is None


# --------------------------------------------------------------- data


def test_sharded_stream_disjoint_and_deterministic():
    a = ShardedTokenStream(100, 16, 8, host_index=0, host_count=2, seed=3)
    b = ShardedTokenStream(100, 16, 8, host_index=1, host_count=2, seed=3)
    ba, bb = next(a), next(b)
    assert ba["tokens"].shape == (4, 16)
    assert not np.array_equal(ba["tokens"], bb["tokens"])
    a2 = ShardedTokenStream(100, 16, 8, host_index=0, host_count=2, seed=3)
    np.testing.assert_array_equal(next(a2)["tokens"], ba["tokens"])


def test_stream_checkpoint_restore():
    a = ShardedTokenStream(50, 8, 4, seed=1)
    next(a)
    st = a.state()
    x = next(a)
    b = ShardedTokenStream(50, 8, 4, seed=1)
    b.restore(st)
    np.testing.assert_array_equal(next(b)["tokens"], x["tokens"])
