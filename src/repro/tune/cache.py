"""Persistent tuning cache keyed by a matrix fingerprint.

A *fingerprint* summarizes the statistics the schedule space actually
responds to — shape, nnz, row-length histogram quantiles and row-length
CV — so two matrices with the same sparsity *profile* share a tuning
record even if their patterns differ.  The cache key is
``fingerprint × n_dense_cols × backend``: dense-column count changes the
workload/balance trade-off (DA-SpMM's N axis) and timings never transfer
across backends.

Records serialize to a single JSON file (``REPRO_TUNE_CACHE`` or
``~/.cache/repro/schedule_cache.json``) with a schema version; a version
mismatch drops the file (stale-schema records silently re-tune rather
than crash).  ``ScheduleCache(path=None)`` is memory-only — used by
benchmarks and tests that must not touch the user's cache.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import tempfile
from typing import Dict, Optional

import numpy as np

from ..core import Schedule

__all__ = [
    "SCHEMA_VERSION",
    "TuneRecord",
    "ScheduleCache",
    "cache_key",
    "default_cache",
    "default_cache_path",
    "fingerprint",
    "fingerprint_from_lengths",
    "set_default_cache",
]

SCHEMA_VERSION = 1

_QUANTILES = (0.0, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0)


def fingerprint_from_lengths(lengths, shape, nnz: int) -> str:
    """Fingerprint from a row-length (or segment-length) histogram.

    Quantiles are rounded to ints and CV to 3 decimals: small pattern
    perturbations that cannot move the schedule choice hash identically,
    while skew/scale changes that do move it produce a fresh key.
    """
    lengths = np.asarray(lengths, np.float64)
    lengths = lengths[lengths > 0]
    if lengths.size:
        qs = [int(round(q)) for q in np.quantile(lengths, _QUANTILES)]
        mean = float(lengths.mean())
        cv = float(lengths.std() / mean) if mean > 0 else 0.0
    else:
        qs = [0] * len(_QUANTILES)
        cv = 0.0
    qstr = "-".join(str(q) for q in qs)
    return (f"m{shape[0]}x{shape[1]}_nnz{int(nnz)}"
            f"_cv{cv:.3f}_q{qstr}")


def fingerprint(csr) -> str:
    """Fingerprint of a :class:`~repro.sparse.formats.CSR` matrix.

    Memoized through the CSR's per-instance conversion cache (where it
    has one): the O(n_rows) histogram pass runs once per matrix, so
    serving-path lookups (``ServeEngine.spmm`` -> ``cached_or_auto``)
    cost a dict probe, not a device sync."""
    def build():
        return fingerprint_from_lengths(
            np.asarray(csr.row_lengths()), csr.shape, csr.nnz)

    cached = getattr(csr, "_cached", None)
    return cached("fingerprint", build) if cached is not None else build()


def cache_key(csr, n_dense_cols: int, backend: str | None = None) -> str:
    if backend is None:
        import jax

        backend = jax.default_backend()
    return f"{fingerprint(csr)}|N{int(n_dense_cols)}|{backend}"


@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """One cached tuning outcome."""

    schedule: Schedule
    us_per_call: float
    measured: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "schedule": dataclasses.asdict(self.schedule),
            "us_per_call": self.us_per_call,
            "measured": self.measured,
        }

    @staticmethod
    def from_json(d: dict) -> "TuneRecord":
        return TuneRecord(schedule=Schedule(**d["schedule"]),
                          us_per_call=float(d["us_per_call"]),
                          measured=dict(d.get("measured", {})))


def default_cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return pathlib.Path(env)
    return (pathlib.Path(os.environ.get("XDG_CACHE_HOME",
                                        pathlib.Path.home() / ".cache"))
            / "repro" / "schedule_cache.json")


class ScheduleCache:
    """On-disk (or memory-only when ``path=None``) map of cache key ->
    :class:`TuneRecord`.  Load is lazy; ``save`` writes atomically."""

    def __init__(self, path: "os.PathLike | str | None" = ...):
        if path is ...:
            path = default_cache_path()
        self.path = pathlib.Path(path) if path is not None else None
        self._data: Dict[str, TuneRecord] = {}
        self._loaded = self.path is None

    # -- persistence -------------------------------------------------------

    def load(self) -> "ScheduleCache":
        if self._loaded:
            return self
        self._loaded = True
        if self.path is None or not self.path.exists():
            return self
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return self
        if raw.get("version") != SCHEMA_VERSION:
            return self  # stale schema: drop, re-tune lazily
        for key, rec in raw.get("records", {}).items():
            try:
                self._data[key] = TuneRecord.from_json(rec)
            except (KeyError, TypeError, ValueError):
                continue  # one bad record must not poison the rest
        return self

    def save(self) -> None:
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        # merge-on-save: another process sharing this file may have
        # persisted records since we loaded — fold the on-disk state in
        # (our own keys win) so concurrent tuners don't drop each
        # other's work
        on_disk = ScheduleCache(self.path).load()
        merged = dict(on_disk._data)
        merged.update(self._data)
        self._data = merged
        payload = {"version": SCHEMA_VERSION,
                   "records": {k: r.to_json()
                               for k, r in sorted(self._data.items())}}
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    # -- mapping -----------------------------------------------------------

    def get(self, key: str) -> Optional[TuneRecord]:
        self.load()
        return self._data.get(key)

    def put(self, key: str, record: TuneRecord) -> None:
        self.load()
        self._data[key] = record

    def __len__(self) -> int:
        self.load()
        return len(self._data)

    def __contains__(self, key: str) -> bool:
        return self.get(key) is not None

    def keys(self):
        self.load()
        return self._data.keys()


_DEFAULT_CACHES: Dict[str, ScheduleCache] = {}
_OVERRIDE: Optional[ScheduleCache] = None


def default_cache() -> ScheduleCache:
    """Process-wide cache at :func:`default_cache_path` (re-resolved each
    call so ``REPRO_TUNE_CACHE`` changes — e.g. in tests — take effect)."""
    if _OVERRIDE is not None:
        return _OVERRIDE
    path = str(default_cache_path())
    cache = _DEFAULT_CACHES.get(path)
    if cache is None:
        cache = _DEFAULT_CACHES[path] = ScheduleCache(path)
    return cache


def set_default_cache(cache: Optional[ScheduleCache]) -> None:
    """Override the default cache (``None`` restores path-based lookup)."""
    global _OVERRIDE
    _OVERRIDE = cache
