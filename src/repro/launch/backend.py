"""Real-backend setup helpers (platform, XLA flags, precision defaults).

Everything in this repo runs interpreted Pallas on CPU by default; this
module is the one place that knows how to point the same code at a real
backend.  All helpers only take effect when called *before* jax
initializes its backends (first device query / first trace), which is
why none of them are called at import time anywhere in the library —
launch scripts call :func:`setup` as their first statement.

``backend_info`` is safe to call any time and is what benches/CI record
next to their numbers, so a result file says which backend (and whether
fp8 storage was real or degraded) produced it.
"""
from __future__ import annotations

import os
import warnings

__all__ = [
    "backend_info",
    "enable_x64",
    "pallas_interpret_default",
    "set_host_device_count",
    "set_platform",
    "setup",
]

# XLA GPU flags that help bandwidth-bound sparse workloads (latency
# hiding + async collectives); harmless elsewhere, only applied for gpu.
_GPU_XLA_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true"
)


def _append_xla_flags(flags: str) -> None:
    cur = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in flags.split() if f not in cur]
    if missing:
        os.environ["XLA_FLAGS"] = " ".join(([cur] if cur else []) + missing)


def set_platform(platform: str = "cpu") -> None:
    """Pin jax to ``'cpu'``/``'gpu'``/``'tpu'``; call before any jax use.

    GPU additionally gets the bandwidth-oriented XLA flags (appended to
    any existing ``XLA_FLAGS``, never clobbering e.g. a forced host
    device count)."""
    if platform not in ("cpu", "gpu", "tpu"):
        raise ValueError(f"unknown platform {platform!r}")
    import jax

    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        _append_xla_flags(_GPU_XLA_FLAGS)


def set_host_device_count(n: int) -> None:
    """Force ``n`` host (CPU) devices via XLA_FLAGS — the multi-device CI
    lane's mechanism (``launch/dryrun.py`` idiom).  Must run before the
    first jax import in the process to take effect; appending here keeps
    other flags intact."""
    n = int(n)
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    cur = os.environ.get("XLA_FLAGS", "")
    kept = [f for f in cur.split()
            if not f.startswith("--xla_force_host_platform_device_count")]
    kept.append(f"--xla_force_host_platform_device_count={n}")
    os.environ["XLA_FLAGS"] = " ".join(kept)


def enable_x64(on: bool = True) -> None:
    """Toggle 64-bit jax defaults (off everywhere in this repo: the
    kernels' accumulation contract is f32; x64 is for oracle checks)."""
    import jax

    jax.config.update("jax_enable_x64", bool(on))


def pallas_interpret_default() -> bool:
    """Whether Pallas kernels should run interpreted on this backend:
    True off-TPU (interpret mode is the only Pallas path on CPU), False
    on real TPU hardware."""
    import jax

    return jax.default_backend() != "tpu"


def setup(platform: str | None = None, *, host_devices: int | None = None,
          x64: bool = False) -> dict:
    """One-call launch-script prologue: optionally pin the platform and
    host device count, set precision defaults, and return
    :func:`backend_info` for logging.  Warns (instead of failing) when
    jax already initialized — the flags would silently not apply."""
    import jax

    if jax._src.xla_bridge._backends and (platform or host_devices):
        warnings.warn(
            "launch.backend.setup() called after jax backend "
            "initialization; platform/device-count settings may not "
            "apply", RuntimeWarning, stacklevel=2)
    if host_devices is not None:
        set_host_device_count(host_devices)
    if platform is not None:
        set_platform(platform)
    enable_x64(x64)
    return backend_info()


def backend_info() -> dict:
    """Snapshot of the realized backend: platform, device kind/count,
    whether fp8 storage is native (vs the bf16 degradation,
    ``core.dtypes.fp8_supported``), and the Pallas interpret default."""
    import jax

    from ..core.dtypes import fp8_supported

    devs = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "none",
        "device_count": len(devs),
        "fp8": fp8_supported(),
        "interpret": pallas_interpret_default(),
    }
