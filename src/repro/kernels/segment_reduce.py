"""Standalone segment-group reduce kernel:
out[s] = ⨁_{t: seg[t]=s} data[t] for a registered strategy × monoid ⨁.

The paper's ``segReduceWarp<T, G>`` macro instruction (Sgap §5.3) as a
first-class Pallas kernel: the same group machinery as ``spmm_eb`` minus
the gather/multiply front-end. Used directly by the SSD chunk combine,
the fused-attention row statistics, and as the microbenchmark target for
Table 1/2.

``op`` selects the reduction monoid ('add' default, 'max', 'min') — the
monoid generalization of the zero-extension rule pads ragged inputs with
the monoid *identity* instead of zero: padded lanes target segment
``num_segments - 1`` carrying identity rows, so they flow through the
datapath and contribute nothing, for any monoid.  Untouched segments
come out as the identity (matching ``jax.ops.segment_max`` etc.).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schedule import get_strategy
from ..sparse.formats import round_up
from .common import group_reduce_scatter


def _segred_kernel(seg_ref, data_ref, out_ref, *, group_size, strategy,
                   op):
    # identity resolved through the registry: a strategy registered with
    # its own combine/identity initializes with *its* identity
    identity = get_strategy(strategy, op=op).monoid.identity

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, identity)

    group_reduce_scatter(
        seg_ref[...], data_ref[...].astype(jnp.float32), out_ref,
        group_size, strategy, op=op)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "tile", "group_size", "strategy",
                     "op", "interpret"),
)
def segment_reduce(seg_ids, data, *, num_segments: int, tile: int = 256,
                   group_size: int = 32, strategy: str = "segment",
                   op: str = "add", interpret: bool = True):
    """seg_ids: (T,) non-decreasing; data: (T, C).  T may be ragged — both
    inputs are identity-extended to the next ``tile`` multiple (padding
    lanes target segment ``num_segments - 1`` with identity data).
    ``strategy`` is the name of any registered reduction strategy; ``op``
    names the reduction monoid ('add' / 'max' / 'min')."""
    if tile % group_size:
        raise ValueError(f"tile={tile} not a multiple of "
                         f"group_size={group_size}")
    monoid = get_strategy(strategy, op=op).monoid
    t, c = data.shape
    t_pad = round_up(max(t, 1), tile)
    if t_pad != t:
        pad = t_pad - t
        seg_ids = jnp.concatenate(
            [seg_ids, jnp.full((pad,), num_segments - 1, seg_ids.dtype)])
        data = jnp.concatenate(
            [data, jnp.full((pad, c), monoid.identity, data.dtype)])
    grid = (1, t_pad // tile)
    kernel = functools.partial(
        _segred_kernel, group_size=group_size, strategy=strategy, op=op)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda j, i: (i,)),
            pl.BlockSpec((tile, c), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, c), lambda j, i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, c), jnp.float32),
        interpret=interpret,
    )(seg_ids, data)
