"""Tests for MoE grouped-matmul dispatch tuning (ISSUE 3).

Covers the acceptance surface: the tuned ``(token_tile,
capacity_factor, f_tile, d_tile)`` is never slower than the static
default under the session's own measurements; a second call with the
same expert histogram replays the per-backend namespace cache with
*zero* measurements; capacity-factor candidates never drop more routed
tokens than the default; the fingerprint is order-invariant but
histogram-shape-sensitive (property test); legacy single-file caches
migrate transparently; and the dispatch plugs into ``apply_moe``
without changing the math when the capacity factor is unchanged.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models.moe import (
    apply_moe,
    balanced_expert_lengths,
    expert_lengths_from_gates,
    init_moe,
    moe_dispatch_schedule,
    moe_tune_dispatch,
)
from repro.tune import (
    SCHEMA_VERSION,
    MoeDispatchSchedule,
    ScheduleCache,
    TuneRecord,
    cache_namespace,
    default_cache_path,
    fingerprint_from_lengths,
    moe_cache_key,
    moe_cached_or_default,
    moe_capacity,
    moe_schedule_key,
    tune_moe_dispatch,
)
from repro.tune.moe import candidate_moe_schedules, dropped_tokens

RTOL = ATOL = 2e-4

SKEWED = np.array([300, 200, 100, 50, 25, 12, 6, 3])
BALANCED = np.full(8, 128)


def _cfg(**kw):
    over = dict(d_model=64, moe_d_ff=64, n_experts=4, experts_per_token=2)
    over.update(kw)
    return smoke_config(ARCHS["qwen3-moe-235b-a22b"]).scaled(**over)


def _fake_measure(costs=None):
    """Deterministic, instant objective keyed on the schedule string."""
    calls = []

    def measure(s: MoeDispatchSchedule) -> float:
        calls.append(s)
        if costs is not None:
            return costs(s)
        h = sum(ord(c) for c in moe_schedule_key(s))
        return 1e-3 * (1.0 + (h % 89) / 89.0)

    return measure, calls


# ---------------------------------------------------------------------------
# Search behavior
# ---------------------------------------------------------------------------


def test_tuned_never_loses_to_default_in_session():
    for lengths in (SKEWED, BALANCED):
        measure, _ = _fake_measure()
        default = MoeDispatchSchedule(capacity_factor=1.25)
        res = tune_moe_dispatch(lengths, 128, 256, default=default,
                                cache=ScheduleCache(None), measure=measure)
        assert isinstance(res.schedule, MoeDispatchSchedule)
        default_key = moe_schedule_key(default)
        assert default_key in res.measured  # default always in the pool
        assert res.us_per_call <= res.measured[default_key] + 1e-12


def test_cache_hit_replays_with_zero_measurements(tmp_path):
    path = tmp_path / "cache.json"
    measure, calls = _fake_measure()
    res = tune_moe_dispatch(SKEWED, 128, 256, cache=ScheduleCache(path),
                            measure=measure)
    assert not res.from_cache and len(calls) > 0

    measure2, calls2 = _fake_measure()
    res2 = tune_moe_dispatch(SKEWED, 128, 256, cache=ScheduleCache(path),
                             measure=measure2)
    assert res2.from_cache
    assert calls2 == []
    assert res2.n_measurements == 0
    assert res2.schedule == res.schedule
    # record round-trips through JSON as a MoeDispatchSchedule
    raw = json.loads(path.read_text())
    rec = next(iter(raw["records"].values()))
    assert rec["kind"] == "moe"


def test_capacity_candidates_never_drop_more_than_default():
    default = MoeDispatchSchedule(capacity_factor=1.25)
    budget = dropped_tokens(SKEWED, moe_capacity(SKEWED, 1.25))
    for s in candidate_moe_schedules(SKEWED, default=default):
        assert dropped_tokens(
            SKEWED, moe_capacity(SKEWED, s.capacity_factor)) <= budget


def test_assumed_histogram_never_shrinks_capacity(tmp_path):
    """Tuning from the *assumed* balanced histogram (no observed
    routing) must not offer sub-default capacity factors: safe on the
    assumption, token-dropping on a skewed live batch."""
    default = MoeDispatchSchedule(capacity_factor=1.25)
    for s in candidate_moe_schedules(BALANCED, default=default,
                                     allow_capacity_shrink=False):
        assert s.capacity_factor >= default.capacity_factor
    # the model-level entry point applies the constraint automatically
    cfg = _cfg()
    measure, _ = _fake_measure()
    res = moe_tune_dispatch(cfg, 256, cache=ScheduleCache(None),
                            measure=measure)
    assert res.schedule.capacity_factor >= cfg.capacity_factor
    # ...but an observed histogram may still shrink when it drops nothing
    factors = {s.capacity_factor
               for s in candidate_moe_schedules(BALANCED, default=default)}
    assert min(factors) < default.capacity_factor


def test_shrink_flag_keys_separate_records(tmp_path):
    """Observed-histogram (shrink allowed) and assumed-histogram
    (no-shrink) tuning key separate cache records — neither regime ever
    replays the other's winner."""
    cache = ScheduleCache(tmp_path / "c.json")
    measure, _ = _fake_measure()
    res_obs = tune_moe_dispatch(BALANCED, 128, 256, cache=cache,
                                measure=measure)
    measure2, calls2 = _fake_measure()
    res_ass = tune_moe_dispatch(BALANCED, 128, 256, cache=cache,
                                measure=measure2,
                                allow_capacity_shrink=False)
    assert calls2  # the observed-regime record was NOT replayed
    assert res_ass.key != res_obs.key
    assert res_ass.schedule.capacity_factor >= 1.25
    # the resolver selects by the same flag
    assert moe_cached_or_default(
        BALANCED, 128, 256, cache=cache,
        allow_capacity_shrink=False) == res_ass.schedule
    assert moe_cached_or_default(BALANCED, 128, 256,
                                 cache=cache) == res_obs.schedule


def test_capacity_clamps_at_deployed_token_count():
    """moe_capacity with max_tokens mirrors models.moe._capacity's upper
    clamp (t_local), which matters when epk × factor > n_experts."""
    lengths = np.full(2, 256)  # n_experts=2, epk=2, t_local=256
    assert moe_capacity(lengths, 1.25, max_tokens=256) == 256
    assert moe_capacity(lengths, 1.25) == 320  # loose bound without it


def test_moe_cached_or_default_never_measures(tmp_path):
    cache = ScheduleCache(tmp_path / "c.json")
    default = MoeDispatchSchedule(capacity_factor=1.5)
    # miss -> the static default, no measurement possible by construction
    assert moe_cached_or_default(SKEWED, 128, 256, default=default,
                                 cache=cache) == default
    measure, calls = _fake_measure()
    tuned = tune_moe_dispatch(SKEWED, 128, 256, cache=cache,
                              measure=measure).schedule
    assert calls
    assert moe_cached_or_default(SKEWED, 128, 256, cache=cache) == tuned


def test_schedule_validation():
    with pytest.raises(ValueError):
        MoeDispatchSchedule(token_tile=4)
    with pytest.raises(ValueError):
        MoeDispatchSchedule(capacity_factor=0.0)


# ---------------------------------------------------------------------------
# Fingerprint properties
# ---------------------------------------------------------------------------


def test_fingerprint_order_invariant_shape_sensitive_basics():
    a = np.array([100, 10, 1, 50])
    fp = moe_cache_key(a, 128, 256)
    assert moe_cache_key(np.array([1, 50, 100, 10]), 128, 256) == fp
    # a different histogram shape, dim, or dtype produces a fresh key
    assert moe_cache_key(np.array([40, 40, 41, 40]), 128, 256) != fp
    assert moe_cache_key(a, 64, 256) != fp
    assert moe_cache_key(a, 128, 512) != fp
    assert moe_cache_key(a, 128, 256, "bfloat16") != fp
    # different deployed token budgets (capacity clamps) key separately
    assert (moe_cache_key(a, 128, 256, max_tokens=512)
            != moe_cache_key(a, 128, 256, max_tokens=256))
    assert moe_cache_key(a, 128, 256, max_tokens=512) != fp


def test_fingerprint_from_lengths_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.integers(min_value=1, max_value=10_000),
                    min_size=2, max_size=64),
           st.randoms(use_true_random=False))
    def prop(lengths, rng):
        lengths = np.asarray(lengths)
        shuffled = lengths.copy()
        rng.shuffle(shuffled)
        shape = (len(lengths), 128)
        nnz = int(lengths.sum())
        # order-invariant: any permutation fingerprints identically
        assert (fingerprint_from_lengths(shuffled, shape, nnz)
                == fingerprint_from_lengths(lengths, shape, nnz))
        # shape-sensitive: doubling every segment moves the quantiles
        assert (fingerprint_from_lengths(lengths * 2, shape, nnz * 2)
                != fingerprint_from_lengths(lengths, shape, nnz))

    prop()


# ---------------------------------------------------------------------------
# Namespacing + migration
# ---------------------------------------------------------------------------


def test_per_backend_namespace_files(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    ns = cache_namespace()
    assert default_cache_path() == tmp_path / f"tune.{ns}.json"
    assert default_cache_path("tpu-v5e") == tmp_path / "tune.tpu-v5e.json"
    # default-cache tuning lands in the namespace file, and a second
    # call replays it measurement-free (the acceptance criterion)
    measure, calls = _fake_measure()
    res = tune_moe_dispatch(SKEWED, 128, 256, measure=measure)
    assert calls and not res.from_cache
    assert (tmp_path / f"tune.{ns}.json").exists()
    assert not (tmp_path / "tune.json").exists()  # legacy file untouched
    measure2, calls2 = _fake_measure()
    res2 = tune_moe_dispatch(SKEWED, 128, 256, measure=measure2)
    assert res2.from_cache and calls2 == []


def test_explicit_path_cache_folds_its_own_legacy_keys(tmp_path):
    """A PR-2-era cache file passed *explicitly* (no namespace) must
    keep its old ``|<backend>``-suffixed records reachable through the
    new stripped keys — the in-file migration path."""
    import jax

    from repro.core import Schedule

    backend = jax.default_backend()
    old = TuneRecord(schedule=Schedule("eb", nnz_tile=512, group_size=8),
                     us_per_call=7.0)
    path = tmp_path / "explicit.json"
    path.write_text(json.dumps({
        "version": SCHEMA_VERSION,
        "records": {f"mAxB_nnz9_cv0.000_q1|N4|{backend}": old.to_json(),
                    "mAxB_nnz9_cv0.000_q1|N4|other": old.to_json()},
    }))
    cache = ScheduleCache(path)
    rec = cache.get("mAxB_nnz9_cv0.000_q1|N4")
    assert rec is not None and rec.schedule == old.schedule
    # the foreign-backend record is not adopted under a stripped key
    assert cache.get("mAxB_nnz9_cv0.000_q1|N4|other") is not None


def test_legacy_single_file_cache_migrates(tmp_path, monkeypatch):
    """Records tuned before namespacing (backend as the last key
    component of one shared file) are found through the namespace cache
    without re-tuning; foreign-backend records are not imported."""
    from repro.core import Schedule
    from repro.tune import default_cache

    legacy = tmp_path / "tune.json"
    backend = cache_namespace().split("-", 1)[0]
    mine = TuneRecord(schedule=Schedule("eb", nnz_tile=512, group_size=8),
                      us_per_call=12.0)
    theirs = TuneRecord(schedule=Schedule("rb"), us_per_call=3.0)
    legacy.write_text(json.dumps({
        "version": SCHEMA_VERSION,
        "records": {f"mAxB_nnz9_cv0.000_q1|N4|{backend}": mine.to_json(),
                    "mAxB_nnz9_cv0.000_q1|N4|other": theirs.to_json()},
    }))
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(legacy))
    cache = default_cache()
    rec = cache.get("mAxB_nnz9_cv0.000_q1|N4")
    assert rec is not None
    assert rec.schedule == mine.schedule
    assert len(cache) == 1  # the foreign-backend record stayed out


# ---------------------------------------------------------------------------
# End-to-end through the model and the engine
# ---------------------------------------------------------------------------


def test_moe_tune_dispatch_end_to_end(tmp_path):
    cfg = _cfg()
    cache = ScheduleCache(tmp_path / "c.json")
    measure, calls = _fake_measure()
    res = moe_tune_dispatch(cfg, 256, cache=cache, measure=measure)
    assert calls
    assert res.schedule.capacity_factor > 0
    # the resolver replays the same schedule with zero measurements
    assert moe_dispatch_schedule(cfg, 256, cache=cache) == res.schedule
    # an *observed* (different) histogram tunes its own record
    gates = np.zeros((256, cfg.n_experts))
    gates[:, 0] = 1.0  # everything routed to expert 0: maximal skew
    lengths = np.asarray(expert_lengths_from_gates(gates))
    assert (moe_cache_key(lengths, cfg.d_model, cfg.moe_d_ff)
            != moe_cache_key(np.asarray(balanced_expert_lengths(cfg, 256)),
                             cfg.d_model, cfg.moe_d_ff))


@pytest.mark.parametrize("moe_d_ff,f_tile,d_tile", [
    (64, 32, 32),   # square d==f, symmetric tiles
    (64, 32, 16),   # square d==f, asymmetric tiles (role swap would show)
    (128, 64, 16),  # rectangular
])
def test_apply_moe_dispatch_matches_default_math(moe_d_ff, f_tile, d_tile):
    """A tuned dispatch with the default capacity factor changes tiles
    only — the Pallas path's output must be identical math, including
    when d_model == moe_d_ff and f_tile != d_tile (tile roles must be
    assigned per GEMM, not sniffed from shapes)."""
    cfg = _cfg(moe_d_ff=moe_d_ff).scaled(moe_pallas_dispatch=True)
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    out_ref, aux_ref = apply_moe(cfg, p, x, None)
    disp = MoeDispatchSchedule(token_tile=32,
                               capacity_factor=cfg.capacity_factor,
                               f_tile=f_tile, d_tile=d_tile)
    out_t, aux_t = apply_moe(cfg, p, x, None, dispatch=disp)
    np.testing.assert_allclose(np.asarray(out_t), np.asarray(out_ref),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(float(aux_t), float(aux_ref), rtol=RTOL)


def test_serve_engine_prepare_moe_and_resolver(tmp_path, monkeypatch):
    from repro.serve.engine import ServeEngine

    monkeypatch.setenv("REPRO_BENCH_ITERS", "1")
    monkeypatch.setenv("REPRO_BENCH_WARMUP", "0")

    class _API:  # the MoE tuning path never touches decode
        def init_cache(self, slots, max_len):
            return {}

        def decode_step(self, params, cache, toks):  # pragma: no cover
            raise NotImplementedError

    cfg = _cfg()
    cache = ScheduleCache(tmp_path / "c.json")
    eng = ServeEngine(_API(), params={}, slots=1, tuner_cache=cache)
    # monkey-free ahead-of-time tuning via the injectable measure is not
    # exposed on the engine; use the real (quick) objective instead
    sched = eng.prepare_moe(cfg, 64)
    assert isinstance(sched, MoeDispatchSchedule)
    # request path: memo hit, no measurement machinery involved
    assert eng.moe_dispatch_schedule(cfg, 64) == sched
    # a second engine sharing the cache file resolves measurement-free
    eng2 = ServeEngine(_API(), params={}, slots=1,
                       tuner_cache=ScheduleCache(tmp_path / "c.json"))
    assert eng2.moe_dispatch_schedule(cfg, 64) == sched
