"""The single public sparse API: schedule coercion + kernel dispatch.

``spmm``, ``sddmm``, ``segment_reduce`` and ``sparse_attention`` all
accept ``schedule=`` as a name ('EB+PR', ...), a
:class:`~repro.core.schedule.Schedule`, an
:class:`~repro.core.AtomicParallelism` point, or a
:class:`~repro.core.SegmentGroup`.  ``spmm`` additionally accepts
``'auto'`` (the data-aware selector — the paper's Table-5 "dynamic
choice" made a library default); the other ops have no matrix to derive
statistics from, so ``'auto'`` raises there.

Fusion surface (DESIGN.md §8; *planned* multi-op fusion lives in
``repro.fuse`` — DESIGN.md §10 — which lowers chain nodes onto these
ops' epilogue slots rather than callers picking per-op):

* ``spmm(..., bias=, residual=, epilogue=)`` fuses the dense epilogue of
  a GCN-style layer (``act(A @ XW + b) [+ res]``) into the kernel's last
  reduction grid step — one kernel instead of three HBM passes.  The
  epilogue spec is auto-derived from the arrays you pass (or taken from
  ``schedule.epilogue`` / an explicit ``epilogue=``).
* ``segment_reduce(..., op="max"|"mean")`` runs the monoid-generalized
  group machinery (graph pooling); ``mean`` is the add monoid with a
  fused count column (one kernel pass + a divide).
* ``sparse_attention`` is the one-pass SDDMM → segment softmax → SpMM
  kernel with online renormalization (``kernels.fused_attention``),
  batched over heads in one launch, with CSR stored values as an
  additive score bias.

``spmm`` over CSR and ``sparse_attention`` are differentiable: forwards
run the scheduled Pallas kernels; ``spmm``'s backward closes the paper's
algebra family on itself (SDDMM / transpose-SpMM / segment ops — Sgap
Eq. 2c/2d) through the pure-JAX oracles, while ``sparse_attention``'s
backward is itself a fused Pallas kernel (DESIGN.md §9): one launch
recomputes the probabilities from the saved softmax row stats, scatters
the softmax-backward row dot δ, and scatter-transposes dK/dV.
Feed-format conversions go through the
per-(format, tile) caches on ``CSR``/``GroupedCOO``, so serving loops
re-using the same matrix do not re-convert every call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.schedule import Epilogue, Schedule, as_schedule
from ..kernels import ops as kops
from ..kernels import ref
from ..kernels.fused_attention import (
    fused_sparse_attention as _fused_attn_fwd,
)
from ..kernels.fused_attention import (
    fused_sparse_attention_bwd as _fused_attn_bwd,
)
from ..kernels.fused_attention import sparse_attention_ref
from ..kernels.segment_reduce import segment_reduce as _segment_reduce_kernel
from .formats import CSR, ELL, GroupedCOO, QuantizedCSR, round_up
from .random import matrix_stats

__all__ = ["spmm", "sddmm", "segment_reduce", "sparse_attention"]


def _resolve_schedule(a, b, schedule, epilogue: Epilogue | None = None):
    if isinstance(schedule, str) and schedule in ("auto", "tune"):
        if isinstance(a, QuantizedCSR):
            # already-quantized input: the dtype axis is decided (int8);
            # select tiling from the inner pattern's statistics
            sched = Schedule.auto(matrix_stats(a.csr), int(b.shape[1]))
        elif not isinstance(a, CSR):
            # no CSR to derive statistics (or a fingerprint) from
            sched = Schedule("eb")
        elif schedule == "tune":
            from ..tune import tune_schedule

            return tune_schedule(a, int(b.shape[1]),
                                 epilogue=epilogue).schedule
        else:
            sched = Schedule.auto(matrix_stats(a), int(b.shape[1]))
    else:
        sched = as_schedule(schedule)
    if epilogue is not None:
        sched = sched.replace(epilogue=epilogue)
    return sched


def _derive_epilogue(schedule, epilogue, bias, residual) -> Epilogue | None:
    """Effective epilogue: an explicit ``epilogue=`` wins, else the
    schedule's own; the bias/residual flags are auto-set from the arrays
    actually passed (so ``spmm(..., bias=b)`` just works)."""
    import dataclasses

    ep = epilogue
    if ep is None and isinstance(schedule, Schedule):
        ep = schedule.epilogue
    if ep is None:
        ep = Epilogue()
    if bias is not None and not ep.bias:
        ep = dataclasses.replace(ep, bias=True)
    if residual is not None and not ep.residual:
        ep = dataclasses.replace(ep, residual=True)
    return None if ep.is_noop else ep


def spmm(a, b, schedule="auto", *, bias=None, residual=None,
         epilogue: Epilogue | None = None, impl: str = "pallas",
         interpret: bool = True):
    """out = epilogue(A @ B) for sparse A (CSR / GroupedCOO / ELL) and
    dense B.

    schedule    'auto' | 'tune' | name | Schedule | AtomicParallelism |
                SegmentGroup.  'tune' measures the top schedule
                candidates for this matrix (replaying the persistent
                fingerprint cache when it can — see ``repro.tune``);
                tuning is epilogue-aware (the fused work is measured).
    bias        (N,) fused bias-row add over output columns.
    residual    (n_rows, N) fused post-activation residual add.
    epilogue    explicit :class:`~repro.core.Epilogue` (activation /
                out_dtype); bias/residual flags are auto-derived from
                the arrays above.
    impl        'pallas' (scheduled kernel) or 'ref' (pure-jnp oracle).

    The CSR + pallas path is differentiable in ``a.vals``, ``b``,
    ``bias`` and ``residual``.  Narrow float ``value_dtype`` schedules
    (DESIGN.md §13) stay differentiable in all four — the forward moves
    the cast storage, the backward is the f32 ref path (straight-through
    w.r.t. the cast).  The int8 quantized path (``value_dtype='int8'``
    or a :class:`QuantizedCSR` input) is differentiable in ``b``/
    ``bias``/``residual`` only: quantization is a host-side calibration
    pass over concrete values, so ``a.vals`` is data there, not an
    operand.
    """
    ep = _derive_epilogue(schedule, epilogue, bias, residual)
    sched = _resolve_schedule(a, b, schedule, epilogue=ep)
    if impl != "ref":
        if isinstance(a, QuantizedCSR):
            return _spmm_quant_diff(a, b, sched, interpret, bias, residual)
        if isinstance(a, CSR):
            if sched.value_dtype == "int8":
                return _spmm_quant_diff(a.quantized(), b, sched,
                                        interpret, bias, residual)
            return _spmm_csr_diff(a, b, sched, interpret, bias, residual)
    return kops.spmm(a, b, sched, bias=bias, residual=residual,
                     impl=impl, interpret=interpret)


def _spmm_csr_diff(a: CSR, b, sched: Schedule, interpret: bool,
                   bias=None, residual=None):
    """Custom-VJP wrapper: scheduled (epilogued) kernel forward, ref
    backward.  ``y = act(A@B + bias) + residual`` (then dtype cast), so

        dz        = dy ⊙ act'(A@B + bias)      (VJP of the activation)
        dvals     = SDDMM(dz, B)               (Eq. 2c)
        dB        = Aᵀ · dz                    (Eq. 2d)
        dbias     = Σ_rows dz
        dresidual = dy
    """
    ep = sched.epilogue
    coo = a.tocoo()  # cached on the CSR instance
    rows, cols = coo.rows, coo.cols
    n_rows, n_cols = a.shape

    if sched.kernel == "eb":
        g0 = a.grouped(sched.nnz_tile, group_size=sched.group_size,
                       split_threshold=sched.split_threshold,
                       merge_threshold=sched.merge_threshold)
        if g0.skew is not None:
            # skew layout interleaves padding, so fresh vals are placed
            # by the memoized scatter index rather than a trailing pad
            pos = g0.skew_positions()

            def run(vals, bb, bias_x, res_x):
                vpad = jnp.zeros((g0.nnz_padded,),
                                 vals.dtype).at[pos].set(vals)
                g = GroupedCOO(rows=g0.rows, cols=g0.cols, vals=vpad,
                               shape=g0.shape, nnz=g0.nnz,
                               nnz_tile=g0.nnz_tile, skew=g0.skew)
                return kops.spmm(g, bb, sched, bias=bias_x,
                                 residual=res_x, interpret=interpret)
        else:
            pad = g0.nnz_padded - g0.nnz

            def run(vals, bb, bias_x, res_x):
                vpad = jnp.concatenate(
                    [vals, jnp.zeros((pad,), vals.dtype)]) if pad else vals
                g = GroupedCOO(rows=g0.rows, cols=g0.cols, vals=vpad,
                               shape=g0.shape, nnz=g0.nnz,
                               nnz_tile=g0.nnz_tile)
                return kops.spmm(g, bb, sched, bias=bias_x,
                                 residual=res_x, interpret=interpret)
    else:
        ell0 = a.ell(row_tile=sched.row_tile)
        rid, pos = a.ell_scatter_index()

        def run(vals, bb, bias_x, res_x):
            evals = jnp.zeros(ell0.vals.shape,
                              vals.dtype).at[rid, pos].set(vals)
            e = ELL(cols=ell0.cols, vals=evals, shape=ell0.shape,
                    width=ell0.width)
            return kops.spmm(e, bb, sched, bias=bias_x, residual=res_x,
                             interpret=interpret)

    @jax.custom_vjp
    def _fn(vals, bb, bias_x, res_x):
        return run(vals, bb, bias_x, res_x)

    def _fwd(vals, bb, bias_x, res_x):
        return run(vals, bb, bias_x, res_x), (vals, bb, bias_x, res_x)

    def _bwd(res, dout):
        vals, bb, bias_x, res_x = res
        dout = dout.astype(jnp.float32)
        dres = dout.astype(res_x.dtype) if ep.residual else None
        if ep.activation is not None:
            # recompute the pre-activation z through the oracle, then
            # pull dout back through the activation
            z = ref.spmm_coo_ref(rows, cols, vals, bb, n_rows)
            if ep.bias:
                z = z + jnp.reshape(bias_x, (1, -1)).astype(jnp.float32)
            from ..core.schedule import ACTIVATIONS

            _, act_vjp = jax.vjp(ACTIVATIONS[ep.activation], z)
            dz, = act_vjp(dout)
        else:
            dz = dout
        dbias = jnp.sum(dz, axis=0).astype(
            bias_x.dtype) if ep.bias else None
        # dA values: sampled dense-dense product at the sparsity pattern
        dvals = ref.sddmm_ref(rows, cols, dz, bb).astype(vals.dtype)
        # dB: transpose SpMM (cols become the segment ids)
        db = ref.spmm_coo_ref(cols, rows, vals, dz, n_cols).astype(bb.dtype)
        return dvals, db, dbias, dres

    _fn.defvjp(_fwd, _bwd)
    return _fn(a.vals, b, bias, residual)


def _spmm_quant_diff(qa: QuantizedCSR, b, sched: Schedule, interpret: bool,
                     bias=None, residual=None):
    """Custom-VJP wrapper for the int8 quantized path: the scheduled
    kernel moves int8 codes + per-row scales forward; the backward runs
    the f32 ref path over the *dequantized* value stream.  Differentiable
    in ``b``/``bias``/``residual`` — the codes are host-calibrated data
    (see :func:`spmm`)."""
    ep = sched.epilogue
    n_rows, n_cols = qa.shape
    coo = qa.csr.tocoo()  # cached on the inner CSR
    rows, cols = coo.rows, coo.cols
    vals_f = qa.dequantize().vals  # f32 stream for the ref backward

    def run(bb, bias_x, res_x):
        return kops.spmm(qa, bb, sched, bias=bias_x, residual=res_x,
                         interpret=interpret)

    @jax.custom_vjp
    def _fn(bb, bias_x, res_x):
        return run(bb, bias_x, res_x)

    def _fwd(bb, bias_x, res_x):
        return run(bb, bias_x, res_x), (bb, bias_x, res_x)

    def _bwd(res, dout):
        bb, bias_x, res_x = res
        dout = dout.astype(jnp.float32)
        dres = dout.astype(res_x.dtype) if ep.residual else None
        if ep.activation is not None:
            z = ref.spmm_coo_ref(rows, cols, vals_f, bb, n_rows)
            if ep.bias:
                z = z + jnp.reshape(bias_x, (1, -1)).astype(jnp.float32)
            from ..core.schedule import ACTIVATIONS

            _, act_vjp = jax.vjp(ACTIVATIONS[ep.activation], z)
            dz, = act_vjp(dout)
        else:
            dz = dout
        dbias = jnp.sum(dz, axis=0).astype(
            bias_x.dtype) if ep.bias else None
        db = ref.spmm_coo_ref(cols, rows, vals_f, dz,
                              n_cols).astype(bb.dtype)
        return db, dbias, dres

    _fn.defvjp(_fwd, _bwd)
    return _fn(b, bias, residual)


def sddmm(rows, cols, a, b, scale=None, *, schedule=None,
          nnz_tile: int | None = None, impl: str = "pallas",
          interpret: bool = True):
    """vals[t] = <A[rows[t]], B[cols[t]]> (* scale[t]); rows/cols (nnz,).

    ``schedule`` supplies the nnz tile (its ``nnz_tile`` field); an
    explicit ``nnz_tile=`` overrides it.  ``schedule="tune"`` reuses the
    tuner's winner for this nnz profile (SDDMM only exposes the tile
    axis, so the tuned ``nnz_tile`` is what transfers).
    """
    if schedule is not None and nnz_tile is None:
        if isinstance(schedule, str) and schedule == "tune":
            from ..tune import tune_segment_reduce

            nnz_tile = tune_segment_reduce(
                rows, int(a.shape[1]),
                num_segments=int(jnp.max(rows)) + 1).schedule.nnz_tile
        else:
            nnz_tile = as_schedule(schedule).nnz_tile
    return kops.sddmm(rows, cols, a, b, scale,
                      nnz_tile=nnz_tile if nnz_tile else 256,
                      impl=impl, interpret=interpret)


def segment_reduce(seg_ids, data, num_segments: int, schedule=None, *,
                   op: str = "sum", interpret: bool = True):
    """out[s] = ⨁_{t: seg_ids[t]=s} data[t] through the segment-group
    kernel, for ``op`` in 'sum' / 'max' / 'min' / 'mean'.

    'max'/'min' run the monoid-generalized strategy machinery (graph
    pooling — untouched segments come out as ±inf, matching
    ``jax.ops.segment_max``).  'mean' is realized as the add monoid with
    a count column fused into the same kernel pass (out = sums / counts;
    empty segments -> 0).  ``schedule`` carries (nnz_tile -> tile,
    group_size, strategy); ``schedule="tune"`` measures (tile, G,
    strategy) for this segment profile (cached by fingerprint); ragged
    inputs are identity-extended by the kernel wrapper."""
    if isinstance(schedule, str) and schedule == "tune":
        from ..tune import tune_segment_reduce

        sched = tune_segment_reduce(
            seg_ids, int(data.shape[1]), num_segments).schedule
    else:
        sched = as_schedule(schedule)
    if op == "mean":
        # one kernel pass: ride a ones column along the data, divide
        aug = jnp.concatenate(
            [data.astype(jnp.float32),
             jnp.ones((data.shape[0], 1), jnp.float32)], axis=1)
        out = _segment_reduce_kernel(
            seg_ids, aug, num_segments=num_segments, tile=sched.nnz_tile,
            group_size=sched.group_size, strategy=sched.strategy,
            interpret=interpret)
        return out[:, :-1] / jnp.maximum(out[:, -1:], 1.0)
    return _segment_reduce_kernel(
        seg_ids, data, num_segments=num_segments, tile=sched.nnz_tile,
        group_size=sched.group_size, strategy=sched.strategy,
        op="add" if op == "sum" else op, interpret=interpret)


# ---------------------------------------------------------------------------
# Fused sparse attention
# ---------------------------------------------------------------------------


def _attn_pattern(adj):
    """``(rows, cols, n_rows, bias)`` from an adjacency.

    A CSR adjacency contributes its *stored values* as an additive
    attention-score bias: ``s[t] = <Q[r_t], K[c_t]>·scale + vals[t]``
    (edge features / relative-position biases ride the adjacency).  The
    softmax is invariant to a per-row-constant shift, so the canonical
    all-ones "pattern" CSR attends identically to a pure pattern — but
    non-constant values now *matter* (they used to be silently ignored).
    An explicit ``(rows, cols, n_rows)`` tuple is a pure pattern
    (``bias=None``).
    """
    if isinstance(adj, CSR):
        coo = adj.tocoo()
        return coo.rows, coo.cols, adj.shape[0], coo.vals
    rows, cols, n_rows = adj
    return rows, cols, int(n_rows), None


def _attn_heads(q, k, v):
    """Normalize q/k/v to the kernel's head-major (H, n, ·) layout.
    2-D inputs are a single head; 3-D inputs are (n, H, ·) — heads on
    axis 1, matching ``models.attention``.  Returns (qh, kh, vh, multi).
    """
    if q.ndim == k.ndim == v.ndim == 2:
        return q[None], k[None], v[None], False
    if not (q.ndim == k.ndim == v.ndim == 3
            and q.shape[1] == k.shape[1] == v.shape[1]):
        raise ValueError(
            f"attention wants all-2-D (n, d) q/k/v or all-3-D (n, H, d) "
            f"with one shared head count H; got {q.shape}, {k.shape}, "
            f"{v.shape}")
    return (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
            jnp.moveaxis(v, 1, 0), True)


def sparse_attention(adj, q, k, v, *, schedule=None,
                     scale: float | None = None, impl: str = "pallas",
                     interpret: bool = True):
    """One-pass sparse attention over a sparsity pattern:
    ``out[r] = Σ_t softmax_row(<Q[r], K[c_t]> · scale + bias_t) V[c_t]``.

    adj       a CSR adjacency — its pattern is attended over and its
              stored values are an additive score bias (row-constant
              values, e.g. the all-ones pattern CSR, cancel in the
              softmax; see :func:`_attn_pattern`) — or a
              ``(rows, cols, n_rows)`` pure-pattern tuple with rows
              sorted non-decreasing (CSR order).
    q         (n_rows, d) queries, or (n_rows, H, d) for H heads;
    k         (n_cols, d) / (n_cols, H, d) keys;
    v         (n_cols, dv) / (n_cols, H, dv) values.  All H heads share
              the pattern and run in ONE kernel launch (the head axis is
              folded into the kernel grid).
    schedule  supplies (nnz_tile, group_size, strategy) for the fused
              kernel's grid; ``"tune"`` measures the real fused kernel
              for this pattern (``repro.tune.tune_sparse_attention``,
              cached by pattern fingerprint × head count × direction);
              'parallel' is excluded (its one-writeback contract does
              not hold for attention rows).
    impl      'pallas' (the fused kernel — SDDMM → online segment
              softmax → SpMM in one pass) or 'ref' (the spec oracle).

    Differentiable in q, k, v — the custom VJP runs the fused *backward*
    kernel (one launch over (H, 2, nnz_tiles): δ scatter + dV transpose,
    then dQ/dK from the carried probabilities), so ``impl="pallas"`` is
    fused in both directions.  The adjacency — pattern AND value bias —
    is *data*, not a differentiable operand: gradients w.r.t. the CSR's
    stored values are not defined (pass the bias through q/k features if
    it must be learned).  ``schedule="tune"`` tunes the forward grid;
    the backward reuses that schedule (tuning the bwd direction from the
    training loop is a ROADMAP follow-on —
    ``tune_sparse_attention(direction="bwd")`` exists for it).  Empty
    rows -> zero rows.
    """
    rows, cols, n_rows, bias = _attn_pattern(adj)
    if scale is None:
        scale = float(q.shape[-1]) ** -0.5
    qh, kh, vh, multi = _attn_heads(q, k, v)
    if impl == "ref":
        outs = [sparse_attention_ref(rows, cols, qh[h], kh[h], vh[h],
                                     n_rows=n_rows, scale=scale, bias=bias)
                for h in range(qh.shape[0])]
        out = jnp.stack(outs, axis=0)
        return jnp.moveaxis(out, 0, 1) if multi else out[0]
    if isinstance(schedule, str) and schedule == "tune":
        from ..tune import tune_sparse_attention

        sched = tune_sparse_attention(
            rows, cols, q, k, v, n_rows=n_rows, bias=bias, scale=scale,
            interpret=interpret).schedule
    else:
        sched = as_schedule(schedule)
    if sched.strategy == "parallel":
        raise ValueError(
            "sparse_attention cannot run the 'parallel' strategy: its "
            "single-writeback contract does not hold for attention rows")
    out = _sparse_attention_diff(rows, cols, qh, kh, vh, n_rows, scale,
                                 sched, interpret, bias)
    return jnp.moveaxis(out, 0, 1) if multi else out[0]


def _sparse_attention_diff(rows, cols, qh, kh, vh, n_rows, scale, sched,
                           interpret, bias=None):
    """Custom-VJP core over head-major (H, n, ·) operands: fused Pallas
    forward (saving the (m, l) softmax row stats — the O(H·n_rows)
    FlashAttention residuals), fused Pallas backward."""
    nnz = int(rows.shape[0])
    nnz_tile = sched.nnz_tile
    nnz_pad = max(round_up(max(nnz, 1), nnz_tile), nnz_tile)
    rows_p = jnp.pad(rows, (0, nnz_pad - nnz))
    cols_p = jnp.pad(cols, (0, nnz_pad - nnz))
    bias_p = (None if bias is None
              else jnp.pad(bias.astype(jnp.float32), (0, nnz_pad - nnz)))
    dv = vh.shape[-1]
    dv_tile = min(128, round_up(dv, 8))
    dv_pad = round_up(dv, dv_tile)

    def _run_fwd(q, k, v):
        v_p = (jnp.pad(v, ((0, 0), (0, 0), (0, dv_pad - dv)))
               if dv_pad != dv else v)
        out, m, l = _fused_attn_fwd(
            rows_p, cols_p, q, k, v_p, n_rows=n_rows, nnz=nnz,
            nnz_tile=nnz_tile, dv_tile=dv_tile, scale=scale,
            group_size=sched.group_size, strategy=sched.strategy,
            bias=bias_p, interpret=interpret)
        return out[..., :dv], m, l

    @jax.custom_vjp
    def _fn(q, k, v):
        return _run_fwd(q, k, v)[0]

    def _fwd(q, k, v):
        out, m, l = _run_fwd(q, k, v)
        return out, (q, k, v, m, l)

    def _bwd(res, dout):
        q, k, v, m, l = res
        dq, dk, dv_ = _fused_attn_bwd(
            rows_p, cols_p, q, k, v, dout, m, l, n_rows=n_rows, nnz=nnz,
            nnz_tile=nnz_tile, scale=scale, group_size=sched.group_size,
            strategy=sched.strategy, bias=bias_p, interpret=interpret)
        return (dq.astype(q.dtype), dk.astype(k.dtype),
                dv_.astype(v.dtype))

    _fn.defvjp(_fwd, _bwd)
    return _fn(qh, kh, vh)
