"""Grouped (expert-segment) matmul Pallas kernel — segment group applied to
MoE dispatch (DESIGN.md §4.1).

MoE expert application is sparse-dense hybrid algebra in the paper's DF
formulation: Q₀ = token→expert routing (sparse), ⊗ = expert GEMM,
⊕ = segment-sum over each expert's token segment. Tokens arrive sorted by
expert and *capacity-padded so every token tile belongs to exactly one
expert* — zero extension again: padding tokens multiply real expert
weights and are masked afterwards.

The tile→expert map is scalar-prefetched so the weight BlockSpec can
select the expert block at DMA-schedule time (the TPU analogue of the
runtime writeback-thread election: the *read* side is decided at runtime
here).

Grid: (token_tiles, f_tiles, d_tiles) — contraction axis innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def fit_tile(n: int, tile: int) -> int:
    """Largest power-of-two shrink of ``tile`` that divides ``n`` —
    ``grouped_matmul`` requires exact blocking of the D/F axes, and
    halving preserves the power-of-two grid.  Shared by the dispatch
    path (``models.moe``) and the tuner (``tune.moe``) so both agree on
    what a legal tile is."""
    t = max(1, min(tile, n))
    while n % t and t > 1:
        t //= 2
    return t


def _gmm_kernel(emap_ref, x_ref, w_ref, out_ref):
    del emap_ref  # consumed by the index maps
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)  # (TT, DT)
    w = w_ref[...].astype(jnp.float32)[0]  # (DT, FT)
    out_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("token_tile", "f_tile", "d_tile", "interpret"),
)
def grouped_matmul(x, tile_experts, weights, *, token_tile: int = 128,
                   f_tile: int = 128, d_tile: int = 128,
                   interpret: bool = True):
    """x: (T_pad, D) tokens sorted by expert, T_pad % token_tile == 0;
    tile_experts: (T_pad // token_tile,) int32 expert of each token tile;
    weights: (E, D, F). Returns (T_pad, F) f32."""
    t_pad, d = x.shape
    e, dw, f = weights.shape
    assert dw == d and t_pad % token_tile == 0
    assert d % d_tile == 0 and f % f_tile == 0

    grid = (t_pad // token_tile, f // f_tile, d // d_tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((token_tile, d_tile), lambda i, j, k, emap: (i, k)),
            pl.BlockSpec((1, d_tile, f_tile),
                         lambda i, j, k, emap: (emap[i], k, j)),
        ],
        out_specs=pl.BlockSpec((token_tile, f_tile),
                               lambda i, j, k, emap: (i, j)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad, f), jnp.float32),
        interpret=interpret,
    )(tile_experts, x, weights)
