"""nnz-split (EB) segment-group SpMM Pallas kernel — the paper's
``{<1 nnz, c col>, r}`` algorithm (Sgap §6.2, Listing 6), TPU-native.

Grid: (col_tiles, nnz_tiles) — nnz innermost so consecutive grid steps
revisit the same output block and accumulation is race-free.

Per grid cell (one ``NNZ_TILE × COL_TILE`` block):
  1. gather dense rows      B[cols]            (zero extension: padded
                                                lanes gather row 0, val 0)
  2. scale by values        P = vals ⊙ B[cols]
  3. segment-group reduce   width-G one-hot MXU reduce + runtime
                            writeback (see kernels/common.py)

VMEM working set per cell:  B block (K × COL_TILE) + partials
(NNZ_TILE × COL_TILE) + out block (n_rows × COL_TILE). The kernel targets
the paper's *balance-intensive* regime (few dense columns), where these
comfortably fit VMEM; ``ops.spmm`` asserts the footprint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import group_reduce_scatter


def _spmm_eb_kernel(rows_ref, cols_ref, vals_ref, b_ref, out_ref, *,
                    group_size: int, strategy: str):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[...]
    cols = cols_ref[...]
    vals = vals_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)

    gathered = jnp.take(b, cols, axis=0)  # (T, C)
    partial = gathered * vals[:, None]
    group_reduce_scatter(rows, partial, out_ref, group_size, strategy)


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "nnz_tile", "col_tile", "group_size",
                     "strategy", "interpret"),
)
def spmm_eb(rows, cols, vals, b, *, n_rows: int, nnz_tile: int = 256,
            col_tile: int = 128, group_size: int = 32,
            strategy: str = "segment", interpret: bool = True):
    """out (n_rows, N) = scatter-reduce over padded COO triplets × B.

    Inputs must be pre-padded: len(vals) % nnz_tile == 0 (see
    ``formats.GroupedCOO``) and b.shape[1] % col_tile == 0 (``ops.spmm``
    does the column padding).
    """
    nnz_pad = vals.shape[0]
    k, n = b.shape
    assert nnz_pad % nnz_tile == 0 and n % col_tile == 0, (nnz_pad, n)
    grid = (n // col_tile, nnz_pad // nnz_tile)

    kernel = functools.partial(
        _spmm_eb_kernel, group_size=group_size, strategy=strategy)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nnz_tile,), lambda j, i: (i,)),
            pl.BlockSpec((nnz_tile,), lambda j, i: (i,)),
            pl.BlockSpec((nnz_tile,), lambda j, i: (i,)),
            pl.BlockSpec((k, col_tile), lambda j, i: (0, j)),
        ],
        out_specs=pl.BlockSpec((n_rows, col_tile), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_rows, n), jnp.float32),
        interpret=interpret,
    )(rows, cols, vals, b)
