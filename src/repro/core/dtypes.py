"""Value-storage dtypes as a first-class scheduling axis (DESIGN.md §13).

Sgap's SpMM-class workloads are memory-bandwidth-bound, so the bytes of
the CSR value stream and the gathered dense operand are a schedule knob
exactly like tile shape or reduction strategy: ``Schedule.value_dtype``
names one of :data:`VALUE_DTYPES` and every layer below (kernels,
runners, cost model, roofline) resolves it through this module.

The accumulation contract is unchanged by any choice here: kernels load
narrow and immediately ``upcast_f32`` (``kernels/common.py``), so the
dtype axis only moves *storage/traffic* precision, never reduction
precision.  ``float32`` (or ``None``) is the identity; ``int8`` selects
the quantized value path (per-row scales, ``sparse.formats.quantize_csr``)
with a ``bfloat16`` dense operand.

``float8_e4m3fn`` degrades to ``bfloat16`` with a :class:`Fp8Fallback`
warning when the running jax has no fp8 type (older pins) or when
``REPRO_DISABLE_FP8`` is set — schedules stay valid and replayable
across heterogeneous fleets; only the realized storage width changes.
"""
from __future__ import annotations

import os
import warnings

#: Valid ``Schedule.value_dtype`` names.  ``float32`` normalizes to
#: ``None`` (the default axis value) so schedule keys and cache records
#: from before the dtype axis existed stay byte-identical.
VALUE_DTYPES = ("float32", "bfloat16", "float16", "float8_e4m3fn", "int8")

#: Shorthand spellings accepted by :func:`canonical_value_dtype`.
_ALIASES = {
    "f32": "float32", "fp32": "float32",
    "bf16": "bfloat16",
    "f16": "float16", "fp16": "float16", "half": "float16",
    "fp8": "float8_e4m3fn", "f8": "float8_e4m3fn",
    "e4m3": "float8_e4m3fn", "float8": "float8_e4m3fn",
}


class Fp8Fallback(RuntimeWarning):
    """Warned when fp8 storage degrades to bf16 (missing type / env)."""


def canonical_value_dtype(value_dtype):
    """Normalize a dtype spelling to its canonical ``Schedule`` form.

    Accepts ``None``, a :data:`VALUE_DTYPES` name, a shorthand alias
    (``"bf16"``, ``"fp8"``, ...), or a numpy/jax dtype-like.  Returns
    ``None`` for float32 (the axis default) or the canonical name;
    raises ``ValueError`` for anything that is not a supported storage
    dtype.  Unsupported-on-this-jax fp8 is still *canonically valid* —
    resolution (and the bf16 fallback) happens at :func:`storage_dtype`
    time so tuned schedules remain portable across jax versions.
    """
    if value_dtype is None:
        return None
    name = value_dtype if isinstance(value_dtype, str) else None
    if name is None:
        import numpy as np

        try:
            name = np.dtype(value_dtype).name
        except TypeError as e:
            raise ValueError(f"invalid value_dtype: {value_dtype!r}") from e
    name = _ALIASES.get(name, name)
    if name not in VALUE_DTYPES:
        raise ValueError(
            f"invalid value_dtype {value_dtype!r}; expected one of "
            f"{VALUE_DTYPES} (or None)")
    return None if name == "float32" else name


def fp8_supported() -> bool:
    """True when this process can store ``float8_e4m3fn`` values.

    ``REPRO_DISABLE_FP8`` (any value but ``""``/``"0"``) forces False —
    the CI fallback leg uses it to exercise the degraded path on a jax
    that does have the type.
    """
    if os.environ.get("REPRO_DISABLE_FP8", "") not in ("", "0"):
        return False
    import jax.numpy as jnp

    return hasattr(jnp, "float8_e4m3fn")


def storage_dtype(value_dtype):
    """Resolve a canonical value-dtype name to the jnp storage dtype.

    ``None``/``"float32"`` -> f32; ``"int8"`` -> int8 (the quantized
    value stream); fp8 -> ``jnp.float8_e4m3fn`` when available, else
    ``jnp.bfloat16`` with a :class:`Fp8Fallback` warning (never an
    error: an old jax pin must degrade, not crash).
    """
    import jax.numpy as jnp

    name = canonical_value_dtype(value_dtype)
    if name is None:
        return jnp.float32
    if name == "float8_e4m3fn" and not fp8_supported():
        warnings.warn(
            "float8_e4m3fn storage unavailable on this jax "
            "(missing jnp.float8_e4m3fn or REPRO_DISABLE_FP8 set); "
            "degrading value storage to bfloat16",
            Fp8Fallback, stacklevel=2)
        return jnp.bfloat16
    return getattr(jnp, name)


def operand_dtype(value_dtype):
    """Storage dtype for the *dense* operand under this value dtype.

    Narrow float values narrow the gathered operand to the same type
    (the gather stream dominates SpMM traffic).  ``int8`` values pair
    with a ``bfloat16`` operand — activation quantization is out of
    scope, but the operand still halves.  fp8 follows the same
    degradation rule as :func:`storage_dtype`.
    """
    import jax.numpy as jnp

    name = canonical_value_dtype(value_dtype)
    if name is None:
        return jnp.float32
    if name == "int8":
        return jnp.bfloat16
    return storage_dtype(name)


def value_itemsize(value_dtype) -> int:
    """Bytes per stored value under this axis choice, post-fallback.

    Used by the cost model (``core.selector.cost_terms``) and the
    roofline byte accounting; reflects the *realized* storage (a
    degraded fp8 schedule costs 2 bytes, not 1).
    """
    import numpy as np

    return int(np.dtype(storage_dtype(value_dtype)).itemsize)


def operand_itemsize(value_dtype) -> int:
    """Bytes per dense-operand element under this axis choice."""
    import numpy as np

    return int(np.dtype(operand_dtype(value_dtype)).itemsize)
