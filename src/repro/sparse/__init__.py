from .formats import COO, CSR, ELL, GroupedCOO  # noqa: F401
from .random import matrix_stats, random_coo, random_csr  # noqa: F401
