"""Low-precision sparse kernels (ISSUE 9, DESIGN.md §13).

Covers the acceptance surface: bf16/fp16/fp8/int8 forward + gradient
parity against the f32 oracle across reduction strategies (per-dtype
tolerances, compared against the *same-strategy* f32 output so a lossy
strategy is not misattributed to the dtype), quantize/dequantize
round-trips and calibration, empty-row / single-nnz / empty-matrix
edges, dtype-preservation regressions in the format constructors,
dtype-axis tuning with zero-remeasure cache replay, the v3 -> v4 cache
schema migration, the fp8 -> bf16 degradation path, and the roofline
byte accounting validated against XLA's compiled memory analysis.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Schedule, cost_terms
from repro.core.dtypes import (
    Fp8Fallback,
    canonical_value_dtype,
    fp8_supported,
    operand_dtype,
    operand_itemsize,
    storage_dtype,
    value_itemsize,
)
from repro.kernels import ref
from repro.sparse import (
    CSR,
    QuantizedCSR,
    dequantize,
    matrix_stats,
    quantize_csr,
    random_csr,
    spmm,
)
from repro.tune import SCHEMA_VERSION, ScheduleCache, TuneRecord, tune_schedule
from repro.tune.search import schedule_key

#: relative-L2 forward tolerance per storage dtype (storage rounding
#: only — accumulation is f32 everywhere, the upcast_f32 contract)
TOL = {"bfloat16": 2e-2, "float16": 3e-3, "float8_e4m3fn": 1.5e-1,
       "int8": 5e-2}

SCHEDULES = [
    Schedule("eb", nnz_tile=128, group_size=8, strategy="segment"),
    Schedule("eb", nnz_tile=128, group_size=8, strategy="accumulate"),
    Schedule("eb", nnz_tile=128, group_size=16, strategy="parallel"),
    Schedule("rb", row_tile=8, strategy="parallel"),
]


def _rel(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float(np.linalg.norm(a - b) / (np.linalg.norm(b) + 1e-12))


def _mat(n=96, density=0.06, seed=0):
    return random_csr(n, n, density=density, seed=seed)


def _b(csr, C=16, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (csr.shape[1], C))


# ---------------------------------------------------------------------------
# Schedule axis validation + keys
# ---------------------------------------------------------------------------


def test_canonical_value_dtype():
    assert canonical_value_dtype(None) is None
    assert canonical_value_dtype("float32") is None  # axis identity
    assert canonical_value_dtype("f32") is None
    assert canonical_value_dtype("bf16") == "bfloat16"
    assert canonical_value_dtype(jnp.bfloat16) == "bfloat16"
    assert canonical_value_dtype("fp8") == "float8_e4m3fn"
    assert canonical_value_dtype("int8") == "int8"
    with pytest.raises(ValueError):
        canonical_value_dtype("int4")


def test_schedule_validates_and_normalizes_value_dtype():
    s = Schedule("eb", value_dtype="bf16")
    assert s.value_dtype == "bfloat16"
    assert Schedule("eb", value_dtype="float32").value_dtype is None
    with pytest.raises(ValueError):
        Schedule("eb", value_dtype="float64")


def test_schedule_key_dtype_suffix():
    base = Schedule("eb", nnz_tile=128, group_size=8, strategy="segment")
    k0 = schedule_key(base)
    assert ":v[" not in k0  # pre-dtype-axis keys unchanged
    k1 = schedule_key(base.replace(value_dtype="bfloat16"))
    assert k1 == k0.replace(":segment", ":segment:v[bfloat16]")
    # replace() round-trips through validation
    assert base.replace(value_dtype="bf16").value_dtype == "bfloat16"


def test_itemsizes():
    assert value_itemsize(None) == 4
    assert value_itemsize("bfloat16") == 2
    assert value_itemsize("int8") == 1
    assert operand_itemsize("int8") == 2  # int8 pairs with a bf16 operand
    assert operand_dtype("int8") == jnp.bfloat16


# ---------------------------------------------------------------------------
# Forward + gradient parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sched", SCHEDULES, ids=lambda s: schedule_key(s))
@pytest.mark.parametrize("vd", ["bfloat16", "float16", "int8"])
def test_forward_parity_vs_same_strategy_f32(sched, vd):
    """Narrow output compared against the *same schedule* at f32 — the
    dtype axis must only add storage rounding, whatever the strategy's
    own deviation from the oracle is."""
    csr = _mat()
    b = _b(csr)
    out32 = spmm(csr, b, sched)
    outn = spmm(csr, b, sched.replace(value_dtype=vd))
    assert outn.dtype == jnp.float32  # accumulation/output stay f32
    assert _rel(outn, out32) < TOL[vd]


def test_forward_parity_vs_oracle():
    """Sanity anchor: with a deviation-free strategy the narrow outputs
    are also close to the dense oracle, not just to each other."""
    csr = _mat()
    b = _b(csr)
    oracle = np.asarray(csr.todense(), np.float64) @ np.asarray(b, np.float64)
    sched = SCHEDULES[0]
    for vd in ("bfloat16", "float16", "int8"):
        out = spmm(csr, b, sched.replace(value_dtype=vd))
        assert _rel(out, oracle) < TOL[vd]


def test_gradients_narrow_float():
    """Narrow-float CSR spmm stays differentiable in all args; grads are
    the straight-through f32 grads up to storage rounding."""
    csr = _mat(64, 0.08)
    b = _b(csr, 8)
    sched = SCHEDULES[0]

    def loss(bb, s):
        return jnp.sum(spmm(csr, bb, s) ** 2)

    g32 = jax.grad(loss)(b, sched)
    gbf = jax.grad(loss)(b, sched.replace(value_dtype="bfloat16"))
    assert _rel(gbf, g32) < 5e-2


def test_gradients_int8_quantized():
    """int8 path differentiates through b (vals are host-side codes)."""
    csr = _mat(64, 0.08)
    b = _b(csr, 8)
    sched = SCHEDULES[0]

    def loss(bb):
        return jnp.sum(spmm(csr, bb, sched.replace(value_dtype="int8")) ** 2)

    gq = jax.grad(loss)(b)
    g32 = jax.grad(lambda bb: jnp.sum(spmm(csr, bb, sched) ** 2))(b)
    assert _rel(gq, g32) < 5e-2


def test_quantized_csr_direct_input():
    """A pre-quantized operand dispatches the quantized kernels under
    'auto' scheduling and matches its own dequantized reference."""
    csr = _mat()
    b = _b(csr)
    q = csr.quantized()
    out = spmm(q, b, "auto")
    want = ref.spmm_coo_ref(q.csr.tocoo().rows, q.csr.tocoo().cols,
                            q.dequantize().tocoo().vals, b, csr.shape[0])
    assert _rel(out, want) < 2e-2


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_per_row():
    csr = _mat()
    q = quantize_csr(csr)
    assert q.csr.vals.dtype == jnp.int8
    assert q.scales.shape == (csr.shape[0],)
    deq = dequantize(q)
    # per-element error bounded by scale/2 per row
    vals = np.asarray(csr.vals)
    rows = np.repeat(np.arange(csr.shape[0]),
                     np.diff(np.asarray(csr.indptr)))
    err = np.abs(np.asarray(deq.vals) - vals)
    assert np.all(err <= np.asarray(q.scales)[rows] / 2 + 1e-7)


def test_quantize_empty_rows_and_methods():
    # matrix with empty rows: their scale must be the harmless 1.0
    indptr = np.array([0, 2, 2, 3], np.int32)
    indices = np.array([0, 2, 1], np.int32)
    vals = np.array([1.0, -3.0, 0.5], np.float32)
    csr = CSR(indptr, indices, vals, (3, 3))
    q = quantize_csr(csr)
    assert float(q.scales[1]) == 1.0
    # percentile calibration clips outliers before the absmax
    qp = quantize_csr(csr, method="percentile", percentile=50.0)
    assert float(qp.scales[0]) <= float(q.scales[0])
    with pytest.raises(ValueError):
        quantize_csr(csr, method="bogus")


def test_quantized_memoization():
    csr = _mat()
    assert csr.quantized() is csr.quantized()
    assert csr.astype(jnp.float32) is csr
    assert csr.astype(jnp.bfloat16) is csr.astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Edges + dtype-preservation regressions
# ---------------------------------------------------------------------------


def test_single_nnz_and_empty_matrix():
    indptr = np.array([0, 1, 1], np.int32)
    csr = CSR(indptr, np.array([0], np.int32),
              np.array([2.5], np.float32), (2, 2))
    b = jnp.ones((2, 4))
    sched = SCHEDULES[0]
    for vd in ("bfloat16", "int8"):
        out = spmm(csr, b, sched.replace(value_dtype=vd))
        assert _rel(out, [[2.5] * 4, [0.0] * 4]) < TOL[vd]
    empty = CSR(np.zeros(3, np.int32), np.zeros(0, np.int32),
                np.zeros(0, np.float32), (2, 2))
    q = quantize_csr(empty)
    assert q.csr.nnz == 0 and np.all(np.asarray(q.scales) == 1.0)


def test_ell_preserves_value_dtype_when_empty():
    """Regression: ELL.fromcsr used to silently widen an *empty* narrow
    value stream back to f32."""
    empty = CSR(np.zeros(3, np.int32), np.zeros(0, np.int32),
                np.zeros(0, np.float32), (2, 2))
    bf = empty.astype(jnp.bfloat16)
    assert bf.ell(row_tile=8).vals.dtype == jnp.bfloat16


def test_grouped_padding_preserves_value_dtype():
    csr = _mat(48, 0.1)
    bf = csr.astype(jnp.bfloat16)
    g = bf.grouped(64, group_size=8)
    assert g.vals.dtype == jnp.bfloat16
    assert bf.ell(row_tile=8).vals.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# fp8 fallback
# ---------------------------------------------------------------------------


def test_fp8_degrades_to_bf16_with_warning(monkeypatch):
    monkeypatch.setenv("REPRO_DISABLE_FP8", "1")
    assert not fp8_supported()
    with pytest.warns(Fp8Fallback):
        assert storage_dtype("float8_e4m3fn") == jnp.bfloat16
    assert value_itemsize("float8_e4m3fn") == 2  # realized width
    # end-to-end: the degraded schedule runs and equals its bf16 twin
    csr = _mat(64, 0.08)
    b = _b(csr, 8)
    sched = SCHEDULES[0]
    with pytest.warns(Fp8Fallback):
        out8 = spmm(csr, b, sched.replace(value_dtype="fp8"))
    outbf = spmm(csr, b, sched.replace(value_dtype="bfloat16"))
    assert _rel(out8, outbf) == 0.0


@pytest.mark.skipif(not hasattr(jnp, "float8_e4m3fn"),
                    reason="this jax has no fp8 type")
def test_fp8_native_when_available(monkeypatch):
    monkeypatch.delenv("REPRO_DISABLE_FP8", raising=False)
    assert fp8_supported()
    assert storage_dtype("fp8") == jnp.float8_e4m3fn
    assert value_itemsize("fp8") == 1
    csr = _mat(64, 0.08)
    b = _b(csr, 8)
    out = spmm(csr, b, SCHEDULES[0].replace(value_dtype="fp8"))
    oracle = np.asarray(csr.todense(), np.float64) @ np.asarray(
        b, np.float64)
    assert _rel(out, oracle) < TOL["float8_e4m3fn"]


# ---------------------------------------------------------------------------
# Tuning: dtype as a searched axis, cache replay, schema migration
# ---------------------------------------------------------------------------


def _counting_measure(bias_dtype=None):
    calls = {"n": 0}

    def measure(s):
        calls["n"] += 1
        # make the biased dtype strictly fastest so the tuner must pick it
        return 0.5e-6 if s.value_dtype == bias_dtype else 1e-6

    return measure, calls


def test_tuner_picks_dtype_and_replays_with_zero_measurements(tmp_path):
    csr = _mat()
    cache = ScheduleCache(path=str(tmp_path / "c.json"))
    measure, calls = _counting_measure("bfloat16")
    res = tune_schedule(csr, 16, cache=cache, measure=measure,
                        value_dtypes=("bfloat16",))
    assert res.schedule.value_dtype == "bfloat16"
    assert not res.from_cache and calls["n"] > 0
    n_first = calls["n"]
    replay = tune_schedule(csr, 16, cache=cache, measure=measure,
                           value_dtypes=("bfloat16",))
    assert replay.from_cache and replay.n_measurements == 0
    assert calls["n"] == n_first  # zero re-measurements
    assert replay.schedule.value_dtype == "bfloat16"
    # the record survives a from-disk reload with its dtype intact
    fresh = ScheduleCache(path=str(tmp_path / "c.json"))
    rec = fresh.get(res.key)
    assert rec is not None and rec.schedule.value_dtype == "bfloat16"


def test_tuner_error_budget_gates_dtypes(tmp_path):
    csr = _mat()
    measure, _ = _counting_measure("bfloat16")
    res = tune_schedule(csr, 16, cache=ScheduleCache(path=None),
                        measure=measure, error_budget=0.0)
    assert res.schedule.value_dtype is None  # nothing fits a 0% budget
    res = tune_schedule(csr, 16, cache=ScheduleCache(path=None),
                        measure=measure, value_dtypes=())
    assert res.schedule.value_dtype is None  # axis disabled


def test_cache_v3_records_are_dropped(tmp_path):
    """v3 -> v4 migration: pre-dtype-axis records must not replay (they
    would silently pin f32 storage); the version gate drops the file
    wholesale and the workload re-tunes."""
    path = tmp_path / "cache.json"
    cache = ScheduleCache(path=str(path))
    cache.put("k", TuneRecord(schedule=Schedule("eb"), us_per_call=1.0))
    cache.save()
    raw = json.loads(path.read_text())
    assert raw["version"] == SCHEMA_VERSION == 4
    raw["version"] = 3
    path.write_text(json.dumps(raw))
    stale = ScheduleCache(path=str(path))
    assert stale.get("k") is None and len(stale) == 0


def test_cost_terms_scale_with_dtype():
    csr = _mat()
    stats = matrix_stats(csr)
    s = Schedule("eb", nnz_tile=128, group_size=8, strategy="segment")
    work, waste, wb, gather = cost_terms(stats, s, 16)
    w2, waste2, wb2, g2 = cost_terms(
        stats, s.replace(value_dtype="bfloat16"), 16)
    assert (w2, wb2) == (work, wb)  # compute/writeback stay f32
    assert g2 == pytest.approx(gather / 2)
    assert waste2 == pytest.approx(waste / 2)
    *_, g1 = cost_terms(stats, s.replace(value_dtype="int8"), 16)
    assert g1 == pytest.approx(gather / 2)  # int8 pairs with bf16 operand


def test_serve_prepare_sparse_can_pin_f32(monkeypatch):
    """``value_dtypes=()`` must reach tune_schedule and disable the
    axis (a parity-critical serving path pins f32 storage)."""
    from repro.serve import engine as serve_engine
    from repro.serve.engine import ServeEngine

    class _API:
        def init_cache(self, slots, max_len):
            return {}

        def decode_step(self, params, cache, toks):  # pragma: no cover
            raise NotImplementedError

    eng = ServeEngine(_API(), params={}, slots=1,
                      tuner_cache=ScheduleCache(path=None))
    csr = _mat()
    seen = {}

    import repro.tune as tune_mod

    real = tune_mod.tune_schedule

    def spy(c, n, **kw):
        seen.update(kw)
        measure, _ = _counting_measure()
        return real(c, n, measure=measure, **kw)

    monkeypatch.setattr(tune_mod, "tune_schedule", spy)
    sched = eng.prepare_sparse(csr, 16, value_dtypes=(),
                               error_budget=0.01)
    assert seen.get("value_dtypes") == ()
    assert seen.get("error_budget") == 0.01
    assert sched.value_dtype is None


# ---------------------------------------------------------------------------
# Roofline byte accounting vs compiled reality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("vd", [None, "bfloat16"])
def test_predicted_arg_bytes_match_compiled(vd):
    """The byte model the bench reports is the number XLA's memory
    analysis measures on the compiled tuner runner (PR 8 style)."""
    from repro.roofline.analysis import predict_spmm_arg_bytes
    from repro.tune.measure import make_eb_runner

    csr = _mat()
    fn, args = make_eb_runner(csr, 16, group_size=8, strategy="accumulate",
                              value_dtype=vd)
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
    except Exception:
        pytest.skip("memory_analysis unavailable on this jax")
    if ma is None:
        pytest.skip("memory_analysis unavailable on this jax")
    pred = predict_spmm_arg_bytes(args[0].shape[0], csr.shape[1], 16,
                                  value_dtype=vd)
    assert ma.argument_size_in_bytes == pred


def test_predicted_traffic_scales_down():
    from repro.roofline.analysis import (
        dtype_itemsize,
        predict_spmm_traffic_bytes,
    )

    assert dtype_itemsize("bf16") == 2
    assert dtype_itemsize("f8e4m3fn") == 1
    assert dtype_itemsize(np.float32) == 4
    b32 = predict_spmm_traffic_bytes(10_000, 512, 64)
    bbf = predict_spmm_traffic_bytes(10_000, 512, 64,
                                     value_dtype="bfloat16")
    assert 1.5 < b32 / bbf < 2.0  # gather dominated -> near-2x


# ---------------------------------------------------------------------------
# launch.backend
# ---------------------------------------------------------------------------


def test_backend_info_and_interpret_default():
    from repro.launch import backend

    info = backend.backend_info()
    assert set(info) == {"backend", "device_kind", "device_count", "fp8",
                         "interpret"}
    assert info["device_count"] >= 1
    # CPU (this container) always interprets Pallas
    if info["backend"] == "cpu":
        assert info["interpret"] is True


def test_set_host_device_count_appends_flag(monkeypatch):
    from repro.launch import backend

    monkeypatch.setenv("XLA_FLAGS", "--xla_cpu_foo=1")
    backend.set_host_device_count(4)
    import os

    flags = os.environ["XLA_FLAGS"]
    assert "--xla_cpu_foo=1" in flags
    assert "--xla_force_host_platform_device_count=4" in flags
    backend.set_host_device_count(8)  # replaces, never duplicates
    flags = os.environ["XLA_FLAGS"]
    assert flags.count("--xla_force_host_platform_device_count") == 1
    assert "--xla_force_host_platform_device_count=8" in flags
    with pytest.raises(ValueError):
        backend.set_host_device_count(0)
