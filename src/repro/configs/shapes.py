"""Assigned input shapes and dry-run input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of the given (arch × shape) cell — weak-type-correct,
shardable, no device allocation. Decode shapes lower ``serve_step`` (one
new token against a seq_len KV cache), not ``train_step``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import ModelConfig, ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256,
                            kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32,
                               kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128,
                              kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1,
                             kind="decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention: run only for SSM/hybrid;
    skip (with reason) for pure full-attention archs per the assignment."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: long_500k skipped per "
                       "assignment (sub-quadratic only)")
    return True, ""


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        # patch stub consumes part of the joint sequence budget
        specs["tokens"] = jax.ShapeDtypeStruct(
            (b, s - cfg.n_vision_tokens), jnp.int32)
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        specs["encoder_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return specs


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, init_cache) -> dict:
    """Specs for serve_step(params, cache, tokens)."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(b, s))
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
    }


def batch_from_specs(specs: dict, key=None) -> dict:
    """Materialize a concrete batch matching the specs (smoke/e2e tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, s.shape, 0, 128, s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, s.dtype)
    return out
