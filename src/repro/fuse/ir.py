"""Fusion IR — chains of ``{sparse op, monoid, epilogue}`` nodes over a
shared iteration space (DESIGN.md §10).

PR 4/5 landed fusions as hand-written instances: an :class:`Epilogue`
field on :class:`Schedule` here, a one-pass attention kernel there.
This module makes the *shape* of those fusions first-class:

* a :class:`FuseNode` is one op in a producer→consumer chain — a
  reducing kernel anchor (``spmm`` / ``grouped_matmul`` /
  ``segment_reduce``), a scatter ``combine``, or elementwise ``ewise``
  work expressed as the :class:`~repro.core.Epilogue` it would fuse as;
* a :class:`Launch` is one executable unit the planner emitted: an
  anchor node plus the chain members folded into its epilogue slot;
* a :class:`FusePlan` is the planner's output — the chain, its
  launches, the per-boundary :class:`FuseDecision`, and the legality
  reason for every split;
* :class:`FuseDecision` alone is what the tuner caches (``fuse:`` keys,
  ``TuneRecord`` kind tag ``"fuse"``): the fuse/split bit per chain
  boundary, replayable onto the same chain via
  :func:`repro.fuse.planner.plan`.

Nodes are *static* descriptions; array operands live in a parallel
per-node params list the executor consumes (``repro.fuse.execute``), so
chains are hashable, cache-keyable and reusable across inputs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from ..core.schedule import Epilogue, Schedule, as_schedule

__all__ = [
    "EPILOGUE_CAPABLE",
    "FuseDecision",
    "FuseNode",
    "FusePlan",
    "KINDS",
    "Launch",
    "PALLAS_KINDS",
    "chain_sig",
    "combine_node",
    "ewise",
    "gcn_chain",
    "grouped_matmul_node",
    "moe_expert_chain",
    "segment_reduce_node",
    "spmm_node",
]

KINDS = ("spmm", "grouped_matmul", "segment_reduce", "combine", "ewise")

#: kinds that execute as a Pallas kernel when they anchor a launch
#: (``combine`` is an XLA scatter, ``ewise`` an XLA elementwise pass)
PALLAS_KINDS = frozenset({"spmm", "grouped_matmul", "segment_reduce"})

#: anchors exposing the shared in-kernel epilogue slot — the targets of
#: the epilogue-fold planner rule.  ``ewise`` is included: an unfused
#: elementwise launch is its own epilogue template and absorbs further
#: elementwise work the same way a kernel's slot does.
EPILOGUE_CAPABLE = frozenset({"spmm", "grouped_matmul", "ewise"})

#: monoid vocabulary of the reducing kinds (mirrors
#: ``sparse.segment_reduce``'s ``op`` — 'mean' is the add monoid with a
#: fused count column; 'sum' is the add monoid)
REDUCE_OPS = ("sum", "max", "min", "mean")


@dataclasses.dataclass(frozen=True)
class FuseNode:
    """One chain node.  ``op`` is the reduction monoid name (reducing
    kinds only); ``epilogue`` is the node's own elementwise work — for
    ``ewise`` nodes it *is* the node, for anchors it is work requested
    at the node itself (usually noop; the planner folds downstream
    ``ewise`` nodes into it).  ``schedule`` rides on ``spmm`` /
    ``segment_reduce`` anchors."""

    kind: str
    op: str = "sum"
    epilogue: Epilogue = Epilogue()
    schedule: Optional[Schedule] = None
    label: str = ""

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown node kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.op not in REDUCE_OPS:
            raise ValueError(f"unknown reduction op {self.op!r}; "
                             f"one of {REDUCE_OPS}")

    @property
    def tag(self) -> str:
        """Stable signature component (cache keys, error messages)."""
        parts = [self.kind]
        if self.kind in ("segment_reduce", "combine") or self.op != "sum":
            parts.append(self.op)
        if not self.epilogue.is_noop:
            parts.append(f"[{self.epilogue.tag}]")
        return ":".join(parts)


def spmm_node(schedule=None, *, epilogue: Epilogue = Epilogue(),
              label: str = "") -> FuseNode:
    """A scheduled SpMM anchor (``out = A @ X`` — optionally ``A @ (X W)``
    when the executor params carry a dense ``w``)."""
    sched = None if schedule is None else as_schedule(schedule)
    return FuseNode("spmm", epilogue=epilogue, schedule=sched, label=label)


def grouped_matmul_node(*, epilogue: Epilogue = Epilogue(),
                        label: str = "") -> FuseNode:
    """An expert-grouped GEMM anchor (``kernels.ops.grouped_matmul``)."""
    return FuseNode("grouped_matmul", epilogue=epilogue, label=label)


def segment_reduce_node(op: str = "sum", *, schedule=None,
                        label: str = "") -> FuseNode:
    """A grouped segment-reduce anchor under the named monoid, with an
    optional explicit :class:`Schedule`."""
    sched = None if schedule is None else as_schedule(schedule)
    return FuseNode("segment_reduce", op=op, schedule=sched, label=label)


def combine_node(op: str = "sum", *, label: str = "") -> FuseNode:
    """The MoE combine scatter: gate-weighted token writeback under the
    named monoid ('sum' / 'min' / 'mean')."""
    return FuseNode("combine", op=op, label=label)


def ewise(activation: Optional[str] = None, *, bias: bool = False,
          residual: bool = False, out_dtype: Optional[str] = None,
          label: str = "") -> FuseNode:
    """Elementwise chain work, expressed as the Epilogue it would fuse
    as: ``cast(act(x + bias) + residual)``."""
    return FuseNode("ewise", label=label,
                    epilogue=Epilogue(activation=activation, bias=bias,
                                      residual=residual,
                                      out_dtype=out_dtype))


@dataclasses.dataclass(frozen=True)
class FuseDecision:
    """The planner's per-boundary choice — ``fused[i]`` says whether the
    boundary between ``chain[i]`` and ``chain[i+1]`` fused.  This is the
    tunable, cacheable part of a plan (``TuneRecord`` kind ``"fuse"``)."""

    fused: Tuple[bool, ...]

    @property
    def tag(self) -> str:
        """Compact chain signature: one F(used)/S(tandalone) per node."""
        return "".join("F" if b else "S" for b in self.fused) or "-"


@dataclasses.dataclass(frozen=True)
class Launch:
    """One executable unit: ``anchor`` runs with ``epilogue`` fused onto
    its output block; ``members`` are the chain indices folded in
    (anchor first)."""

    anchor: FuseNode
    anchor_idx: int
    epilogue: Epilogue
    members: Tuple[int, ...]

    @property
    def is_pallas(self) -> bool:
        """True when the anchor lowers to a Pallas kernel (fusible)."""
        return self.anchor.kind in PALLAS_KINDS


@dataclasses.dataclass(frozen=True)
class FusePlan:
    """Planner output.  ``reasons[i]`` is empty when boundary ``i``
    fused, else the legality (or decision) reason it split."""

    chain: Tuple[FuseNode, ...]
    launches: Tuple[Launch, ...]
    decision: FuseDecision
    reasons: Tuple[str, ...]

    @property
    def n_launches(self) -> int:
        """Pallas kernel launches this plan executes (XLA elementwise /
        scatter launches are not counted — they are what fusion into a
        kernel epilogue *removes*)."""
        return sum(1 for ln in self.launches if ln.is_pallas)


def chain_sig(chain) -> str:
    """Stable chain signature for ``fuse:`` cache keys."""
    return ">".join(n.tag for n in chain)


# ---------------------------------------------------------------------------
# Chain builders for the landed fusions (each returns (chain, params)
# ready for plan() / execute.run_plan()).
# ---------------------------------------------------------------------------


def gcn_chain(adj, weights, biases=None, *, activation: str = "relu",
              final_activation: Optional[str] = None, schedule=None):
    """Two-layer GCN — ``act(Ã (X W₀) + b₀)`` → ``Ã (· W₁) + b₁`` — as a
    4-node chain ``spmm → ewise → spmm [→ ewise]``.  The planner folds
    each ewise into its producing SpMM's epilogue, so the whole model
    runs in 2 Pallas launches.

    ``weights`` is ``(w0, w1)``; ``biases`` optionally ``(b0, b1)`` (a
    ``None`` entry drops that bias).  Returns ``(chain, params)``.
    """
    w0, w1 = weights
    b0, b1 = biases if biases is not None else (None, None)
    chain = [spmm_node(schedule, label="gcn0"),
             ewise(activation, bias=b0 is not None, label="gcn0.ep"),
             spmm_node(schedule, label="gcn1")]
    params = [{"a": adj, "w": w0}, {"bias": b0}, {"a": adj, "w": w1}]
    if final_activation is not None or b1 is not None:
        chain.append(ewise(final_activation, bias=b1 is not None,
                           label="gcn1.ep"))
        params.append({"bias": b1})
    return tuple(chain), params


def moe_expert_chain(tile_experts, weights, bias=None, *,
                     activation: str = "silu",
                     out_dtype: Optional[str] = None,
                     token_tile: int = 128, f_tile: int = 128,
                     d_tile: int = 128):
    """The MoE expert up-projection — ``act(x @ W[e] + b[e])`` — as a
    2-node chain ``grouped_matmul → ewise``.  Fused, the activation (and
    per-expert bias / output cast) runs on the GEMM's output block: one
    Pallas launch per token tile instead of a GEMM pass plus an XLA
    elementwise pass.  Returns ``(chain, params)``.
    """
    chain = (grouped_matmul_node(label="expert_gemm"),
             ewise(activation, bias=bias is not None, out_dtype=out_dtype,
                   label="expert_gemm.ep"))
    params = [{"tile_experts": tile_experts, "weights": weights,
               "token_tile": token_tile, "f_tile": f_tile,
               "d_tile": d_tile},
              {"bias": bias}]
    return chain, params
