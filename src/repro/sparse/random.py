"""Synthetic sparse matrix generators (uniform and power-law row lengths).

The paper evaluates on the DA-SpMM matrix suite (SuiteSparse-derived).
Offline we synthesize matrices with controlled statistics instead: density,
row-length skew (CV), and size — the three features the data-aware selector
conditions on.
"""
from __future__ import annotations

import numpy as np

from .formats import COO, CSR


def random_csr(
    n_rows: int,
    n_cols: int,
    density: float = 0.01,
    skew: float = 0.0,
    seed: int = 0,
    dtype=np.float32,
) -> CSR:
    """Random CSR with expected ``density`` and row-length skew.

    skew = 0.0 -> uniform Bernoulli rows; skew > 0 -> power-law row lengths
    (a few very long rows), the regime where nnz-split + segment reduction
    wins in the paper.
    """
    rng = np.random.default_rng(seed)
    target_nnz = max(1, int(n_rows * n_cols * density))
    if skew <= 0.0:
        lengths = rng.multinomial(target_nnz, np.full(n_rows, 1.0 / n_rows))
    else:
        w = rng.pareto(1.0 / max(skew, 1e-3), size=n_rows) + 1e-6
        w = w / w.sum()
        lengths = rng.multinomial(target_nnz, w)
    lengths = np.minimum(lengths, n_cols)

    indptr = np.zeros(n_rows + 1, np.int64)
    np.cumsum(lengths, out=indptr[1:])
    nnz = int(indptr[-1])
    indices = np.empty(nnz, np.int32)
    for r in range(n_rows):
        k = lengths[r]
        if k:
            indices[indptr[r]: indptr[r + 1]] = np.sort(
                rng.choice(n_cols, size=k, replace=False)
            )
    vals = rng.standard_normal(nnz).astype(dtype)
    import jax.numpy as jnp

    return CSR(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(indices),
        vals=jnp.asarray(vals),
        shape=(n_rows, n_cols),
    )


def random_coo(n_rows, n_cols, density=0.01, skew=0.0, seed=0) -> COO:
    return random_csr(n_rows, n_cols, density, skew, seed).tocoo()


def matrix_stats(csr: CSR) -> dict:
    """Features used by the data-aware schedule selector."""
    lengths = np.asarray(csr.row_lengths())
    mean = float(lengths.mean()) if lengths.size else 0.0
    std = float(lengths.std()) if lengths.size else 0.0
    return {
        "n_rows": csr.shape[0],
        "n_cols": csr.shape[1],
        "nnz": csr.nnz,
        "density": csr.nnz / max(1, csr.shape[0] * csr.shape[1]),
        "row_mean": mean,
        "row_cv": (std / mean) if mean > 0 else 0.0,
        "row_max": int(lengths.max()) if lengths.size else 0,
    }
