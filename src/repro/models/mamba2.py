"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

The chunked SSD algorithm is structurally the paper's segment-group
pattern over the *time* axis: intra-chunk reduction (the masked C·B
"attention" matmul = within-group one-hot reduce) + inter-chunk carry
(the group-boundary accumulation). See DESIGN.md §6.

Projections are SPLIT (z/x/BC/dt as separate matrices rather than one
fused in_proj) so tensor parallelism can column-shard z/x/dt on the head
dim and keep the small B/C/dt replicated — the TP scheme the Mamba-2
paper itself describes. Math is identical to the fused layout.

Layout: tokens (B, S, D); SSM heads H = d_inner / head_dim (P); state N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_dense, rmsnorm

# ------------------------------------------------------------------ init


def init_mixer(cfg, key):
    kz, kx, kbc, kdt, ko = jax.random.split(key, 5)
    d = cfg.d_model
    di = cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    k = cfg.conv_kernel
    return {
        "z_proj": init_dense(kz, d, di, cfg.param_dtype)["w"],
        "x_proj": init_dense(kx, d, di, cfg.param_dtype)["w"],
        "bc_proj": init_dense(kbc, d, 2 * g * n, cfg.param_dtype)["w"],
        "dt_proj": init_dense(kdt, d, h, cfg.param_dtype)["w"],
        "conv_x_w": (jax.random.normal(key, (k, di)) * k ** -0.5
                     ).astype(cfg.param_dtype),
        "conv_x_b": jnp.zeros((di,), cfg.param_dtype),
        "conv_bc_w": (jax.random.normal(kbc, (k, 2 * g * n)) * k ** -0.5
                      ).astype(cfg.param_dtype),
        "conv_bc_b": jnp.zeros((2 * g * n,), cfg.param_dtype),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((di,), cfg.param_dtype),
        "out_proj": init_dense(ko, di, d, cfg.param_dtype,
                               scale=di ** -0.5)["w"],
    }


# ------------------------------------------------------------------- ssd


def _conv1d_causal(x, w, b):
    """Depthwise causal conv. x: (B, S, C); w: (K, C); b: (C,)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x, dt, a, b_in, c_in, chunk, d_skip, init_state=None,
                unroll: bool = False):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (already softplus'd); a: (H,) negative;
    b_in/c_in: (B, S, G, N). Returns (y (B, S, H, P), final_state
    (B, H, N, P)).
    """
    bs, s0, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    hpg = h // g
    q = min(chunk, s0)
    pad = (-s0) % q
    if pad:
        # zero extension along time: dt=0 -> decay 1, contribution 0, so
        # both outputs and the final state are unaffected.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = s0 + pad
    nc = s // q

    xf = x.astype(jnp.float32).reshape(bs, nc, q, h, p)
    dtc = dt.astype(jnp.float32).reshape(bs, nc, q, h)
    da = (dtc * a).astype(jnp.float32)  # (B,nc,Q,H)
    bh = jnp.repeat(b_in.astype(jnp.float32).reshape(bs, nc, q, g, n),
                    hpg, axis=3)  # (B,nc,Q,H,N)
    ch = jnp.repeat(c_in.astype(jnp.float32).reshape(bs, nc, q, g, n),
                    hpg, axis=3)

    seg = jnp.cumsum(da, axis=2)  # (B,nc,Q,H) inclusive
    # intra-chunk ("diagonal block"): masked attention-like matmul
    cb = jnp.einsum("bnihe,bnjhe->bnijh", ch, bh)  # (B,nc,Q,Q,H)
    decay = seg[:, :, :, None, :] - seg[:, :, None, :, :]
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])
    l_mat = jnp.where(mask[None, None, ..., None], jnp.exp(decay), 0.0)
    w_mat = cb * l_mat * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bnijh,bnjhp->bnihp", w_mat, xf)

    # chunk states + inter-chunk carry (the group-boundary accumulation)
    seg_end = seg[:, :, -1:, :]  # (B,nc,1,H)
    sdecay = jnp.exp(seg_end - seg)  # (B,nc,Q,H)
    states = jnp.einsum("bnqh,bnqhe,bnqhp->bnhep",
                        dtc * sdecay, bh, xf)  # (B,nc,H,N,P)
    chunk_decay = jnp.exp(seg_end[:, :, 0, :])  # (B,nc,H)

    def scan_fn(carry, inp):
        st, cd = inp
        return carry * cd[..., None, None] + st, carry

    init = (jnp.zeros((bs, h, n, p), jnp.float32)
            if init_state is None else init_state.astype(jnp.float32))
    final, prev = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=nc if unroll else 1)
    prev = jnp.moveaxis(prev, 0, 1)  # (B,nc,H,N,P) state before each chunk

    y_off = jnp.einsum("bnqhe,bnhep,bnqh->bnqhp", ch, prev, jnp.exp(seg))
    y = (y_diag + y_off).reshape(bs, s, h, p)
    y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y[:, :s0].astype(x.dtype), final


# ----------------------------------------------------------------- block


def _project(cfg, p, x):
    """x (..., D) -> z (..., di), xs (..., di), bc (..., 2GN), dt (..., H)
    pre-conv/pre-activation."""
    z = jnp.einsum("...d,df->...f", x, p["z_proj"].astype(x.dtype))
    xs = jnp.einsum("...d,df->...f", x, p["x_proj"].astype(x.dtype))
    bc = jnp.einsum("...d,df->...f", x, p["bc_proj"].astype(x.dtype))
    dt = jnp.einsum("...d,df->...f", x, p["dt_proj"].astype(x.dtype))
    return z, xs, bc, dt


def mixer_fwd(cfg, p, x, init_state=None, return_state=False):
    """Full-sequence mamba2 mixer. x: (B, S, D) -> (B, S, D)."""
    bs, s, _ = x.shape
    h, pd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xs_raw, bc_raw, dt = _project(cfg, p, x)
    xs = jax.nn.silu(_conv1d_causal(xs_raw, p["conv_x_w"], p["conv_x_b"]))
    bc = jax.nn.silu(_conv1d_causal(bc_raw, p["conv_bc_w"], p["conv_bc_b"]))
    xh = xs.reshape(bs, s, h, pd)
    b_in = bc[..., : g * n].reshape(bs, s, g, n)
    c_in = bc[..., g * n:].reshape(bs, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    y, final = ssd_chunked(xh, dt, a, b_in, c_in, cfg.ssm_chunk, p["D"],
                           init_state, unroll=cfg.ssd_unroll)
    y = y.reshape(bs, s, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm"])
    out = jnp.einsum("bsf,fd->bsd", y, p["out_proj"].astype(y.dtype))
    if return_state:
        kk = cfg.conv_kernel - 1
        pad_x = jnp.zeros((bs, max(0, kk - s), xs_raw.shape[-1]), x.dtype)
        pad_bc = jnp.zeros((bs, max(0, kk - s), bc_raw.shape[-1]), x.dtype)
        st = {
            "ssm": final,
            "conv_x": jnp.concatenate([pad_x, xs_raw[:, -kk:]], axis=1),
            "conv_bc": jnp.concatenate([pad_bc, bc_raw[:, -kk:]], axis=1),
        }
        return out, st
    return out


def init_mixer_cache(cfg, batch_size, dtype=None):
    dtype = dtype or cfg.compute_dtype
    g, n = cfg.ssm_groups, cfg.ssm_state
    kk = cfg.conv_kernel - 1
    return {
        "ssm": jnp.zeros((batch_size, cfg.ssm_heads, n, cfg.ssm_head_dim),
                         jnp.float32),
        "conv_x": jnp.zeros((batch_size, kk, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros((batch_size, kk, 2 * g * n), dtype),
    }


def _conv_step(window, new, w, b):
    """One causal-conv step. window (B, K-1, C), new (B, C) -> (out (B, C),
    new window)."""
    full = jnp.concatenate([window, new[:, None, :]], axis=1)
    out = jnp.einsum("bkc,kc->bc", full.astype(jnp.float32),
                     w.astype(jnp.float32)) + b.astype(jnp.float32)
    return out, full[:, 1:]


def mixer_decode(cfg, p, cache, x):
    """Single-token step. x: (B, D) -> (B, D), new cache."""
    bs, _ = x.shape
    h, pd, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xs_raw, bc_raw, dt = _project(cfg, p, x)
    cx, new_conv_x = _conv_step(cache["conv_x"], xs_raw,
                                p["conv_x_w"], p["conv_x_b"])
    cbc, new_conv_bc = _conv_step(cache["conv_bc"], bc_raw,
                                  p["conv_bc_w"], p["conv_bc_b"])
    xs = jax.nn.silu(cx).astype(x.dtype).reshape(bs, h, pd)
    bc = jax.nn.silu(cbc).astype(x.dtype)
    b_in = jnp.repeat(bc[..., : g * n].reshape(bs, g, n), h // g, axis=1)
    c_in = jnp.repeat(bc[..., g * n:].reshape(bs, g, n), h // g, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)  # (B, H)
    state = cache["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bhe,bhp->bhep", dt, b_in.astype(jnp.float32),
        xs.astype(jnp.float32))
    y = jnp.einsum("bhe,bhep->bhp", c_in.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bs, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm"])
    out = jnp.einsum("bf,fd->bd", y.astype(x.dtype),
                     p["out_proj"].astype(x.dtype))
    return out, {"ssm": state, "conv_x": new_conv_x, "conv_bc": new_conv_bc}
