"""Differentiable SpMM: custom-vjp (SDDMM backward) vs dense autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.sparse import random_csr
from repro.sparse.autodiff import make_spmm


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_spmm_grads_match_dense(impl):
    csr = random_csr(24, 20, density=0.1, seed=0)
    coo = csr.tocoo()
    n_rows, n_cols = csr.shape
    b = jax.random.normal(jax.random.PRNGKey(0), (n_cols, 6))
    vals = coo.vals

    spmm_fn = make_spmm(coo.rows, coo.cols, n_rows, n_cols, impl=impl)
    tgt = jax.random.normal(jax.random.PRNGKey(1), (n_rows, 6))

    def loss_sparse(vals, b):
        return jnp.sum((spmm_fn(vals, b) - tgt) ** 2)

    def loss_dense(vals, b):
        dense = jnp.zeros((n_rows, n_cols)).at[coo.rows, coo.cols].set(vals)
        return jnp.sum((dense @ b - tgt) ** 2)

    l1, (dv1, db1) = jax.value_and_grad(loss_sparse, argnums=(0, 1))(vals, b)
    l2, (dv2, db2) = jax.value_and_grad(loss_dense, argnums=(0, 1))(vals, b)
    assert abs(float(l1) - float(l2)) < 1e-3
    np.testing.assert_allclose(np.asarray(dv1), np.asarray(dv2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db1), np.asarray(db2),
                               rtol=1e-4, atol=1e-4)


def test_gcn_layer_trains_through_sparse():
    """One GCN aggregation layer optimized end-to-end via the sparse vjp."""
    csr = random_csr(16, 16, density=0.2, seed=3)
    coo = csr.tocoo()
    spmm_fn = make_spmm(coo.rows, coo.cols, 16, 16)
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    y = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    w = jnp.zeros((8, 4))

    def loss(w):
        return jnp.mean((spmm_fn(coo.vals, x @ w) - y) ** 2)

    g = jax.grad(loss)
    losses = []
    for _ in range(25):
        w = w - 0.1 * g(w)
        losses.append(float(loss(w)))
    assert losses[-1] < losses[0] * 0.9
