"""SDDMM Pallas kernel: vals[t] = <A[rows[t]], B[cols[t]]> (* scale[t]).

The second sparse-dense hybrid algebra of the paper (Eq. 2c) — reduction
here runs along two *dense* dimensions, so the segment group degenerates
to a per-lane feature-axis reduce; what Sgap contributes is the nnz-split
tiling + zero extension.

``scale=None`` is a fast path: no all-ones scale operand is materialized
or streamed.  Padded lanes then produce garbage dot products — which is
*legal* zero extension, because GroupedCOO padding is strictly trailing
and the ``ops.sddmm`` wrapper crops ``out[:nnz]``; with a scale the
padded entries carry ``scale = 0`` and are masked in-kernel as before.

Grid: (nnz_tiles, d_tiles) — feature axis innermost, accumulating the
per-lane dot products.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import upcast_f32


def _sddmm_kernel(*refs, has_scale: bool):
    if has_scale:
        rows_ref, cols_ref, scale_ref, a_ref, b_ref, out_ref = refs
    else:
        rows_ref, cols_ref, a_ref, b_ref, out_ref = refs
        scale_ref = None

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[...]
    cols = cols_ref[...]
    # narrow (bf16/fp8) operands upcast here; the dot accumulates in f32
    a, b = upcast_f32(a_ref[...], b_ref[...])  # (M, Dt), (N, Dt)
    ga = jnp.take(a, rows, axis=0)  # (T, Dt)
    gb = jnp.take(b, cols, axis=0)  # (T, Dt)
    out_ref[...] += jnp.sum(ga * gb, axis=-1)

    if scale_ref is not None:
        @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
        def _scale():
            out_ref[...] *= upcast_f32(scale_ref[...])


@functools.partial(
    jax.jit, static_argnames=("nnz_tile", "d_tile", "interpret"))
def sddmm(rows, cols, a, b, scale=None, *, nnz_tile: int = 256,
          d_tile: int = 128, interpret: bool = True):
    """rows/cols/scale: (nnz_pad,) padded to nnz_tile (scale 0 on padding,
    or scale omitted entirely — the wrapper crops trailing pad lanes);
    a: (M, D), b: (N, D) with D padded to d_tile by the wrapper."""
    nnz_pad = rows.shape[0]
    m, d = a.shape
    n, _ = b.shape
    assert nnz_pad % nnz_tile == 0 and d % d_tile == 0
    grid = (nnz_pad // nnz_tile, d // d_tile)
    has_scale = scale is not None
    operands = [rows, cols] + ([scale] if has_scale else []) + [a, b]
    lane_spec = pl.BlockSpec((nnz_tile,), lambda i, u: (i,))
    in_specs = [lane_spec] * (3 if has_scale else 2) + [
        pl.BlockSpec((m, d_tile), lambda i, u: (0, u)),
        pl.BlockSpec((n, d_tile), lambda i, u: (0, u)),
    ]
    return pl.pallas_call(
        functools.partial(_sddmm_kernel, has_scale=has_scale),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((nnz_tile,), lambda i, u: (i,)),
        out_shape=jax.ShapeDtypeStruct((nnz_pad,), jnp.float32),
        interpret=interpret,
    )(*operands)
