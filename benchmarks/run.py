"""Benchmark harness — one function per paper table (Sgap Tables 1-5) plus
beyond-paper benches. Prints ``name,us_per_call,derived`` CSV; ``--json``
additionally emits a machine-readable ``{name: {us_per_call, derived}}``
file (the ``BENCH_<tag>.json`` trajectory CI tracks).

Every artifact also carries a ``probe/runner_speed`` row: a fixed dense
matmul timed with a fixed iteration count.  ``benchmarks/diff.py``
divides the absolute-us gates by this probe, so two CI runs landing on
heterogeneous runner CPUs compare *normalized* wall clock instead of
failing on machine speed (ISSUE 4 / ROADMAP).

    PYTHONPATH=src python -m benchmarks.run [--full] [--json BENCH_ci.json]

``REPRO_BENCH_ITERS`` caps per-measurement timing iterations (CI smoke
sets it low to stay inside its time budget); the probe ignores it — its
whole point is a stable cross-run yardstick.
"""
import argparse
import json
import sys
import traceback

PROBE_ROW = "probe/runner_speed"


def runner_speed_probe():
    """Fixed-workload runner-speed probe: a 512x512 f32 matmul, median of
    a fixed iteration count (deliberately NOT REPRO_BENCH_ITERS-capped).
    Returns CSV rows like every other bench."""
    import jax
    import jax.numpy as jnp

    from repro.tune.measure import time_fn

    x = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
    fn = jax.jit(lambda a: a @ a)
    # cap_env=False: the probe must be comparable across runs whatever
    # iteration caps the surrounding smoke suite set
    t = time_fn(fn, x, warmup=2, iters=7, cap_env=False)
    return [(PROBE_ROW, t * 1e6, "fixed 512x512 f32 matmul, iters=7")]


#: The bench registry: group name -> (module, function, tags).  ``--only``
#: accepts names AND tags ('-'/'_' interchangeable), so CI lanes invoke a
#: tag (``--only ci-smoke``, ``--only dist``) instead of a hand-kept
#: comma list that drifts when a bench is added.  ``ci_smoke`` marks the
#: smoke-lane set (it must cover every ``benchmarks/diff.py``
#: DEFAULT_GROUPS prefix — tested in tests/test_benchmarks.py); ``dist``
#: marks the multi-device benches the 8-device CI lane re-runs on a real
#: mesh.  Adding a bench here is the *single* registration step.
BENCHES = {
    "table1": ("tables", "table1_group_size", {"ci_smoke"}),
    "table2": ("tables", "table2_segment_vs_atomic", set()),
    "table3": ("tables", "table3_new_vs_original", set()),
    "table4": ("tables", "table4_tuning", set()),
    "table5": ("tables", "table5_dynamic_choice", {"ci_smoke"}),
    "moe": ("beyond", "moe_dispatch", set()),
    "moe_tuner": ("beyond", "moe_tuner_gap", {"ci_smoke"}),
    "selector": ("beyond", "selector_quality", {"ci_smoke"}),
    "fused_attention": ("beyond", "fused_attention", {"ci_smoke"}),
    "fused_attention_bwd": ("beyond", "fused_attention_bwd", {"ci_smoke"}),
    "fusion_planner": ("beyond", "fusion_planner", {"ci_smoke"}),
    "skew": ("beyond", "skew_tuner_gap", {"ci_smoke"}),
    "lowprec": ("beyond", "lowprec_spmm", {"ci_smoke"}),
    "dist_attention": ("beyond", "dist_attention_gap",
                       {"ci_smoke", "dist"}),
    "dist_moe": ("beyond", "dist_moe_gap", {"ci_smoke", "dist"}),
    "joint_dist": ("beyond", "joint_dist_gap", {"ci_smoke", "dist"}),
    "fuse_boundary": ("beyond", "fuse_boundary_gap", {"ci_smoke"}),
}


def bench_names() -> list:
    """Registered bench group names, registry order (single source for
    ``--only`` help, error messages, and callers like CI smoke)."""
    return list(BENCHES)


def bench_tags() -> list:
    """Every tag carried by at least one registered bench."""
    tags = set()
    for _, _, t in BENCHES.values():
        tags |= t
    return sorted(tags)


def resolve_only(wanted: list) -> tuple:
    """Expand an ``--only`` list into bench names: each entry is a bench
    name first, else a tag ('-' and '_' interchangeable in both).
    Returns (names in registry order, unknown entries)."""
    picked, unknown = set(), []
    by_norm = {name.replace("-", "_"): name for name in BENCHES}
    for w in wanted:
        norm = w.replace("-", "_")
        if norm in by_norm:
            picked.add(by_norm[norm])
        else:
            tagged = [name for name, (_, _, tags) in BENCHES.items()
                      if norm in tags]
            if tagged:
                picked.update(tagged)
            else:
                unknown.append(w)
    return [n for n in BENCHES if n in picked], unknown


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger matrices (slower, closer to paper scale)")
    ap.add_argument("--only", default=None,
                    help="comma list of bench groups or tags ('-'/'_' "
                         "interchangeable); groups: "
                         + ",".join(bench_names())
                         + "; tags: " + ",".join(bench_tags()))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write {name: {us_per_call, derived}} JSON")
    args = ap.parse_args()
    quick = not args.full

    from . import beyond, tables

    modules = {"tables": tables, "beyond": beyond}
    benches = {
        name: (lambda mod, fn: lambda: getattr(modules[mod], fn)(quick))(
            mod, fn)
        for name, (mod, fn, _tags) in BENCHES.items()
    }
    if args.only:
        wanted, unknown = resolve_only(args.only.split(","))
        if unknown:
            ap.error(f"unknown bench(es)/tag(s) {unknown}; have "
                     f"{sorted(benches)} and tags {bench_tags()}")
    else:
        wanted = list(benches)
    # the probe always runs (first, before the machine heats up caches
    # differently per bench subset) so every artifact is normalizable
    wanted = ["probe"] + [w for w in wanted if w != "probe"]
    benches["probe"] = runner_speed_probe

    print("name,us_per_call,derived")
    results = {}
    ok = True
    for name in wanted:
        try:
            for row in benches[name]():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
                results[row[0]] = {"us_per_call": float(row[1]),
                                   "derived": str(row[2]),
                                   "status": "ok"}
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            ok = False
            # the ERROR row goes to the CSV (so graders see it in-band)
            # AND to stderr with the full traceback (so CI logs show
            # *where* it failed instead of a swallowed repr)
            print(f"{name},NaN,ERROR:{e!r}")
            print(f"{name},NaN,ERROR:{e!r}", file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
            sys.stderr.flush()
            # ``status`` is the machine-readable failure flag: CI gates
            # on it instead of grepping "ERROR" out of the CSV (a bench
            # *name or derived text* containing ERROR must not trip it)
            results[name] = {"us_per_call": None, "derived": f"ERROR:{e!r}",
                             "status": "error"}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
