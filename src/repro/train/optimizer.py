"""AdamW (pure JAX, shardable) + schedules + global-norm clipping.

Implemented from scratch (no optax dependency): ``init`` builds f32
moment/master trees shaped like the params; ``update`` is fully
elementwise, so XLA SPMD lays the optimizer out under whatever shardings
the trainer assigns — with ZeRO-1 the moments are additionally sharded
over the data axes and XLA inserts the reduce-scatter / all-gather pair
automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array  # ()
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float | None = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu), gnorm


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor_frac + (1 - floor_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def constant_schedule(lr_val: float) -> Callable:
    return lambda step: jnp.full((), lr_val, jnp.float32)
