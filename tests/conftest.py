"""Shared test plumbing.

``run_distributed`` is the single place that builds the forced-host-
device environment for distributed subprocess tests: the 8-device
``XLA_FLAGS`` goes into the *child's environment* (previously every
snippet carried its own fragile ``os.environ["XLA_FLAGS"] = ...`` line
that had to run before the first jax import), and a prologue asserts
the 8-device view actually materialized — a snippet silently running on
1 device would pass every parity check without testing a collective.
"""
import os
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

#: Device count every distributed subprocess test sees (the CI ``dist``
#: lane forces the same number for the in-process tests it runs).
DEVICE_COUNT = 8


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (subprocess / multi-device)")


def run_distributed(code: str, timeout=600, device_count: int = DEVICE_COUNT):
    """Run ``code`` in a subprocess seeing ``device_count`` forced host
    devices; asserts the device view before the snippet runs and a zero
    exit code after.  Returns the child's stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={device_count}")
    prologue = (
        "import jax\n"
        f"assert jax.device_count() == {device_count}, (\n"
        f"    'forced host devices did not materialize: '\n"
        f"    f'{{jax.device_count()}} != {device_count}')\n")
    r = subprocess.run([sys.executable, "-c", prologue + code],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout
