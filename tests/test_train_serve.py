"""End-to-end trainer + serving engine tests on a tiny model (CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.data.synthetic import ShardedTokenStream
from repro.models import get_model
from repro.serve.engine import Request, ServeEngine
from repro.train.optimizer import AdamW, constant_schedule
from repro.train.train_step import init_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_config(ARCHS["qwen2-7b"])
    api = get_model(cfg)
    return cfg, api


def test_loss_decreases(tiny, tmp_path):
    cfg, api = tiny
    opt = AdamW(lr=constant_schedule(3e-3), weight_decay=0.0)
    data = ShardedTokenStream(cfg.vocab_size, 32, 8, seed=0)
    tr = Trainer(api, opt, iter(data), ckpt_dir=tmp_path,
                 tcfg=TrainerConfig(total_steps=30, ckpt_every=10,
                                    log_every=100))
    state = tr.init_or_restore(jax.random.PRNGKey(0))
    state = tr.run(state)
    losses = tr.losses()
    assert losses[-5:].mean() < losses[:5].mean() - 0.1, losses
    assert tr.ckpt.all_steps()  # checkpoints written


def test_checkpoint_restart_continuity(tiny, tmp_path):
    cfg, api = tiny
    opt = AdamW(lr=constant_schedule(1e-3), weight_decay=0.0)
    data = ShardedTokenStream(cfg.vocab_size, 32, 8, seed=0)
    tr = Trainer(api, opt, iter(data), ckpt_dir=tmp_path,
                 tcfg=TrainerConfig(total_steps=10, ckpt_every=5,
                                    log_every=100))
    state = tr.init_or_restore(jax.random.PRNGKey(0))
    tr.run(state)
    # second trainer restores from step 10 and continues
    data2 = ShardedTokenStream(cfg.vocab_size, 32, 8, seed=0)
    tr2 = Trainer(api, opt, iter(data2), ckpt_dir=tmp_path,
                  tcfg=TrainerConfig(total_steps=12, ckpt_every=5,
                                     log_every=100))
    state2 = tr2.init_or_restore(jax.random.PRNGKey(1))
    assert int(state2.opt.step) == 10
    state2 = tr2.run(state2)
    assert int(state2.opt.step) == 12


def test_microbatch_equivalence(tiny):
    """grad accumulation over 2 microbatches == full-batch step (same loss
    trajectory within fp tolerance)."""
    cfg, api = tiny
    opt = AdamW(lr=constant_schedule(1e-3), weight_decay=0.0,
                clip_norm=None)
    state = init_state(api, opt, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab_size, jnp.int32)}
    s1 = jax.jit(make_train_step(api, opt))(state, batch)[0]
    s2 = jax.jit(make_train_step(api, opt, microbatches=2))(state, batch)[0]
    w1 = np.asarray(jax.tree.leaves(s1.params)[0], np.float32)
    w2 = np.asarray(jax.tree.leaves(s2.params)[0], np.float32)
    np.testing.assert_allclose(w1, w2, rtol=5e-4, atol=5e-5)


def test_grad_compression_step_runs(tiny):
    cfg, api = tiny
    opt = AdamW(lr=constant_schedule(1e-3))
    state = init_state(api, opt, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size, jnp.int32)}
    step = jax.jit(make_train_step(api, opt, grad_compression="int8"))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_serve_engine_completes(tiny):
    cfg, api = tiny
    params = api.init(jax.random.PRNGKey(0))
    eng = ServeEngine(api, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, 8,
                                               dtype=np.int32),
                           max_new_tokens=4))
    results = eng.run_to_completion(max_steps=50)
    assert sorted(results) == [0, 1, 2]
    assert all(len(v) == 4 for v in results.values())
    assert all(0 <= t < cfg.vocab_size for v in results.values() for t in v)


def test_moe_pallas_dispatch_matches_einsum():
    """The Pallas grouped-matmul MoE path must equal the einsum path."""
    from repro.models.moe import apply_moe, init_moe

    cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"])
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    out_e, aux_e = apply_moe(cfg, p, x, None)
    cfg_p = cfg.scaled(moe_pallas_dispatch=True)
    out_p, aux_p = apply_moe(cfg_p, p, x, None)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_p),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_e), float(aux_p), rtol=1e-5)
