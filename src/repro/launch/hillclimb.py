"""§Perf hillclimb driver: re-run selected cells with optimization
variants and print before/after roofline terms.

    PYTHONPATH=src python -m repro.launch.hillclimb [--cell arch:shape:tag]
    PYTHONPATH=src python -m repro.launch.hillclimb --spmm [--n-dense 4]
    PYTHONPATH=src python -m repro.launch.hillclimb --moe
    PYTHONPATH=src python -m repro.launch.hillclimb --attention
    PYTHONPATH=src python -m repro.launch.hillclimb --dist

``--moe`` does the same for the MoE grouped-matmul dispatch space
(token_tile × capacity × f_tile × d_tile, keyed by the expert-segment
histogram) — populating the per-backend cache ahead of serving.
``--attention`` covers the fused-attention tuner (fwd and bwd records),
and ``--dist`` the joint collective × tiling × value-dtype distributed
SpMM search — together the four flags pre-warm every tuner surface the
serving resolvers replay.

``--spmm`` hillclimbs *schedules* instead of cfg knobs: it runs the
empirical autotuner (``repro.tune``) over the synthetic matrix suite,
consulting and populating the persistent fingerprint cache
(``REPRO_TUNE_CACHE``) — a second run replays every cell for free, and
serving (``ServeEngine.spmm``) picks the tuned schedules up from the
same cache.  Prints auto (static selector) vs tuned wall clock per cell.

Variants for the roofline mode are cfg-level knobs (tags):
    sp        seq_parallel_attn=True (Megatron-SP attention)
    inplace   decode_inplace_cache=True (fori_loop cache, no double buffer)
    mb16      microbatches=16
    nochunkkv kv_chunk=2048 (bigger flash kv tiles)

The roofline mode imports ``.dryrun``, which forces a 512-device host
platform *at import* — that is why it is imported lazily per mode:
``--spmm`` must measure under the same single-device XLA environment the
serving process that replays the cache will run under.
"""
import argparse
import json

VARIANTS = {
    "sp": {"overrides": {"seq_parallel_attn": True}},
    "gc_bf16": {"grad_compression": "bf16"},
    "sp_gc": {"overrides": {"seq_parallel_attn": True},
              "grad_compression": "bf16"},
    "sp_mb4": {"overrides": {"seq_parallel_attn": True}, "microbatches": 4},
    "inplace": {"overrides": {"decode_inplace_cache": True}},
    "sp_inplace": {"overrides": {"seq_parallel_attn": True,
                                 "decode_inplace_cache": True}},
    "mb16": {"microbatches": 16},
    "kv2048": {"overrides": {"kv_chunk": 2048}},
}

# The three hillclimbed cells (chosen per assignment criteria from the
# baseline grid):
#   qwen3-moe train_4k      — most representative of the paper's technique
#                             (segment-group MoE dispatch) + memory-dom
#                             with useful=0.07 (attention replication);
#   deepseek prefill_32k    — most collective-bound (coll/mem = 2.8);
#   deepseek decode_32k     — decode memory floor (cache double-buffer).
# See EXPERIMENTS.md §Perf for the full hypothesis->measure log.
DEFAULT_PLAN = [
    ("qwen3-moe-235b-a22b", "train_4k", ["sp"]),
    ("deepseek-coder-33b", "prefill_32k", ["sp", "kv2048"]),
    ("deepseek-coder-33b", "decode_32k", ["inplace"]),
    ("qwen2-7b", "train_4k", ["sp", "gc_bf16", "sp_gc"]),
]


def compare(arch, shape, tag):
    from .dryrun import OUT_DIR

    base = json.loads(
        (OUT_DIR / f"{arch}__{shape}__16x16.json").read_text())
    opt = json.loads(
        (OUT_DIR / f"{arch}__{shape}__16x16__{tag}.json").read_text())
    print(f"--- {arch} × {shape} [{tag}] ---")
    for key in ("compute", "memory", "collective"):
        b, o = base["terms_s"][key], opt["terms_s"][key]
        print(f"  {key:10s} {b * 1e3:9.1f} ms -> {o * 1e3:9.1f} ms "
              f"({b / max(o, 1e-12):.2f}x)")
    tb = base["per_chip"]["temp_bytes"] / 1e9
    to = opt["per_chip"]["temp_bytes"] / 1e9
    print(f"  temp       {tb:9.2f} GB -> {to:9.2f} GB")
    print(f"  frac       {base['roofline_fraction']:.4f} -> "
          f"{opt['roofline_fraction']:.4f}")


def spmm_hillclimb(n_dense: int = 4, quick: bool = True):
    """Tune schedules for the synthetic suite through the persistent
    cache; print auto-vs-tuned per cell and the geomean win."""
    import numpy as np

    from repro.core import Schedule
    from repro.sparse import matrix_stats, random_csr
    from repro.tune import default_cache, measure_schedule, tune_schedule

    cache = default_cache()
    cells = [(1024 if quick else 4096, d, s)
             for d in (0.002, 0.01) for s in (0.0, 1.5)]
    wins = []
    for m, d, s in cells:
        csr = random_csr(m, m, density=d, skew=s, seed=int(s * 10))
        res = tune_schedule(csr, n_dense, cache=cache)
        auto = Schedule.auto(matrix_stats(csr), n_dense)
        t_auto = measure_schedule(csr, n_dense, auto) * 1e6
        wins.append(t_auto / max(res.us_per_call, 1e-9))
        src = "cache" if res.from_cache else f"{res.n_measurements} meas"
        print(f"--- spmm {m}x{m} d={d} skew={s} N={n_dense} [{src}] ---")
        print(f"  auto  {auto}: {t_auto:9.1f} us")
        print(f"  tuned {res.schedule}: {res.us_per_call:9.1f} us "
              f"({wins[-1]:.2f}x)")
    print(f"geomean tuned-vs-auto: "
          f"{float(np.exp(np.mean(np.log(np.maximum(wins, 1e-9))))):.3f}x "
          f"({len(cache)} records in {cache.path})")


def moe_hillclimb(quick: bool = True):
    """Tune MoE dispatch schedules for representative expert histograms
    (balanced and skewed routing) through the persistent per-backend
    cache; print default-vs-tuned per cell and the geomean win.  Serving
    (``ServeEngine.moe_dispatch_schedule``) picks the results up from
    the same cache with zero measurements."""
    import numpy as np

    from repro.configs import ARCHS, smoke_config
    from repro.models.moe import (balanced_expert_lengths, default_dispatch,
                                  moe_tune_dispatch, skewed_expert_lengths)
    from repro.tune import default_cache
    from repro.tune.moe import measure_moe_dispatch, moe_schedule_key

    cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"]).scaled(
        d_model=128 if quick else 256, moe_d_ff=128 if quick else 512,
        n_experts=8, experts_per_token=2)
    cache = default_cache()
    cells = []
    for t in ((512,) if quick else (512, 2048)):
        cells.append((f"balanced_t{t}", t, balanced_expert_lengths(cfg, t)))
        cells.append((f"skewed_t{t}", t, skewed_expert_lengths(cfg, t)))

    wins = []
    for name, t, lengths in cells:
        res = moe_tune_dispatch(cfg, t, expert_lengths=lengths, cache=cache)
        base = default_dispatch(cfg)
        # the default is always in the tuner's measured pool; only a
        # cache-hit replay (which measured nothing) times it afresh
        t_base = res.measured.get(moe_schedule_key(base))
        if t_base is None:
            t_base = measure_moe_dispatch(
                lengths, cfg.d_model, cfg.moe_d_ff, base,
                dtype=str(cfg.param_dtype), max_tokens=t) * 1e6
        wins.append(t_base / max(res.us_per_call, 1e-9))
        src = "cache" if res.from_cache else f"{res.n_measurements} meas"
        print(f"--- moe {name} E={cfg.n_experts} D={cfg.d_model} "
              f"F={cfg.moe_d_ff} [{src}] ---")
        print(f"  default {base}: {t_base:9.1f} us")
        print(f"  tuned   {res.schedule}: {res.us_per_call:9.1f} us "
              f"({wins[-1]:.2f}x)")
    print(f"geomean tuned-vs-default: "
          f"{float(np.exp(np.mean(np.log(np.maximum(wins, 1e-9))))):.3f}x "
          f"({len(cache)} records in {cache.path})")


def attention_hillclimb(quick: bool = True):
    """Tune the fused-attention kernels (fwd and bwd) for representative
    sparsity patterns through the persistent per-backend cache, so
    training/serving loops replay them measurement-free."""
    import jax
    import numpy as np

    from repro.sparse import random_csr
    from repro.tune import default_cache, tune_sparse_attention

    cache = default_cache()
    n = 256 if quick else 1024
    d = dv = 16 if quick else 64
    cells = [("uniform", 0.0), ("skewed", 1.5)]
    for name, skew in cells:
        coo = random_csr(n, n, density=0.05, skew=skew,
                         seed=int(skew * 10)).tocoo()
        kq = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(kq[0], (n, d))
        k = jax.random.normal(kq[1], (n, d))
        v = jax.random.normal(kq[2], (n, dv))
        for direction in ("fwd", "bwd"):
            res = tune_sparse_attention(
                np.asarray(coo.rows), np.asarray(coo.cols), q, k, v,
                n_rows=n, direction=direction, cache=cache)
            src = ("cache" if res.from_cache
                   else f"{res.n_measurements} meas")
            print(f"--- attn {name} {n}x{n} d={d} {direction} [{src}] ---")
            print(f"  tuned {res.schedule}: {res.us_per_call:9.1f} us")
    print(f"({len(cache)} records in {cache.path})")


def dist_hillclimb(n_dense: int = 4, quick: bool = True):
    """Joint collective × tiling × value-dtype tuning for sharded SpMM
    on the local mesh (§14's joint axis search), populating the same
    per-backend cache ``dist_spmm(..., schedule='tune')`` and
    ``ServeEngine.prepare_dist`` replay from."""
    from repro.launch.mesh import make_reduction_mesh
    from repro.sparse import random_csr
    from repro.tune import default_cache, tune_dist_spmm

    cache = default_cache()
    mesh = make_reduction_mesh()
    axis_size = int(mesh.shape["shards"])
    n = 512 if quick else 2048
    for d in (0.002, 0.01):
        csr = random_csr(n, n, density=d, seed=7)
        res = tune_dist_spmm(csr, n_dense, mesh=mesh, axis="shards",
                             cache=cache)
        src = "cache" if res.from_cache else f"{res.n_measurements} meas"
        print(f"--- dist {n}x{n} d={d} mesh={axis_size} [{src}] ---")
        print(f"  tuned {res.schedule}: {res.us_per_call:9.1f} us "
              f"(collective={res.schedule.collective}, "
              f"value_dtype={res.schedule.value_dtype})")
    print(f"({len(cache)} records in {cache.path})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=None,
                    help="arch:shape:tag (repeatable)")
    ap.add_argument("--spmm", action="store_true",
                    help="hillclimb sparse schedules via the autotuner "
                         "(populates the persistent tuner cache)")
    ap.add_argument("--moe", action="store_true",
                    help="tune MoE grouped-matmul dispatch schedules "
                         "(populates the same per-backend tuner cache)")
    ap.add_argument("--attention", action="store_true",
                    help="tune the fused attention kernels (fwd+bwd) so "
                         "training/serving replay measurement-free")
    ap.add_argument("--dist", action="store_true",
                    help="joint collective × dtype tuning for sharded "
                         "SpMM on the local mesh")
    ap.add_argument("--n-dense", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    if args.spmm:
        spmm_hillclimb(args.n_dense, quick=not args.full)
        return
    if args.moe:
        moe_hillclimb(quick=not args.full)
        return
    if args.attention:
        attention_hillclimb(quick=not args.full)
        return
    if args.dist:
        dist_hillclimb(args.n_dense, quick=not args.full)
        return

    # roofline mode: importing .dryrun forces the 512-device host platform
    from .dryrun import run_cell

    plan = []
    if args.cell:
        for c in args.cell:
            arch, shape, tag = c.split(":")
            plan.append((arch, shape, [tag]))
    else:
        plan = DEFAULT_PLAN

    for arch, shape, tags in plan:
        for tag in tags:
            v = VARIANTS[tag]
            run_cell(arch, shape, multi_pod=False,
                     overrides=v.get("overrides"),
                     microbatches=v.get("microbatches", 8),
                     grad_compression=v.get("grad_compression"), tag=tag)
            try:
                compare(arch, shape, tag)
            except FileNotFoundError:
                print(f"(no baseline for {arch} × {shape} yet)")


if __name__ == "__main__":
    main()
