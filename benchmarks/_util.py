"""Benchmark utilities: the shared measurement layer + the synthetic
matrix suite.

``time_fn`` and the schedule runners moved into ``repro.tune.measure``
(ISSUE 2) so the autotuner and the paper-table benchmarks time schedules
with the same instrument; they are re-exported here so existing
benchmark code keeps importing from ``benchmarks._util``.  Timing is
XLA-CPU wall clock (this container's only real backend) — relative
schedule effects track the paper's axes, absolute numbers are
CPU-specific (DESIGN.md changed assumption 5).  ``REPRO_BENCH_ITERS``
bounds the per-measurement iteration count (CI smoke sets it low).
"""
from __future__ import annotations

import numpy as np

from repro.tune.measure import (  # noqa: F401
    bench_iters,
    make_eb_runner,
    make_rb_runner,
    make_runner,
    measure_schedule,
    time_fn,
)


def geomean(xs) -> float:
    xs = np.asarray(list(xs), np.float64)
    return float(np.exp(np.mean(np.log(xs))))


def suite(sizes=((4096, 4096),), densities=(0.001, 0.01),
          skews=(0.0, 1.0, 2.0), seed: int = 0):
    """The synthetic matrix suite (stands in for the paper's SuiteSparse
    selection — DESIGN.md changed assumption 5)."""
    from repro.sparse import random_csr

    mats = []
    for (m, n) in sizes:
        for d in densities:
            for s in skews:
                mats.append(((m, n, d, s),
                             random_csr(m, n, density=d, skew=s,
                                        seed=seed + int(s * 10))))
    return mats
