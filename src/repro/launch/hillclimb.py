import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
"""§Perf hillclimb driver: re-run selected cells with optimization
variants and print before/after roofline terms.

    PYTHONPATH=src python -m repro.launch.hillclimb [--cell arch:shape:tag]

Variants are cfg-level knobs (tags):
    sp        seq_parallel_attn=True (Megatron-SP attention)
    inplace   decode_inplace_cache=True (fori_loop cache, no double buffer)
    mb16      microbatches=16
    nochunkkv kv_chunk=2048 (bigger flash kv tiles)
"""
import argparse
import json
import pathlib

from .dryrun import OUT_DIR, run_cell

VARIANTS = {
    "sp": {"overrides": {"seq_parallel_attn": True}},
    "gc_bf16": {"grad_compression": "bf16"},
    "sp_gc": {"overrides": {"seq_parallel_attn": True},
              "grad_compression": "bf16"},
    "sp_mb4": {"overrides": {"seq_parallel_attn": True}, "microbatches": 4},
    "inplace": {"overrides": {"decode_inplace_cache": True}},
    "sp_inplace": {"overrides": {"seq_parallel_attn": True,
                                 "decode_inplace_cache": True}},
    "mb16": {"microbatches": 16},
    "kv2048": {"overrides": {"kv_chunk": 2048}},
}

# The three hillclimbed cells (chosen per assignment criteria from the
# baseline grid):
#   qwen3-moe train_4k      — most representative of the paper's technique
#                             (segment-group MoE dispatch) + memory-dom
#                             with useful=0.07 (attention replication);
#   deepseek prefill_32k    — most collective-bound (coll/mem = 2.8);
#   deepseek decode_32k     — decode memory floor (cache double-buffer).
# See EXPERIMENTS.md §Perf for the full hypothesis->measure log.
DEFAULT_PLAN = [
    ("qwen3-moe-235b-a22b", "train_4k", ["sp"]),
    ("deepseek-coder-33b", "prefill_32k", ["sp", "kv2048"]),
    ("deepseek-coder-33b", "decode_32k", ["inplace"]),
    ("qwen2-7b", "train_4k", ["sp", "gc_bf16", "sp_gc"]),
]


def compare(arch, shape, tag):
    base = json.loads(
        (OUT_DIR / f"{arch}__{shape}__16x16.json").read_text())
    opt = json.loads(
        (OUT_DIR / f"{arch}__{shape}__16x16__{tag}.json").read_text())
    print(f"--- {arch} × {shape} [{tag}] ---")
    for key in ("compute", "memory", "collective"):
        b, o = base["terms_s"][key], opt["terms_s"][key]
        print(f"  {key:10s} {b * 1e3:9.1f} ms -> {o * 1e3:9.1f} ms "
              f"({b / max(o, 1e-12):.2f}x)")
    tb = base["per_chip"]["temp_bytes"] / 1e9
    to = opt["per_chip"]["temp_bytes"] / 1e9
    print(f"  temp       {tb:9.2f} GB -> {to:9.2f} GB")
    print(f"  frac       {base['roofline_fraction']:.4f} -> "
          f"{opt['roofline_fraction']:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=None,
                    help="arch:shape:tag (repeatable)")
    args = ap.parse_args()

    plan = []
    if args.cell:
        for c in args.cell:
            arch, shape, tag = c.split(":")
            plan.append((arch, shape, [tag]))
    else:
        plan = DEFAULT_PLAN

    for arch, shape, tags in plan:
        for tag in tags:
            v = VARIANTS[tag]
            run_cell(arch, shape, multi_pod=False,
                     overrides=v.get("overrides"),
                     microbatches=v.get("microbatches", 8),
                     grad_compression=v.get("grad_compression"), tag=tag)
            try:
                compare(arch, shape, tag)
            except FileNotFoundError:
                print(f"(no baseline for {arch} × {shape} yet)")


if __name__ == "__main__":
    main()
