"""Differentiable SpMM — the backward pass closes the paper's algebra
family on itself:

    out = A @ B            (SpMM,  Eq. 2d)
    dvals = SDDMM(dOut, B) (Eq. 2c: dA[i,j] = <dOut[i,:], B[j,:]>)
    dB    = Aᵀ @ dOut      (SpMM with rows/cols swapped — unsorted row
                            stream, which the segment-group kernel
                            handles by opening extra runs)

``make_spmm`` closes over the (static) sparsity pattern and returns a
custom-vjp function of (vals, b), so GNN training differentiates through
the same kernels the forward uses.
"""
from __future__ import annotations

import jax

from ..kernels import ref


def make_spmm(rows, cols, n_rows: int, n_cols: int, *, impl: str = "ref",
              schedule=None, interpret: bool = True):
    """Returns spmm_fn(vals, b) -> (n_rows, b.shape[1]) differentiable in
    vals and b. rows/cols: (nnz,) int32 (row-sorted preferred)."""

    def _fwd_impl(vals, b):
        if impl == "pallas":
            from ..core.schedule import Schedule, as_schedule
            from ..kernels.ops import spmm as kspmm
            from .formats import GroupedCOO

            sched = (as_schedule(schedule) if schedule is not None
                     else Schedule("eb", nnz_tile=64, col_tile=8,
                                   group_size=8))
            g = GroupedCOO(rows=rows, cols=cols, vals=vals,
                           shape=(n_rows, n_cols), nnz=vals.shape[0],
                           nnz_tile=vals.shape[0])
            return kspmm(g, b, sched, interpret=interpret)
        return ref.spmm_coo_ref(rows, cols, vals, b, n_rows)

    @jax.custom_vjp
    def _spmm_fn(vals, b):
        return _fwd_impl(vals, b)

    def _fwd(vals, b):
        return _fwd_impl(vals, b), (vals, b)

    def _bwd(res, dout):
        vals, b = res
        # dA values: sampled dense-dense product at the sparsity pattern
        dvals = ref.sddmm_ref(rows, cols, dout, b).astype(vals.dtype)
        # dB: transpose SpMM (cols become the segment ids)
        db = ref.spmm_coo_ref(cols, rows, vals, dout, n_cols).astype(b.dtype)
        return dvals, db

    _spmm_fn.defvjp(_fwd, _bwd)
    return _spmm_fn
