"""Synthetic token pipeline: deterministic, shardable, host-partitioned.

``ShardedTokenStream`` yields fixed-shape batches; each data-parallel host
draws a disjoint slice of the global batch (by host index), the standard
multi-host input layout. A Zipf-ish unigram distribution gives non-uniform
token statistics so losses move realistically during the example runs.
"""
from __future__ import annotations

import numpy as np


class ShardedTokenStream:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, host_index: int = 0, host_count: int = 1, seed: int = 0,
                 zipf_a: float = 1.2):
        assert global_batch % host_count == 0
        self.vocab = vocab_size
        self.seq = seq_len
        self.local_batch = global_batch // host_count
        self.rng = np.random.default_rng(seed * 1000003 + host_index)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** -zipf_a
        self.p = p / p.sum()
        self._step = 0

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        self._step += 1
        tokens = self.rng.choice(
            self.vocab, size=(self.local_batch, self.seq), p=self.p
        ).astype(np.int32)
        return {"tokens": tokens}

    def state(self) -> dict:
        """Checkpointable pipeline position."""
        return {"step": self._step,
                "bit_generator": self.rng.bit_generator.state}

    def restore(self, state: dict):
        self._step = state["step"]
        self.rng.bit_generator.state = state["bit_generator"]
