"""Generate the EXPERIMENTS.md §Dry-run + §Roofline tables from the
dry-run JSON records.

    PYTHONPATH=src python -m repro.roofline.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ARCH_ORDER = [
    "starcoder2-7b", "deepseek-coder-33b", "yi-34b", "qwen2-7b",
    "paligemma-3b", "mamba2-2.7b", "qwen3-moe-235b-a22b", "dbrx-132b",
    "hymba-1.5b", "whisper-large-v3",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

HINTS = {
    "compute": ("drop replicated attention flops (seq-parallel attention) "
                "or raise arithmetic intensity via larger per-chip tiles"),
    "memory": ("cut HBM traffic: fuse/raise remat granularity, quantize "
               "KV/grads, avoid cache double-buffering"),
    "collective": ("reshard to move bytes off the wire: reduce-scatter "
                   "instead of all-reduce, overlap with compute, compress"),
}


def load(dir_: pathlib.Path) -> dict:
    recs = {}
    for f in sorted(dir_.glob("*.json")):
        r = json.loads(f.read_text())
        key = (r["arch"], r["shape"], r.get("mesh", "skip"),
               r.get("tag") or "")
        recs[key] = r
    return recs


def fmt_si(x, unit=""):
    for div, suf in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")):
        if abs(x) >= div:
            return f"{x / div:.2f}{suf}{unit}"
    return f"{x:.2f}{unit}"


def roofline_table(recs, mesh: str) -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | model GFLOPs | useful | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh, ""))
            if r is None:
                skip = recs.get((a, s, "skip", ""))
                if skip is not None and mesh == "16x16":
                    lines.append(f"| {a} | {s} | — | — | — | skipped | — | "
                                 f"— | — | {skip['skipped'][:42]}… |")
                continue
            t = r["terms_s"]
            lines.append(
                f"| {a} | {s} | {t['compute'] * 1e3:.1f} | "
                f"{t['memory'] * 1e3:.1f} | {t['collective'] * 1e3:.1f} | "
                f"**{r['dominant']}** | "
                f"{fmt_si(r['model_flops_global'] / 1e9)} | "
                f"{r['useful_flops_ratio']:.2f} | "
                f"{r['roofline_fraction']:.3f} | "
                f"{HINTS[r['dominant']][:52]}… |")
    return "\n".join(lines)


def optimized_table(recs) -> str:
    lines = [
        "| arch | shape | variant | compute (ms) | memory (ms) | "
        "collective (ms) | temp GB | roofline frac (base -> opt) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, mesh, tag), r in sorted(recs.items()):
        if not tag or mesh != "16x16":
            continue
        base = recs.get((a, s, mesh, ""))
        t = r["terms_s"]
        bf = base["roofline_fraction"] if base else float("nan")
        lines.append(
            f"| {a} | {s} | {tag} | {t['compute'] * 1e3:.1f} | "
            f"{t['memory'] * 1e3:.1f} | {t['collective'] * 1e3:.1f} | "
            f"{(r['per_chip']['temp_bytes'] or 0) / 1e9:.1f} | "
            f"{bf:.3f} -> **{r['roofline_fraction']:.3f}** |")
    return "\n".join(lines)


def dryrun_table(recs, mesh: str) -> str:
    lines = [
        "| arch | shape | HLO GFLOP/chip | HLO GB/chip | coll GB/chip | "
        "top collectives | temp GB | args GB | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh, ""))
            if r is None:
                continue
            pc = r["per_chip"]
            colls = sorted(r["collectives"].items(),
                           key=lambda kv: -kv[1]["bytes"])[:2]
            cstr = "; ".join(f"{k}×{v['count']}({fmt_si(v['bytes'], 'B')})"
                             for k, v in colls) or "none"
            lines.append(
                f"| {a} | {s} | {pc['hlo_flops'] / 1e9:.0f} | "
                f"{pc['hlo_bytes'] / 1e9:.1f} | "
                f"{pc['collective_bytes'] / 1e9:.2f} | {cstr} | "
                f"{(pc['temp_bytes'] or 0) / 1e9:.1f} | "
                f"{(pc['arg_bytes'] or 0) / 1e9:.1f} | "
                f"{r.get('t_compile_s', 0):.0f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None)
    args = ap.parse_args()
    d = (pathlib.Path(args.dir) if args.dir else
         pathlib.Path(__file__).resolve().parents[3] / "experiments" /
         "dryrun")
    recs = load(d)
    for mesh in ("16x16", "2x16x16"):
        n = sum(1 for k in recs if k[2] == mesh and not k[3])
        print(f"\n### Roofline (baseline) — mesh {mesh} ({n} cells)\n")
        print(roofline_table(recs, mesh))
        print(f"\n### Dry-run detail — mesh {mesh}\n")
        print(dryrun_table(recs, mesh))
    print("\n### Optimized variants (§Perf, single-pod)\n")
    print(optimized_table(recs))


if __name__ == "__main__":
    main()
