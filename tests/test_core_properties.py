"""Property-based tests (hypothesis) for the core invariants:

1. segment_group_reduce == segment_sum for every group size / strategy
   (ACCUMULATE, SEGMENT) on arbitrary non-decreasing segment ids.
2. Atomic-parallelism legality rules match the paper's three rules.
3. Sparse format round-trips preserve the dense matrix exactly.
4. Zero extension: padding nnz with val=0 never changes SpMM output.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail collection
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (DA_SPMM_POINTS, AtomicParallelism, GroupReduceStrategy,
                        enumerate_space, is_legal, segment_group_reduce,
                        segment_sum_ref)
from repro.core.atomic_parallelism import Fraction
from repro.kernels import ref, spmm
from repro.core.atomic_parallelism import KernelSchedule
from repro.sparse import CSR, ELL, GroupedCOO, random_csr


@st.composite
def seg_problem(draw):
    n_groups = draw(st.integers(1, 6))
    g = draw(st.sampled_from([2, 4, 8, 16]))
    t = n_groups * g
    n_segs = draw(st.integers(1, 12))
    ids = sorted(draw(st.lists(st.integers(0, n_segs - 1),
                               min_size=t, max_size=t)))
    c = draw(st.integers(1, 5))
    seed = draw(st.integers(0, 2 ** 16))
    return g, np.asarray(ids, np.int32), n_segs, c, seed


@given(seg_problem())
@settings(max_examples=40, deadline=None)
def test_segment_group_reduce_equals_segment_sum(prob):
    g, ids, n_segs, c, seed = prob
    data = np.random.default_rng(seed).standard_normal(
        (len(ids), c)).astype(np.float32)
    want = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(ids),
                                      n_segs))
    for strat in (GroupReduceStrategy.SEGMENT, GroupReduceStrategy.ACCUMULATE):
        got = np.asarray(segment_group_reduce(
            jnp.asarray(data), jnp.asarray(ids), n_segs, group_size=g,
            strategy=strat))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(st.sampled_from(["nnz", "row"]),
       st.sampled_from([Fraction(1, 32), Fraction(1, 8), Fraction(1),
                        Fraction(8), Fraction(32)]),
       st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([1, 2, 4, 8, 16, 32]))
@settings(max_examples=60, deadline=None)
def test_legality_rules(split, x, c, r):
    p = AtomicParallelism(split, x, c, r)
    legal = is_legal(p)
    # Rule 1: fractional nnz illegal
    if split == "nnz" and x < 1:
        assert not legal
    # Rule 2: row collaboration needs r >= g
    if split == "row" and x < 1 and r < 1 / x:
        assert not legal
    if split == "nnz" and x >= 1:
        assert legal
    if split == "row" and (x >= 1 or r >= 1 / x):
        assert legal


def test_da_spmm_points_all_legal():
    for name, p in DA_SPMM_POINTS.items():
        assert is_legal(p), name


def test_enumerate_space_nonempty_and_legal():
    pts = enumerate_space()
    assert len(pts) > 50
    assert all(is_legal(p) for p in pts)


@given(st.integers(8, 40), st.integers(8, 40),
       st.floats(0.01, 0.3), st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_format_roundtrips(n_rows, n_cols, density, seed):
    csr = random_csr(n_rows, n_cols, density=density, seed=seed)
    dense = np.asarray(csr.todense())
    np.testing.assert_array_equal(
        np.asarray(GroupedCOO.fromcsr(csr, 16).todense()), dense)
    np.testing.assert_array_equal(
        np.asarray(ELL.fromcsr(csr).todense()), dense)
    np.testing.assert_array_equal(
        np.asarray(CSR.fromdense(dense).todense()), dense)


@given(st.integers(0, 10 ** 6), st.sampled_from([16, 64, 256]))
@settings(max_examples=10, deadline=None)
def test_zero_extension_invariance(seed, nnz_tile):
    """Padding the nnz stream (val=0) must never change the result —
    the paper's zero-extension legality argument."""
    csr = random_csr(40, 30, density=0.05, seed=seed)
    b = np.random.default_rng(seed).standard_normal((30, 8)).astype(np.float32)
    coo = csr.tocoo()
    want = np.asarray(ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals,
                                       jnp.asarray(b), 40))
    for tile in (nnz_tile, 2 * nnz_tile):
        got = np.asarray(spmm(
            csr, jnp.asarray(b),
            KernelSchedule("eb", nnz_tile=tile, col_tile=8, group_size=8)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rule3_unrepresentable():
    with pytest.raises(ValueError):
        AtomicParallelism("row", Fraction(1, 4), 0, 8)
