"""Yi-34B [arXiv:2403.04652]: llama-arch dense GQA."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab_size=64000,
    norm="rmsnorm", mlp_type="swiglu", rope_theta=5e6,
)
