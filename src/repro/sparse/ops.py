"""The single public sparse API: schedule coercion + kernel dispatch.

``spmm``, ``sddmm`` and ``segment_reduce`` all accept ``schedule=`` as a
name ('EB+PR', ...), a :class:`~repro.core.schedule.Schedule`, an
:class:`~repro.core.AtomicParallelism` point, or a
:class:`~repro.core.SegmentGroup`.  ``spmm`` additionally accepts
``'auto'`` (the data-aware selector — the paper's Table-5 "dynamic
choice" made a library default); the other ops have no matrix to derive
statistics from, so ``'auto'`` raises there.

``spmm`` over CSR is differentiable: the forward runs the scheduled
Pallas kernel, the backward closes the paper's algebra family on itself
(dvals = SDDMM(dOut, B), dB = Aᵀ·dOut — Sgap Eq. 2c/2d).  Feed-format
conversions go through the per-(format, tile) cache on ``CSR``, so a
training loop re-using the same matrix does not re-convert every step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.schedule import Schedule, as_schedule
from ..kernels import ops as kops
from ..kernels import ref
from ..kernels.segment_reduce import segment_reduce as _segment_reduce_kernel
from .formats import CSR, ELL, GroupedCOO
from .random import matrix_stats

__all__ = ["spmm", "sddmm", "segment_reduce"]


def _resolve_schedule(a, b, schedule) -> Schedule:
    if isinstance(schedule, str) and schedule in ("auto", "tune"):
        if not isinstance(a, CSR):
            # no CSR to derive statistics (or a fingerprint) from
            return Schedule("eb")
        if schedule == "tune":
            from ..tune import tune_schedule

            return tune_schedule(a, int(b.shape[1])).schedule
        return Schedule.auto(matrix_stats(a), int(b.shape[1]))
    return as_schedule(schedule)


def spmm(a, b, schedule="auto", *, impl: str = "pallas",
         interpret: bool = True):
    """out = A @ B for sparse A (CSR / GroupedCOO / ELL) and dense B.

    schedule    'auto' | 'tune' | name | Schedule | AtomicParallelism |
                SegmentGroup.  'tune' measures the top schedule
                candidates for this matrix (replaying the persistent
                fingerprint cache when it can — see ``repro.tune``).
    impl        'pallas' (scheduled kernel) or 'ref' (pure-jnp oracle).

    The CSR + pallas path is differentiable in ``a.vals`` and ``b``.
    """
    sched = _resolve_schedule(a, b, schedule)
    if impl != "ref" and isinstance(a, CSR):
        return _spmm_csr_diff(a, b, sched, interpret)
    return kops.spmm(a, b, sched, impl=impl, interpret=interpret)


def _spmm_csr_diff(a: CSR, b, sched: Schedule, interpret: bool):
    """Custom-VJP wrapper: scheduled kernel forward, ref backward."""
    coo = a.tocoo()  # cached on the CSR instance
    rows, cols = coo.rows, coo.cols
    n_rows, n_cols = a.shape

    if sched.kernel == "eb":
        g0 = a.grouped(sched.nnz_tile)
        pad = g0.nnz_padded - g0.nnz

        def run(vals, bb):
            vpad = jnp.concatenate(
                [vals, jnp.zeros((pad,), vals.dtype)]) if pad else vals
            g = GroupedCOO(rows=g0.rows, cols=g0.cols, vals=vpad,
                           shape=g0.shape, nnz=g0.nnz, nnz_tile=g0.nnz_tile)
            return kops.spmm(g, bb, sched, interpret=interpret)
    else:
        ell0 = a.ell(row_tile=sched.row_tile)
        rid, pos = a.ell_scatter_index()

        def run(vals, bb):
            evals = jnp.zeros(ell0.vals.shape,
                              vals.dtype).at[rid, pos].set(vals)
            e = ELL(cols=ell0.cols, vals=evals, shape=ell0.shape,
                    width=ell0.width)
            return kops.spmm(e, bb, sched, interpret=interpret)

    @jax.custom_vjp
    def fn(vals, bb):
        return run(vals, bb)

    def fwd(vals, bb):
        return run(vals, bb), (vals, bb)

    def bwd(res, dout):
        vals, bb = res
        # dA values: sampled dense-dense product at the sparsity pattern
        dvals = ref.sddmm_ref(rows, cols, dout, bb).astype(vals.dtype)
        # dB: transpose SpMM (cols become the segment ids)
        db = ref.spmm_coo_ref(cols, rows, vals, dout, n_cols).astype(bb.dtype)
        return dvals, db

    fn.defvjp(fwd, bwd)
    return fn(a.vals, b)


def sddmm(rows, cols, a, b, scale=None, *, schedule=None,
          nnz_tile: int | None = None, impl: str = "pallas",
          interpret: bool = True):
    """vals[t] = <A[rows[t]], B[cols[t]]> (* scale[t]); rows/cols (nnz,).

    ``schedule`` supplies the nnz tile (its ``nnz_tile`` field); an
    explicit ``nnz_tile=`` overrides it.  ``schedule="tune"`` reuses the
    tuner's winner for this nnz profile (SDDMM only exposes the tile
    axis, so the tuned ``nnz_tile`` is what transfers).
    """
    if schedule is not None and nnz_tile is None:
        if isinstance(schedule, str) and schedule == "tune":
            from ..tune import tune_segment_reduce

            nnz_tile = tune_segment_reduce(
                rows, int(a.shape[1]),
                num_segments=int(jnp.max(rows)) + 1).schedule.nnz_tile
        else:
            nnz_tile = as_schedule(schedule).nnz_tile
    return kops.sddmm(rows, cols, a, b, scale,
                      nnz_tile=nnz_tile if nnz_tile else 256,
                      impl=impl, interpret=interpret)


def segment_reduce(seg_ids, data, num_segments: int, schedule=None, *,
                   interpret: bool = True):
    """out[s] = Σ_{t: seg_ids[t]=s} data[t] through the segment-group
    kernel.  ``schedule`` carries (nnz_tile -> tile, group_size, strategy);
    ``schedule="tune"`` measures (tile, G, strategy) for this segment
    profile (cached by fingerprint); ragged inputs are zero-extended by
    the kernel wrapper."""
    if isinstance(schedule, str) and schedule == "tune":
        from ..tune import tune_segment_reduce

        sched = tune_segment_reduce(
            seg_ids, int(data.shape[1]), num_segments).schedule
    else:
        sched = as_schedule(schedule)
    return _segment_reduce_kernel(
        seg_ids, data, num_segments=num_segments, tile=sched.nnz_tile,
        group_size=sched.group_size, strategy=sched.strategy,
        interpret=interpret)
