"""PaliGemma-style VLM backbone (arXiv:2407.07726): SigLIP patch stub +
Gemma text decoder.

The vision frontend is a STUB per the assignment: ``input_specs`` provides
post-projection patch embeddings (B, n_vision_tokens, D). The decoder is
the shared transformer (MQA kv=1, GeGLU). PaliGemma's bidirectional
prefix attention is approximated as causal (DESIGN.md changed
assumptions); loss is computed on text positions only.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import transformer
from .layers import embed, lm_loss_from_features

init_params = transformer.init_params
init_cache = transformer.init_cache
decode_step = transformer.decode_step


def _embeds(cfg, params, batch):
    tok = embed(params["embed"], batch["tokens"]).astype(cfg.compute_dtype)
    patches = batch["patch_embeds"].astype(cfg.compute_dtype)
    return jnp.concatenate([patches, tok], axis=1)


def forward(cfg, params, batch, ctx=None):
    x = _embeds(cfg, params, batch)
    logits, aux = transformer.forward(cfg, params, None, ctx,
                                      inputs_embeds=x)
    return logits, aux


def loss_fn(cfg, params, batch, ctx=None):
    x, _ = transformer.forward_features(cfg, params, None, ctx,
                                        inputs_embeds=_embeds(cfg, params,
                                                              batch))
    nv = batch["patch_embeds"].shape[1]
    text_x = x[:, nv:]
    return lm_loss_from_features(params["embed"], text_x[:, :-1],
                                 batch["tokens"][:, 1:], batch.get("mask"))


def prefill(cfg, params, batch, max_len, ctx=None):
    x = _embeds(cfg, params, batch)
    return transformer.prefill(cfg, params, None, max_len, ctx,
                               inputs_embeds=x)
