"""Persistent tuning cache keyed by a workload fingerprint.

A *fingerprint* summarizes the statistics the schedule space actually
responds to — shape, nnz, row-length histogram quantiles and row-length
CV — so two matrices with the same sparsity *profile* share a tuning
record even if their patterns differ.  The same quantile machinery
fingerprints MoE expert-segment histograms (``tune.moe``): skewed
routing and balanced routing hash differently, which is exactly when the
profitable token tile / capacity changes.

The cache is **namespaced per backend + device kind**: timings never
transfer across backends, so instead of carrying the backend inside
every key, each ``backend-devicekind`` combination gets its *own* cache
file (``schedule_cache.<namespace>.json`` next to the configured path).
Fleets can then ship a pre-tuned cache file per TPU/GPU generation and
drop it in place.  A legacy single-file cache (schema written before the
namespacing, keys suffixed ``|<backend>``) is migrated transparently on
load: records whose backend component matches the namespace are folded
in under their stripped key, and persisted on the next ``save``.

Records serialize to JSON (base path ``REPRO_TUNE_CACHE`` or
``~/.cache/repro/schedule_cache.json``) with a schema version; a version
mismatch drops the file (stale-schema records silently re-tune rather
than crash).  ``ScheduleCache(path=None)`` is memory-only — used by
benchmarks and tests that must not touch the user's cache.  ``save()``
holds an ``fcntl.flock`` over the merge-and-rewrite so two processes
tuning against one file cannot interleave read-merge-write and drop
each other's records (no-op on platforms without ``fcntl``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import pathlib
import re
import tempfile
from typing import Dict, Optional

import numpy as np

from ..core import Schedule

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX
    fcntl = None

__all__ = [
    "MIGRATIONS",
    "SCHEMA_VERSION",
    "TuneRecord",
    "migrate_records",
    "ScheduleCache",
    "cache_key",
    "cache_namespace",
    "default_cache",
    "default_cache_path",
    "fingerprint",
    "fingerprint_from_lengths",
    "legacy_cache_path",
    "set_default_cache",
]

#: Current on-disk schema.  Bump it whenever the searched space or the
#: key format changes in a way that makes old winners unsound; register
#: a step in :data:`MIGRATIONS` saying how records of the *previous*
#: version move forward (``{}`` = drop-and-retune).
SCHEMA_VERSION = 4


def _drop_v1(records: dict) -> dict:
    """v1 → v2: Schedule gained split/merge thresholds (skew-aware
    two-level grouping, DESIGN.md §11).  Pre-skew winners were picked
    without the skew entry points in the pool, so they are dropped to
    re-tune against the enlarged space."""
    return {}


def _drop_v2(records: dict) -> dict:
    """v2 → v3: Schedule (and MoeDispatchSchedule) gained the mesh-level
    ``collective`` field (DESIGN.md §12).  Dropped so distributed
    workloads re-tune over the enlarged space instead of replaying a
    record that silently pins the wire mode to None."""
    return {}


def _drop_v3(records: dict) -> dict:
    """v3 → v4: Schedule gained the ``value_dtype`` axis (DESIGN.md
    §13).  Dropped so workloads re-tune with the dtype axis in the pool
    instead of replaying a record pinned to f32 storage."""
    return {}


#: version ``n`` → the step migrating raw JSON records from ``n`` to
#: ``n + 1``.  ``migrate_records`` chains steps until the current
#: version; an unregistered (unknown or future) version drops the file.
MIGRATIONS = {1: _drop_v1, 2: _drop_v2, 3: _drop_v3}


def migrate_records(version, records: dict) -> dict:
    """Chain :data:`MIGRATIONS` steps from ``version`` up to
    :data:`SCHEMA_VERSION` over raw (pre-``from_json``) record dicts.
    Unknown, corrupt, or future versions return ``{}`` — stale-schema
    records silently re-tune rather than crash."""
    if not isinstance(version, int) or isinstance(version, bool):
        return {}
    while version != SCHEMA_VERSION:
        step = MIGRATIONS.get(version)
        if step is None:
            return {}
        records = step(records)
        version = version + 1
    return records

_QUANTILES = (0.0, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0)


def fingerprint_from_lengths(lengths, shape, nnz: int) -> str:
    """Fingerprint from a row-length (or segment-length) histogram.

    Quantiles are rounded to ints and CV to 3 decimals: small pattern
    perturbations that cannot move the schedule choice hash identically,
    while skew/scale changes that do move it produce a fresh key.
    """
    lengths = np.asarray(lengths, np.float64)
    lengths = lengths[lengths > 0]
    if lengths.size:
        qs = [int(round(q)) for q in np.quantile(lengths, _QUANTILES)]
        mean = float(lengths.mean())
        cv = float(lengths.std() / mean) if mean > 0 else 0.0
    else:
        qs = [0] * len(_QUANTILES)
        cv = 0.0
    qstr = "-".join(str(q) for q in qs)
    return (f"m{shape[0]}x{shape[1]}_nnz{int(nnz)}"
            f"_cv{cv:.3f}_q{qstr}")


def fingerprint(csr) -> str:
    """Fingerprint of a :class:`~repro.sparse.formats.CSR` matrix.

    Memoized through the CSR's per-instance conversion cache (where it
    has one): the O(n_rows) histogram pass runs once per matrix, so
    serving-path lookups (``ServeEngine.spmm`` -> ``cached_or_auto``)
    cost a dict probe, not a device sync."""
    def _build():
        return fingerprint_from_lengths(
            np.asarray(csr.row_lengths()), csr.shape, csr.nnz)

    cached = getattr(csr, "_cached", None)
    return cached("fingerprint", _build) if cached is not None else _build()


def cache_key(csr, n_dense_cols: int) -> str:
    """Key of an SpMM tuning record *within* a namespace cache.

    The backend is **not** part of the key any more — it selects the
    cache file (:func:`cache_namespace`), so one file's records are
    mutually comparable by construction."""
    return f"{fingerprint(csr)}|N{int(n_dense_cols)}"


def cache_namespace(backend: str | None = None) -> str:
    """``backend`` or ``backend-devicekind`` namespace for the cache
    file, e.g. ``cpu``, ``tpu-v5e``, ``gpu-nvidia-a100``.  The device
    kind is folded in because timings do not transfer across hardware
    generations of one backend."""
    import jax

    if backend is None:
        backend = jax.default_backend()
    try:
        kind = jax.devices(backend)[0].device_kind
    except RuntimeError:
        # backend not initialisable here (e.g. naming a foreign backend
        # to pre-load its shipped cache): namespace on the name alone
        kind = backend
    kind = re.sub(r"[^a-z0-9]+", "-", str(kind).lower()).strip("-")
    backend = re.sub(r"[^a-z0-9]+", "-", str(backend).lower()).strip("-")
    if kind == backend or not kind:
        return backend
    if kind.startswith(backend + "-"):
        return kind
    return f"{backend}-{kind}"


def legacy_cache_path() -> pathlib.Path:
    """The pre-namespacing single-file location (``REPRO_TUNE_CACHE``
    itself, or the un-suffixed default path).  Only read for migration —
    never written."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return pathlib.Path(env)
    return (pathlib.Path(os.environ.get("XDG_CACHE_HOME",
                                        pathlib.Path.home() / ".cache"))
            / "repro" / "schedule_cache.json")


def default_cache_path(namespace: str | None = None) -> pathlib.Path:
    """Per-namespace cache file: the legacy base path with the namespace
    spliced in before the suffix (``tune.json`` -> ``tune.cpu.json``)."""
    base = legacy_cache_path()
    if namespace is None:
        namespace = cache_namespace()
    suffix = base.suffix or ".json"
    return base.with_name(f"{base.stem}.{namespace}{suffix}")


@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """One cached tuning outcome.  ``schedule`` is a
    :class:`~repro.core.Schedule` (SpMM / segment-reduce records), a
    :class:`~repro.tune.moe.MoeDispatchSchedule` (``moe:``-prefixed
    records), or a :class:`~repro.fuse.FuseDecision` (``fuse:``-prefixed
    planner records); serialization dispatches on a ``kind`` tag."""

    schedule: object
    us_per_call: float
    measured: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        """Serialize to a plain dict, tagging non-Schedule kinds (``moe``,
        ``fuse``) so ``from_json`` can reconstruct the right type."""
        from ..fuse.ir import FuseDecision
        from .moe import MoeDispatchSchedule

        d = {
            "schedule": dataclasses.asdict(self.schedule),
            "us_per_call": self.us_per_call,
            "measured": self.measured,
        }
        if isinstance(self.schedule, MoeDispatchSchedule):
            d["kind"] = "moe"
        elif isinstance(self.schedule, FuseDecision):
            d["kind"] = "fuse"
            d["schedule"] = {"fused": list(self.schedule.fused)}
        elif not isinstance(self.schedule, Schedule):
            raise TypeError(
                f"unserializable schedule type {type(self.schedule).__name__}"
                " (known kinds: Schedule, MoeDispatchSchedule, "
                "FuseDecision)")
        return d

    @staticmethod
    def from_json(d: dict) -> "TuneRecord":
        """Inverse of :meth:`to_json`; dispatches on the ``kind`` tag."""
        if d.get("kind") == "moe":
            from .moe import MoeDispatchSchedule

            sched = MoeDispatchSchedule(**d["schedule"])
        elif d.get("kind") == "fuse":
            from ..fuse.ir import FuseDecision

            sched = FuseDecision(fused=tuple(bool(b)
                                             for b in d["schedule"]["fused"]))
        else:
            sched = Schedule(**d["schedule"])
        return TuneRecord(schedule=sched,
                          us_per_call=float(d["us_per_call"]),
                          measured=dict(d.get("measured", {})))


@contextlib.contextmanager
def _file_lock(path: pathlib.Path):
    """Exclusive advisory lock on ``<path>.lock`` for the duration of the
    block (POSIX ``fcntl.flock``; silently a no-op where unavailable)."""
    if fcntl is None:
        yield
        return
    lock_path = path.with_name(path.name + ".lock")
    with open(lock_path, "a+") as f:
        try:
            fcntl.flock(f.fileno(), fcntl.LOCK_EX)
        except OSError:  # e.g. network FS without lock support
            yield
            return
        try:
            yield
        finally:
            fcntl.flock(f.fileno(), fcntl.LOCK_UN)


class ScheduleCache:
    """On-disk (or memory-only when ``path=None``) map of cache key ->
    :class:`TuneRecord`.  Load is lazy; ``save`` merges and writes
    atomically under a file lock.

    ``namespace``/``legacy_path`` make the cache a per-backend namespace
    file: on load, records from a pre-namespacing single-file cache whose
    key backend component matches the namespace are folded in (under the
    stripped key) so existing tuning work survives the layout change.

    Keys no longer carry the backend, so an *explicit-path* cache is
    single-backend by construction: sharing one file across
    heterogeneous hosts would let one backend's records replay on
    another.  Heterogeneous fleets use :func:`default_cache` (or one
    explicit path per :func:`cache_namespace`) — one pre-tuned file per
    hardware generation is the intended distribution unit.
    """

    def __init__(self, path: "os.PathLike | str | None" = ...,
                 *, namespace: str | None = None,
                 legacy_path: "os.PathLike | str | None" = None):
        if path is ...:
            path = default_cache_path(namespace)
        self.path = pathlib.Path(path) if path is not None else None
        self.namespace = namespace
        self.legacy_path = (pathlib.Path(legacy_path)
                            if legacy_path is not None else None)
        self._data: Dict[str, TuneRecord] = {}
        self._loaded = self.path is None

    # -- persistence -------------------------------------------------------

    def _read_records(self, path: pathlib.Path) -> Dict[str, TuneRecord]:
        out: Dict[str, TuneRecord] = {}
        if not path.exists():
            return out
        try:
            raw = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            return out
        records = raw.get("records", {})
        if raw.get("version") != SCHEMA_VERSION:
            # stale schema: run the migration chain (today every step is
            # drop-and-retune, so this empties the file; a future
            # rewriting step slots in via MIGRATIONS)
            records = migrate_records(raw.get("version"), records)
        if not isinstance(records, dict):
            return out
        for key, rec in records.items():
            try:
                out[key] = TuneRecord.from_json(rec)
            except (KeyError, TypeError, ValueError):
                continue  # one bad record must not poison the rest
        return out

    def _backend(self) -> str:
        """Backend whose legacy (``|<backend>``-suffixed) records this
        cache may adopt: the namespace's backend component, or — for an
        explicit-path cache with no namespace — the process backend
        (pre-namespacing files were written by the process that owned
        them, so its backend is the right owner for their records)."""
        if self.namespace is not None:
            return self.namespace.split("-", 1)[0]
        import jax

        return jax.default_backend()

    def _fold_legacy_keys(self, records: Dict[str, TuneRecord]) -> None:
        """Register pre-namespacing records (backend as the last ``|``
        key component) under their stripped key when the backend matches
        and the stripped key is still free — so old tuning work stays
        reachable through the new key format.  Idempotent: fresh-format
        records always win."""
        backend = self._backend()
        for key, rec in records.items():
            base, _, key_backend = key.rpartition("|")
            if base and key_backend == backend:
                self._data.setdefault(base, rec)

    def load(self) -> "ScheduleCache":
        """Read the backing file once (idempotent), folding in legacy
        pre-namespacing keys for this backend.  Returns self."""
        if self._loaded:
            return self
        self._loaded = True
        if self.path is None:
            return self
        own = self._read_records(self.path)
        self._data.update(own)
        # in-file migration (an explicit pre-namespacing cache path)...
        self._fold_legacy_keys(own)
        # ...and cross-file migration from the old shared single file
        # (left untouched on disk: other namespaces still need their
        # share of its records)
        if self.legacy_path is not None and self.legacy_path != self.path:
            self._fold_legacy_keys(self._read_records(self.legacy_path))
        return self

    def save(self) -> None:
        """Persist records atomically, merging with concurrent writers
        under an exclusive file lock (our own keys win)."""
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        # merge-on-save under an exclusive lock: another process sharing
        # this file may have persisted records since we loaded — fold the
        # on-disk state in (our own keys win) so concurrent tuners don't
        # drop each other's work, and lock so the read-merge-write itself
        # cannot interleave with another writer's.
        with _file_lock(self.path):
            merged = self._read_records(self.path)
            merged.update(self._data)
            self._data = merged
            payload = {"version": SCHEMA_VERSION,
                       "records": {k: r.to_json()
                                   for k, r in sorted(self._data.items())}}
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       prefix=self.path.name, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise

    # -- mapping -----------------------------------------------------------

    def get(self, key: str) -> Optional[TuneRecord]:
        """Record for ``key`` (schema-current records only), or None."""
        self.load()
        return self._data.get(key)

    def put(self, key: str, record: TuneRecord) -> None:
        """Insert/overwrite in memory; call :meth:`save` to persist."""
        self.load()
        self._data[key] = record

    def __len__(self) -> int:
        self.load()
        return len(self._data)

    def __contains__(self, key: str) -> bool:
        return self.get(key) is not None

    def keys(self):
        """All cached schedule keys (loads the backing file first)."""
        self.load()
        return self._data.keys()


_DEFAULT_CACHES: Dict[str, ScheduleCache] = {}
_OVERRIDE: Optional[ScheduleCache] = None


def default_cache(backend: str | None = None) -> ScheduleCache:
    """Process-wide cache for ``backend``'s namespace (default: the
    current JAX backend).  The path is re-resolved each call so
    ``REPRO_TUNE_CACHE`` changes — e.g. in tests — take effect."""
    if _OVERRIDE is not None:
        return _OVERRIDE
    ns = cache_namespace(backend)
    path = str(default_cache_path(ns))
    cache = _DEFAULT_CACHES.get(path)
    if cache is None:
        cache = _DEFAULT_CACHES[path] = ScheduleCache(
            path, namespace=ns, legacy_path=legacy_cache_path())
    return cache


def set_default_cache(cache: Optional[ScheduleCache]) -> None:
    """Override the default cache (``None`` restores path-based lookup)."""
    global _OVERRIDE
    _OVERRIDE = cache
