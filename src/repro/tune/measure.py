"""Measurement layer shared by the autotuner and the benchmark harness.

``time_fn`` is the single wall-clock timer in the repo — the paper-table
benchmarks (``benchmarks/_util``) re-export it from here, and the tuner
(``tune.search``) calls it directly, so a tuned number and a benchmarked
number come from the same instrument.  The iteration count is
env-tunable (``REPRO_BENCH_ITERS`` / ``REPRO_BENCH_WARMUP``) so CI smoke
runs can trade variance for wall time.

The schedule runners build a jitted pure-JAX analogue of each kernel
schedule — XLA compiles a genuinely different program per schedule point
(group size, strategy, tiling all change the compiled structure), so
relative effects track the paper's axes; absolute numbers are
backend-specific (DESIGN.md changed assumption 5).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import GroupReduceStrategy, Schedule, segment_group_reduce
from ..kernels import ref

__all__ = [
    "bench_iters",
    "bench_warmup",
    "time_fn",
    "make_eb_runner",
    "make_rb_runner",
    "make_runner",
    "make_dist_runner",
    "measure_schedule",
    "measure_dist_schedule",
]


def bench_iters(default: int = 7) -> int:
    """Timing iterations per measurement; override with REPRO_BENCH_ITERS
    (CI smoke sets a small value to stay under its time budget)."""
    return max(1, int(os.environ.get("REPRO_BENCH_ITERS", default)))


def bench_warmup(default: int = 2) -> int:
    """Warmup iterations per measurement; override with
    REPRO_BENCH_WARMUP (CI smoke lowers it to fit its time budget)."""
    return max(0, int(os.environ.get("REPRO_BENCH_WARMUP", default)))


def time_fn(fn, *args, warmup: int | None = None,
            iters: int | None = None, cap_env: bool = True) -> float:
    """Median seconds/call of a jitted fn (blocks on results).

    ``REPRO_BENCH_ITERS`` / ``REPRO_BENCH_WARMUP`` supply defaults and
    *cap* explicit arguments, so CI smoke bounds total bench time without
    touching call sites.  ``cap_env=False`` exempts a measurement from
    the caps — for fixed-workload yardsticks that must be comparable
    across runs (the ``probe/runner_speed`` row)."""
    if warmup is None:
        warmup = bench_warmup()
    elif cap_env and "REPRO_BENCH_WARMUP" in os.environ:
        warmup = min(warmup, bench_warmup())
    if iters is None:
        iters = bench_iters()
    elif cap_env and "REPRO_BENCH_ITERS" in os.environ:
        iters = max(1, min(iters, bench_iters()))
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# ------------------------------------------------------------------------
# Schedule executor: pure-JAX analogue of each kernel schedule, jitted so
# XLA compiles a genuinely different program per schedule point.
# ------------------------------------------------------------------------


def _dense_b(csr, n_dense):
    return jax.random.normal(jax.random.PRNGKey(0), (csr.shape[1], n_dense))


def _epilogue_args(epilogue, n_rows, n_dense):
    """Synthesized epilogue operands for measurement (the tuner measures
    the fused work a real workload would run — DESIGN.md §8)."""
    if epilogue is None or epilogue.is_noop:
        return None, None
    key = jax.random.PRNGKey(1)
    bias = (jax.random.normal(key, (n_dense,))
            if epilogue.bias else None)
    res = (jax.random.normal(key, (n_rows, n_dense))
           if epilogue.residual else None)
    return bias, res


def _apply_epilogue(out, epilogue, bias, res):
    if epilogue is None or epilogue.is_noop:
        return out
    return epilogue.apply(out, bias=bias, residual=res)


def make_eb_runner(csr, n_dense, *, group_size: int, strategy: str,
                   nnz_tile: int = 256, epilogue=None,
                   split_threshold: int | None = None,
                   merge_threshold: int | None = None,
                   value_dtype: str | None = None):
    """Jitted pure-JAX analogue of the EB kernel schedule.

    With split/merge thresholds the feed is the two-level skew layout
    (DESIGN.md §11): the leading heavy region holds single-row groups,
    so it reduces with a cheap per-group sum + leader ``segment_sum``
    (the 'parallel' realization's cost shape) instead of the full
    segment-group machinery — the measured program genuinely changes
    with the thresholds, which is what lets the tuner prefer them on
    power-law inputs.

    ``value_dtype`` (DESIGN.md §13) narrows the *fed arrays* — narrow
    floats cast the value stream and B; 'int8' feeds codes + per-row
    scales with the dequant inside the measured program — so XLA
    compiles a genuinely narrower program and the tuner's dtype axis
    measures real traffic, not a relabeled f32 run."""
    scales = None
    if value_dtype == "int8":
        q = csr.quantized()
        scales, csr_feed = q.scales, q.csr
    else:
        csr_feed = csr
    tile = max(nnz_tile, group_size)
    g = csr_feed.grouped(tile, group_size=group_size,
                         split_threshold=split_threshold,
                         merge_threshold=merge_threshold)
    n_rows = csr.shape[0]
    hn = g.heavy_tiles * tile  # static heavy-region lane count
    bias, res = _epilogue_args(epilogue, n_rows, n_dense)

    def _run(rows, cols, vals, b):
        v32 = vals.astype(jnp.float32)
        if scales is not None:
            v32 = v32 * jnp.take(scales, rows)
        partial = v32[:, None] * jnp.take(
            b.astype(jnp.float32), cols, axis=0)
        if strategy == GroupReduceStrategy.ACCUMULATE.value:
            out = jax.ops.segment_sum(partial, rows, num_segments=n_rows)
        else:
            tail_p, tail_r = partial, rows
            out = jnp.zeros((n_rows, partial.shape[1]), jnp.float32)
            if hn:
                # heavy region: groups are single-row, so a plain
                # within-group sum + one scatter per group (the
                # 'parallel' realization) replaces the one-hot reduce
                gsum = partial[:hn].reshape(-1, group_size,
                                            partial.shape[1]).sum(1)
                leaders = rows[:hn].reshape(-1, group_size)[:, 0]
                out = out + jax.ops.segment_sum(gsum, leaders,
                                                num_segments=n_rows)
                tail_p, tail_r = partial[hn:], rows[hn:]
            if tail_p.shape[0]:
                # any registered strategy name dispatches via the registry
                out = out + segment_group_reduce(tail_p, tail_r, n_rows,
                                                 group_size=group_size,
                                                 strategy=strategy)
        return _apply_epilogue(out, epilogue, bias, res)

    fn = jax.jit(_run)
    vals_feed, b_feed = _storage_feed(g.vals, _dense_b(csr, n_dense),
                                      value_dtype)
    args = (g.rows, g.cols, vals_feed, b_feed)
    return fn, args


def _storage_feed(vals, b, value_dtype):
    """Cast (vals, B) to the schedule's storage dtypes — the runner's
    compiled program then *reads narrow*, which is the effect the dtype
    axis is tuning.  int8 feeds are pre-quantized by the caller."""
    if value_dtype is None:
        return vals, b
    from ..core.dtypes import operand_dtype, storage_dtype

    if value_dtype != "int8":
        vals = vals.astype(storage_dtype(value_dtype))
    return vals, b.astype(operand_dtype(value_dtype))


def make_rb_runner(csr, n_dense, *, row_tile: int = 8,
                   width: int | None = None, epilogue=None,
                   value_dtype: str | None = None):
    """Jitted (fn, args) measuring the row-balanced (ELL) SpMM analogue
    with the epilogue folded into the measured program (``value_dtype``
    narrows the fed arrays as in :func:`make_eb_runner`)."""
    scales = None
    if value_dtype == "int8":
        q = csr.quantized()
        ell = q.csr.ell(row_tile=row_tile, width=width)
        scales = jnp.pad(
            q.scales, (0, ell.n_rows_padded - csr.shape[0]),
            constant_values=1.0)
    else:
        ell = csr.ell(row_tile=row_tile, width=width)
    n_rows = csr.shape[0]
    bias, res = _epilogue_args(epilogue, n_rows, n_dense)

    def _run(ecols, evals, b):
        ev = evals.astype(jnp.float32)
        if scales is not None:
            ev = ev * scales[:, None]
        return _apply_epilogue(ref.spmm_ell_ref(ecols, ev, b, n_rows),
                               epilogue, bias, res)

    fn = jax.jit(_run)
    vals_feed, b_feed = _storage_feed(ell.vals, _dense_b(csr, n_dense),
                                      value_dtype)
    args = (ell.cols, vals_feed, b_feed)
    return fn, args


def make_runner(csr, n_dense: int, sched: Schedule):
    """Runner for an arbitrary :class:`Schedule` (dispatch on kernel);
    the schedule's epilogue and value dtype are part of the measured
    program."""
    if sched.kernel == "eb":
        return make_eb_runner(csr, n_dense, group_size=sched.group_size,
                              strategy=sched.strategy,
                              nnz_tile=sched.nnz_tile,
                              epilogue=sched.epilogue,
                              split_threshold=sched.split_threshold,
                              merge_threshold=sched.merge_threshold,
                              value_dtype=sched.value_dtype)
    return make_rb_runner(csr, n_dense, row_tile=sched.row_tile,
                          epilogue=sched.epilogue,
                          value_dtype=sched.value_dtype)


def measure_schedule(csr, n_dense: int, sched: Schedule, *,
                     warmup: int | None = None,
                     iters: int | None = None) -> float:
    """Seconds/call of ``sched`` applied to ``csr @ B`` with ``n_dense``
    dense columns — the tuner's objective function."""
    fn, args = make_runner(csr, n_dense, sched)
    return time_fn(fn, *args, warmup=warmup, iters=iters)


# ------------------------------------------------------------------------
# Distributed measurement: the real shard_map program under a real mesh
# ------------------------------------------------------------------------


def make_dist_runner(csr, n_dense: int, sched: Schedule, *, mesh,
                     axis: str, interpret: bool = True):
    """Jitted (fn, args) running ``spmm_shard_map`` under ``sched`` on a
    *real* mesh (the forced-host-device mesh in CI) — unlike the
    single-device analogues there is no cheaper stand-in that still
    observes the collective axis: the wire mode only exists in the
    compiled SPMD program, so the objective is the program itself.
    Partitioning (host-side) happens here, outside the timed region.
    A narrow ``sched.value_dtype`` narrows the fed value/operand arrays
    (:func:`_storage_feed`) so the joint collective × dtype search times
    the storage width it is choosing."""
    from ..sparse.distributed import (partition_nnz_coo, partition_rows_coo,
                                      spmm_shard_map)

    axis_size = mesh.shape[axis]
    if (sched.collective or "nnz_rs") == "row":
        rows, cols, vals, _ = partition_rows_coo(csr, axis_size,
                                                 sched.nnz_tile)
    else:
        rows, cols, vals, _ = partition_nnz_coo(csr, axis_size,
                                                sched.nnz_tile)

    def _run(r, c, v, b):
        return spmm_shard_map(r, c, v, b, n_rows=csr.shape[0], mesh=mesh,
                              axis=axis, schedule=sched,
                              interpret=interpret)

    vals_feed, b_feed = _storage_feed(vals, _dense_b(csr, n_dense),
                                      sched.value_dtype)
    args = (rows, cols, vals_feed, b_feed)
    return _run, args


def measure_dist_schedule(csr, n_dense: int, sched: Schedule, *, mesh,
                          axis: str, warmup: int | None = None,
                          iters: int | None = None,
                          interpret: bool = True) -> float:
    """Seconds/call of the distributed schedule point (local tiling +
    ``sched.collective`` wire mode) — ``tune_dist_spmm``'s objective."""
    fn, args = make_dist_runner(csr, n_dense, sched, mesh=mesh, axis=axis,
                                interpret=interpret)
    return time_fn(fn, *args, warmup=warmup, iters=iters)
