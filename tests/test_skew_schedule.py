"""Tests for the two-level skew-aware schedule (ISSUE 7): the
split/merge GroupedCOO layout against the dense oracle on power-law
patterns (including empty-row and single-heavy-row edges), regrouped
memoization under the new layout parameters, Schedule threshold
validation, schedule-key / cache-record round-trips, and the serving
path replaying a tuned skew winner measurement-free.

Property tests run under hypothesis when it is installed (CI does);
without it they degrade to a fixed seed sweep covering the same edge
cases instead of skipping, so the parity contract is always enforced.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in the lean container
    HAVE_HYPOTHESIS = False

from repro.core import Schedule  # noqa: E402
from repro.sparse import (  # noqa: E402
    CSR,
    matrix_stats,
    power_law_csr,
    random_csr,
    spmm,
)
from repro.tune import (  # noqa: E402
    SCHEMA_VERSION,
    ScheduleCache,
    TuneRecord,
    schedule_key,
    tune_schedule,
)

RTOL = ATOL = 2e-5


def _skew_sched(split, merge, *, group_size=8, nnz_tile=32,
                strategy="segment"):
    return Schedule(kernel="eb", nnz_tile=nnz_tile, group_size=group_size,
                    strategy=strategy, split_threshold=split,
                    merge_threshold=merge)


def _check_parity(csr, sched, n_dense=3, seed=0):
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.standard_normal((csr.shape[1], n_dense)),
                    dtype=jnp.float32)
    got = spmm(csr, b, schedule=sched)
    want = jnp.asarray(csr.todense(), jnp.float32) @ b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Layout parity vs the dense oracle
# ---------------------------------------------------------------------------


def _lengths_to_csr(lengths, n_cols, seed=0):
    rng = np.random.default_rng(seed)
    dense = np.zeros((len(lengths), n_cols), np.float32)
    for r, ln in enumerate(lengths):
        ln = min(int(ln), n_cols)
        if ln:
            cols = rng.choice(n_cols, size=ln, replace=False)
            dense[r, cols] = rng.standard_normal(ln)
    return CSR.fromdense(jnp.asarray(dense))


EDGE_LENGTH_PROFILES = [
    [0, 0, 5, 0, 1],            # leading/interior empty rows
    [40, 1, 1, 1, 0, 1],        # single heavy row dominating the nnz
    [0, 0, 0, 0, 1],            # almost-everything-empty
    [9, 9, 9, 9],               # uniform: split threshold above all rows
    [33],                       # one row IS the matrix
]


@pytest.mark.parametrize("lengths", EDGE_LENGTH_PROFILES)
@pytest.mark.parametrize("split,merge", [(4, 2), (4, 0), (2, 1)])
def test_skew_edge_profiles_match_oracle(lengths, split, merge):
    csr = _lengths_to_csr(lengths, n_cols=48)
    _check_parity(csr, _skew_sched(split, merge))


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000),
           alpha=st.floats(0.3, 2.5),
           split=st.integers(2, 16),
           merge=st.integers(0, 2),
           strategy=st.sampled_from(["segment", "parallel", "accumulate"]))
    def test_skew_powerlaw_matches_oracle(seed, alpha, split, merge,
                                          strategy):
        csr = power_law_csr(48, 48, avg_degree=5.0, alpha=alpha, seed=seed)
        sched = _skew_sched(split, min(merge, split),
                            strategy=strategy)
        _check_parity(csr, sched, seed=seed)

else:  # fixed sweep over the same space

    @pytest.mark.parametrize("seed,alpha,split,merge,strategy", [
        (0, 2.2, 8, 2, "segment"),
        (1, 1.6, 4, 0, "parallel"),
        (2, 0.5, 2, 1, "accumulate"),
        (3, 2.5, 16, 2, "segment"),
    ])
    def test_skew_powerlaw_matches_oracle(seed, alpha, split, merge,
                                          strategy):
        csr = power_law_csr(48, 48, avg_degree=5.0, alpha=alpha, seed=seed)
        _check_parity(csr, _skew_sched(split, merge, strategy=strategy),
                      seed=seed)


def test_skew_autodiff_matches_reference():
    import jax

    csr = power_law_csr(32, 32, avg_degree=4.0, alpha=1.8, seed=7)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal((32, 3)), jnp.float32)
    sched = _skew_sched(4, 1)

    def loss(vals, b):
        a = CSR(indptr=csr.indptr, indices=csr.indices, vals=vals,
                shape=csr.shape)
        return jnp.sum(spmm(a, b, schedule=sched) ** 2)

    dv, db = jax.grad(loss, argnums=(0, 1))(csr.vals, b)

    def loss_ref(vals, b):
        dense = jnp.zeros(csr.shape, jnp.float32)
        rows = jnp.searchsorted(
            csr.indptr, jnp.arange(csr.nnz, dtype=jnp.int32),
            side="right").astype(jnp.int32) - 1
        dense = dense.at[rows, csr.indices].set(vals)
        return jnp.sum((dense @ b) ** 2)

    dv_ref, db_ref = jax.grad(loss_ref, argnums=(0, 1))(csr.vals, b)
    np.testing.assert_allclose(np.asarray(dv), np.asarray(dv_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(db), np.asarray(db_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Conversion memoization under the new layout parameters
# ---------------------------------------------------------------------------


def test_grouped_skew_memoized_per_parameter_tuple():
    csr = power_law_csr(64, 64, avg_degree=6.0, alpha=1.8, seed=3)
    g1 = csr.grouped(32, group_size=8, split_threshold=4, merge_threshold=2)
    g2 = csr.grouped(32, group_size=8, split_threshold=4, merge_threshold=2)
    assert g1 is g2  # second conversion is a dict probe
    g3 = csr.grouped(32, group_size=8, split_threshold=8, merge_threshold=2)
    assert g3 is not g1  # distinct thresholds are distinct layouts
    plain = csr.grouped(32)
    assert plain.skew is None and g1.skew is not None


def test_regrouped_matching_target_returns_self():
    csr = power_law_csr(64, 64, avg_degree=6.0, alpha=1.8, seed=3)
    g = csr.grouped(32, group_size=8, split_threshold=4, merge_threshold=2)
    assert g.regrouped(32, group_size=8, split_threshold=4,
                       merge_threshold=2) is g
    plain = csr.grouped(32)
    assert plain.regrouped(32) is plain


def test_regrouped_retargets_and_memoizes():
    csr = power_law_csr(64, 64, avg_degree=6.0, alpha=1.8, seed=3)
    g = csr.grouped(32)
    s1 = g.regrouped(32, group_size=8, split_threshold=4, merge_threshold=2)
    assert s1.skew is not None and s1 is not g
    # memoized: the same retarget is a dict probe, not a re-layout
    assert g.regrouped(32, group_size=8, split_threshold=4,
                       merge_threshold=2) is s1
    # distinct targets coexist under distinct memo keys
    s2 = g.regrouped(32, group_size=8, split_threshold=8, merge_threshold=0)
    assert s2 is not s1
    # round-trip back to the plain layout preserves the matrix
    p = s1.regrouped(32)
    assert p.skew is None
    np.testing.assert_allclose(np.asarray(p.todense()),
                               np.asarray(csr.todense()),
                               rtol=1e-6, atol=1e-6)


def test_skew_regroup_needs_group_size():
    csr = random_csr(32, 32, density=0.1, seed=0)
    g = csr.grouped(32)
    with pytest.raises(ValueError, match="group_size"):
        g.regrouped(32, split_threshold=4)


# ---------------------------------------------------------------------------
# Schedule validation + identity
# ---------------------------------------------------------------------------


def test_schedule_threshold_validation():
    assert _skew_sched(4, 2).is_skew
    assert not Schedule(kernel="eb").is_skew
    with pytest.raises(ValueError, match="'eb'"):
        Schedule(kernel="rb", split_threshold=4)
    with pytest.raises(ValueError, match="split_threshold"):
        _skew_sched(0, 0)
    with pytest.raises(ValueError, match="merge_threshold"):
        _skew_sched(4, -1)
    with pytest.raises(ValueError, match="must not exceed"):
        _skew_sched(4, 5)


def test_schedule_key_carries_thresholds():
    plain = Schedule(kernel="eb", nnz_tile=64, group_size=8)
    skew = plain.replace(split_threshold=4, merge_threshold=2)
    k_plain, k_skew = schedule_key(plain), schedule_key(skew)
    assert k_plain != k_skew
    assert ":s4:m2" in k_skew and ":s" not in k_plain.replace(":segment", "")
    # distinct thresholds must not share a memo/cache slot
    assert schedule_key(plain.replace(split_threshold=8,
                                      merge_threshold=2)) != k_skew


def test_tune_record_roundtrips_thresholds():
    skew = Schedule(kernel="eb", nnz_tile=64, group_size=8,
                    split_threshold=4, merge_threshold=2)
    rec = TuneRecord(schedule=skew, us_per_call=12.5,
                     measured={schedule_key(skew): 12.5})
    back = TuneRecord.from_json(rec.to_json())
    assert back.schedule == skew
    assert back.schedule.is_skew
    assert dataclasses.asdict(back.schedule)["split_threshold"] == 4


def test_schema_version_bumped_for_skew_fields():
    # pre-skew records lack the threshold fields; the schema bump drops
    # them instead of replaying a record that deserializes differently
    assert SCHEMA_VERSION >= 2


# ---------------------------------------------------------------------------
# Tuner + serving path
# ---------------------------------------------------------------------------


def _fake_measure(favor_skew):
    calls = []

    def measure(s: Schedule) -> float:
        calls.append(s)
        base = 1e-3 + 1e-6 * (s.nnz_tile + s.group_size)
        if favor_skew and s.is_skew:
            base *= 0.25
        return base

    return measure, calls


def test_tuner_explores_and_caches_skew_winner():
    csr = power_law_csr(128, 128, avg_degree=8.0, alpha=1.8, seed=0)
    stats = matrix_stats(csr)
    assert "row_quantiles" in stats  # skew candidates need the histogram
    cache = ScheduleCache(path=None)
    measure, calls = _fake_measure(favor_skew=True)
    res = tune_schedule(csr, 4, cache=cache, measure=measure)
    assert any(s.is_skew for s in calls), "no skew candidate was measured"
    assert res.schedule.is_skew
    # replay: same fingerprint, zero further measurements
    measure2, calls2 = _fake_measure(favor_skew=True)
    res2 = tune_schedule(csr, 4, cache=cache, measure=measure2)
    assert res2.from_cache and not calls2
    assert res2.schedule == res.schedule


def test_serving_path_replays_skew_without_measuring(monkeypatch):
    from repro.tune import cached_or_auto, cache_key

    csr = power_law_csr(96, 96, avg_degree=6.0, alpha=2.0, seed=1)
    cache = ScheduleCache(path=None)
    measure, _ = _fake_measure(favor_skew=True)
    tuned = tune_schedule(csr, 3, cache=cache, measure=measure).schedule
    assert tuned.is_skew

    # the serving resolver must never measure: poison the objective
    import repro.tune.measure as measure_mod

    def _boom(*a, **k):  # pragma: no cover - failure path
        raise AssertionError("serving path ran a measurement")

    monkeypatch.setattr(measure_mod, "measure_schedule", _boom)
    sched = cached_or_auto(csr, 3, cache=cache,
                           key=cache_key(csr, 3))
    assert sched == tuned
    # and the replayed schedule actually runs the skew layout correctly
    _check_parity(csr, sched, n_dense=3)
