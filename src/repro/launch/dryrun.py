import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the XLA_FLAGS line above must precede ANY jax import)
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh and record memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json; the
roofline table (EXPERIMENTS.md §Roofline) is generated from them.
"""
import argparse
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import (ARCHS, SHAPES, cell_is_runnable, decode_specs,
                       get_config, train_batch_specs)
from ..distributed.sharding import (batch_shardings, cache_shardings,
                                    data_axes, param_shardings, replicated)
from ..models import get_model
from ..models.moe import ShardingCtx
from ..roofline.analysis import (analyze, combine_costs, count_active_params,
                                 count_params, extract_costs)
from ..train.optimizer import AdamW, cosine_schedule
from ..train.train_step import TrainState, init_state, make_train_step
from .mesh import make_production_mesh

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def zero1_shardings(mesh, params_shape, pshard):
    """ZeRO-1: shard optimizer moments over the data axes on the first
    still-unsharded, divisible dim of each param."""
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def rule(leaf, psh):
        spec = list(psh.spec) + [None] * (len(leaf.shape) - len(psh.spec))
        used = set()
        for cur in spec:
            for a in (cur if isinstance(cur, tuple) else (cur,)):
                if a is not None:
                    used.add(a)
        if used & set(dp):  # already data-sharded (e.g. FSDP attention)
            return psh
        for dim, cur in enumerate(spec):
            if cur is None and leaf.shape[dim] % dp_size == 0:
                spec[dim] = dp
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(rule, params_shape, pshard)


def make_ctx(cfg, mesh):
    if cfg.family == "moe":
        return ShardingCtx(mesh=mesh, data_axes=data_axes(mesh),
                           model_axis="model")
    return None


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               zero1: bool = True, overrides: dict | None = None,
               microbatches: int = 8, grad_compression: str | None = None):
    """Build and lower one (arch × shape × mesh) cell. Returns
    (lowered, meta) without compiling."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.scaled(**overrides)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return None, {"skipped": why, "arch": arch, "shape": shape_name}

    api = get_model(cfg)
    ctx = make_ctx(cfg, mesh)
    params_shape = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    pshard = param_shardings(mesh, params_shape)
    n_chips = mesh.devices.size

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "n_chips": n_chips,
        "tokens": (shape.global_batch if shape.kind == "decode"
                   else shape.tokens),
        "n_params": count_params(params_shape),
        "n_active_params": count_active_params(params_shape, cfg),
    }

    if shape.kind == "train":
        opt = AdamW(lr=cosine_schedule(3e-4, 2000, 100_000))
        state_shape = jax.eval_shape(
            functools.partial(init_state, api, opt), jax.random.PRNGKey(0))
        mom_shard = (zero1_shardings(mesh, params_shape, pshard)
                     if zero1 else pshard)
        state_sh = TrainState(
            params=pshard,
            opt=type(state_shape.opt)(step=replicated(mesh), mu=mom_shard,
                                      nu=mom_shard))
        specs = train_batch_specs(cfg, shape)
        bsh = batch_shardings(mesh, specs)
        # microbatched grad accumulation: the production knob that bounds
        # per-layer activation residuals (B_loc/µB per microbatch).
        step_fn = make_train_step(api, opt, ctx, microbatches=microbatches,
                                  grad_compression=grad_compression)
        metrics_sh = {k: replicated(mesh)
                      for k in ("loss", "grad_norm", "step")}
        with mesh:
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, bsh),
                out_shardings=(state_sh, metrics_sh),
                donate_argnums=(0,),
            ).lower(state_shape, specs)
        return lowered, meta

    if shape.kind == "prefill":
        specs = train_batch_specs(cfg, shape)
        bsh = batch_shardings(mesh, specs)
        max_len = shape.seq_len

        def prefill_fn(params, batch):
            return api.prefill(params, batch, max_len)

        cache_shape = jax.eval_shape(
            lambda: api.init_cache(shape.global_batch, max_len))
        csh = cache_shardings(mesh, cfg, cache_shape)
        logits_sh = NamedSharding(
            mesh, P(data_axes(mesh),
                    "model" if cfg.vocab_size % mesh.shape["model"] == 0
                    else None))
        with mesh:
            lowered = jax.jit(
                prefill_fn,
                in_shardings=(pshard, bsh),
                out_shardings=(logits_sh, csh),
            ).lower(params_shape, specs)
        return lowered, meta

    # decode
    specs = decode_specs(cfg, shape, api.init_cache)
    cache_shape = specs["cache"]
    csh = cache_shardings(mesh, cfg, cache_shape)
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_sh = NamedSharding(
        mesh, P(dp if shape.global_batch % dp_size == 0 else None))
    logits_sh = NamedSharding(
        mesh, P(dp if shape.global_batch % dp_size == 0 else None,
                "model" if cfg.vocab_size % mesh.shape["model"] == 0
                else None))

    def serve_step(params, cache, tokens):
        return api.decode_step(params, cache, tokens)

    with mesh:
        lowered = jax.jit(
            serve_step,
            in_shardings=(pshard, csh, tok_sh),
            out_shardings=(logits_sh, csh),
            donate_argnums=(1,),
        ).lower(params_shape, cache_shape, specs["tokens"])
    return lowered, meta


def _ladder(arch, shape_name, *, multi_pod, zero1, n_layers, family,
            extra_overrides, overrides=None, microbatches=8,
            grad_compression=None):
    """XLA cost analysis counts scan bodies once; compile L=1 and L=2
    variants (with unrolled layer scans) and extrapolate:
    total = cost(1) + (L-1)·(cost(2)-cost(1)). Exact for
    scan-homogeneous layer stacks (all of ours)."""
    per_l = {}
    for l_val in (1, 2):
        ov = dict(overrides or {})
        ov.update(n_layers=l_val, scan_unroll=True, ssd_unroll=True)
        ov.update(extra_overrides)
        if family == "encdec":
            ov["n_encoder_layers"] = l_val
        # microbatches=1 for measurement: the grad-accum scan is a while
        # loop whose body XLA cost analysis counts once; the single-batch
        # step has identical total flops/collective bytes.
        lowered, _ = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                zero1=zero1, overrides=ov, microbatches=1,
                                grad_compression=grad_compression)
        per_l[l_val] = extract_costs(lowered.compile())
    body = {
        "flops": per_l[2]["flops"] - per_l[1]["flops"],
        "bytes": per_l[2]["bytes"] - per_l[1]["bytes"],
        "coll_bytes": per_l[2]["coll_bytes"] - per_l[1]["coll_bytes"],
        "collectives": {
            op: {"count": per_l[2]["collectives"].get(op, {"count": 0})["count"]
                 - per_l[1]["collectives"].get(op, {"count": 0})["count"],
                 "bytes": per_l[2]["collectives"].get(op, {"bytes": 0})["bytes"]
                 - per_l[1]["collectives"].get(op, {"bytes": 0})["bytes"]}
            for op in set(per_l[2]["collectives"]) | set(per_l[1]["collectives"])
        },
    }
    return combine_costs(per_l[1], body, n_layers - 1)


def _extrapolated_costs(arch, shape_name, *, multi_pod, zero1, n_layers,
                        family, overrides=None, microbatches=8,
                        grad_compression=None):
    """Two measurement ladders (DESIGN.md §9 / EXPERIMENTS.md §Roofline):

    flops  — single-trip attention chunks (q/kv_chunk = big): identical
             math, every matmul visible to cost analysis. Exact.
    bytes/ — default chunked attention: the single-chunk module would
    colls    "write" the S² score matrix to HBM, wildly inflating the
             memory term vs the flash structure where score blocks stay
             in VMEM. Chunk-loop bodies are counted once, matching one
             streaming pass over q/k/v — the flash HBM traffic model.
    """
    common = dict(arch=arch, shape_name=shape_name, multi_pod=multi_pod,
                  zero1=zero1, n_layers=n_layers, family=family,
                  overrides=overrides, microbatches=microbatches,
                  grad_compression=grad_compression)
    flop_costs = _ladder(extra_overrides={"q_chunk": 1 << 22,
                                          "kv_chunk": 1 << 22}, **common)
    byte_costs = _ladder(extra_overrides={}, **common)
    return {
        "flops": flop_costs["flops"],
        "bytes": byte_costs["bytes"],
        "coll_bytes": byte_costs["coll_bytes"],
        "collectives": byte_costs["collectives"],
    }


def run_cell(arch, shape_name, *, multi_pod, zero1=True, save=True,
             overrides=None, tag=None, microbatches=8,
             grad_compression=None):
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod=multi_pod,
                               zero1=zero1, overrides=overrides,
                               microbatches=microbatches,
                               grad_compression=grad_compression)
    if tag:
        meta["tag"] = tag
    if lowered is None:
        print(f"SKIP {arch} × {shape_name}: {meta['skipped']}")
        if save:
            _save(meta)
        return meta
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    cfg = get_config(arch)
    costs = _extrapolated_costs(
        arch, shape_name, multi_pod=multi_pod, zero1=zero1,
        n_layers=(cfg.n_layers if not overrides
                  else overrides.get("n_layers", cfg.n_layers)),
        family=cfg.family, overrides=overrides, microbatches=microbatches,
        grad_compression=grad_compression)
    res = analyze(costs, compiled.memory_analysis(),
                  n_chips=meta["n_chips"], kind=meta["kind"],
                  tokens=meta["tokens"], n_params=meta["n_params"],
                  n_active_params=meta["n_active_params"])
    res["uncorrected_scan_once"] = extract_costs(compiled)
    res.update(meta)
    res["t_lower_s"] = round(t_lower, 2)
    res["t_compile_s"] = round(t_compile, 2)
    print(f"OK {arch} × {shape_name} × {res['mesh']}: "
          f"flops/chip={res['per_chip']['hlo_flops']:.3e} "
          f"coll={res['per_chip']['collective_bytes']:.3e}B "
          f"dom={res['dominant']} frac={res['roofline_fraction']:.3f} "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    if save:
        _save(res)
    return res


def _save(res):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"__{res['tag']}" if res.get("tag") else ""
    name = f"{res['arch']}__{res['shape']}__{res.get('mesh', 'skip')}{tag}.json"
    (OUT_DIR / name).write_text(json.dumps(res, indent=2, default=float))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-zero1", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, multi_pod=args.multi_pod,
                     zero1=not args.no_zero1)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((a, s, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
