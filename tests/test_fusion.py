"""Fused-vs-unfused parity tests (ISSUE 4): kernel epilogues, the
monoid-generalized reduction registry, and the one-pass fused sparse
attention — forward AND gradients against the pure-JAX spec oracles,
property-tested over random patterns including empty-row /
single-nnz-row edge cases and the strategy matrix.

Property tests run under hypothesis when it is installed (CI does);
without it they degrade to a fixed seed sweep covering the same edge
cases instead of skipping, so the parity contract is always enforced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in the lean container
    HAVE_HYPOTHESIS = False

from repro.core import (  # noqa: E402
    Epilogue,
    Schedule,
    get_strategy,
    register_strategy,
    segment_group_reduce,
)
from repro.kernels import ref  # noqa: E402
from repro.kernels.fused_attention import sparse_attention_ref  # noqa: E402
from repro.sparse import (  # noqa: E402
    random_csr,
    sddmm,
    segment_reduce,
    sparse_attention,
    spmm,
)

RTOL = ATOL = 1e-5


# ---------------------------------------------------------------------------
# problem generators: hypothesis strategies + fixed fallback sweeps
# ---------------------------------------------------------------------------


def _property(strategy_fn, examples, max_examples=10):
    """``@given`` under hypothesis, a fixed parametrize sweep without."""
    if HAVE_HYPOTHESIS:
        def deco(f):
            return settings(max_examples=max_examples, deadline=None)(
                given(prob=strategy_fn())(f))

        return deco
    return pytest.mark.parametrize("prob", examples)


if HAVE_HYPOTHESIS:
    @st.composite
    def csr_problem(draw):
        """Small CSR (with empty rows / single-nnz rows) + dense width."""
        m = draw(st.integers(6, 60))
        n = draw(st.integers(6, 60))
        density = draw(st.sampled_from([0.005, 0.05, 0.15]))
        skew = draw(st.sampled_from([0.0, 1.5]))
        c = draw(st.integers(1, 10))
        seed = draw(st.integers(0, 2 ** 16))
        return m, n, density, skew, c, seed

    @st.composite
    def attn_problem(draw):
        n_rows = draw(st.integers(4, 40))
        n_cols = draw(st.integers(4, 40))
        # nnz up to 3*n_rows: sparse enough to leave rows empty, and
        # rows with exactly one nnz appear routinely
        nnz = draw(st.integers(1, 3 * n_rows))
        d = draw(st.sampled_from([4, 8]))
        dv = draw(st.sampled_from([4, 16]))
        seed = draw(st.integers(0, 2 ** 16))
        return n_rows, n_cols, nnz, d, dv, seed
else:
    csr_problem = attn_problem = None

# fixed sweeps mirroring the strategies (many empty rows at 0.005;
# skewed long rows at 1.5; ragged non-multiple sizes)
CSR_EXAMPLES = [
    (6, 6, 0.05, 0.0, 1, 0),
    (33, 47, 0.005, 0.0, 3, 1),     # mostly empty rows, nnz < one tile
    (60, 24, 0.15, 1.5, 10, 2),     # skewed: a few very long rows
    (24, 60, 0.05, 1.5, 7, 3),
]
ATTN_EXAMPLES = [
    (4, 4, 1, 4, 4, 0),             # single nnz in the whole pattern
    (40, 24, 25, 8, 16, 1),         # most rows empty
    (24, 40, 72, 8, 4, 2),          # dense-ish rows
    (17, 9, 51, 4, 16, 3),          # ragged sizes
]


def _attn_pattern(n_rows, n_cols, nnz, seed):
    rng = np.random.default_rng(seed)
    rows = np.sort(rng.integers(0, n_rows, nnz)).astype(np.int32)
    cols = rng.integers(0, n_cols, nnz).astype(np.int32)
    return jnp.asarray(rows), jnp.asarray(cols)


# ---------------------------------------------------------------------------
# Epilogued SpMM: fused kernel == unfused spec, forward + grads
# ---------------------------------------------------------------------------

EPILOGUED_SCHEDS = [
    Schedule("eb", nnz_tile=64, col_tile=8, group_size=8,
             strategy="segment"),
    Schedule("eb", nnz_tile=64, col_tile=8, group_size=16,
             strategy="accumulate"),
    Schedule("rb", row_tile=8, col_tile=8, strategy="parallel"),
]


@pytest.mark.parametrize("sched", EPILOGUED_SCHEDS,
                         ids=lambda s: f"{s.kernel}-{s.strategy}")
@_property(csr_problem, CSR_EXAMPLES)
def test_epilogued_spmm_matches_unfused(sched, prob):
    m, n, density, skew, c, seed = prob
    csr = random_csr(m, n, density=density, skew=skew, seed=seed)
    key = jax.random.PRNGKey(seed)
    kb, kbias, kres = jax.random.split(key, 3)
    b = jax.random.normal(kb, (n, c))
    bias = jax.random.normal(kbias, (c,))
    res = jax.random.normal(kres, (m, c))
    ep = Epilogue(activation="relu", bias=True, residual=True)
    got = np.asarray(spmm(csr, b, schedule=sched.replace(epilogue=ep),
                          bias=bias, residual=res))
    # unfused spec: oracle spmm, then the epilogue's executable spec
    z = spmm(csr, b, impl="ref")
    want = np.asarray(ep.apply(z, bias=bias, residual=res))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("sched", EPILOGUED_SCHEDS,
                         ids=lambda s: f"{s.kernel}-{s.strategy}")
def test_epilogued_spmm_grads_match_unfused(sched):
    csr = random_csr(30, 24, density=0.1, skew=1.0, seed=7)
    coo = csr.tocoo()
    key = jax.random.PRNGKey(0)
    kb, kbias, kres = jax.random.split(key, 3)
    b = jax.random.normal(kb, (24, 5))
    bias = jax.random.normal(kbias, (5,))
    res = jax.random.normal(kres, (30, 5))
    ep = Epilogue(activation="tanh", bias=True, residual=True)

    def loss_fused(args):
        bb, bi, rr = args
        return jnp.sum(spmm(csr, bb, schedule=sched.replace(epilogue=ep),
                            bias=bi, residual=rr) ** 2)

    def loss_spec(args):
        bb, bi, rr = args
        z = ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, bb, 30)
        return jnp.sum((jnp.tanh(z + bi[None, :]) + rr) ** 2)

    g_f = jax.grad(loss_fused)((b, bias, res))
    g_s = jax.grad(loss_spec)((b, bias, res))
    for gf, gs in zip(g_f, g_s):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gs),
                                   rtol=1e-4, atol=1e-4)


def test_epilogue_out_dtype_cast_in_kernel():
    """out_dtype narrowing accumulates in the f32 scratch and casts only
    on the final store: long rows (many reduction steps) must stay
    within a single bf16 rounding of the f32 oracle."""
    csr = random_csr(40, 200, density=0.4, seed=3)  # long rows
    b = jax.random.normal(jax.random.PRNGKey(1), (200, 8))
    want = np.asarray(spmm(csr, b, impl="ref"))
    for sched in (Schedule("eb", nnz_tile=64, col_tile=8, group_size=8),
                  Schedule("rb", row_tile=8, col_tile=8,
                           strategy="parallel")):
        got = spmm(csr, b, schedule=sched.replace(
            epilogue=Epilogue(out_dtype="bfloat16")))
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=1.2e-2, atol=1.2e-2)


def test_epilogue_requires_declared_arrays():
    csr = random_csr(20, 20, density=0.1, seed=0)
    b = jax.random.normal(jax.random.PRNGKey(0), (20, 4))
    sched = Schedule("eb", nnz_tile=64, col_tile=8, group_size=8,
                     epilogue=Epilogue(bias=True))
    with pytest.raises(ValueError, match="bias"):
        spmm(csr, b, schedule=sched)
    with pytest.raises(ValueError):
        Epilogue(activation="not-an-activation")


def test_gcn_layer_is_single_fused_call():
    from repro.models.layers import gcn_layer

    csr = random_csr(32, 32, density=0.1, seed=5)
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 6)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(2), (6,))
    got = np.asarray(gcn_layer(csr, x, w, b, schedule=Schedule(
        "eb", nnz_tile=64, col_tile=8, group_size=8)))
    want = np.asarray(jax.nn.relu(spmm(csr, x @ w, impl="ref")
                                  + b[None, :]))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# Monoid-generalized reductions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["segment", "accumulate"])
@pytest.mark.parametrize("op,oracle", [
    ("max", jax.ops.segment_max),
    ("min", jax.ops.segment_min),
])
def test_segment_reduce_monoids_through_kernel(strategy, op, oracle):
    rng = np.random.default_rng(11)
    seg = np.sort(rng.integers(0, 25, 300)).astype(np.int32)
    data = rng.standard_normal((300, 7)).astype(np.float32)
    sched = Schedule("eb", nnz_tile=64, group_size=8, strategy=strategy)
    got = np.asarray(segment_reduce(jnp.asarray(seg), jnp.asarray(data),
                                    25, schedule=sched, op=op))
    want = np.asarray(oracle(jnp.asarray(data), jnp.asarray(seg),
                             num_segments=25))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@_property(
    (lambda: st.tuples(st.integers(0, 2 ** 16),
                       st.sampled_from([2, 4, 8, 16])))
    if HAVE_HYPOTHESIS else None,
    [(0, 2), (1, 4), (2, 8), (3, 16), (4, 8)],
    max_examples=20)
def test_segment_group_reduce_spec_max_matches_segment_max(prob):
    seed, g = prob
    rng = np.random.default_rng(seed)
    t = g * rng.integers(1, 8)
    s = int(rng.integers(1, 15))
    seg = np.sort(rng.integers(0, s, t)).astype(np.int32)
    data = rng.standard_normal((t, 3)).astype(np.float32)
    got = np.asarray(segment_group_reduce(
        jnp.asarray(data), jnp.asarray(seg), s, group_size=g,
        strategy="segment", op="max"))
    want = np.asarray(jax.ops.segment_max(jnp.asarray(data),
                                          jnp.asarray(seg),
                                          num_segments=s))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_segment_reduce_mean_and_empty_segments():
    seg = jnp.asarray([0, 0, 3], jnp.int32)  # segments 1, 2 empty
    data = jnp.asarray([[2.0], [4.0], [5.0]])
    got = np.asarray(segment_reduce(seg, data, 4, op="mean",
                                    schedule=Schedule("eb", nnz_tile=64,
                                                      group_size=8)))
    np.testing.assert_allclose(got[:, 0], [3.0, 0.0, 0.0, 5.0],
                               rtol=RTOL, atol=ATOL)
    # max over an empty segment is the identity (-inf), like segment_max
    got_max = np.asarray(segment_reduce(seg, data, 4, op="max"))
    assert got_max[1, 0] == -np.inf and got_max[2, 0] == -np.inf
    np.testing.assert_allclose(got_max[[0, 3], 0], [4.0, 5.0],
                               rtol=RTOL, atol=ATOL)


def test_register_strategy_with_custom_combine():
    from repro.core import available_strategies

    # a user monoid: combine=maximum registered as the strategy's own
    name = "test-max-combine"
    if name not in available_strategies():
        register_strategy(
            name,
            lambda p, s, n, g, monoid=None: jax.ops.segment_max(
                p, s, num_segments=n),
            combine=jnp.maximum, identity=-jnp.inf)
    entry = get_strategy(name)
    assert entry.monoid.identity == -jnp.inf
    # a conflicting op= must refuse; the default add op defers to the
    # strategy's own combine
    with pytest.raises(ValueError, match="combine"):
        get_strategy(name, op="min")
    assert get_strategy(name, op="add").monoid is entry.monoid
    # spec-only strategy falls back in-kernel and still reduces max
    # (its own monoid supplies the -inf init/padding identity)
    rng = np.random.default_rng(2)
    seg = np.sort(rng.integers(0, 10, 64)).astype(np.int32)
    data = rng.standard_normal((64, 3)).astype(np.float32)
    got = np.asarray(segment_reduce(
        jnp.asarray(seg), jnp.asarray(data), 10,
        schedule=Schedule("eb", nnz_tile=64, group_size=8,
                          strategy=name)))
    want = np.asarray(jax.ops.segment_max(jnp.asarray(data),
                                          jnp.asarray(seg),
                                          num_segments=10))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# Fused sparse attention
# ---------------------------------------------------------------------------

ATTN_SCHEDS = [
    Schedule("eb", nnz_tile=64, group_size=8, strategy="segment"),
    Schedule("eb", nnz_tile=64, group_size=32, strategy="accumulate"),
]


@pytest.mark.parametrize("sched", ATTN_SCHEDS,
                         ids=lambda s: s.strategy)
@_property(attn_problem, ATTN_EXAMPLES, max_examples=12)
def test_sparse_attention_matches_oracle(sched, prob):
    n_rows, n_cols, nnz, d, dv, seed = prob
    rows, cols = _attn_pattern(n_rows, n_cols, nnz, seed)
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (n_rows, d))
    k = jax.random.normal(kk, (n_cols, d))
    v = jax.random.normal(kv, (n_cols, dv))
    got = np.asarray(sparse_attention((rows, cols, n_rows), q, k, v,
                                      schedule=sched))
    want = np.asarray(sparse_attention_ref(rows, cols, q, k, v,
                                           n_rows=n_rows))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("sched", ATTN_SCHEDS, ids=lambda s: s.strategy)
def test_sparse_attention_grads_match_oracle(sched):
    rows, cols = _attn_pattern(24, 20, 60, seed=9)
    key = jax.random.PRNGKey(4)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (24, 8))
    k = jax.random.normal(kk, (20, 8))
    v = jax.random.normal(kv, (20, 6))
    tgt = jax.random.normal(jax.random.PRNGKey(5), (24, 6))

    def loss_fused(qkv):
        out = sparse_attention((rows, cols, 24), *qkv, schedule=sched)
        return jnp.sum((out - tgt) ** 2)

    def loss_spec(qkv):
        out = sparse_attention_ref(rows, cols, *qkv, n_rows=24)
        return jnp.sum((out - tgt) ** 2)

    g_f = jax.grad(loss_fused)((q, k, v))
    g_s = jax.grad(loss_spec)((q, k, v))
    for gf, gs in zip(g_f, g_s):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gs),
                                   rtol=1e-4, atol=1e-4)


def test_sparse_attention_empty_and_single_nnz_rows():
    rows = jnp.asarray([1, 3, 3], jnp.int32)
    cols = jnp.asarray([0, 1, 2], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(0), (5, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (6, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (6, 4))
    got = np.asarray(sparse_attention((rows, cols, 5), q, k, v))
    want = np.asarray(sparse_attention_ref(rows, cols, q, k, v, n_rows=5))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    # empty rows are exact zeros; a single-nnz row is exactly V[col]
    assert np.all(got[0] == 0) and np.all(got[2] == 0) and np.all(got[4] == 0)
    np.testing.assert_allclose(got[1], np.asarray(v[0], np.float32),
                               rtol=RTOL, atol=ATOL)


def test_sparse_attention_accepts_csr_and_rejects_parallel():
    adj = random_csr(16, 16, density=0.2, seed=1)
    q = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (16, 4))
    got = np.asarray(sparse_attention(adj, q, k, v))
    # a CSR's stored values are an additive score bias (ISSUE 5)
    coo = adj.tocoo()
    want = np.asarray(sparse_attention_ref(coo.rows, coo.cols, q, k, v,
                                           n_rows=16, bias=coo.vals))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    with pytest.raises(ValueError, match="parallel"):
        sparse_attention(adj, q, k, v,
                         schedule=Schedule("eb", strategy="parallel"))


def test_graph_attention_multihead():
    from repro.models.attention import graph_attention

    adj = random_csr(12, 12, density=0.25, seed=2)
    coo = adj.tocoo()
    q = jax.random.normal(jax.random.PRNGKey(0), (12, 2, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (12, 2, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (12, 2, 4))
    got = np.asarray(graph_attention(adj, q, k, v))
    assert got.shape == (12, 2, 4)
    for h in range(2):
        want = np.asarray(sparse_attention_ref(
            coo.rows, coo.cols, q[:, h], k[:, h], v[:, h], n_rows=12,
            bias=coo.vals))
        np.testing.assert_allclose(got[:, h], want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# Satellites: GroupedCOO regroup memoization + sddmm no-scale fast path
# ---------------------------------------------------------------------------


def test_groupedcoo_regroup_is_memoized():
    csr = random_csr(50, 50, density=0.05, seed=8)
    g = csr.grouped(64)
    assert g.regrouped(64) is g  # tile match: no work at all
    r1 = g.regrouped(128)
    assert r1 is g.regrouped(128)  # converted once
    assert r1 is not g.regrouped(256)
    assert r1.nnz == g.nnz and r1.nnz_padded % 128 == 0
    # a GroupedCOO fed to spmm under a different tuned tile still matches
    b = jax.random.normal(jax.random.PRNGKey(0), (50, 4))
    got = spmm(g, b, schedule=Schedule("eb", nnz_tile=128, col_tile=8,
                                       group_size=8))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(spmm(csr, b, impl="ref")),
                               rtol=RTOL, atol=ATOL)


def test_sddmm_none_scale_fast_path_matches():
    csr = random_csr(40, 30, density=0.08, seed=6)
    coo = csr.tocoo()
    a = jax.random.normal(jax.random.PRNGKey(0), (40, 12))
    b = jax.random.normal(jax.random.PRNGKey(1), (30, 12))
    want = np.asarray(ref.sddmm_ref(coo.rows, coo.cols, a, b))
    got = np.asarray(sddmm(coo.rows, coo.cols, a, b, nnz_tile=64))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    # and the scaled path still masks padding via scale=0
    got_s = np.asarray(sddmm(coo.rows, coo.cols, a, b, coo.vals,
                             nnz_tile=64))
    np.testing.assert_allclose(
        got_s, np.asarray(ref.sddmm_ref(coo.rows, coo.cols, a, b,
                                        coo.vals)),
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Tuner epilogue-awareness
# ---------------------------------------------------------------------------


def test_tuner_is_epilogue_aware():
    from repro.tune import ScheduleCache, tune_schedule
    from repro.tune.search import schedule_key

    csr = random_csr(64, 64, density=0.05, seed=4)
    cache = ScheduleCache(path=None)
    ep = Epilogue(activation="relu", bias=True)
    calls = []

    def fake_measure(s):
        calls.append(s)
        return 1e-6

    res_plain = tune_schedule(csr, 8, cache=cache, measure=fake_measure)
    n_plain = len(calls)
    res_ep = tune_schedule(csr, 8, cache=cache, measure=fake_measure,
                           epilogue=ep)
    # separate cache keys: the epilogued workload never replays plain
    assert res_plain.key != res_ep.key and "ep:" in res_ep.key
    # every measured candidate carried the epilogue into the objective
    ep_calls = calls[n_plain:]
    assert ep_calls and all(s.epilogue == ep for s in ep_calls)
    assert all("ep[" in schedule_key(s) for s in ep_calls)
    assert res_ep.schedule.epilogue == ep
    # replay: zero measurements on the second epilogued call
    res_hit = tune_schedule(csr, 8, cache=cache, measure=fake_measure,
                            epilogue=ep)
    assert res_hit.from_cache and res_hit.schedule.epilogue == ep


def test_schedule_epilogue_roundtrips_through_cache_json():
    from repro.tune.cache import TuneRecord

    s = Schedule("eb", nnz_tile=64, group_size=8,
                 epilogue=Epilogue(activation="gelu", bias=True,
                                   out_dtype="bfloat16"))
    rec = TuneRecord(schedule=s, us_per_call=12.5)
    back = TuneRecord.from_json(rec.to_json())
    assert back.schedule == s
    assert back.schedule.epilogue.activation == "gelu"
