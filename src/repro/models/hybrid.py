"""Hymba-style hybrid LM: parallel attention + mamba heads per layer
(arXiv:2411.13676), then an MLP block.

Fusion follows Hymba's normalized weighted sum (learned per-layer scalars
over per-branch RMS-normalized outputs). Meta-tokens and the sliding-window
mix are not modeled (noted in DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import decode_attention
from .layers import (apply_dense, apply_mlp, apply_norm, embed,
                     init_embedding, init_mlp, init_norm, layer_scan,
                     lm_loss_from_features, rmsnorm, unembed)
from .mamba2 import init_mixer, init_mixer_cache, mixer_decode, mixer_fwd
from .transformer import _qkv, attn_block, init_attn


def init_layer(cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": init_attn(cfg, k1),
        "mixer": init_mixer(cfg, k2),
        "beta_attn": jnp.ones((), jnp.float32),
        "beta_ssm": jnp.ones((), jnp.float32),
        "ln2": init_norm(cfg, cfg.d_model),
        "mlp": init_mlp(cfg, k3),
    }


def init_params(cfg, key):
    ke, kl = jax.random.split(key)
    layers = jax.vmap(lambda k: init_layer(cfg, k))(
        jax.random.split(kl, cfg.n_layers))
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                cfg.param_dtype),
        "layers": layers,
        "final_norm": init_norm(cfg, cfg.d_model),
    }


def _fuse(p_l, a, m):
    af = rmsnorm(a, jnp.zeros((a.shape[-1],), a.dtype))
    mf = rmsnorm(m, jnp.zeros((m.shape[-1],), m.dtype))
    return 0.5 * (p_l["beta_attn"] * af.astype(jnp.float32)
                  + p_l["beta_ssm"] * mf.astype(jnp.float32)).astype(a.dtype)


def forward_features(cfg, params, tokens, ctx=None):
    del ctx
    x = embed(params["embed"], tokens).astype(cfg.compute_dtype)
    positions = jnp.arange(x.shape[1])

    def layer(p_l, x):
        h = apply_norm(cfg, p_l["ln1"], x)
        a, _ = attn_block(cfg, p_l["attn"], h, positions)
        m = mixer_fwd(cfg, p_l["mixer"], h)
        x = x + _fuse(p_l, a, m)
        return x + apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    def step(x, p_l):
        return layer(p_l, x), None

    x, _ = layer_scan(cfg, step, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    return x


def forward(cfg, params, tokens, ctx=None):
    x = forward_features(cfg, params, tokens, ctx)
    return unembed(params["embed"], x), jnp.zeros((), jnp.float32)


def loss_fn(cfg, params, batch, ctx=None):
    x = forward_features(cfg, params, batch["tokens"], ctx)
    return lm_loss_from_features(params["embed"], x[:, :-1],
                                 batch["tokens"][:, 1:], batch.get("mask"))


def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or cfg.compute_dtype
    kv_shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.d_head)
    one = init_mixer_cache(cfg, batch_size, dtype)
    return {
        "k": jnp.zeros(kv_shape, dtype),
        "v": jnp.zeros(kv_shape, dtype),
        "mixer": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg, params, tokens, max_len, ctx=None):
    del ctx
    b, s = tokens.shape
    x = embed(params["embed"], tokens).astype(cfg.compute_dtype)
    positions = jnp.arange(s)

    def step(x, p_l):
        h = apply_norm(cfg, p_l["ln1"], x)
        a, (k, v) = attn_block(cfg, p_l["attn"], h, positions)
        m, st = mixer_fwd(cfg, p_l["mixer"], h, return_state=True)
        x = x + _fuse(p_l, a, m)
        x = x + apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))
        return x, (k, v, st)

    x, (ks, vs, states) = layer_scan(cfg, step, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x[:, -1])
    pad = max_len - s
    return logits, {
        "k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "mixer": states,
        "pos": jnp.asarray(s, jnp.int32),
    }


def decode_step(cfg, params, cache, tokens, ctx=None):
    del ctx
    pos = cache["pos"]
    x = embed(params["embed"], tokens)[:, None, :].astype(cfg.compute_dtype)
    positions = pos[None, None].astype(jnp.float32) + jnp.zeros(
        (x.shape[0], 1), jnp.float32)

    def step(x, inp):
        p_l, k_c, v_c, mix_c = inp
        h = apply_norm(cfg, p_l["ln1"], x)
        q, k, v = _qkv(cfg, p_l["attn"], h, positions)
        k_c = jax.lax.dynamic_update_slice(k_c, k, (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v, (0, pos, 0, 0))
        o = decode_attention(q[:, 0], k_c, v_c, pos)
        a = apply_dense(p_l["attn"]["wo"],
                        o.reshape(x.shape[0], cfg.attn_dim))[:, None, :]
        m, new_mix = mixer_decode(cfg, p_l["mixer"], mix_c, h[:, 0])
        x = x + _fuse(p_l, a, m[:, None, :])
        x = x + apply_mlp(cfg, p_l["mlp"], apply_norm(cfg, p_l["ln2"], x))
        return x, (k_c, v_c, new_mix)

    x, (ks, vs, mixs) = layer_scan(
        cfg, step, x, (params["layers"], cache["k"], cache["v"], cache["mixer"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x[:, 0])
    return logits, {"k": ks, "v": vs, "mixer": mixs, "pos": pos + 1}
