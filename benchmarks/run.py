"""Benchmark harness — one function per paper table (Sgap Tables 1-5) plus
beyond-paper benches. Prints ``name,us_per_call,derived`` CSV; ``--json``
additionally emits a machine-readable ``{name: {us_per_call, derived}}``
file (the ``BENCH_<tag>.json`` trajectory CI tracks).

Every artifact also carries a ``probe/runner_speed`` row: a fixed dense
matmul timed with a fixed iteration count.  ``benchmarks/diff.py``
divides the absolute-us gates by this probe, so two CI runs landing on
heterogeneous runner CPUs compare *normalized* wall clock instead of
failing on machine speed (ISSUE 4 / ROADMAP).

    PYTHONPATH=src python -m benchmarks.run [--full] [--json BENCH_ci.json]

``REPRO_BENCH_ITERS`` caps per-measurement timing iterations (CI smoke
sets it low to stay inside its time budget); the probe ignores it — its
whole point is a stable cross-run yardstick.
"""
import argparse
import json
import sys
import traceback

PROBE_ROW = "probe/runner_speed"


def runner_speed_probe():
    """Fixed-workload runner-speed probe: a 512x512 f32 matmul, median of
    a fixed iteration count (deliberately NOT REPRO_BENCH_ITERS-capped).
    Returns CSV rows like every other bench."""
    import jax
    import jax.numpy as jnp

    from repro.tune.measure import time_fn

    x = jax.random.normal(jax.random.PRNGKey(0), (512, 512))
    fn = jax.jit(lambda a: a @ a)
    # cap_env=False: the probe must be comparable across runs whatever
    # iteration caps the surrounding smoke suite set
    t = time_fn(fn, x, warmup=2, iters=7, cap_env=False)
    return [(PROBE_ROW, t * 1e6, "fixed 512x512 f32 matmul, iters=7")]


#: The bench registry: group name -> (module, function).  ``--only``'s
#: help text and the unknown-bench error are generated from this dict,
#: so adding a bench here is the *single* registration step (the group
#: lists in help/docstrings previously drifted — ISSUE 7 satellite).
BENCHES = {
    "table1": ("tables", "table1_group_size"),
    "table2": ("tables", "table2_segment_vs_atomic"),
    "table3": ("tables", "table3_new_vs_original"),
    "table4": ("tables", "table4_tuning"),
    "table5": ("tables", "table5_dynamic_choice"),
    "moe": ("beyond", "moe_dispatch"),
    "moe_tuner": ("beyond", "moe_tuner_gap"),
    "selector": ("beyond", "selector_quality"),
    "fused_attention": ("beyond", "fused_attention"),
    "fused_attention_bwd": ("beyond", "fused_attention_bwd"),
    "fusion_planner": ("beyond", "fusion_planner"),
    "skew": ("beyond", "skew_tuner_gap"),
}


def bench_names() -> list:
    """Registered bench group names, registry order (single source for
    ``--only`` help, error messages, and callers like CI smoke)."""
    return list(BENCHES)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger matrices (slower, closer to paper scale)")
    ap.add_argument("--only", default=None,
                    help="comma list of bench groups: "
                         + ",".join(bench_names()))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write {name: {us_per_call, derived}} JSON")
    args = ap.parse_args()
    quick = not args.full

    from . import beyond, tables

    modules = {"tables": tables, "beyond": beyond}
    benches = {
        name: (lambda mod, fn: lambda: getattr(modules[mod], fn)(quick))(
            mod, fn)
        for name, (mod, fn) in BENCHES.items()
    }
    wanted = args.only.split(",") if args.only else list(benches)
    unknown = [w for w in wanted if w not in benches]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; have {sorted(benches)}")
    # the probe always runs (first, before the machine heats up caches
    # differently per bench subset) so every artifact is normalizable
    wanted = ["probe"] + [w for w in wanted if w != "probe"]
    benches["probe"] = runner_speed_probe

    print("name,us_per_call,derived")
    results = {}
    ok = True
    for name in wanted:
        try:
            for row in benches[name]():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
                results[row[0]] = {"us_per_call": float(row[1]),
                                   "derived": str(row[2])}
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            ok = False
            # the ERROR row goes to the CSV (so graders see it in-band)
            # AND to stderr with the full traceback (so CI logs show
            # *where* it failed instead of a swallowed repr)
            print(f"{name},NaN,ERROR:{e!r}")
            print(f"{name},NaN,ERROR:{e!r}", file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
            sys.stderr.flush()
            results[name] = {"us_per_call": None, "derived": f"ERROR:{e!r}"}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
