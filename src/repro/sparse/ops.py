"""High-level sparse ops: schedule selection + kernel dispatch.

``spmm(a, b)`` with ``schedule='auto'`` runs the data-aware selector
(core/selector.py) on the matrix statistics — the paper's Table-5
"dynamic choice" made a library default.
"""
from __future__ import annotations

from ..core.atomic_parallelism import KernelSchedule
from ..core.selector import select_schedule
from ..kernels import ops as kops
from .formats import CSR
from .random import matrix_stats

__all__ = ["spmm", "sddmm"]


def spmm(a, b, schedule="auto", *, impl: str = "pallas",
         interpret: bool = True):
    if schedule == "auto":
        if isinstance(a, CSR):
            schedule = select_schedule(matrix_stats(a), int(b.shape[1]))
        else:
            schedule = KernelSchedule("eb")
    return kops.spmm(a, b, schedule, impl=impl, interpret=interpret)


def sddmm(rows, cols, a, b, scale=None, **kw):
    return kops.sddmm(rows, cols, a, b, scale, **kw)
