"""Unified scheduling API — one user-facing `Schedule` object (DESIGN.md §3).

The paper's two contributions — changeable group size (challenge 1) and
user-defined reduction strategy (challenge 2) — used to be spread over
three overlapping types: ``AtomicParallelism`` (design-space point),
``KernelSchedule`` (kernel tiles + a stringly-typed strategy) and
``SegmentGroup`` (the schedule handle, never threaded into dispatch).
This module collapses them:

* :class:`Schedule` is the single handle every public op accepts
  (``repro.sparse.spmm/sddmm/segment_reduce`` take ``schedule=``).  It is
  constructible from every existing entry point:

  - ``Schedule.from_point(p)``    — an :class:`AtomicParallelism` point
    (the mapping that used to live in ``to_schedule``);
  - ``Schedule.named("EB+PR")``   — the four DA-SpMM points;
  - ``Schedule.auto(stats, n)``   — the data-aware selector;
  - ``Schedule.from_group(sg)``   — a :class:`SegmentGroup`;
  - :func:`as_schedule` coerces any of the above (or a name string).

* the **reduction-strategy registry** makes the paper's "user-defined
  reduction strategy" first-class: a strategy is a name plus

  - ``spec_fn(partials, seg_ids, num_segments, group_size)`` — the
    pure-JAX executable specification (the oracle), and
  - ``pallas_fn(rows, partial, out_ref, group_size)`` — the in-kernel
    realization (optional; kernels fall back to running the spec on the
    tile and accumulating the result).

  SEGMENT / PARALLEL / ACCUMULATE are registered built-ins; both the spec
  dispatcher (``core.segment_group.segment_group_reduce``) and the Pallas
  dispatcher (``kernels.common.group_reduce_scatter``) go through this
  registry, so a strategy registered once runs everywhere.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, Optional, Tuple

from .dtypes import canonical_value_dtype

from .segment_group import (
    MONOIDS,
    GroupReduceStrategy,
    Monoid,
    SegmentGroup,
    get_monoid,
    make_monoid,
    spec_accumulate,
    spec_parallel,
    spec_segment,
)

__all__ = [
    "ACTIVATIONS",
    "COLLECTIVES",
    "Epilogue",
    "ReductionStrategy",
    "Schedule",
    "as_schedule",
    "attach_pallas_impl",
    "available_strategies",
    "call_pallas_fn",
    "call_spec_fn",
    "get_strategy",
    "register_strategy",
    "schedule_axes",
    "strategy_name",
]


# ---------------------------------------------------------------------------
# Reduction-strategy registry (paper challenge 2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReductionStrategy:
    """A named reduction strategy.

    ``spec_fn(partials, seg_ids, num_segments, group_size) -> (S, C)``
        pure-JAX executable specification; serves as the oracle for any
        kernel realization of this strategy.
    ``pallas_fn(rows, partial, out_ref, group_size) -> None``
        in-kernel realization reducing ``partial`` (T, C) by ``rows`` (T,)
        into ``out_ref`` (S, C).  ``None`` means kernels run the spec on
        the tile and accumulate the result (correct, not tuned).
    ``monoid``
        the reduction monoid the strategy combines with (default add).
        Built-in strategies are monoid-generic: ``get_strategy(name,
        op="max")`` returns a variant entry carrying the max monoid.
        Both fns may (but need not) take a ``monoid`` keyword; the
        dispatchers pass it only when the signature accepts it, so 4-arg
        user strategies keep working.
    ``monoid_explicit``
        the strategy was registered with its own ``combine``/``identity``
        (such a strategy refuses a conflicting ``op=`` at dispatch).
    """

    name: str
    spec_fn: Callable
    pallas_fn: Optional[Callable] = None
    builtin: bool = False
    monoid: Monoid = MONOIDS["add"]
    monoid_explicit: bool = False


_REGISTRY: Dict[str, ReductionStrategy] = {}


def strategy_name(strategy) -> str:
    """Canonical registry name for an enum / string / entry handle."""
    if isinstance(strategy, GroupReduceStrategy):
        return strategy.value
    if isinstance(strategy, ReductionStrategy):
        return strategy.name
    return str(strategy)


def register_strategy(name: str, spec_fn: Callable,
                      pallas_fn: Optional[Callable] = None, *,
                      combine: "Callable | str | None" = None,
                      identity: float | None = None,
                      overwrite: bool = False) -> ReductionStrategy:
    """Register a user-defined reduction strategy under ``name``.

    ``combine``/``identity`` fix the strategy's reduction monoid: pass a
    registered monoid name ('max', 'min', ...) or a raw binary combine
    plus its identity (it must be commutative and associative).  Left
    unset, the strategy is monoid-generic over the add default and ops
    may select another via their ``op=`` argument.

    Returns the registry entry.  Re-registering an existing name requires
    ``overwrite=True`` (note: jit caches keyed on the old entry are not
    invalidated; use a fresh name when iterating interactively).
    """
    name = strategy_name(name)
    if name in _REGISTRY and not overwrite:
        raise ValueError(
            f"strategy {name!r} already registered "
            f"(available: {sorted(_REGISTRY)}); pass overwrite=True")
    monoid, explicit = MONOIDS["add"], False
    if combine is not None:
        explicit = True
        if isinstance(combine, str):
            monoid = get_monoid(combine)
        else:
            if identity is None:
                raise ValueError(
                    "a callable combine needs its identity= scalar")
            monoid = make_monoid(f"{name}-combine", combine, identity)
    elif identity is not None:
        raise ValueError("identity= is only meaningful with combine=")
    entry = ReductionStrategy(name=name, spec_fn=spec_fn,
                              pallas_fn=pallas_fn, monoid=monoid,
                              monoid_explicit=explicit)
    _REGISTRY[name] = entry
    return entry


def attach_pallas_impl(name: str, pallas_fn: Callable) -> ReductionStrategy:
    """Attach (or replace) the in-kernel realization of a registered
    strategy — used by ``kernels.common`` to supply the built-in Pallas
    implementations without a core -> kernels import."""
    entry = get_strategy(name)
    entry = dataclasses.replace(entry, pallas_fn=pallas_fn)
    _REGISTRY[entry.name] = entry
    return entry


def get_strategy(strategy, op=None) -> ReductionStrategy:
    """Resolve a strategy name/enum/entry to its registry record,
    specialized to monoid ``op`` when given (raises on unknown names —
    the schedule/cache layers rely on names being stable identities)."""
    name = strategy_name(strategy)
    try:
        entry = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown reduction strategy {name!r}; "
            f"available: {sorted(_REGISTRY)} "
            f"(register new ones with repro.core.register_strategy)"
        ) from None
    if op is None:
        return entry
    monoid = get_monoid(op)
    if monoid == entry.monoid:
        return entry
    if entry.monoid_explicit:
        if monoid == MONOIDS["add"]:
            # 'add' is the unspecified default: the strategy's own
            # registered combine wins
            return entry
        raise ValueError(
            f"strategy {name!r} was registered with its own combine "
            f"({entry.monoid.name}); it cannot run under op="
            f"{monoid.name!r}")
    return dataclasses.replace(entry, monoid=monoid)


def _accepts_monoid(fn: Callable) -> bool:
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins / partials without sigs
        return False
    return any(p.name == "monoid" or p.kind == p.VAR_KEYWORD
               for p in params.values())


def call_spec_fn(entry: ReductionStrategy, partials, seg_ids,
                 num_segments: int, group_size: int):
    """Invoke a strategy spec, passing the entry's monoid when the spec's
    signature accepts it (4-arg user specs are called unchanged)."""
    if _accepts_monoid(entry.spec_fn):
        return entry.spec_fn(partials, seg_ids, num_segments, group_size,
                             monoid=entry.monoid)
    return entry.spec_fn(partials, seg_ids, num_segments, group_size)


def call_pallas_fn(pallas_fn: Callable, rows, partial, out_ref,
                   group_size: int, monoid: Monoid):
    """Invoke an in-kernel realization, passing the monoid when its
    signature accepts it (4-arg user realizations are called unchanged)."""
    if _accepts_monoid(pallas_fn):
        return pallas_fn(rows, partial, out_ref, group_size, monoid=monoid)
    return pallas_fn(rows, partial, out_ref, group_size)


def available_strategies() -> Tuple[str, ...]:
    """Registered reduction-strategy names, sorted (built-ins plus any
    ``register_strategy`` extensions)."""
    return tuple(sorted(_REGISTRY))


def _register_builtins() -> None:
    for name, spec in (("segment", spec_segment),
                       ("parallel", spec_parallel),
                       ("accumulate", spec_accumulate)):
        _REGISTRY[name] = ReductionStrategy(name=name, spec_fn=spec,
                                            builtin=True)


_register_builtins()


# ---------------------------------------------------------------------------
# Kernel epilogues
# ---------------------------------------------------------------------------


def _act_relu(x):
    import jax.numpy as jnp

    return jnp.maximum(x, 0.0)


def _act_gelu(x):
    import jax

    return jax.nn.gelu(x)


def _act_silu(x):
    import jax

    return jax.nn.silu(x)


def _act_tanh(x):
    import jax.numpy as jnp

    return jnp.tanh(x)


def _act_sigmoid(x):
    import jax

    return jax.nn.sigmoid(x)


#: Activations an :class:`Epilogue` may name (applied in-kernel on the
#: f32 accumulator before the dtype cast).
ACTIVATIONS: Dict[str, Callable] = {
    "relu": _act_relu,
    "gelu": _act_gelu,
    "silu": _act_silu,
    "tanh": _act_tanh,
    "sigmoid": _act_sigmoid,
}


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Fused kernel epilogue spec (DESIGN.md §8).

    Describes the *structure* of the post-reduction work a kernel applies
    to its output block on the last reduction grid step — the arrays
    themselves (bias vector, residual matrix) are passed to the op
    alongside the data, so the spec stays static/hashable and can live on
    a :class:`Schedule` (and in the tuner cache).

    Semantics, in order:  ``y = act(acc + bias) + residual``, then cast
    to ``out_dtype`` — i.e. a GCN layer's ``act(A @ XW + b)`` plus a
    post-activation residual connection, in one pass over the nonzeros
    instead of three HBM round trips.

    activation   name in :data:`ACTIVATIONS` (or None);
    bias         a (+ bias-row) add over output columns is fused;
    residual     a post-activation element-wise residual add is fused;
    out_dtype    dtype name the kernel casts the output block to
                 (None = float32, the accumulator dtype).
    """

    activation: Optional[str] = None
    bias: bool = False
    residual: bool = False
    out_dtype: Optional[str] = None

    def __post_init__(self):
        if self.activation is not None and self.activation not in ACTIVATIONS:
            raise ValueError(
                f"unknown activation {self.activation!r}; "
                f"known: {sorted(ACTIVATIONS)}")
        if self.out_dtype is not None:
            import numpy as np

            np.dtype(self.out_dtype)  # raises on unknown names

    @property
    def is_noop(self) -> bool:
        """True when no epilogue work is attached (kernels then skip the
        epilogue step entirely; a no-op epilogue hashes/keys as '')."""
        return not (self.activation or self.bias or self.residual
                    or self.out_dtype)

    @property
    def tag(self) -> str:
        """Compact identity string ('' when no-op) — used by the tuner's
        schedule/cache keys."""
        parts = []
        if self.activation:
            parts.append(self.activation)
        if self.bias:
            parts.append("b")
        if self.residual:
            parts.append("r")
        if self.out_dtype:
            parts.append(str(self.out_dtype))
        return "+".join(parts)

    def apply(self, acc, bias=None, residual=None):
        """The executable spec: apply this epilogue to an accumulator
        (also what the kernels run in-kernel on the output block)."""
        import jax.numpy as jnp

        if self.bias:
            acc = acc + bias.astype(acc.dtype)
        if self.activation:
            acc = ACTIVATIONS[self.activation](acc)
        if self.residual:
            acc = acc + residual.astype(acc.dtype)
        if self.out_dtype:
            acc = acc.astype(jnp.dtype(self.out_dtype))
        return acc

    def extended(self, tail: "Epilogue") -> "Optional[Epilogue]":
        """Absorb ``tail`` (elementwise work that would run *after* this
        epilogue) into one fused epilogue, or return ``None`` when the
        fixed template order — ``cast(act(acc + bias) + residual)`` —
        cannot express the composition.

        This is the planner-rule target of ``repro.fuse``: an ``ewise``
        chain node fuses into the producing kernel's launch exactly when
        ``producer.epilogue.extended(node.epilogue)`` is not ``None``.
        The template absorbs fields strictly left to right, so a bias
        cannot land after an activation already did, a second activation
        never merges, and nothing lands after a dtype cast.
        """
        merged = self
        if self.out_dtype and not tail.is_noop:
            return None  # the cast is terminal: nothing fuses past it
        if tail.bias:
            if merged.bias or merged.activation or merged.residual:
                return None  # bias slot is before act/residual
            merged = dataclasses.replace(merged, bias=True)
        if tail.activation:
            if merged.activation or merged.residual:
                return None  # one activation, before the residual
            merged = dataclasses.replace(merged,
                                         activation=tail.activation)
        if tail.residual:
            if merged.residual:
                return None
            merged = dataclasses.replace(merged, residual=True)
        if tail.out_dtype:
            merged = dataclasses.replace(merged, out_dtype=tail.out_dtype)
        return merged


# ---------------------------------------------------------------------------
# The unified Schedule object
# ---------------------------------------------------------------------------


#: Collective-level realizations of the reduction strategies (DESIGN.md
#: §12): how a shard_map-distributed op combines per-shard partials.
#: 'row' ↔ parallel (disjoint outputs, no collective), 'nnz_ar' ↔ atomic
#: (all-reduce), 'nnz_rs' ↔ segment (reduce-scatter).
COLLECTIVES: Tuple[str, ...] = ("row", "nnz_ar", "nnz_rs")


@dataclasses.dataclass(frozen=True)
class Schedule:
    """TPU realization of a scheduling decision (DESIGN.md §3).

    kernel      'eb' (nnz-split) or 'rb' (row-split).
    nnz_tile    nnz per grid cell ('eb'); also the tile of the standalone
                ``segment_reduce`` kernel.
    row_tile    rows per grid cell ('rb').
    col_tile    dense columns per grid cell (coarsen × lane width).
    group_size  segment-group width G — sub-tile reduce width ('eb');
                vestigial for 'rb' (single writeback per row).
    strategy    name of a registered reduction strategy ('segment',
                'parallel', 'accumulate', or user-registered).
    epilogue    fused post-reduction work (:class:`Epilogue`); the no-op
                default keeps plain schedules unchanged.

    split_threshold / merge_threshold (DESIGN.md §11, 'eb' only) select
    the two-level skew partition: rows with at least ``split_threshold``
    nonzeros are split across dedicated groups (reduced 'parallel'
    per-group, partials combined by the registry's accumulate-style
    read-modify-write) and tail rows with at most ``merge_threshold``
    nonzeros are merged into shared groups (longer tail rows get
    group-aligned).  ``None`` (the default) keeps the standard
    single-level layout; the empirical tuner searches the thresholds per
    matrix fingerprint alongside group size, and cached records replay
    them measurement-free.

    collective (DESIGN.md §12) elevates the reduction strategy to the
    mesh: how a ``shard_map``-distributed op combines per-shard partials
    on the wire.  ``None`` (default) means single-device / caller-chosen;
    'row' is the parallel realization (pre-partitioned rows, no
    collective), 'nnz_ar' the atomic one (psum all-reduce of full-height
    partials), 'nnz_rs' the segment one (psum_scatter — each shard keeps
    its row slice, moving 1/P of the all-reduce bytes).  The distributed
    tuner searches it alongside the kernel tiling and cached records
    replay it measurement-free.

    value_dtype (DESIGN.md §13) is the storage-precision axis: the dtype
    the CSR value stream (and the gathered dense operand) is *moved* in.
    ``None`` (default) keeps float32; 'bfloat16'/'float16'/
    'float8_e4m3fn' store values narrow (fp8 degrades to bf16 with a
    warning on jax builds without the type); 'int8' selects the
    quantized value path (per-row scales, dequant fused into the
    reduction).  Accumulation is always f32 regardless (the
    ``upcast_f32`` contract), so this axis trades operand *bandwidth*
    for precision — the empirical tuner searches it under a parity-error
    budget and cached records replay it measurement-free.
    """

    # each field names the search axis that owns it (``metadata["axis"]``
    # matches a built-in in ``repro.tune.space``; ``schedule_axes()``
    # exposes the map) — adding a tuned field means adding/extending an
    # axis, not editing six tuners
    kernel: str = dataclasses.field(
        default="eb", metadata={"axis": "tiling"})
    nnz_tile: int = dataclasses.field(
        default=256, metadata={"axis": "tiling"})
    row_tile: int = dataclasses.field(
        default=8, metadata={"axis": "tiling"})
    col_tile: int = dataclasses.field(
        default=128, metadata={"axis": "tiling"})
    group_size: int = dataclasses.field(
        default=32, metadata={"axis": "strategy"})
    strategy: str = dataclasses.field(
        default="segment", metadata={"axis": "strategy"})
    epilogue: Epilogue = dataclasses.field(
        default=Epilogue(), metadata={"axis": "epilogue"})
    split_threshold: Optional[int] = dataclasses.field(
        default=None, metadata={"axis": "skew"})
    merge_threshold: Optional[int] = dataclasses.field(
        default=None, metadata={"axis": "skew"})
    collective: Optional[str] = dataclasses.field(
        default=None, metadata={"axis": "collective"})
    value_dtype: Optional[str] = dataclasses.field(
        default=None, metadata={"axis": "value_dtype"})

    def __post_init__(self):
        if self.kernel not in ("eb", "rb"):
            raise ValueError(f"kernel must be 'eb' or 'rb', got {self.kernel}")
        object.__setattr__(self, "strategy", strategy_name(self.strategy))
        get_strategy(self.strategy)  # raises on unregistered names
        if self.epilogue is None:
            object.__setattr__(self, "epilogue", Epilogue())
        elif isinstance(self.epilogue, dict):
            object.__setattr__(self, "epilogue", Epilogue(**self.epilogue))
        if self.kernel == "eb" and self.nnz_tile % self.group_size != 0:
            raise ValueError("nnz_tile must be a multiple of group_size")
        if self.split_threshold is not None or self.merge_threshold is not None:
            if self.kernel != "eb":
                raise ValueError(
                    "split/merge thresholds are an 'eb' (nnz-split) "
                    "feature: the rb kernel owns whole rows per cell and "
                    "has no group partition to rebalance")
            if self.split_threshold is not None and self.split_threshold < 1:
                raise ValueError("split_threshold must be >= 1")
            if self.merge_threshold is not None and self.merge_threshold < 0:
                raise ValueError("merge_threshold must be >= 0")
            if (self.split_threshold is not None
                    and self.merge_threshold is not None
                    and self.merge_threshold > self.split_threshold):
                raise ValueError(
                    f"merge_threshold ({self.merge_threshold}) must not "
                    f"exceed split_threshold ({self.split_threshold}): a "
                    "row cannot be both merged and split")
        if self.collective is not None and self.collective not in COLLECTIVES:
            raise ValueError(
                f"unknown collective {self.collective!r}; known: "
                f"{sorted(COLLECTIVES)} (or None for single-device)")
        # normalizes aliases ('bf16') and float32 -> None; raises on
        # unsupported names so a typo'd axis value fails at construction
        object.__setattr__(self, "value_dtype",
                           canonical_value_dtype(self.value_dtype))

    @property
    def is_skew(self) -> bool:
        """Whether this schedule carries a two-level skew partition."""
        return (self.split_threshold is not None
                or self.merge_threshold is not None)

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_point(cls, p, *, lane_width: int = 128, base_nnz_tile: int = 256,
                   base_row_tile: int = 8) -> "Schedule":
        """Map an :class:`AtomicParallelism` point ``{<x split, c col>, r}``
        to a concrete TPU schedule (DESIGN.md §2).

        GPU threads disappear on TPU; what survives is (a) how much sparse
        work a grid cell owns, (b) the reduction granularity G inside the
        cell, and (c) the dense-column tile.  ``x = g nnz`` scales the nnz
        tile; ``x = 1/g row`` means g-wide collaboration on a row, which on
        TPU is simply the row-split kernel (whole rows per cell, MXU does
        the intra-row reduction).  ``r`` becomes the segment-group width
        for nnz-split.
        """
        col_tile = max(lane_width, p.c * lane_width // 4)
        if p.split == "nnz":
            g = int(p.x) if p.x >= 1 else 1
            nnz_tile = base_nnz_tile * max(1, g // 8)
            group = p.r if p.r > 1 else min(32, nnz_tile)
            strategy = "segment" if p.r > 1 else "accumulate"
            # group must divide nnz_tile
            while nnz_tile % group:
                group //= 2
            return cls(kernel="eb", nnz_tile=nnz_tile, col_tile=col_tile,
                       group_size=max(group, 1), strategy=strategy)
        if p.x >= 1:
            row_tile = base_row_tile * int(p.x)
        else:
            # 1/g row: g-wide collaboration -> narrower row tile, wider
            # reduce; on TPU both land in the same row-split kernel.
            row_tile = base_row_tile
        return cls(kernel="rb", row_tile=row_tile, col_tile=col_tile,
                   group_size=p.r, strategy="parallel")

    @classmethod
    def named(cls, name: str, **kw) -> "Schedule":
        """One of the four DA-SpMM points: 'EB+PR', 'EB+SR', 'RB+PR',
        'RB+SR' (paper §3.3)."""
        from .atomic_parallelism import DA_SPMM_POINTS

        try:
            point = DA_SPMM_POINTS[name]
        except KeyError:
            raise ValueError(
                f"unknown schedule name {name!r}; "
                f"known: {sorted(DA_SPMM_POINTS)}") from None
        return cls.from_point(point, **kw)

    @classmethod
    def auto(cls, stats: dict, n_dense_cols: int) -> "Schedule":
        """Data-aware selection (the paper's Table-5 dynamic choice) from
        matrix statistics — see ``core.selector``."""
        from .selector import select_schedule

        return select_schedule(stats, n_dense_cols)

    @classmethod
    def tune(cls, matrix, n_dense_cols: int, **kw) -> "Schedule":
        """Empirically tuned schedule for ``matrix @ B`` — measures the
        top candidates (or replays the fingerprint cache) via
        ``repro.tune.tune_schedule``; ``**kw`` forwards (cache=, top_k=,
        ...)."""
        from ..tune import tune_schedule

        return tune_schedule(matrix, n_dense_cols, **kw).schedule

    @classmethod
    def from_group(cls, group: SegmentGroup, **kw) -> "Schedule":
        """Lift a :class:`SegmentGroup` (group width + strategy) into a
        full schedule; tiling fields come from ``**kw`` or defaults."""
        strategy = strategy_name(group.strategy)
        kw.setdefault("kernel", "eb")
        if kw["kernel"] == "eb":
            nnz_tile = kw.get("nnz_tile", Schedule.nnz_tile)
            if nnz_tile % group.group_size:
                kw["nnz_tile"] = _lcm_tile(nnz_tile, group.group_size)
        return cls(group_size=group.group_size, strategy=strategy, **kw)

    # -- views -------------------------------------------------------------

    @property
    def segment_group(self) -> SegmentGroup:
        """The reduction half of this schedule (round-trips through
        :meth:`from_group`)."""
        return SegmentGroup(group_size=self.group_size, strategy=self.strategy)

    def replace(self, **kw) -> "Schedule":
        """``dataclasses.replace`` shorthand — the tuner's hillclimb
        moves are built from this (validation re-runs, so an illegal
        move raises ``ValueError`` rather than producing a bad point)."""
        return dataclasses.replace(self, **kw)

    def with_epilogue(self, activation: Optional[str] = None, *,
                      bias: bool = False, residual: bool = False,
                      out_dtype: Optional[str] = None) -> "Schedule":
        """This schedule with a fused epilogue attached."""
        return self.replace(epilogue=Epilogue(
            activation=activation, bias=bias, residual=residual,
            out_dtype=out_dtype))

    def __str__(self):
        tile = (f"nnz_tile={self.nnz_tile}" if self.kernel == "eb"
                else f"row_tile={self.row_tile}")
        ep = ("" if self.epilogue.is_noop
              else f", epilogue={self.epilogue.tag}")
        sk = ("" if not self.is_skew
              else f", split>={self.split_threshold}"
                   f"/merge<={self.merge_threshold}")
        wire = ("" if self.collective is None
                else f", collective={self.collective}")
        vd = ("" if self.value_dtype is None
              else f", value_dtype={self.value_dtype}")
        return (f"Schedule({self.kernel}, {tile}, col_tile={self.col_tile}, "
                f"G={self.group_size}, strategy={self.strategy}{sk}{wire}"
                f"{vd}{ep})")


def schedule_axes() -> dict:
    """Search-axis name → the :class:`Schedule` fields it owns, read
    from the field metadata declared next to each field.  This is the
    authoritative field↔axis map the ``repro.tune.space`` built-ins (and
    their key fragments) are checked against."""
    out: dict = {}
    for f in dataclasses.fields(Schedule):
        out.setdefault(f.metadata.get("axis", "other"), []).append(f.name)
    return {k: tuple(v) for k, v in out.items()}


def _lcm_tile(tile: int, group: int) -> int:
    import math

    return tile * group // math.gcd(tile, group)


def as_schedule(s, *, stats: dict | None = None,
                n_dense_cols: int | None = None,
                matrix=None) -> Schedule:
    """Coerce any schedule-like value into a :class:`Schedule`.

    Accepts ``None`` (library default), a :class:`Schedule`, a DA-SpMM name
    ('EB+PR', ...), 'auto' (requires ``stats`` and ``n_dense_cols``),
    'tune' (requires ``matrix`` — a CSR — and ``n_dense_cols``; runs or
    replays the empirical autotuner), an :class:`AtomicParallelism`
    point, or a :class:`SegmentGroup`.
    """
    if s is None:
        return Schedule()
    if isinstance(s, Schedule):
        return s
    if isinstance(s, SegmentGroup):
        return Schedule.from_group(s)
    if isinstance(s, str):
        if s == "auto":
            if stats is None or n_dense_cols is None:
                raise ValueError(
                    "'auto' needs matrix statistics: pass stats= and "
                    "n_dense_cols= to as_schedule, or use an op that "
                    "derives them (repro.sparse.spmm)")
            return Schedule.auto(stats, n_dense_cols)
        if s == "tune":
            if matrix is None or n_dense_cols is None:
                raise ValueError(
                    "'tune' needs the matrix itself: pass matrix= (CSR) "
                    "and n_dense_cols= to as_schedule, or use an op that "
                    "supplies them (repro.sparse.spmm)")
            return Schedule.tune(matrix, n_dense_cols)
        return Schedule.named(s)
    from .atomic_parallelism import AtomicParallelism

    if isinstance(s, AtomicParallelism):
        return Schedule.from_point(s)
    raise TypeError(
        f"cannot interpret {type(s).__name__} as a Schedule; expected "
        "Schedule | SegmentGroup | AtomicParallelism | name | 'auto'")
