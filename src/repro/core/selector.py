"""Data-aware schedule selector (DA-SpMM-style, Sgap §7.2 Table 5).

Given matrix statistics and the dense-column count N, pick an
(atomic-parallelism) schedule. The decision mirrors the paper's findings:

* few dense columns (N <= 8): *balance*-bound -> nnz-split (EB) wins when
  row lengths are skewed; group size should shrink when rows are short
  (challenge 1: parallelism waste).
* many dense columns: *workload*-bound -> row-split (RB) with wide column
  tiles reuses the loaded sparse row across columns.
* segment strategy when writeback targets are runtime-dependent (high CV),
  parallel strategy when rows are long and regular.

Also exposes :func:`predict_cost` — the cost model used here, by the
§Perf hillclimb loop and by the empirical autotuner (``repro.tune``).
The model is a weighted sum of four raw terms (:func:`cost_terms`); the
weights default to the hand-set napkin values but are *calibratable*:
``repro.tune.calibrate`` least-squares fits them against measured
timings and installs the fit via :func:`set_cost_weights`.
"""
from __future__ import annotations

import math
from typing import Dict, Sequence, Tuple

from .schedule import Schedule
from .segment_group import group_waste_fraction

__all__ = [
    "select_schedule",
    "predict_cost",
    "predict_dist_cost",
    "collective_cost_terms",
    "candidate_schedules",
    "cost_terms",
    "COST_TERM_NAMES",
    "DEFAULT_COST_WEIGHTS",
    "WIRE_COST_WEIGHT",
    "get_cost_weights",
    "set_cost_weights",
]

COST_TERM_NAMES = ("work", "waste", "writeback", "gather")

#: Hand-set napkin weights (the pre-calibration model): cost =
#: work + waste + 2*writeback + 0.25*gather.
DEFAULT_COST_WEIGHTS: Tuple[float, float, float, float] = (1.0, 1.0, 2.0,
                                                           0.25)

_cost_weights: Tuple[float, float, float, float] = DEFAULT_COST_WEIGHTS


def get_cost_weights() -> Tuple[float, float, float, float]:
    """The active (work, waste, writeback, gather) term weights."""
    return _cost_weights


def set_cost_weights(weights: Sequence[float] | None) -> None:
    """Install calibrated term weights (``None`` restores the defaults).

    Affects every subsequent :func:`predict_cost` / ``Schedule.auto``
    call — this is how measured tuning data feeds back into the static
    selector (``repro.tune.calibrate``).
    """
    global _cost_weights
    if weights is None:
        _cost_weights = DEFAULT_COST_WEIGHTS
        return
    w = tuple(float(x) for x in weights)
    if len(w) != 4:
        raise ValueError(f"need 4 weights {COST_TERM_NAMES}, got {len(w)}")
    if any(x < 0 for x in w) or not any(x > 0 for x in w):
        raise ValueError(f"weights must be >= 0 with at least one > 0: {w}")
    _cost_weights = w


def candidate_schedules(n_dense_cols: int) -> list[Schedule]:
    """The tuning grid from the paper's dgSPARSE experiment, TPU-mapped:
    <groupSz, blockSz, tileSz, workerDimR> -> <G, nnz/row tile, col tile>."""
    cands = []
    col_tile = max(8, min(128, n_dense_cols))
    for g in (8, 16, 32, 64):
        for nnz_tile in (128, 256, 512):
            if nnz_tile % g:
                continue
            cands.append(Schedule("eb", nnz_tile=nnz_tile,
                                  col_tile=col_tile, group_size=g,
                                  strategy="segment"))
    for row_tile in (8, 16, 32):
        cands.append(Schedule("rb", row_tile=row_tile,
                              col_tile=col_tile, strategy="parallel"))
    return cands


def cost_terms(stats: Dict, sched: Schedule,
               n_dense_cols: int) -> Tuple[float, float, float, float]:
    """The four raw cost-model terms (lower = better, unweighted):

    work        nnz * C multiply-adds (same for every schedule);
    waste       zero-extension padding lanes (rb: rows padded to ELL width;
                eb: short rows padded to the group width) — grows with G;
    writeback   segment writeback events: one per row touched plus one
                carry per group boundary (eb) — the carry part *shrinks*
                with G, which is the paper's reason to widen groups; rb
                pays exactly one per row;
    gather      dense-row gather traffic ~ nnz * col_tile.

    waste and writeback pull G in opposite directions, so the
    waste:writeback weight ratio (calibratable — ``repro.tune``) decides
    the group size, exactly the machine-dependent trade the paper tunes.

    A narrow ``sched.value_dtype`` (DESIGN.md §13) rescales the two
    traffic-shaped terms by itemsize/4: gather by the *operand* width
    (B is read at the operand dtype) and waste by the *storage* width
    (padding lanes move value-stream bytes).  work and writeback are
    unchanged — accumulation and output stay f32.
    """
    nnz = max(1, stats["nnz"])
    C = max(1, n_dense_cols)
    row_mean = max(stats["row_mean"], 1e-3)
    row_max = max(stats["row_max"], 1)
    n_rows = max(1, stats["n_rows"])

    work = nnz * C
    if sched.kernel == "rb":
        # ELL pads every row to row_max
        waste = (row_max * n_rows - nnz) * C
        writeback = n_rows * C
    elif sched.is_skew and stats.get("row_quantiles"):
        waste, writeback = _skew_terms(stats, sched, nnz, C, row_mean,
                                       row_max)
    else:
        waste_frac = group_waste_fraction(
            [max(1, int(row_mean))], sched.group_size
        )
        waste = work * waste_frac
        # one writeback per row touched + one carry per group boundary
        groups = nnz / sched.group_size
        rows_touched = nnz / row_mean
        writeback = (rows_touched + groups) * C
    gather = nnz * min(C, sched.col_tile)
    if sched.value_dtype is not None:
        from .dtypes import operand_itemsize, value_itemsize

        waste *= value_itemsize(sched.value_dtype) / 4.0
        gather *= operand_itemsize(sched.value_dtype) / 4.0
    return (float(work), float(waste), float(writeback), float(gather))


def _frac_rows_above(quantiles, thr: float) -> float:
    """Approximate fraction of (non-empty) rows with length > ``thr`` by
    piecewise-linear interpolation of the ``(percent, length)`` quantile
    pairs from ``matrix_stats`` — the cost model's view of the histogram
    the fingerprint hashes."""
    pts = sorted(quantiles)
    if not pts:
        return 0.0
    if thr < pts[0][1]:
        return 1.0
    if thr >= pts[-1][1]:
        # beyond the top quantile: decay the top tail mass linearly
        return max(0.0, (100 - pts[-1][0]) / 100.0 / 2.0)
    for (p0, v0), (p1, v1) in zip(pts, pts[1:]):
        if v0 <= thr < v1:
            t = (thr - v0) / max(1e-9, v1 - v0)
            return 1.0 - (p0 + t * (p1 - p0)) / 100.0
    return 0.0


def _skew_terms(stats: Dict, sched: Schedule, nnz: float, C: float,
                row_mean: float, row_max: float) -> Tuple[float, float]:
    """waste/writeback under the two-level skew layout (DESIGN.md §11):
    the rebalanced histogram the thresholds produce, not the mean-row
    approximation.

    *Heavy* rows (length >= split) sit in dedicated groups padded to the
    group width — at most G-1 pad lanes per row, plus one extra combine
    writeback per heavy group.  *Merged* light rows (length <= merge)
    pack with zero padding.  Mid rows align to a group boundary — on
    average G/2 pad lanes each.
    """
    G = sched.group_size
    rq = stats["row_quantiles"]
    rows_touched = nnz / row_mean
    split = sched.split_threshold or float("inf")
    merge = sched.merge_threshold or 0
    frac_heavy = (0.0 if split == float("inf")
                  else _frac_rows_above(rq, split - 1))
    frac_mid = max(0.0, _frac_rows_above(rq, merge) - frac_heavy)
    heavy_rows = rows_touched * frac_heavy
    mid_rows = rows_touched * frac_mid
    # heavy nnz: mean heavy length approximated by the split/max midpoint
    heavy_nnz = (min(nnz, heavy_rows * (min(split, row_max) + row_max) / 2.0)
                 if heavy_rows > 0 else 0.0)
    waste = (heavy_rows * (G - 1) + mid_rows * G / 2.0) * C
    heavy_groups = (heavy_nnz + heavy_rows * (G - 1)) / G
    tail_groups = max(0.0, nnz - heavy_nnz) / G
    writeback = (rows_touched + heavy_groups + tail_groups) * C
    return float(waste), float(writeback)


def predict_cost(stats: Dict, sched: Schedule, n_dense_cols: int,
                 weights: Sequence[float] | None = None) -> float:
    """Weighted relative cost (lower = better): dot of :func:`cost_terms`
    with ``weights`` (default: the active, possibly calibrated, weights)."""
    w = _cost_weights if weights is None else tuple(weights)
    terms = cost_terms(stats, sched, n_dense_cols)
    return (w[0] * terms[0] + w[1] * terms[1]
            + w[2] * terms[2] + w[3] * terms[3])


#: Relative weight of one wire element vs one local element op in
#: :func:`predict_dist_cost`.  Interconnect bytes are far scarcer than
#: local FLOPs (ICI vs HBM bandwidth), so a wire element costs more than
#: a MAC; like the four local weights this is a ranking prior — the
#: distributed tuner's measurements decide.
WIRE_COST_WEIGHT = 8.0


def collective_cost_terms(collective, *, n_rows: int, n_dense_cols: int,
                          axis_size: int,
                          shard_nnz: "Sequence[int] | None" = None,
                          ) -> Tuple[float, float]:
    """``(wire_elems, imbalance)`` of a collective mode (DESIGN.md §12).

    wire_elems    per-device collective result *elements* — the bytes
                  model ``roofline.analysis.predict_collective_bytes``
                  divided by the itemsize: 'nnz_ar' moves the full
                  ``n_rows * N`` partial, 'nnz_rs' its 1/P row slice,
                  'row' nothing.
    imbalance     max/mean per-shard nnz (>= 1.0): the straggler factor
                  the slowest shard imposes on the whole step.  nnz
                  splits are balanced by construction; 'row' splits
                  inherit the row-block skew via ``shard_nnz``.
    """
    if axis_size <= 1 or collective in (None, "row"):
        wire = 0.0
    else:
        wire = float(n_rows * n_dense_cols)
        if collective == "nnz_rs":
            wire /= axis_size
        elif collective != "nnz_ar":
            raise ValueError(f"unknown collective {collective!r}")
    imbalance = 1.0
    if shard_nnz:
        mean = sum(shard_nnz) / len(shard_nnz)
        if mean > 0:
            imbalance = max(shard_nnz) / mean
    return wire, imbalance


def predict_dist_cost(stats: Dict, sched: Schedule, n_dense_cols: int, *,
                      axis_size: int,
                      shard_nnz: "Sequence[int] | None" = None) -> float:
    """Relative cost of a distributed schedule point: the local cost
    model scaled to the slowest shard, plus the wire term.

    local work is ~1/P of the single-device :func:`predict_cost` times
    the straggler factor; the collective adds ``WIRE_COST_WEIGHT``
    element-costs per wire element.  Used by ``repro.tune``'s
    distributed tuner to rank (tiling × collective) candidates before
    measuring — same role :func:`predict_cost` plays single-device.
    """
    wire, imbalance = collective_cost_terms(
        sched.collective, n_rows=stats["n_rows"],
        n_dense_cols=n_dense_cols, axis_size=axis_size,
        shard_nnz=shard_nnz)
    local = predict_cost(stats, sched, n_dense_cols) / max(axis_size, 1)
    return local * imbalance + WIRE_COST_WEIGHT * wire


def select_schedule(stats: Dict, n_dense_cols: int) -> Schedule:
    """Pick the argmin of the cost model over the candidate grid, with the
    paper's qualitative rules as a prior (they also act as tie-breakers)."""
    cands = candidate_schedules(n_dense_cols)
    best, best_cost = None, math.inf
    for s in cands:
        c = predict_cost(stats, s, n_dense_cols)
        # prior: high row-CV strongly prefers nnz-split + segment
        if stats.get("row_cv", 0.0) > 1.0 and s.kernel == "rb":
            c *= 1.0 + stats["row_cv"]
        if c < best_cost:
            best, best_cost = s, c
    return best
