"""Fusion IR / planner tests (ISSUE 6): legality, parity of fused vs.
split execution for the legal 2–3 node chains, illegal-fusion splits,
launch counting for the landed fusions (two-layer GCN ≤2 launches, MoE
expert GEMM 1 launch per tile), tuner-cache integration, and the
``grouped_matmul`` epilogue satellite.

Property tests follow the ``test_fusion.py`` convention: hypothesis when
installed, a fixed sweep over the same cases otherwise.
"""
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in the lean container
    HAVE_HYPOTHESIS = False

import repro.fuse as F
from repro.core import Epilogue, Schedule
from repro.kernels import ops as kops
from repro.sparse import random_csr
from repro.tune.cache import ScheduleCache, TuneRecord

RTOL = ATOL = 2e-4

EB = Schedule("eb", nnz_tile=64, group_size=8)
RB = Schedule("rb", row_tile=8)


# ---------------------------------------------------------------------------
# chain case builders: every legal 2–3 node chain shape over the node
# vocabulary (spmm / grouped_matmul anchors; ewise / reduce consumers)
# ---------------------------------------------------------------------------


def _gmm_problem(seed, t_tiles=4, tile=16, d=32, f=32, e=4):
    rng = np.random.default_rng(seed)
    t = t_tiles * tile
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    te = jnp.asarray(rng.integers(0, e, size=(t_tiles,)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(e, d, f)) * d ** -0.5, jnp.float32)
    b = jnp.asarray(rng.normal(size=(e, f)), jnp.float32)
    gp = {"tile_experts": te, "weights": w, "token_tile": tile,
          "f_tile": 16, "d_tile": 16}
    return x, b, gp


def build_case(kind, m, c, seed):
    """Returns (chain, params, x) for one chain shape."""
    rng = np.random.default_rng(seed)
    adj = random_csr(m, m, 0.12, seed=seed)
    x = jnp.asarray(rng.normal(size=(m, c)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(c,)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(m, c)), jnp.float32)
    sched = RB if kind.startswith("rb") else EB

    if kind in ("spmm-act", "rb-spmm-act"):
        return ([F.spmm_node(sched), F.ewise("relu")],
                [{"a": adj}, {}], x)
    if kind == "spmm-bias-act":
        return ([F.spmm_node(sched), F.ewise(bias=True),
                 F.ewise("tanh")],
                [{"a": adj}, {"bias": b}, {}], x)
    if kind == "spmm-act-res":
        return ([F.spmm_node(sched), F.ewise("gelu", bias=True),
                 F.ewise(residual=True)],
                [{"a": adj}, {"bias": b}, {"residual": res}], x)
    if kind == "spmm-act-spmm":
        w0 = jnp.asarray(rng.normal(size=(c, c)) * c ** -0.5, jnp.float32)
        return ([F.spmm_node(sched), F.ewise("relu", bias=True),
                 F.spmm_node(sched)],
                [{"a": adj, "w": w0}, {"bias": b}, {"a": adj}], x)
    if kind == "spmm-segred":
        # legal chain whose boundary must SPLIT (reduce consumer)
        seg = jnp.asarray(np.sort(rng.integers(0, max(m // 3, 1),
                                               size=(m,))), jnp.int32)
        return ([F.spmm_node(sched),
                 F.segment_reduce_node("sum", schedule=EB)],
                [{"a": adj}, {"seg_ids": seg,
                              "num_segments": max(m // 3, 1)}], x)
    if kind == "gmm-act":
        xg, _, gp = _gmm_problem(seed)
        return ([F.grouped_matmul_node(), F.ewise("silu")],
                [gp, {}], xg)
    if kind == "gmm-bias-act":
        xg, bg, gp = _gmm_problem(seed)
        return ([F.grouped_matmul_node(),
                 F.ewise("silu", bias=True)], [gp, {"bias": bg}], xg)
    if kind == "gmm-act-combine":
        xg, _, gp = _gmm_problem(seed)
        s = xg.shape[0]
        topi = jnp.asarray(rng.integers(0, s // 2, size=(s,)), jnp.int32)
        topv = jnp.asarray(rng.uniform(0.1, 1.0, size=(s,)), jnp.float32)
        return ([F.grouped_matmul_node(), F.ewise("silu"),
                 F.combine_node("sum")],
                [gp, {}, {"topi": topi, "topv": topv,
                          "num_tokens": s // 2}], xg)
    raise KeyError(kind)


CASES = ("spmm-act", "rb-spmm-act", "spmm-bias-act", "spmm-act-res",
         "spmm-act-spmm", "spmm-segred", "gmm-act", "gmm-bias-act",
         "gmm-act-combine")

FIXED_EXAMPLES = [(k, m, c, s)
                  for k in CASES
                  for m, c, s in ((24, 8, 0), (40, 5, 7))]


def _property(strategy_fn, examples, max_examples=18):
    if HAVE_HYPOTHESIS:
        def deco(f):
            return settings(max_examples=max_examples, deadline=None)(
                given(prob=strategy_fn())(f))

        return deco
    return pytest.mark.parametrize("prob", examples)


if HAVE_HYPOTHESIS:
    @st.composite
    def chain_problem(draw):
        kind = draw(st.sampled_from(CASES))
        m = draw(st.integers(10, 48))
        c = draw(st.integers(2, 10))
        seed = draw(st.integers(0, 2 ** 16))
        return kind, m, c, seed
else:
    chain_problem = None


@_property(chain_problem, FIXED_EXAMPLES)
def test_fused_and_split_match_spec(prob):
    """Every legal chain: the greedy (max-fused) plan AND the fully-
    split plan both match the unfused spec composition."""
    kind, m, c, seed = prob
    chain, params, x = build_case(kind, m, c, seed)
    ref = F.run_chain_ref(chain, x, params)
    for p in (F.plan(chain), F.split_all(chain)):
        out = F.run_plan(p, x, params)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=RTOL, atol=ATOL,
                                   err_msg=f"{kind}:{p.decision.tag}")


def test_plans_fuse_where_expected():
    """The greedy plan fuses exactly the boundaries the legality pass
    allows: every ewise boundary after an epilogue-capable anchor fuses,
    every reduce boundary splits with a recorded reason."""
    chain, _, _ = build_case("spmm-act-spmm", 24, 8, 0)
    p = F.plan(chain)
    assert p.decision.fused == (True, False)
    assert p.n_launches == 2 and len(p.launches) == 2
    assert p.reasons[0] == "" and "iteration space" in p.reasons[1]

    chain, _, _ = build_case("spmm-segred", 24, 8, 0)
    p = F.plan(chain)
    assert p.decision.fused == (False,)
    assert p.n_launches == 2

    chain, _, _ = build_case("gmm-act-combine", 24, 8, 0)
    p = F.plan(chain)
    assert p.decision.fused == (True, False)
    assert p.n_launches == 1  # combine is an XLA scatter, not a kernel


# ---------------------------------------------------------------------------
# illegal fusions: the legality pass must split (and say why)
# ---------------------------------------------------------------------------


def test_illegal_double_activation_splits():
    chain = [F.spmm_node(EB), F.ewise("relu"), F.ewise("relu")]
    p = F.plan(chain)
    assert p.decision.fused == (True, False)
    assert "cannot absorb" in p.reasons[1]


def test_illegal_bias_after_activation_splits():
    # template order is cast(act(acc+bias)+res): a bias landing after
    # the activation cannot fold into the same epilogue
    chain = [F.spmm_node(EB), F.ewise("relu"), F.ewise(bias=True)]
    p = F.plan(chain)
    assert p.decision.fused == (True, False)
    assert "cannot absorb" in p.reasons[1]


def test_illegal_ewise_after_cast_splits():
    chain = [F.spmm_node(EB), F.ewise("relu", out_dtype="bfloat16"),
             F.ewise("tanh")]
    p = F.plan(chain)
    assert p.decision.fused == (True, False)


def test_illegal_gmm_residual_splits():
    chain = [F.grouped_matmul_node(), F.ewise(residual=True)]
    p = F.plan(chain)
    assert p.decision.fused == (False,)
    assert "residual" in p.reasons[0]


def test_illegal_nonadditive_monoid_reason():
    chain = [F.grouped_matmul_node(), F.combine_node("min")]
    p = F.plan(chain)
    assert p.decision.fused == (False,)
    assert "monoid" in p.reasons[0]
    chain = [F.spmm_node(EB), F.segment_reduce_node("max")]
    p = F.plan(chain)
    assert "monoid" in p.reasons[0]


def test_illegal_ewise_into_segment_reduce_splits():
    chain = [F.segment_reduce_node("sum"), F.ewise("relu")]
    p = F.plan(chain)
    assert p.decision.fused == (False,)
    assert "no in-kernel epilogue slot" in p.reasons[0]


def test_decision_cannot_override_legality():
    """A cached decision bit never forces an illegal fusion."""
    chain = [F.spmm_node(EB), F.ewise("relu"), F.ewise("relu")]
    p = F.plan(chain, F.FuseDecision((True, True)))
    assert p.decision.fused == (True, False)


def test_decision_forces_split():
    chain, params, x = build_case("spmm-act", 24, 8, 0)
    p = F.plan(chain, F.FuseDecision((False,)))
    assert p.decision.fused == (False,) and len(p.launches) == 2
    assert p.reasons[0] == "split by decision"
    ref = F.run_chain_ref(chain, x, params)
    np.testing.assert_allclose(np.asarray(F.run_plan(p, x, params)),
                               np.asarray(ref), rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# rule registry: a new fusion is a planner rule
# ---------------------------------------------------------------------------


def test_register_rule_extends_planner():
    # a veto rule ahead of the built-in fold flips the plan to split...
    F.register_rule("test-veto",
                    lambda launch, node: (None, "vetoed by test")
                    if node.kind == "ewise" else None,
                    before="epilogue-fold")
    try:
        chain = [F.spmm_node(EB), F.ewise("relu")]
        p = F.plan(chain)
        assert p.decision.fused == (False,)
        assert p.reasons[0] == "vetoed by test"
    finally:
        F.unregister_rule("test-veto")
    # ...and unregistering restores the built-in behaviour
    assert F.plan([F.spmm_node(EB), F.ewise("relu")]).decision.fused == (
        True,)
    assert "epilogue-fold" in F.available_rules()


# ---------------------------------------------------------------------------
# landed fusions: launch counts + parity (the acceptance criteria)
# ---------------------------------------------------------------------------


def _count_calls(module, name):
    """Monkeypatch ``module.name`` with a counting wrapper; returns
    (calls list, restore fn)."""
    orig = getattr(module, name)
    calls = []

    def wrapper(*a, **k):
        calls.append(name)
        return orig(*a, **k)

    setattr(module, name, wrapper)
    return calls, lambda: setattr(module, name, orig)


def test_gcn_two_layer_two_launches_and_grads():
    from repro.models.layers import gcn_two_layer

    rng = np.random.default_rng(3)
    adj = random_csr(32, 32, 0.15, seed=3)
    x = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(8, 8)) * 0.3, jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(8, 4)) * 0.3, jnp.float32)
    b0 = jnp.asarray(rng.normal(size=(8,)), jnp.float32)

    chain, params = F.gcn_chain(adj, (w0, w1), (b0, None), schedule=EB)
    assert F.plan(chain).n_launches <= 2

    calls, restore = _count_calls(kops, "_spmm_eb")
    try:
        out = gcn_two_layer(adj, x, w0, w1, b0, schedule=EB)
    finally:
        restore()
    assert len(calls) == 2  # one Pallas launch per layer, epilogue fused

    ref = F.run_chain_ref(chain, x, params)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=RTOL, atol=ATOL)

    def loss(x_, w0_, w1_, b0_):
        return jnp.sum(gcn_two_layer(adj, x_, w0_, w1_, b0_,
                                     schedule=EB) ** 2)

    def loss_ref(x_, w0_, w1_, b0_):
        c, pr = F.gcn_chain(adj, (w0_, w1_), (b0_, None), schedule=EB)
        return jnp.sum(F.run_chain_ref(c, x_, pr) ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2, 3))(x, w0, w1, b0)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, w0, w1, b0)
    for a, r in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)


def test_moe_expert_chain_single_launch():
    x, b, gp = _gmm_problem(5)
    chain, params = F.moe_expert_chain(
        gp["tile_experts"], gp["weights"], b, token_tile=gp["token_tile"],
        f_tile=gp["f_tile"], d_tile=gp["d_tile"])
    p = F.plan(chain)
    assert p.n_launches == 1 and p.decision.fused == (True,)

    calls, restore = _count_calls(kops, "_gmm_pallas")
    try:
        out = F.run_plan(p, x, params)
    finally:
        restore()
    assert len(calls) == 1  # GEMM + SiLU + bias in ONE launch per tile

    ref = F.run_chain_ref(chain, x, params)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=RTOL, atol=ATOL)

    # grads fwd through the fused launch match the spec composition
    w = gp["weights"]

    def loss(x_, w_, b_):
        c, pr = F.moe_expert_chain(gp["tile_experts"], w_, b_,
                                   token_tile=gp["token_tile"],
                                   f_tile=gp["f_tile"],
                                   d_tile=gp["d_tile"])
        return jnp.sum(F.run_plan(F.plan(c), x_, pr) ** 2)

    def loss_ref(x_, w_, b_):
        c, pr = F.moe_expert_chain(gp["tile_experts"], w_, b_,
                                   token_tile=gp["token_tile"],
                                   f_tile=gp["f_tile"],
                                   d_tile=gp["d_tile"])
        return jnp.sum(F.run_chain_ref(c, x_, pr) ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r),
                                   rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# tuner integration: fuse/split choice recorded + replayed
# ---------------------------------------------------------------------------


def test_tune_plan_records_and_replays():
    chain, params, x = build_case("spmm-act", 24, 8, 0)
    cache = ScheduleCache(path=None)

    timings = {"F": 1e-3, "S": 2e-3}
    res = F.tune_plan(chain, x, params, cache=cache,
                      measure=lambda p: timings[p.decision.tag])
    assert res.schedule == F.FuseDecision((True,))
    assert not res.from_cache and res.key.startswith("fuse:")
    assert set(res.measured) == {"F", "S"}
    assert cache.get(res.key).schedule == res.schedule

    def boom(_):
        raise AssertionError("replay must not measure")

    res2 = F.tune_plan(chain, x, params, cache=cache, measure=boom)
    assert res2.from_cache and res2.schedule == res.schedule

    # the replayed decision plans identically
    assert F.plan(chain, res2.schedule).decision == F.plan(chain).decision


def test_tune_plan_can_prefer_split():
    chain, params, x = build_case("spmm-act", 24, 8, 1)
    cache = ScheduleCache(path=None)
    res = F.tune_plan(chain, x, params, cache=cache,
                      measure=lambda p: 1e-3 if "S" in p.decision.tag
                      else 5e-3)
    assert res.schedule == F.FuseDecision((False,))
    tuned = F.tuned_plan(chain, x, params, cache=cache)
    assert tuned.decision.fused == (False,)


def test_fuse_record_json_roundtrip():
    rec = TuneRecord(schedule=F.FuseDecision((True, False, True)),
                     us_per_call=12.5, measured={"FSF": 12.5})
    d = rec.to_json()
    assert d["kind"] == "fuse"
    rt = TuneRecord.from_json(d)
    assert rt.schedule == rec.schedule and rt.us_per_call == 12.5


def test_tune_plan_measures_real_execution():
    """Default objective really executes both candidate plans."""
    chain, params, x = build_case("gmm-act", 24, 8, 2)
    cache = ScheduleCache(path=None)
    res = F.tune_plan(chain, x, params, cache=cache, warmup=0, iters=1)
    assert res.us_per_call > 0 and len(res.measured) == 2


# ---------------------------------------------------------------------------
# satellite: grouped_matmul epilogue (bias / activation / out_dtype)
# ---------------------------------------------------------------------------


def test_grouped_matmul_epilogue_parity_and_narrowing():
    x, b, gp = _gmm_problem(9)
    ep = Epilogue(activation="silu", bias=True, out_dtype="bfloat16")
    out = kops.grouped_matmul(x, gp["tile_experts"], gp["weights"],
                              bias=b, epilogue=ep,
                              token_tile=gp["token_tile"],
                              f_tile=gp["f_tile"], d_tile=gp["d_tile"])
    assert out.dtype == jnp.bfloat16
    ref = kops.grouped_matmul_ref(x, gp["tile_experts"], gp["weights"],
                                  bias=b, epilogue=ep,
                                  token_tile=gp["token_tile"])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_grouped_matmul_rejects_residual_epilogue():
    x, _, gp = _gmm_problem(1)
    with pytest.raises(AssertionError):
        kops.grouped_matmul(x, gp["tile_experts"], gp["weights"],
                            epilogue=Epilogue(residual=True),
                            token_tile=gp["token_tile"],
                            f_tile=gp["f_tile"], d_tile=gp["d_tile"])


# ---------------------------------------------------------------------------
# satellite: MoE combine surfaces (min / mean)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", ["sum", "min", "mean"])
def test_moe_combine_monoids(op):
    rng = np.random.default_rng(11)
    s, d, t = 24, 6, 10
    y = jnp.asarray(rng.normal(size=(s, d)), jnp.float32)
    topi = jnp.asarray(rng.integers(0, t, size=(s,)), jnp.int32)
    topv = jnp.asarray(rng.uniform(0.1, 1.0, size=(s,)), jnp.float32)
    out = F.moe_combine(y, topi, topv, t, op=op)
    wy = np.asarray(y) * np.asarray(topv)[:, None]
    expect = np.zeros((t, d), np.float32)
    for tok in range(t):
        rows = wy[np.asarray(topi) == tok]
        if not len(rows):
            continue
        if op == "sum":
            expect[tok] = rows.sum(0)
        elif op == "min":
            expect[tok] = rows.min(0)
        else:
            expect[tok] = rows.mean(0)
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("combine", ["min", "mean"])
def test_apply_moe_combine_paths_agree(combine):
    from repro.configs import ARCHS, smoke_config
    from repro.models.moe import apply_moe, init_moe

    cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"])
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
    out_e, _ = apply_moe(cfg, p, x, None, combine=combine)
    out_p, _ = apply_moe(cfg.scaled(moe_pallas_dispatch=True), p, x, None,
                         combine=combine)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_p),
                               rtol=2e-4, atol=2e-4)
    assert not np.allclose(np.asarray(out_e),
                           np.asarray(apply_moe(cfg, p, x, None)[0]))


# ---------------------------------------------------------------------------
# satellite: the PR-4 ops._regroup shim is gone (grep-clean)
# ---------------------------------------------------------------------------


def test_regroup_shim_removed():
    assert not hasattr(kops, "_regroup")
    src = pathlib.Path(__file__).resolve().parents[1] / "src"
    offenders = [
        str(f) for f in src.rglob("*.py")
        if re.search(r"\b_regroup\b", f.read_text())
    ]
    assert offenders == [], f"_regroup shim references survive: {offenders}"
