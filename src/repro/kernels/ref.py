"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests ``assert_allclose`` against,
and the ``impl='ref'`` execution path of ``repro.sparse.ops``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_coo_ref(rows, cols, vals, b, n_rows):
    """SpMM from COO triplets: out[r] += val * B[c]  (segment-sum form)."""
    partial = vals[:, None].astype(jnp.float32) * b[cols].astype(jnp.float32)
    return jax.ops.segment_sum(partial, rows, num_segments=n_rows)


def spmm_ell_ref(ecols, evals, b, n_rows):
    """SpMM from ELL: per-row padded gather + reduce over the width axis."""
    gathered = b[ecols].astype(jnp.float32)  # (R, W, C)
    out = jnp.sum(evals[..., None].astype(jnp.float32) * gathered, axis=1)
    return out[:n_rows]


def spmm_dense_ref(a_dense, b):
    return a_dense.astype(jnp.float32) @ b.astype(jnp.float32)


def sddmm_ref(rows, cols, a, b, scale=None):
    """SDDMM: vals[t] = <A[rows[t]], B[cols[t]]> (optionally * scale[t])."""
    prod = jnp.sum(
        a[rows].astype(jnp.float32) * b[cols].astype(jnp.float32), axis=-1
    )
    if scale is not None:
        prod = prod * scale.astype(jnp.float32)
    return prod


def segment_reduce_ref(data, seg_ids, num_segments):
    return jax.ops.segment_sum(data.astype(jnp.float32), seg_ids,
                               num_segments=num_segments)


def grouped_matmul_ref(x, expert_ids, weights):
    """Per-token expert matmul: out[t] = x[t] @ W[expert_ids[t]].

    x: (T, D), expert_ids: (T,) int32, weights: (E, D, F) -> (T, F).
    Oracle uses a gather of the full expert weight per token (memory-heavy
    but simple); the kernel exploits sorted/aligned expert ids instead.
    """
    w = weights[expert_ids]  # (T, D, F)
    return jnp.einsum(
        "td,tdf->tf", x.astype(jnp.float32), w.astype(jnp.float32)
    )
