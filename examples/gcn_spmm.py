"""2-layer GCN on a synthetic graph with the Sgap SpMM at its core —
the paper's own motivating workload family (GNN aggregation).

Aggregation Ã·X runs through the segment-group SpMM (auto-selected
schedule); training uses plain jax.grad through the ref path (the Pallas
kernel is validated against it elsewhere).

    PYTHONPATH=src python examples/gcn_spmm.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import select_schedule
from repro.kernels import ref
from repro.sparse import CSR, random_csr
from repro.sparse.ops import spmm
from repro.sparse.random import matrix_stats

N_NODES, N_FEAT, N_CLASS = 256, 32, 4

# synthetic graph: random adjacency + self loops, symmetric-normalized
adj = random_csr(N_NODES, N_NODES, density=0.02, seed=0)
dense = np.asarray(adj.todense())
dense = ((dense + dense.T) > 0).astype(np.float32)
np.fill_diagonal(dense, 1.0)
deg = dense.sum(1)
norm = dense / np.sqrt(np.outer(deg, deg))
A = CSR.fromdense(norm)
coo = A.tocoo()

sched = select_schedule(matrix_stats(A), N_FEAT)
print(f"selected aggregation schedule: {sched}")

rng = np.random.default_rng(0)
feats = jnp.asarray(rng.standard_normal((N_NODES, N_FEAT)), jnp.float32)
# learnable task: labels from a random teacher GCN (graph-correlated)
w_teacher = jnp.asarray(rng.standard_normal((N_FEAT, N_CLASS)), jnp.float32)
labels = jnp.argmax(jnp.asarray(norm, jnp.float32) @ feats @ w_teacher,
                    axis=-1)
params = {
    "w1": jnp.asarray(rng.standard_normal((N_FEAT, 64)) * 0.1, jnp.float32),
    "w2": jnp.asarray(rng.standard_normal((64, N_CLASS)) * 0.1, jnp.float32),
}


def gcn_fwd(params, x):
    # layer 1: Ã X W1  (aggregation = the paper's SpMM)
    h = ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, x @ params["w1"],
                         N_NODES)
    h = jax.nn.relu(h)
    h = ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, h @ params["w2"],
                         N_NODES)
    return h


def loss_fn(params, x, y):
    logits = gcn_fwd(params, x)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(N_NODES), y])


# sanity: the Pallas segment-group kernel agrees with the training path
h0 = feats @ params["w1"]
np.testing.assert_allclose(
    np.asarray(spmm(A, h0, sched)),
    np.asarray(ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, h0, N_NODES)),
    rtol=1e-4, atol=1e-4)
print("pallas aggregation matches training path ✓")

step = jax.jit(jax.value_and_grad(loss_fn))
lr = 0.5
losses = []
for i in range(40):
    loss, grads = step(params, feats, labels)
    params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    losses.append(float(loss))
print(f"GCN loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert losses[-1] < losses[0] - 0.1
print("gcn_spmm complete ✓")
