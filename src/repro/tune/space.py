"""Axis-based search spaces — the one framework behind every tuner
(DESIGN.md §14).

PRs 2–9 grew six tuner entry points that each re-implemented the same
loop (candidate pool → cost warm start → top-k measurement → hillclimb
→ cache) and every new schedule axis — skew thresholds, ``collective``,
``value_dtype`` — had to be hand-threaded through each one.  This
module makes the *axis* the unit of composition instead:

* an :class:`Axis` bundles everything one searchable dimension needs —
  a pool-stage candidate generator (:meth:`Axis.cross` /
  :meth:`Axis.expand`), a winner-stage variant generator with its
  legality/parity gate (:meth:`Axis.variants`), hillclimb moves
  (:meth:`Axis.neighbors`), a cost-model hook (:meth:`Axis.cost`) and
  the schedule-key fragment it owns (:meth:`Axis.key_fragment`);
* a :class:`SearchSpace` composes axes (plus the per-tuner key fn,
  dedupe signature and feasibility filter) into the object
  :func:`repro.tune.driver.drive` consumes;
* the built-ins — :class:`TilingAxis`, :class:`StrategyAxis`,
  :class:`SkewAxis`, :class:`CollectiveAxis`, :class:`ValueDtypeAxis`,
  :class:`EpilogueAxis`, :class:`FuseBoundaryAxis` (and the MoE
  dispatch pair :class:`MoeTilingAxis` / :class:`CapacityAxis`) — cover
  every axis the six tuners search today.

The key-fragment encoders are load-bearing: ``schedule_key`` is the
concatenation of the Schedule axes' fragments in declaration order, so
an axis owns its cache-key syntax the same way it owns its moves.
:func:`repro.core.schedule_axes` maps the same axis names to the
:class:`~repro.core.Schedule` fields they own (the field metadata lives
next to the field), and the test suite pins the two views together.

Adding an axis (the §14 walkthrough): subclass :class:`Axis`, implement
the hooks your dimension needs (most need only one or two), give its
``Schedule`` field ``metadata={"axis": <name>}``, and append an
instance to the space of every tuner that should search it — the driver
picks it up with no per-tuner loop changes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

__all__ = [
    "Axis",
    "CapacityAxis",
    "CollectiveAxis",
    "EpilogueAxis",
    "FuseBoundaryAxis",
    "MoeTilingAxis",
    "SCHEDULE_AXES",
    "SearchContext",
    "SearchSpace",
    "SkewAxis",
    "StrategyAxis",
    "TilingAxis",
    "ValueDtypeAxis",
    "schedule_key",
]

# hillclimb move bounds shared by the tiling axes (the grid the paper's
# Table-4 search walks)
_MIN_TILE, _MAX_NNZ_TILE = 32, 2048
_MAX_ROW_TILE = 128


@dataclasses.dataclass
class SearchContext:
    """Workload facts the axes read: matrix statistics, the dense width,
    the mesh extent for distributed spaces, the workload handle itself
    (CSR / expert histogram / fuse chain) and a free-form ``extra`` dict
    for tuner-specific knobs (e.g. the MoE capacity-factor ladder)."""

    stats: Optional[dict] = None
    n_dense_cols: Optional[int] = None
    axis_size: int = 1
    workload: object = None
    extra: dict = dataclasses.field(default_factory=dict)


class Axis:
    """One searchable dimension.  Every hook has a no-op default so an
    axis implements only the stages it participates in; ``drive`` calls
    them at fixed points of the one shared search loop."""

    name = "axis"

    def cross(self, ctx: SearchContext, pool: List) -> List:
        """Pool-stage crossing *before* cost ranking (e.g. seed tilings
        × feasible collectives).  Returns the new pool."""
        return pool

    def expand(self, ctx: SearchContext, pool: List, ranked: Sequence) -> List:
        """Extra pool entries *after* the top-k cut (e.g. kernel-family
        diversity, skew entry points).  Sees the pool built so far."""
        return []

    def neighbors(self, ctx: SearchContext, point) -> List:
        """Hillclimb moves around ``point`` along this axis."""
        return []

    def variants(self, ctx: SearchContext, best, memo) -> List:
        """Winner-stage variants of the measured pool winner (e.g. the
        dtype axis), already gated by :meth:`admit`."""
        return []

    def admit(self, ctx: SearchContext, point) -> bool:
        """Legality/parity gate for a point along this axis."""
        return True

    def key_fragment(self, point) -> str:
        """The schedule-key substring this axis owns ('' when the point
        sits at the axis default)."""
        return ""

    def cost(self, ctx: SearchContext, point) -> float:
        """Additive cost-model term for ranking (0.0 when the base cost
        model already prices this axis)."""
        return 0.0


class SearchSpace:
    """A tuner's declared space: its axes plus the point-identity pieces
    the driver needs (key fn, dedupe signature, persisted record form,
    neighbor feasibility filter)."""

    def __init__(self, axes: Sequence[Axis], *,
                 key_fn: Callable[[object], str],
                 dedupe: Optional[Callable] = None,
                 record_of: Optional[Callable] = None,
                 neighbor_filter: Optional[Callable] = None):
        self.axes = tuple(axes)
        self.key_fn = key_fn
        self._dedupe = dedupe
        self._record_of = record_of
        self._neighbor_filter = neighbor_filter

    def cross(self, ctx: SearchContext, seeds: Sequence) -> List:
        """Apply every axis's pool-stage crossing to the seed points."""
        pool = list(seeds)
        for ax in self.axes:
            pool = ax.cross(ctx, pool)
        return pool

    def rank(self, ctx: SearchContext, cands: Sequence,
             base_cost: Callable[[object], float]) -> List:
        """Cost-rank candidates: the tuner's base model plus each axis's
        additive term (stable sort, so equal-cost order is preserved)."""
        return sorted(cands, key=lambda s: base_cost(s) + sum(
            ax.cost(ctx, s) for ax in self.axes))

    def neighbors(self, ctx: SearchContext, point) -> List:
        """Union of the axes' hillclimb moves (axis declaration order),
        run through the space's feasibility filter."""
        out: List = []
        for ax in self.axes:
            out.extend(ax.neighbors(ctx, point))
        if self._neighbor_filter is not None:
            out = self._neighbor_filter(ctx, out)
        return out

    def variants(self, ctx: SearchContext, best, memo) -> List:
        """Union of the axes' winner-stage variants."""
        out: List = []
        for ax in self.axes:
            out.extend(ax.variants(ctx, best, memo))
        return out

    def dedupe(self, ctx: SearchContext, point):
        """Pool-identity signature (default: the point itself — frozen
        schedule dataclasses hash by value)."""
        return point if self._dedupe is None else self._dedupe(ctx, point)

    def record_of(self, point):
        """The object persisted in the :class:`TuneRecord` for a
        measured point (default: the point; the fuse space stores the
        plan's :class:`FuseDecision`)."""
        return point if self._record_of is None else self._record_of(point)


# ---------------------------------------------------------------------------
# Built-in Schedule axes (SpMM / segment-reduce / attention / dist)
# ---------------------------------------------------------------------------


class TilingAxis(Axis):
    """Kernel choice + tile shape: ``kernel``, ``nnz_tile``, ``row_tile``
    and ``col_tile``.  Hillclimb takes x2 / /2 tile moves; ``col_tile``
    is deliberately not searched — the jitted measurement analogues run
    the full dense width in one program, so a col_tile move would be
    selected by pure timing noise.  ``expand`` seeds kernel-family
    diversity: the cost model can rank one family's whole grid above the
    other's, but hillclimb only explores *within* a family."""

    name = "tiling"

    def expand(self, ctx, pool, ranked):
        """Seed the missing kernel family from the ranked pool."""
        out = []
        for kernel in ("eb", "rb"):
            fam = next((s for s in ranked if s.kernel == kernel), None)
            if fam is not None and not any(s.kernel == kernel for s in pool):
                out.append(fam)
        return out

    def neighbors(self, ctx, s):
        """x2 / /2 moves on the active family's tile size."""
        out = []
        if s.kernel == "eb":
            for t in (s.nnz_tile * 2, s.nnz_tile // 2):
                if (max(_MIN_TILE, s.group_size) <= t <= _MAX_NNZ_TILE
                        and t != s.nnz_tile):
                    _try_replace(out, s, nnz_tile=t)
        else:
            for rt in (s.row_tile * 2, s.row_tile // 2):
                if 1 <= rt <= _MAX_ROW_TILE and rt != s.row_tile:
                    _try_replace(out, s, row_tile=rt)
        return out

    def key_fragment(self, s):
        """Leading ``{kernel}:t{tile}:c{col_tile}`` fragment."""
        tile = s.nnz_tile if s.kernel == "eb" else s.row_tile
        return f"{s.kernel}:t{tile}:c{s.col_tile}"


class StrategyAxis(Axis):
    """Segment-group width × reduction strategy — the paper's two
    contributions as one axis (``group_size`` moves; the strategy name
    itself flips via the candidate grid, not hillclimb)."""

    name = "strategy"

    def neighbors(self, ctx, s):
        """x2 / /2 moves on the eb group size (bounded by the tile)."""
        out = []
        if s.kernel == "eb":
            for g in (s.group_size * 2, s.group_size // 2):
                if 1 <= g <= s.nnz_tile and g != s.group_size:
                    _try_replace(out, s, group_size=g)
        return out

    def key_fragment(self, s):
        """``:G{group_size}:{strategy}`` fragment."""
        return f":G{s.group_size}:{s.strategy}"


class SkewAxis(Axis):
    """Two-level skew partitioning (DESIGN.md §11): ``split_threshold``
    / ``merge_threshold``.  ``expand`` seeds quantile-placed entry
    points on high-CV matrices; hillclimb refines them with x2 / /2
    moves plus the escape hatch back to the plain layout."""

    name = "skew"

    def expand(self, ctx, pool, ranked):
        """Quantile-seeded skew entry points on high-CV matrices."""
        stats = ctx.stats or {}
        return [s for s in _skew_candidates(stats, list(pool) + list(ranked))
                if s not in pool]

    def neighbors(self, ctx, s):
        """Threshold x2 / /2 walks plus the plain-layout escape."""
        out = []
        if s.kernel != "eb" or not s.is_skew:
            return out
        # skew thresholds are searched like the tile axes: x2 / /2 moves
        # (invalid combinations — e.g. merge > split — are rejected by
        # Schedule validation), plus the escape hatch back to the plain
        # layout
        if s.split_threshold is not None:
            for st in (s.split_threshold * 2, s.split_threshold // 2):
                if st >= 1 and st != s.split_threshold:
                    _try_replace(out, s, split_threshold=st)
        mt = s.merge_threshold
        if mt is not None:
            for m in {mt * 2, mt // 2, mt + 1 if mt == 0 else 0}:
                if m is not None and m >= 0 and m != mt:
                    _try_replace(out, s, merge_threshold=m)
        _try_replace(out, s, split_threshold=None, merge_threshold=None)
        return out

    def key_fragment(self, s):
        """``:s{split}:m{merge}`` fragment; empty on plain layouts."""
        return (f":s{s.split_threshold}:m{s.merge_threshold}"
                if s.is_skew else "")


class CollectiveAxis(Axis):
    """Mesh-level wire mode (DESIGN.md §12).  A collective flip
    re-partitions the operands, so it is a *pool* move (``cross``), not
    a neighbor move — hillclimb holds the collective fixed."""

    name = "collective"

    def __init__(self, modes: Sequence[str] = ()):
        self.modes = tuple(modes)

    def cross(self, ctx, pool):
        """Multiply the pool by every feasible wire mode."""
        if not self.modes:
            return pool
        out = []
        for s in pool:
            for mode in self.modes:
                cand = s.replace(collective=mode)
                if cand not in out:
                    out.append(cand)
        return out

    def admit(self, ctx, s):
        """Reject collectives outside the feasible mode set."""
        return s.collective is None or s.collective in self.modes

    def key_fragment(self, s):
        """``:w[{collective}]`` fragment; empty when unset."""
        return "" if s.collective is None else f":w[{s.collective}]"


class ValueDtypeAxis(Axis):
    """Storage-precision axis (DESIGN.md §13), searched at the winner
    stage: the dtype rescales traffic uniformly across tilings, so each
    admitted dtype is measured as a variant of the measured pool winner
    instead of crossing the whole grid.  ``parity(ctx, dtype)`` is the
    admission gate — the relative L2 storage-parity error vs the f32
    oracle must fit ``error_budget``."""

    name = "value_dtype"

    def __init__(self, dtypes: Sequence[str] = (),
                 error_budget: float = 0.05,
                 parity: Optional[Callable] = None):
        self.dtypes = tuple(dtypes)
        self.error_budget = error_budget
        self.parity = parity

    def variants(self, ctx, best, memo):
        """Parity-admitted narrow-storage replacements of the winner."""
        out = []
        for vd in self.dtypes:
            try:
                cand = best.replace(value_dtype=vd)
            except (TypeError, ValueError):
                continue
            if cand.value_dtype is None or memo.seen(cand):
                continue  # alias of f32 (or already measured) — skip
            if self.admit(ctx, cand):
                out.append(cand)
        return out

    def admit(self, ctx, s):
        """Parity gate: storage error must fit ``error_budget``."""
        if s.value_dtype is None or self.parity is None:
            return True
        try:
            err = self.parity(ctx, s.value_dtype)
        except (TypeError, ValueError):
            return False  # e.g. int8 under a traced / unquantizable input
        return err <= self.error_budget

    def key_fragment(self, s):
        """``:v[{dtype}]`` fragment; empty for f32 storage."""
        return "" if s.value_dtype is None else f":v[{s.value_dtype}]"


class EpilogueAxis(Axis):
    """Fused epilogue (DESIGN.md §8).  Not *searched* — the workload
    dictates the fused work — but it owns a key fragment: an epilogued
    point measures a different program than the plain one."""

    name = "epilogue"

    def key_fragment(self, s):
        """``:ep[{tag}]`` fragment; empty for the no-op epilogue."""
        ep = s.epilogue
        return "" if ep.is_noop else f":ep[{ep.tag}]"


#: The Schedule axes in key-fragment order — ``schedule_key`` is their
#: concatenation, so each axis owns its own slice of the cache-key
#: syntax.  The byte format is pinned by tests: changing a fragment is a
#: schema event (bump ``tune.cache.SCHEMA_VERSION``).
SCHEDULE_AXES = (TilingAxis(), StrategyAxis(), SkewAxis(),
                 CollectiveAxis(), ValueDtypeAxis(), EpilogueAxis())


def schedule_key(s) -> str:
    """Stable string identity of a schedule point (JSON-safe dict key),
    composed from the built-in axes' key fragments.

    Skew thresholds are part of the identity: a skew-partitioned point
    measures a different program than the plain point with the same
    tiling, so they must not share a memo/cache slot.  So is the
    collective mode (DESIGN.md §12): the same local tiling under
    all-reduce and reduce-scatter are different distributed programs —
    and the value dtype (DESIGN.md §13): bf16 storage moves half the
    bytes of the f32 point with the same tiling.  Axis defaults add no
    suffix, so pre-axis keys are unchanged."""
    return "".join(ax.key_fragment(s) for ax in SCHEDULE_AXES)


# ---------------------------------------------------------------------------
# MoE dispatch axes
# ---------------------------------------------------------------------------


class MoeTilingAxis(Axis):
    """MoE grouped-GEMM blocking: token_tile × f_tile × d_tile with
    x2 / /2 hillclimb moves over the candidate grid's range."""

    name = "moe_tiling"

    def __init__(self, tiles: Sequence[int]):
        self.tiles = tuple(tiles)

    def neighbors(self, ctx, s):
        """x2 / /2 moves per tile field within the grid's range."""
        out = []
        for field in ("token_tile", "f_tile", "d_tile"):
            v = getattr(s, field)
            for nv in (v * 2, v // 2):
                if self.tiles[0] <= nv <= self.tiles[-1] and nv != v:
                    out.append(s.replace(**{field: nv}))
        return out

    def key_fragment(self, s):
        """Leading ``moe:tt..:f..:d..`` fragment."""
        return f"moe:tt{s.token_tile}:f{s.f_tile}:d{s.d_tile}"


class CapacityAxis(Axis):
    """Per-expert capacity factor, hillclimbed over the *drop-
    constrained* ladder the candidate grid admitted (adjacent rungs
    only — capacity is a quality knob, so moves never leave the
    pre-vetted ladder)."""

    name = "capacity"

    def __init__(self, factors: Sequence[float]):
        self.factors = list(factors)

    def neighbors(self, ctx, s):
        """Adjacent rungs of the drop-constrained capacity ladder."""
        out = []
        if s.capacity_factor in self.factors:
            i = self.factors.index(s.capacity_factor)
            for j in (i - 1, i + 1):
                if 0 <= j < len(self.factors):
                    out.append(s.replace(capacity_factor=self.factors[j]))
        return out

    def key_fragment(self, s):
        """``:cf{factor}`` fragment."""
        return f":cf{s.capacity_factor:g}"


# ---------------------------------------------------------------------------
# Fuse-boundary axis (the planner's per-boundary decisions)
# ---------------------------------------------------------------------------


class FuseBoundaryAxis(Axis):
    """Per-boundary fuse/split bits of a chain plan.  Points are
    *realized* :class:`~repro.fuse.ir.FusePlan`\\ s; a neighbor flips one
    boundary bit and re-plans, so legality is never overridden (an
    illegal fuse realizes back to a split and dedupes away).  This is
    what turns ``tune_plan`` from an all-or-nothing choice into a
    per-boundary search on 3+-node chains."""

    name = "fuse_boundary"

    def __init__(self, chain):
        self.chain = tuple(chain)

    def neighbors(self, ctx, point):
        """Single-boundary-bit flips, realized through ``plan()``."""
        from ..fuse.ir import FuseDecision
        from ..fuse.planner import plan as _plan

        out = []
        bits = point.decision.fused
        for i in range(len(bits)):
            flipped = bits[:i] + (not bits[i],) + bits[i + 1:]
            out.append(_plan(self.chain, FuseDecision(flipped)))
        return out

    def key_fragment(self, point):
        """The plan's boundary tag (e.g. ``FSF``)."""
        return point.decision.tag


# ---------------------------------------------------------------------------
# Shared candidate helpers
# ---------------------------------------------------------------------------


def _try_replace(out: List, s, **kw) -> None:
    """Append ``s.replace(**kw)`` when the schedule validates (invalid
    moves — e.g. merge > split — are silently rejected)."""
    try:
        out.append(s.replace(**kw))
    except ValueError:
        pass


def _skew_candidates(stats: dict, seeds: List) -> List:
    """Two-level skew variants of the best eb seed for high-CV matrices.

    Thresholds come from the ``row_quantiles`` in ``matrix_stats`` (the
    same histogram the cache fingerprint hashes, so a cached decision
    replays measurement-free): split at ~q90/q99 so only genuine hubs
    pay the cross-group combine, merge at ~q50 so the light-row majority
    packs densely.  Low-CV matrices get no candidates — the plain layout
    already balances them.
    """
    rq = dict(stats.get("row_quantiles") or ())
    if stats.get("row_cv", 0.0) <= 1.0 or not rq:
        return []
    base = next((s for s in seeds if s.kernel == "eb" and not s.is_skew),
                None)
    if base is None:
        return []
    q50, q90, q99 = rq.get(50, 0), rq.get(90, 0), rq.get(99, 0)
    out: List = []
    for split_q in (q90, q99):
        split = max(2, base.group_size, int(split_q))
        merge = max(0, min(int(q50), split))
        for m in {merge, 0}:
            try:
                s = base.replace(split_threshold=split, merge_threshold=m)
            except ValueError:
                continue
            if s not in out:
                out.append(s)
    return out
