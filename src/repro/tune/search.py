"""Empirical schedule search over the atomic-parallelism space.

The paper's dgSPARSE result (1.6x–2.3x, Table 4) comes from *tuning*
``<groupSz, blockSz, tileSz, workerDim>``, not from a fixed heuristic.
:func:`tune_schedule` makes that search a library call:

1. **warm start** — rank :func:`~repro.core.candidate_schedules` by the
   static cost model (:func:`~repro.core.predict_cost`), prune points
   whose working set overflows VMEM;
2. **measure** — time the top-k candidates plus the selector's own pick
   (``Schedule.auto`` is always in the measured pool, so the tuned
   choice can never lose to it beyond timing noise);
3. **dtype axis** — re-measure the winner under each narrow value dtype
   (``DEFAULT_VALUE_DTYPES``) whose storage-parity error fits the
   ``error_budget`` — precision is a tuned knob, not a global switch
   (DESIGN.md §13);
4. **hillclimb** — take x2 / /2 steps on ``group_size`` and the tile
   fields around the measured winner until no neighbor improves;
5. **cache** — persist the winner in the :class:`~.cache.ScheduleCache`
   under the matrix fingerprint, so serving/training loops tune once and
   replay (a hit performs *zero* measurements).

``measure=`` is injectable (schedule -> seconds) for tests and for
calibration replays.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core import (COLLECTIVES, Schedule, candidate_schedules, predict_cost,
                    predict_dist_cost, select_schedule)
from ..kernels.ops import schedule_fits_vmem
from ..sparse.random import matrix_stats
from .cache import ScheduleCache, TuneRecord, cache_key, default_cache
from .measure import measure_dist_schedule, measure_schedule, time_fn

__all__ = [
    "DEFAULT_VALUE_DTYPES",
    "TuneResult",
    "cached_or_auto",
    "schedule_key",
    "tune_dist_spmm",
    "tune_schedule",
    "tune_segment_reduce",
]

#: Dtype-axis candidates measured by default (DESIGN.md §13).  fp8 is
#: deliberately absent: on backends without native fp8 it silently
#: degrades to bf16 (``core.dtypes.storage_dtype``), so tuning would
#: just measure bf16 twice; pass ``value_dtypes=("float8_e4m3fn", ...)``
#: explicitly on hardware that has it.
DEFAULT_VALUE_DTYPES = ("bfloat16", "float16", "int8")


def schedule_key(s: Schedule) -> str:
    """Stable string identity of a schedule point (JSON-safe dict key).

    Skew thresholds are part of the identity: a skew-partitioned point
    measures a different program than the plain point with the same
    tiling, so they must not share a memo/cache slot.  So is the
    collective mode (DESIGN.md §12): the same local tiling under
    all-reduce and reduce-scatter are different distributed programs —
    and the value dtype (DESIGN.md §13): bf16 storage moves half the
    bytes of the f32 point with the same tiling.  ``value_dtype=None``
    adds no suffix, so pre-dtype-axis keys are unchanged."""
    tile = s.nnz_tile if s.kernel == "eb" else s.row_tile
    ep = "" if s.epilogue.is_noop else f":ep[{s.epilogue.tag}]"
    skew = (f":s{s.split_threshold}:m{s.merge_threshold}"
            if s.is_skew else "")
    wire = "" if s.collective is None else f":w[{s.collective}]"
    vd = "" if s.value_dtype is None else f":v[{s.value_dtype}]"
    return (f"{s.kernel}:t{tile}:c{s.col_tile}:G{s.group_size}"
            f":{s.strategy}{skew}{wire}{vd}{ep}")


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one tuning run (or cache replay)."""

    schedule: Schedule
    us_per_call: float
    from_cache: bool
    key: str
    measured: Dict[str, float]  # schedule_key -> us/call this run

    @property
    def n_measurements(self) -> int:
        """Timing measurements this run paid for (0 on cache replay)."""
        return 0 if self.from_cache else len(self.measured)


# ---------------------------------------------------------------------------
# Candidate generation
# ---------------------------------------------------------------------------

_MIN_TILE, _MAX_NNZ_TILE = 32, 2048
_MAX_ROW_TILE = 128


def _neighbors(s: Schedule) -> List[Schedule]:
    """x2 / /2 moves on the tunable axes, respecting the divisibility and
    range invariants ``Schedule.__post_init__`` enforces.

    Only axes the measurement objective can observe are searched: the
    jitted schedule analogues (``tune.measure``) compile differently per
    group_size / strategy / nnz_tile / row_tile, but are invariant to
    ``col_tile`` (they run the full dense width in one program), so a
    col_tile move would be selected by pure timing noise — col_tile
    stays at the candidate grid's data-aware value instead."""
    out = []

    def _try(**kw):
        try:
            out.append(s.replace(**kw))
        except ValueError:
            pass

    if s.kernel == "eb":
        for g in (s.group_size * 2, s.group_size // 2):
            if 1 <= g <= s.nnz_tile and g != s.group_size:
                _try(group_size=g)
        for t in (s.nnz_tile * 2, s.nnz_tile // 2):
            if (max(_MIN_TILE, s.group_size) <= t <= _MAX_NNZ_TILE
                    and t != s.nnz_tile):
                _try(nnz_tile=t)
        if s.is_skew:
            # skew thresholds are searched like the tile axes: x2 / /2
            # moves (invalid combinations — e.g. merge > split — are
            # rejected by Schedule validation inside _try), plus the
            # escape hatch back to the plain layout
            if s.split_threshold is not None:
                for st in (s.split_threshold * 2, s.split_threshold // 2):
                    if st >= 1 and st != s.split_threshold:
                        _try(split_threshold=st)
            mt = s.merge_threshold
            if mt is not None:
                for m in {mt * 2, mt // 2, mt + 1 if mt == 0 else 0}:
                    if m is not None and m >= 0 and m != mt:
                        _try(merge_threshold=m)
            _try(split_threshold=None, merge_threshold=None)
    else:
        for rt in (s.row_tile * 2, s.row_tile // 2):
            if 1 <= rt <= _MAX_ROW_TILE and rt != s.row_tile:
                _try(row_tile=rt)
    return out


def _skew_candidates(stats: dict, seeds: List[Schedule]) -> List[Schedule]:
    """Two-level skew variants of the best eb seed for high-CV matrices.

    Thresholds come from the ``row_quantiles`` in ``matrix_stats`` (the
    same histogram the cache fingerprint hashes, so a cached decision
    replays measurement-free): split at ~q90/q99 so only genuine hubs
    pay the cross-group combine, merge at ~q50 so the light-row majority
    packs densely.  Low-CV matrices get no candidates — the plain layout
    already balances them.
    """
    rq = dict(stats.get("row_quantiles") or ())
    if stats.get("row_cv", 0.0) <= 1.0 or not rq:
        return []
    base = next((s for s in seeds if s.kernel == "eb" and not s.is_skew),
                None)
    if base is None:
        return []
    q50, q90, q99 = rq.get(50, 0), rq.get(90, 0), rq.get(99, 0)
    out: List[Schedule] = []
    for split_q in (q90, q99):
        split = max(2, base.group_size, int(split_q))
        merge = max(0, min(int(q50), split))
        for m in {merge, 0}:
            try:
                s = base.replace(split_threshold=split, merge_threshold=m)
            except ValueError:
                continue
            if s not in out:
                out.append(s)
    return out


def _feasible(cands: List[Schedule], stats: dict) -> List[Schedule]:
    kept = [s for s in cands
            if schedule_fits_vmem(s, n_rows=stats["n_rows"],
                                  n_cols=stats["n_cols"],
                                  row_max=stats["row_max"])]
    return kept or cands  # never let pruning empty the pool


class _Memo:
    """Measure-at-most-once memo over schedule points (shared by all
    tuners): ``memo(s)`` returns us/call, measuring on first sight.
    ``key_fn`` stringifies a point (``schedule_key`` for SpMM /
    segment-reduce, ``moe_schedule_key`` for MoE dispatch)."""

    def __init__(self, measure: Callable[[object], float],
                 key_fn: Callable[[object], str] = schedule_key):
        self._measure = measure
        self._key_fn = key_fn
        self.timings: Dict[str, float] = {}

    def __call__(self, s) -> float:
        k = self._key_fn(s)
        if k not in self.timings:
            self.timings[k] = float(self._measure(s)) * 1e6
        return self.timings[k]

    def seen(self, s) -> bool:
        """True when ``s`` has already been measured this run."""
        return self._key_fn(s) in self.timings


def _dtype_parity_error(csr, n_dense_cols: int, vd: str) -> float:
    """Relative L2 error of the ``vd`` storage analogue vs the f32
    oracle on a deterministic dense B (the same ``_dense_b`` the
    runners feed).

    Measures storage-precision loss only — the analogue accumulates in
    f32 like the kernels (``upcast_f32`` contract), so the number is a
    property of (matrix, dtype), independent of tiling/strategy, and is
    computed once per dtype per tuning run.  int8 goes through the real
    quantize/dequantize path (per-row symmetric scales)."""
    import jax.numpy as jnp

    from ..core.dtypes import operand_dtype, storage_dtype
    from ..kernels import ref
    from .measure import _dense_b

    coo = csr.tocoo()
    b = _dense_b(csr, n_dense_cols)
    out32 = ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, b, csr.shape[0])
    if vd == "int8":
        vals = csr.quantized().dequantize().tocoo().vals
    else:
        vals = coo.vals.astype(storage_dtype(vd))
    out = ref.spmm_coo_ref(coo.rows, coo.cols, vals,
                           b.astype(operand_dtype(vd)), csr.shape[0])
    num = float(jnp.linalg.norm((out - out32).ravel()))
    den = float(jnp.linalg.norm(out32.ravel()))
    return num / (den + 1e-12)


def _persist(cache: ScheduleCache, key: str, best,
             memo: _Memo) -> TuneResult:
    """Record the winner and write the cache through (shared epilogue)."""
    result = TuneResult(schedule=best, us_per_call=memo(best),
                        from_cache=False, key=key,
                        measured=dict(memo.timings))
    cache.put(key, TuneRecord(schedule=best, us_per_call=result.us_per_call,
                              measured=result.measured))
    cache.save()
    return result


def _replay(cache: ScheduleCache, key: str) -> Optional[TuneResult]:
    rec = cache.get(key)
    if rec is None:
        return None
    return TuneResult(schedule=rec.schedule, us_per_call=rec.us_per_call,
                      from_cache=True, key=key, measured={})


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


def tune_schedule(
    csr,
    n_dense_cols: int,
    *,
    cache: Optional[ScheduleCache] = None,
    top_k: int = 4,
    hill_steps: int = 3,
    measure: Optional[Callable[[Schedule], float]] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
    backend: Optional[str] = None,
    epilogue=None,
    value_dtypes: Optional[tuple] = None,
    error_budget: float = 0.05,
) -> TuneResult:
    """Empirically pick the best schedule for ``csr @ B`` (B with
    ``n_dense_cols`` columns); see the module docstring for the phases.

    cache       ScheduleCache to consult/update (default: the process
                cache at ``REPRO_TUNE_CACHE``); a hit replays with zero
                measurements.
    top_k       cost-model-ranked candidates to measure beyond the
                selector's pick.
    hill_steps  max hillclimb rounds around the measured winner.
    measure     override objective ``schedule -> seconds`` (tests,
                calibration replays); default wall-clocks the jitted
                schedule analogue via ``tune.measure``.
    epilogue    fused :class:`~repro.core.Epilogue` the workload will run
                — attached to every measured candidate so the fused work
                is *part of the objective*, and folded into the cache key
                (an epilogued workload never replays a plain record or
                vice versa).  The returned/tuned schedule carries it.
    value_dtypes  dtype-axis candidates (DESIGN.md §13); default
                :data:`DEFAULT_VALUE_DTYPES`, ``()`` disables the axis.
                Each candidate is admitted only if its storage-parity
                error vs the f32 oracle is within ``error_budget``, then
                measured as a variant of the pool winner (the dtype
                rescales traffic uniformly across tilings, so crossing
                the full grid with every dtype would waste measurements).
    error_budget  max relative L2 parity error an admitted narrow dtype
                may introduce (default 5%).
    """
    if cache is None:
        cache = default_cache(backend)
    if epilogue is not None and epilogue.is_noop:
        epilogue = None
    key = cache_key(csr, n_dense_cols)
    if epilogue is not None:
        key = f"{key}|ep:{epilogue.tag}"
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    stats = matrix_stats(csr)
    if measure is None:
        def measure(s: Schedule) -> float:
            return measure_schedule(csr, n_dense_cols, s,
                                    warmup=warmup, iters=iters)

    def _with_ep(s: Schedule) -> Schedule:
        return s if epilogue is None else s.replace(epilogue=epilogue)

    ranked = sorted(_feasible(candidate_schedules(n_dense_cols), stats),
                    key=lambda s: predict_cost(stats, s, n_dense_cols))
    ranked = [_with_ep(s) for s in ranked]
    pool: List[Schedule] = [_with_ep(select_schedule(stats, n_dense_cols))]
    for s in ranked:
        if len(pool) > top_k:
            break
        if s not in pool:
            pool.append(s)
    # kernel-family diversity: the cost model can rank one family's whole
    # grid above the other's, but hillclimb only explores *within* a
    # family — seed the pool with the best-ranked point of each kernel so
    # the measured search can cross the eb/rb boundary.
    for kernel in ("eb", "rb"):
        fam = next((s for s in ranked if s.kernel == kernel), None)
        if fam is not None and not any(s.kernel == kernel for s in pool):
            pool.append(fam)
    # skew entry points: on high-CV (power-law) matrices, seed the pool
    # with two-level split/merge variants of the best-ranked eb point,
    # thresholds placed from the row-length quantiles the fingerprint
    # already hashes (DESIGN.md §11) — hillclimb then refines them.
    for s in _skew_candidates(stats, pool + ranked):
        if s not in pool:
            pool.append(s)

    memo = _Memo(measure)
    best = min(pool, key=memo)

    # dtype axis (DESIGN.md §13): parity-gate each candidate dtype once
    # (the error is tiling-independent — storage precision only), then
    # measure admitted dtypes as variants of the pool winner.  Runs
    # before hillclimb so tile refinement happens at the chosen width.
    if value_dtypes is None:
        value_dtypes = DEFAULT_VALUE_DTYPES
    variants: List[Schedule] = []
    for vd in value_dtypes:
        try:
            cand = best.replace(value_dtype=vd)
        except (TypeError, ValueError):
            continue
        if cand.value_dtype is None or memo.seen(cand):
            continue  # alias of f32 (or already measured) — skip
        try:
            err = _dtype_parity_error(csr, n_dense_cols, cand.value_dtype)
        except (TypeError, ValueError):
            continue  # e.g. int8 under a traced / unquantizable input
        if err <= error_budget:
            variants.append(cand)
    if variants:
        best = min([best] + variants, key=memo)

    for _ in range(hill_steps):
        nbs = [s for s in _feasible(_neighbors(best), stats)
               if not memo.seen(s)]
        if not nbs:
            break
        contender = min(nbs, key=memo)
        if memo(contender) >= memo(best):
            break
        best = contender

    return _persist(cache, key, best, memo)


def cached_or_auto(csr, n_dense_cols: int, *,
                   cache: Optional[ScheduleCache] = None,
                   backend: Optional[str] = None,
                   key: Optional[str] = None) -> Schedule:
    """Cache-hit schedule if one exists, else the static selector's pick —
    **never measures**.  This is the serving-path resolver: a latency-
    sensitive loop consults tuning done ahead of time (e.g. by
    ``ServeEngine.prepare_sparse`` or ``launch.hillclimb --spmm``) and
    must not stall a request on a tuning run."""
    if cache is None:
        cache = default_cache(backend)
    rec = cache.get(key if key is not None
                    else cache_key(csr, n_dense_cols))
    if rec is not None:
        return rec.schedule
    return Schedule.auto(matrix_stats(csr), n_dense_cols)


# ---------------------------------------------------------------------------
# segment_reduce tuning (no CSR matrix: segments play the role of rows)
# ---------------------------------------------------------------------------


def tune_segment_reduce(
    seg_ids,
    n_cols: int,
    num_segments: int,
    *,
    cache: Optional[ScheduleCache] = None,
    measure: Optional[Callable[[Schedule], float]] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
    backend: Optional[str] = None,
) -> TuneResult:
    """Tune (tile, group_size, strategy) for a standalone segment reduce.

    The segment-length histogram stands in for the row-length histogram
    in the fingerprint (keys prefixed ``segred:``); candidates are the
    EB half of the grid (the RB kernel has no segment-reduce analogue).
    The objective times the *actual* segment-reduce kernel wrapper —
    unlike SpMM tuning there is no cheaper analogue that still observes
    the tile axis, and the kernel is the op being tuned."""
    from .cache import fingerprint_from_lengths

    seg = np.asarray(seg_ids)
    t = int(seg.shape[0])
    lengths = np.bincount(seg, minlength=max(num_segments, 1))
    fp = fingerprint_from_lengths(lengths, (num_segments, n_cols), t)
    key = f"segred:{fp}|N{n_cols}"

    if cache is None:
        cache = default_cache(backend)
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    if measure is None:
        import jax
        import jax.numpy as jnp

        from ..kernels.segment_reduce import segment_reduce as _segred

        data = jax.random.normal(jax.random.PRNGKey(0), (t, n_cols))
        seg_j = jnp.asarray(seg, jnp.int32)

        def measure(s: Schedule) -> float:
            def fn(ss, d):
                return _segred(ss, d, num_segments=num_segments,
                               tile=s.nnz_tile, group_size=s.group_size,
                               strategy=s.strategy)

            return time_fn(fn, seg_j, data, warmup=warmup, iters=iters)

    memo = _Memo(measure)
    pool = [Schedule("eb", nnz_tile=tile, group_size=g, strategy=st)
            for tile in (128, 512)
            for g in (8, 32)
            for st in ("segment", "accumulate")]
    best = min(pool, key=memo)
    return _persist(cache, key, best, memo)


# ---------------------------------------------------------------------------
# Distributed tuning: one search over (local tiling × collective mode)
# ---------------------------------------------------------------------------


def _feasible_collectives(stats: dict, axis_size: int) -> List[str]:
    """Collective modes the mesh/shape combination can realize: 'nnz_ar'
    always works; 'row' and 'nnz_rs' finalize a row block per shard, so
    they need ``n_rows % axis_size == 0`` (DESIGN.md §12)."""
    modes = ["nnz_ar"]
    if axis_size <= 1 or stats["n_rows"] % axis_size == 0:
        modes += ["nnz_rs", "row"]
    return modes


def tune_dist_spmm(
    csr,
    n_dense_cols: int,
    *,
    mesh,
    axis: str,
    cache: Optional[ScheduleCache] = None,
    top_k: int = 2,
    hill_steps: int = 2,
    measure: Optional[Callable[[Schedule], float]] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
    backend: Optional[str] = None,
    interpret: bool = True,
) -> TuneResult:
    """One empirical search over (kernel tiling × collective mode) for a
    sharded ``csr @ B`` on ``mesh`` — the tentpole of DESIGN.md §12: the
    wire strategy is a :class:`Schedule` axis, not a separate knob, so
    the tuner can trade local tile shape against collective bytes in a
    single objective (``measure_dist_schedule`` times the real shard_map
    program).

    Candidates are the top-ranked *local* eb tilings (the shard-local
    kernel only takes the eb path) crossed with every feasible collective
    mode, pre-ranked by :func:`~repro.core.predict_dist_cost` — the
    per-shard cost model plus the ``WIRE_COST_WEIGHT`` wire term and the
    ``shard_nnz`` straggler factor — then measured; a short hillclimb
    refines the winner's local axes with the collective held fixed (a
    collective flip re-partitions the operands, so it is a pool move,
    not a neighbor move).  The cache key folds in the mesh extent:
    ``dist:<fingerprint>|mesh:<P>`` — the same matrix on a different
    mesh is a different tuning problem.
    """
    axis_size = int(mesh.shape[axis])
    if cache is None:
        cache = default_cache(backend)
    key = f"dist:{cache_key(csr, n_dense_cols)}|mesh:{axis_size}"
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    from ..sparse.distributed import shard_nnz_counts

    stats = matrix_stats(csr)
    if measure is None:
        def measure(s: Schedule) -> float:
            return measure_dist_schedule(csr, n_dense_cols, s, mesh=mesh,
                                         axis=axis, warmup=warmup,
                                         iters=iters, interpret=interpret)

    modes = _feasible_collectives(stats, axis_size)
    eb = [s for s in _feasible(candidate_schedules(n_dense_cols), stats)
          if s.kernel == "eb"]
    eb.sort(key=lambda s: predict_cost(stats, s, n_dense_cols))
    auto = select_schedule(stats, n_dense_cols)
    seeds = ([auto] if auto.kernel == "eb" else []) + eb[:max(1, top_k)]
    pool: List[Schedule] = []
    for s in seeds:
        for mode in modes:
            cand = s.replace(collective=mode)
            if cand not in pool:
                pool.append(cand)
    pool.sort(key=lambda s: predict_dist_cost(
        stats, s, n_dense_cols, axis_size=axis_size,
        shard_nnz=shard_nnz_counts(csr, axis_size, s.collective)))

    memo = _Memo(measure)
    best = min(pool, key=memo)

    for _ in range(hill_steps):
        nbs = [s for s in _feasible(_neighbors(best), stats)
               if s.collective in COLLECTIVES and not memo.seen(s)]
        if not nbs:
            break
        contender = min(nbs, key=memo)
        if memo(contender) >= memo(best):
            break
        best = contender

    return _persist(cache, key, best, memo)
