"""Planner rule registry — how a fusion becomes a planner rule.

A *rule* is a function ``fn(launch, node)`` returning

* ``None`` — the rule does not apply to this (launch, node) pair;
* ``(merged_epilogue, "")`` — the rule fuses the node: the launch keeps
  its anchor and its epilogue becomes ``merged_epilogue``;
* ``(None, reason)`` — the rule *claims* the pair and forbids the
  fusion; the planner splits and records ``reason``.

Rules are consulted in registration order; the first non-``None``
verdict wins.  The built-ins make the two refactored mechanisms —
``core.Epilogue`` and the monoid registry — targets of planner rules
rather than ad-hoc ``Schedule`` fields:

* ``epilogue-fold`` — elementwise consumers fold into the producer's
  epilogue slot exactly when ``Epilogue.extended`` accepts them
  (``legality.ewise_fusable``);
* ``monoid-split`` — reducing consumers anchor a new launch, with the
  monoid-compatibility reason when their monoid is non-additive
  (``legality.reduce_fusable``).

To land a new fusion (say, folding a norm into a kernel that grows a
norm slot): implement the capability in the kernel, then
``register_rule("norm-fold", fn, before="monoid-split")`` with ``fn``
deciding from the launch anchor and the node — no planner changes.
DESIGN.md §10 walks through this.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

from ..core.schedule import Epilogue
from .ir import FuseNode, Launch

__all__ = ["available_rules", "register_rule", "try_fuse",
           "unregister_rule"]

RuleFn = Callable[[Launch, FuseNode],
                  Optional[Tuple[Optional[Epilogue], str]]]

_RULES: List[Tuple[str, RuleFn]] = []


def register_rule(name: str, fn: RuleFn, *,
                  before: Optional[str] = None) -> None:
    """Register a fusion rule.  ``before`` names an existing rule to
    insert ahead of (default: append — consulted after the built-ins)."""
    if any(n == name for n, _ in _RULES):
        raise ValueError(f"rule {name!r} already registered")
    if before is None:
        _RULES.append((name, fn))
        return
    for i, (n, _) in enumerate(_RULES):
        if n == before:
            _RULES.insert(i, (name, fn))
            return
    raise KeyError(f"no rule named {before!r} to insert before")


def unregister_rule(name: str) -> None:
    """Remove a rule by name (tests; undoing an experimental rule)."""
    for i, (n, _) in enumerate(_RULES):
        if n == name:
            del _RULES[i]
            return
    raise KeyError(name)


def available_rules() -> Tuple[str, ...]:
    """Registered fusion-rule names, in application order."""
    return tuple(n for n, _ in _RULES)


def try_fuse(launch: Launch,
             node: FuseNode) -> Tuple[Optional[Epilogue], str, str]:
    """Consult the registry: ``(merged_epilogue, reason, rule_name)``.
    ``merged_epilogue`` is ``None`` on a split, with ``reason`` from the
    deciding rule; a pair no rule claims splits with a generic reason."""
    for name, fn in _RULES:
        out = fn(launch, node)
        if out is not None:
            merged, reason = out
            return merged, reason, name
    return None, (f"no fusion rule applies to "
                  f"{launch.anchor.kind} ← {node.kind}"), ""


# -- built-ins ---------------------------------------------------------------


def _epilogue_fold(launch: Launch, node: FuseNode):
    if node.kind != "ewise":
        return None
    from .legality import ewise_fusable

    return ewise_fusable(launch, node)


def _monoid_split(launch: Launch, node: FuseNode):
    if node.kind == "ewise":
        return None
    from .legality import reduce_fusable

    return reduce_fusable(launch, node)


register_rule("epilogue-fold", _epilogue_fold)
register_rule("monoid-split", _monoid_split)
