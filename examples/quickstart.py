"""Quickstart: the Sgap segment-group SpMM through the unified Schedule API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.sparse import (Schedule, matrix_stats, random_csr,
                          register_strategy, segment_reduce, spmm)

# A skewed sparse matrix (a few very long rows) — the regime where the
# paper's flexible reduction wins.
A = random_csr(512, 512, density=0.02, skew=1.5, seed=0)
B = jax.random.normal(jax.random.PRNGKey(0), (512, 8))

# 1. schedule='auto' runs the data-aware selector (paper Table 5 made a
#    library default) and checks against the pure-jnp oracle.
stats = matrix_stats(A)
print(f"matrix: {stats['nnz']} nnz, row CV {stats['row_cv']:.2f}")
print(f"auto schedule: {Schedule.auto(stats, B.shape[1])}")
out = spmm(A, B, schedule="auto")
ref = spmm(A, B, impl="ref")
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                           atol=1e-4)
print("auto schedule matches oracle ✓")

# 2. The four DA-SpMM points are named schedules; explicit Schedule objects
#    expose every tile / group-size / strategy knob.
for name in ("EB+PR", "EB+SR", "RB+PR", "RB+SR"):
    out_n = spmm(A, B, schedule=name)
    np.testing.assert_allclose(np.asarray(out_n), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    print(f"{name}: OK")
for r in (8, 32):
    s = Schedule("eb", nnz_tile=256, col_tile=8, group_size=r,
                 strategy="segment")
    out_r = spmm(A, B, schedule=s)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    print(f"group size r={r}: OK")

# 3. User-defined reduction strategy (paper challenge 2): register a pure-
#    JAX spec + in-kernel realization once; every op dispatches through it.
def _spec(partials, seg_ids, num_segments, group_size):
    onehot = (seg_ids[:, None]
              == jnp.arange(num_segments)[None, :]).astype(partials.dtype)
    return jnp.einsum("ts,tc->sc", onehot, partials)


def _pallas(rows, partial, out_ref, group_size):
    s = out_ref.shape[0]
    onehot = (rows[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (rows.shape[0], s), 1)).astype(partial.dtype)
    out_ref[...] += jnp.dot(onehot.T, partial,
                            preferred_element_type=jnp.float32)


register_strategy("onehot-tile", _spec, _pallas, overwrite=True)
seg = jnp.asarray(np.sort(np.random.default_rng(0).integers(0, 40, 200)),
                  jnp.int32)
data = jax.random.normal(jax.random.PRNGKey(1), (200, 8))
got = segment_reduce(seg, data, 40,
                     schedule=Schedule("eb", nnz_tile=64, group_size=32,
                                       strategy="onehot-tile"))
want = jax.ops.segment_sum(data, seg, num_segments=40)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                           atol=1e-4)
print("custom strategy through the kernel: OK")

# 4. Generalized monoids + fused epilogues (DESIGN.md §8): the same
#    group machinery reduces with max (graph pooling), and a GCN layer's
#    act(A@XW + b) runs as ONE kernel via the schedule epilogue.
got_max = segment_reduce(seg, data, 40, op="max")
np.testing.assert_allclose(
    np.asarray(got_max),
    np.asarray(jax.ops.segment_max(data, seg, num_segments=40)),
    rtol=1e-4, atol=1e-4)
print("segment_reduce(op='max') through the registry: OK")

from repro.models.layers import gcn_layer  # noqa: E402

w = jax.random.normal(jax.random.PRNGKey(2), (512, 16)) * 0.1
bias = jax.random.normal(jax.random.PRNGKey(3), (16,))
fused = gcn_layer(A, jnp.eye(512), w, bias, activation="relu",
                  schedule="auto")
np.testing.assert_allclose(
    np.asarray(fused),
    np.asarray(jax.nn.relu(spmm(A, w, impl="ref") + bias[None, :])),
    rtol=1e-4, atol=1e-4)
print("fused GCN layer (bias+relu epilogue, one kernel): OK")

# 5. The fusion planner (DESIGN.md §10): describe a whole model fragment
#    as a chain of {sparse op, monoid, epilogue} nodes and let the
#    planner decide, per boundary, what rides which kernel launch.  The
#    two-layer GCN chain (spmm -> relu+bias -> spmm) plans to TWO Pallas
#    launches: each ewise node folds into its producing SpMM's epilogue.
import repro.fuse as fuse  # noqa: E402

w1 = jax.random.normal(jax.random.PRNGKey(4), (16, 8)) * 0.1
chain, params = fuse.gcn_chain(A, (w, w1), (bias, None), schedule="EB+PR")
plan = fuse.plan(chain)
print("GCN chain plan:", plan.decision.tag,
      f"({plan.n_launches} Pallas launches)")
assert plan.n_launches <= 2
for boundary, reason in enumerate(plan.reasons):
    if reason:
        print(f"  boundary {boundary} split: {reason}")

x = jnp.eye(512)
fused2 = fuse.run_plan(plan, x, params)
np.testing.assert_allclose(
    np.asarray(fused2),
    np.asarray(fuse.run_chain_ref(chain, x, params)),
    rtol=1e-4, atol=1e-4)
print("planned 2-layer GCN matches the unfused spec: OK")

# Fuse-vs-split is also a *measured* choice: tune_plan times both and
# records the winning FuseDecision in the schedule cache (fuse: keys),
# so the next call replays it with zero measurements.
from repro.tune import ScheduleCache  # noqa: E402

cache = ScheduleCache(path=None)  # demo: memory-only
res = fuse.tune_plan(chain, x, params, cache=cache, warmup=0, iters=1)
print("tuned decision:", res.schedule.tag, "| cached replay:",
      fuse.tune_plan(chain, x, params, cache=cache).from_cache)

# 6. Skew-aware two-level scheduling (DESIGN.md §11): on a power-law
#    graph, schedule='tune' searches split/merge thresholds that break
#    hub rows across dedicated 'parallel' groups and merge the 1-2 nnz
#    tail into shared ones — then replays the winner from cache.
from repro.sparse import power_law_csr  # noqa: E402
from repro.tune import tune_schedule  # noqa: E402

G = power_law_csr(1024, 1024, avg_degree=8.0, alpha=1.8, seed=0)
gstats = matrix_stats(G)
print(f"power-law graph: {gstats['nnz']} nnz, row CV "
      f"{gstats['row_cv']:.2f}, q50/q90/q99 row lengths "
      f"{[q for _, q in gstats['row_quantiles']]}")
res = tune_schedule(G, 4, cache=cache, warmup=1, iters=3)
print("tuned schedule:", res.schedule)
import re  # noqa: E402

best_static = min(us for key, us in res.measured.items()
                  if not re.search(r":s\d", key))  # non-skew points
print(f"tuned vs best static point: {best_static / res.us_per_call:.2f}x")
out_t = spmm(G, jax.random.normal(jax.random.PRNGKey(5), (1024, 4)),
             schedule=res.schedule)
print("skew-tuned spmm runs: OK | cached replay:",
      tune_schedule(G, 4, cache=cache).from_cache)

# 7. Mesh-elevated reduction strategies (DESIGN.md §12): the same
#    strategy question one level up — shards hold partial row sums and
#    the cross-shard combine is a collective ('row' = none, 'nnz_ar' =
#    psum, 'nnz_rs' = reduce-scatter).  schedule='tune' picks kernel
#    tiling AND wire mode in one pass and caches per mesh width.  Run
#    with XLA_FLAGS=--xla_force_host_platform_device_count=8 to see a
#    real 8-way mesh; on one device the mesh is degenerate but the path
#    is identical.
from repro.launch.mesh import make_reduction_mesh  # noqa: E402
from repro.sparse import dist_spmm  # noqa: E402
from repro.tune import tune_dist_spmm  # noqa: E402

mesh = make_reduction_mesh()
print(f"mesh: {mesh.shape}")
Bg = jax.random.normal(jax.random.PRNGKey(5), (1024, 4))
out_d = dist_spmm(G, Bg, mesh=mesh, axis="shards", schedule="tune",
                  cache=cache)
np.testing.assert_allclose(np.asarray(out_d),
                           np.asarray(spmm(G, Bg, impl="ref")),
                           rtol=1e-4, atol=1e-4)
res_d = tune_dist_spmm(G, 4, mesh=mesh, axis="shards", cache=cache)
print("distributed spmm matches oracle: OK | tuned collective:",
      res_d.schedule.collective, "| cached replay:", res_d.from_cache)

# 8. Low-precision value storage (DESIGN.md §13): the stored dtype is
#    itself a schedule axis.  Values stream as bf16/fp16/fp8 — or int8
#    with per-row scales dequantized inside the reduction — while
#    accumulation stays f32.  schedule='tune' measures narrow variants
#    of the winning schedule and keeps one only when it is faster AND
#    inside a relative-error budget; on hosts without native fp8 the
#    fp8 dtypes degrade to bf16 with a warning instead of failing.
from repro.core import fp8_supported  # noqa: E402
from repro.sparse import quantize_csr  # noqa: E402

s16 = Schedule("eb", nnz_tile=256, col_tile=8, group_size=8,
               strategy="segment", value_dtype="bfloat16")
out16 = spmm(A, B, schedule=s16)
err16 = float(jnp.linalg.norm(out16 - ref) / jnp.linalg.norm(ref))
print(f"bf16 storage, f32 accumulation: rel err {err16:.1e}")

qA = quantize_csr(A)  # int8 values + per-row f32 scales
qerr = float(np.abs(np.asarray(qA.dequantize().vals)
                    - np.asarray(A.vals)).max())
print(f"int8 per-row quantization round-trip: max abs err {qerr:.1e}")

cache8 = ScheduleCache(path=None)
res8 = tune_schedule(A, 8, cache=cache8, warmup=0, iters=1,
                     value_dtypes=("bfloat16", "int8"))
print("tuned with dtype axis:", res8.schedule.value_dtype or "float32",
      "| fp8 native here:", fp8_supported())

# 9. Joint axis search (DESIGN.md §14): every tuner is a thin wrapper
#    over ONE driver composing Axis objects, so searches span axes
#    jointly.  tune_dist_spmm searches local tiling x collective wire
#    mode x value dtype in a single objective — a narrow dtype that
#    only pays off under reduce-scatter (or vice versa) is reachable,
#    where two sequential single-axis searches would each lock in the
#    other knob's default.  value_dtypes=() reduces to the §12
#    single-axis search; the winner replays measurement-free.
res_j = tune_dist_spmm(G, 4, mesh=mesh, axis="shards",
                       cache=ScheduleCache(path=None), warmup=0, iters=1)
sj = res_j.schedule
print(f"joint collective x dtype search: collective={sj.collective}",
      f"| dtype={sj.value_dtype or 'float32'}",
      f"| points measured={res_j.n_measurements}")
print("done")
