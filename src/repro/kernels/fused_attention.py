"""Fused sparse attention: SDDMM → segment softmax → SpMM in ONE kernel.

The motivating chain (graph attention / sparse transformer): for a
sparsity pattern (rows, cols) over queries Q (n_rows, d), keys
K (n_cols, d) and values V (n_cols, dv),

    s[t]   = <Q[rows[t]], K[cols[t]]> * scale          (SDDMM)
    w[t]   = softmax over {t' : rows[t'] = rows[t]}    (segment softmax)
    out[r] = Σ_{t: rows[t]=r} w[t] * V[cols[t]]        (SpMM)

Composed as three ops this costs three HBM round trips and materializes
two (nnz,)-sized intermediates.  The fused kernel makes one pass over
the nonzeros with FlashAttention-style *online renormalization* per
output row: a running row max ``m`` and denominator ``l`` carried
through the race-free sequential nnz grid —

    per nnz tile i:   m_new = max(m, rowmax_i(s))          (max monoid
                      α     = exp(m - m_new)                through the
                      l     = l·α + rowsum_i(exp(s-m_new))  strategy
                      acc   = acc·α + Σ exp(s-m_new)·V      registry)
    last tile:        out   = acc / l

The row max / row sum scatters run through ``group_reduce_scatter`` with
the generalized monoids (``op="max"`` / add) — the first consumer of the
monoid-generalized registry beyond ``segment_reduce``.

Grid: (nnz_tiles, dv_tiles) — dv innermost.  The row statistics (m, l,
α) are computed once per nnz tile (at the first dv step) and stored in
(n_rows, 1) carry blocks revisited by every step; later dv steps of the
same nnz tile replay the final ``m`` and the stored ``α``.  The scores
``s`` (and probabilities) *are* recomputed per dv step — a deliberate
compute-for-traffic trade (an (nnz_tile,) probability carry would save
the d-length dots when dv spans several tiles; ROADMAP fusion
follow-on).

Padded lanes (trailing, from the nnz tile round-up) are masked by the
static true ``nnz``: their scores are forced to the -1e30 floor and
their probabilities to 0, so they contribute nothing to any row.  Empty
rows come out as exact zeros (matching the spec oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import group_reduce_scatter

NEG_INF = -1e30  # finite floor: keeps masked-lane arithmetic NaN-free


# ---------------------------------------------------------------------------
# Pure-JAX spec oracle
# ---------------------------------------------------------------------------


def sparse_softmax_weights(rows, cols, q, k, *, n_rows: int,
                           scale: float):
    """Spec of the SDDMM→segment-softmax front half: the normalized
    per-nnz attention weights ``w``.  Shared by the forward oracle and
    the custom VJP's recompute, so the numerically load-bearing details
    (the empty-row isfinite guard, the 1e-30 denominator floor) cannot
    desynchronize between forward and backward."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.sum(qf[rows] * kf[cols], axis=-1) * scale  # (nnz,)
    m = jax.ops.segment_max(s, rows, num_segments=n_rows)
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # empty rows: any finite value
    p = jnp.exp(s - m[rows])
    l = jax.ops.segment_sum(p, rows, num_segments=n_rows)
    return p / jnp.maximum(l[rows], 1e-30)


def sparse_attention_ref(rows, cols, q, k, v, *, n_rows: int,
                         scale: float | None = None):
    """Executable specification of the fused kernel (the oracle the
    kernel and its VJP are tested against).  Empty rows -> zero rows."""
    if scale is None:
        scale = float(q.shape[-1]) ** -0.5
    w = sparse_softmax_weights(rows, cols, q, k, n_rows=n_rows,
                               scale=scale)
    return jax.ops.segment_sum(w[:, None] * v.astype(jnp.float32)[cols],
                               rows, num_segments=n_rows)


# ---------------------------------------------------------------------------
# The fused Pallas kernel
# ---------------------------------------------------------------------------


def _fused_attn_kernel(rows_ref, cols_ref, q_ref, k_ref, v_ref,
                       out_ref, m_ref, l_ref, a_ref, *,
                       nnz: int, nnz_tile: int, scale: float,
                       group_size: int, strategy: str):
    i = pl.program_id(0)  # nnz tile (outer, sequential carry)
    j = pl.program_id(1)  # dv tile (inner)

    @pl.when((i == 0) & (j == 0))
    def _init_stats():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(i == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[...]
    cols = cols_ref[...]
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)

    # SDDMM front-end: per-lane scores, padded lanes floored to NEG_INF
    lane = i * nnz_tile + jax.lax.broadcasted_iota(
        jnp.int32, (nnz_tile,), 0)
    valid = lane < nnz
    s = jnp.sum(jnp.take(q, rows, axis=0) * jnp.take(k, cols, axis=0),
                axis=-1) * scale
    s = jnp.where(valid, s, NEG_INF)

    @pl.when(j == 0)
    def _update_stats():
        m_old = m_ref[...]  # (R, 1)
        # running row max: the max-monoid scatter through the registry
        group_reduce_scatter(rows, s[:, None], m_ref, group_size,
                             strategy, op="max")
        m_new = m_ref[...]
        alpha = jnp.where(m_old <= NEG_INF / 2, 0.0,
                          jnp.exp(m_old - m_new))  # (R, 1)
        a_ref[...] = alpha
        p = jnp.where(valid,
                      jnp.exp(jnp.where(valid, s, 0.0)
                              - jnp.take(m_ref[...][:, 0], rows)), 0.0)
        l_ref[...] = l_ref[...] * alpha
        group_reduce_scatter(rows, p[:, None], l_ref, group_size,
                             strategy)

    # SpMM back-end (every dv step): rescale the accumulator by this nnz
    # tile's α, then scatter-add the probability-weighted values
    m_new = m_ref[...][:, 0]
    p = jnp.where(valid,
                  jnp.exp(jnp.where(valid, s, 0.0) - jnp.take(m_new, rows)),
                  0.0)
    vj = v_ref[...].astype(jnp.float32)  # (n_cols, dv_tile)
    out_ref[...] = out_ref[...] * a_ref[...]
    group_reduce_scatter(rows, p[:, None] * jnp.take(vj, cols, axis=0),
                         out_ref, group_size, strategy)

    @pl.when(i == pl.num_programs(0) - 1)
    def _normalize():
        out_ref[...] = out_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "nnz", "nnz_tile", "dv_tile", "scale",
                     "group_size", "strategy", "interpret"),
)
def fused_sparse_attention(rows, cols, q, k, v, *, n_rows: int, nnz: int,
                           nnz_tile: int = 256, dv_tile: int = 128,
                           scale: float, group_size: int = 32,
                           strategy: str = "segment",
                           interpret: bool = True):
    """One-pass SDDMM→softmax→SpMM.  Inputs pre-padded by the wrapper:
    rows/cols (nnz_pad,) with nnz_pad % nnz_tile == 0 (``nnz`` is the
    true count — trailing pad lanes are masked in-kernel), v's feature
    axis padded to dv_tile.  Returns (out (n_rows, dv_pad), m, l) — the
    row statistics are exposed for diagnostics; ``out`` is final.
    """
    nnz_pad = rows.shape[0]
    n_q, d = q.shape
    n_kv, dv = v.shape
    assert nnz_pad % nnz_tile == 0 and dv % dv_tile == 0, (nnz_pad, dv)
    assert n_q == n_rows and k.shape == (n_kv, d)
    grid = (nnz_pad // nnz_tile, dv // dv_tile)

    kernel = functools.partial(
        _fused_attn_kernel, nnz=nnz, nnz_tile=nnz_tile, scale=scale,
        group_size=group_size, strategy=strategy)
    stat_spec = pl.BlockSpec((n_rows, 1), lambda i, j: (0, 0))
    out, m, l, _alpha = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nnz_tile,), lambda i, j: (i,)),
            pl.BlockSpec((nnz_tile,), lambda i, j: (i,)),
            pl.BlockSpec((n_rows, d), lambda i, j: (0, 0)),
            pl.BlockSpec((n_kv, d), lambda i, j: (0, 0)),
            pl.BlockSpec((n_kv, dv_tile), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((n_rows, dv_tile), lambda i, j: (0, j)),
            stat_spec, stat_spec, stat_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, dv), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(rows, cols, q, k, v)
    return out, m, l
