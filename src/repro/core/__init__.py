"""Sgap core: atomic parallelism (design space) + segment group (schedule
abstraction + executable reduction spec)."""
from .atomic_parallelism import (  # noqa: F401
    DA_SPMM_POINTS,
    AtomicParallelism,
    KernelSchedule,
    enumerate_space,
    is_legal,
    to_schedule,
)
from .segment_group import (  # noqa: F401
    GroupReduceStrategy,
    SegmentGroup,
    group_waste_fraction,
    group_writeback_counts,
    segment_group_reduce,
    segment_sum_ref,
)
from .selector import candidate_schedules, predict_cost, select_schedule  # noqa: F401
