"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), per the assignment:

    compute   = HLO_FLOPs_per_chip / peak_FLOPs_per_chip
    memory    = HLO_bytes_per_chip / HBM_bw
    collective= collective_bytes_per_chip / ICI_link_bw

``compiled.cost_analysis()`` operates on the post-SPMD per-device module,
so its flops/bytes are already per chip. Collective bytes are parsed from
the compiled HLO text (result-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import re

# TPU v5e-like hardware constants (assignment spec)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


def dtype_itemsize(dt) -> int:
    """Bytes per element of ``dt`` — an HLO short name ('bf16',
    'f8e4m3fn') or anything ``np.dtype`` accepts (jax/numpy dtypes,
    'bfloat16' via the ml_dtypes registration jax ships)."""
    if isinstance(dt, str) and dt in _DTYPE_BYTES:
        return _DTYPE_BYTES[dt]
    import numpy as np

    return int(np.dtype(dt).itemsize)


def predict_spmm_arg_bytes(lanes: int, n_cols: int, n_dense_cols: int, *,
                           value_dtype=None, scales_rows: int = 0,
                           index_bytes: int = 4) -> int:
    """Modeled per-call argument bytes of the EB SpMM measurement program
    (``tune.measure.make_eb_runner``): two index streams over the
    ``lanes`` padded/grouped nonzeros, the value stream at the *storage*
    width of ``value_dtype`` (DESIGN.md §13, post-fp8-fallback), and the
    dense ``(n_cols, n_dense_cols)`` operand at the *operand* width —
    plus f32 per-row scales when the int8 quantized path adds them.

    This is the number ``memory_analysis().argument_size_in_bytes``
    reads back from the compiled runner, and the 'modeled bytes' the
    ``beyond/lowprec_spmm`` bench reports: a bf16 schedule should show
    ~2x fewer bytes than f32 on the same pattern.
    """
    from ..core.dtypes import operand_itemsize, value_itemsize

    total = lanes * (2 * index_bytes + value_itemsize(value_dtype))
    total += n_cols * n_dense_cols * operand_itemsize(value_dtype)
    total += scales_rows * 4
    return int(total)


def predict_spmm_traffic_bytes(lanes: int, n_rows: int,
                               n_dense_cols: int, *, value_dtype=None,
                               scales_rows: int = 0,
                               index_bytes: int = 4) -> int:
    """Modeled HBM traffic of one EB SpMM call — the bandwidth-bound
    roofline term the dtype axis moves (DESIGN.md §13).

    Unlike :func:`predict_spmm_arg_bytes` (argument footprint) this
    counts the *streams*: index + value lanes once, the gathered dense
    rows once per lane (``lanes * n_dense_cols`` elements at the
    operand width — the dominant term, and the one a narrow dtype
    halves), and the f32 output write.  ``modeled_speedup = f32_bytes /
    narrow_bytes`` is what a bandwidth-bound backend realizes; XLA-CPU
    wall clock does not track it (scalar bf16 converts), which is why
    the ``beyond/lowprec_spmm`` bench reports both."""
    from ..core.dtypes import operand_itemsize, value_itemsize

    total = lanes * (2 * index_bytes + value_itemsize(value_dtype))
    total += lanes * n_dense_cols * operand_itemsize(value_dtype)  # gather
    total += n_rows * n_dense_cols * 4  # f32 output
    total += scales_rows * 4
    return int(total)

_COLL_RE = re.compile(
    r"=\s*(?P<types>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d*)\[(?P<dims>[\d,]*)\]")


def _shape_bytes(types: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(types):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-op-type result bytes of every collective in the (per-device)
    compiled module. '-done' ops are skipped (async pair double-count)."""
    out: dict = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("types"))
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def predict_collective_bytes(collective, out_shape, *, axis_size: int,
                             itemsize: int = 4) -> int:
    """Per-device collective result bytes a distributed reduction op
    should compile to under ``collective`` (DESIGN.md §12) — the number
    :func:`collective_bytes` reads back from the compiled HLO.

    'row' (and ``None``) move nothing; 'nnz_ar' all-reduces the full
    ``out_shape`` partial on every device; 'nnz_rs' reduce-scatters it,
    so each device's collective *result* is the 1/P row slice it
    finalizes — 1/P of the all-reduce bytes on the wire per shard.  A
    1-member axis compiles its collectives away (0 bytes).
    """
    if axis_size <= 1 or collective in (None, "row"):
        return 0
    full = itemsize
    for d in out_shape:
        full *= int(d)
    if collective == "nnz_ar":
        return full
    if collective == "nnz_rs":
        return full // axis_size
    raise ValueError(f"unknown collective {collective!r}")


def predict_attention_collective_bytes(collective, *, n_heads: int,
                                       n_rows: int, dv_pad: int,
                                       axis_size: int,
                                       itemsize: int = 4) -> int:
    """Collective result bytes of one distributed fused-attention combine
    (``repro.sparse.dist_attention_shard_map``): the (H, R) row-max pmax
    is always a full all-reduce; the weighted l and accumulator — (H, R)
    and (H, R, dv_pad) — combine per ``collective`` like SpMM partials.
    """
    if axis_size <= 1 or collective in (None, "row"):
        return 0
    stats = n_heads * n_rows * itemsize  # pmax on m: always all-reduce
    lw_acc = n_heads * n_rows * (dv_pad + 1) * itemsize
    if collective == "nnz_rs":
        lw_acc //= axis_size
    elif collective != "nnz_ar":
        raise ValueError(f"unknown collective {collective!r}")
    return stats + lw_acc


def extract_costs(compiled) -> dict:
    """Raw per-chip cost numbers from one compiled module. NOTE: XLA cost
    analysis counts a while/scan body ONCE (not × trip count); callers that
    scan over layers must extrapolate (see dryrun._extrapolated_costs)."""
    ca = compiled.cost_analysis() or {}
    colls = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(v["bytes"] for v in colls.values())),
        "collectives": colls,
    }


def combine_costs(base: dict, body: dict, n_extra: int) -> dict:
    """total = base + n_extra * body (elementwise, incl. per-op colls)."""
    out = {
        "flops": base["flops"] + n_extra * body["flops"],
        "bytes": base["bytes"] + n_extra * body["bytes"],
        "coll_bytes": base["coll_bytes"] + n_extra * body["coll_bytes"],
    }
    colls = {}
    ops = set(base["collectives"]) | set(body["collectives"])
    for op in ops:
        b = base["collectives"].get(op, {"count": 0, "bytes": 0})
        d = body["collectives"].get(op, {"count": 0, "bytes": 0})
        colls[op] = {"count": b["count"] + n_extra * d["count"],
                     "bytes": b["bytes"] + n_extra * d["bytes"]}
    out["collectives"] = colls
    return out


def analyze(costs: dict, ma, *, n_chips: int, kind: str, tokens: int,
            n_params: int, n_active_params: int) -> dict:
    colls = costs["collectives"]
    coll_b = costs["coll_bytes"]
    flops = costs["flops"]
    bytes_acc = costs["bytes"]

    compute_t = flops / PEAK_FLOPS
    memory_t = bytes_acc / HBM_BW
    coll_t = coll_b / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)

    flops_factor = {"train": 6, "prefill": 2, "decode": 2}[kind]
    model_flops = flops_factor * n_active_params * tokens
    hlo_flops_global = flops * n_chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    bound = max(terms.values())
    # fraction of roofline = time the hardware MUST spend / modelled step
    # time (the dominant term). Decode is bandwidth-bound by construction:
    # its floor is one pass over params + KV state (the arg bytes), not a
    # flop count.
    if kind == "decode" and ma is not None:
        floor = ma.argument_size_in_bytes / HBM_BW
    else:
        floor = model_flops / n_chips / PEAK_FLOPS
    roofline_frac = floor / bound if bound else 0.0

    return {
        "per_chip": {
            "hlo_flops": flops,
            "hlo_bytes": bytes_acc,
            "collective_bytes": coll_b,
            "temp_bytes": ma.temp_size_in_bytes if ma else None,
            "arg_bytes": ma.argument_size_in_bytes if ma else None,
            "out_bytes": ma.output_size_in_bytes if ma else None,
        },
        "collectives": colls,
        "terms_s": terms,
        "dominant": dominant,
        "model_flops_global": model_flops,
        "n_params": n_params,
        "n_active_params": n_active_params,
        "useful_flops_ratio": useful,
        "roofline_fraction": roofline_frac,
        "tokens": tokens,
        "kind": kind,
        "n_chips": n_chips,
    }


def count_params(params_shape) -> int:
    import jax

    return int(sum(
        __import__("math").prod(x.shape) for x in jax.tree.leaves(params_shape)))


def count_active_params(params_shape, cfg) -> int:
    """Active params per token: MoE experts count top_k/E; rest full."""
    import math as _m

    import jax

    total = 0
    expert = 0

    def visit(path, leaf):
        nonlocal total, expert
        n = _m.prod(leaf.shape)
        p = "/".join(str(getattr(k, "key", k)) for k in path)
        total += n
        if "moe/w" in p:
            expert += n

    jax.tree_util.tree_map_with_path(visit, params_shape)
    if cfg.family == "moe" and cfg.n_experts:
        frac = cfg.experts_per_token / cfg.n_experts
        return int(total - expert + expert * frac)
    return total
