"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-30B-A3B family]: 128 experts top-8,
GQA kv=4, QK-norm."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=0, moe_d_ff=1536, vocab_size=151936,
    n_experts=128, experts_per_token=8, capacity_factor=1.25,
    qk_norm=True, norm="rmsnorm", mlp_type="swiglu", rope_theta=1e6,
)
