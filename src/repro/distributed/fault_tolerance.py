"""Fault tolerance for 1000+-node runs: heartbeats, straggler detection,
elastic re-mesh. All host-side and CPU-simulatable (unit-tested).

Model: the trainer ticks a HeartbeatMonitor with per-host step latencies.
A host that misses ``timeout_s`` is *dead* -> restart from checkpoint on a
smaller mesh (``plan_remesh``). A host whose step time exceeds
``straggler_factor`` × the fleet p50 for ``patience`` consecutive steps is
a *straggler* -> it is reported for eviction (TPU pods can't re-balance a
single slow chip; eviction + elastic re-mesh is the production response).
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque


@dataclasses.dataclass
class HostStatus:
    alive: bool
    straggler: bool
    last_seen: float
    p50_ratio: float


class HeartbeatMonitor:
    def __init__(self, hosts, *, timeout_s: float = 60.0,
                 straggler_factor: float = 1.5, patience: int = 3,
                 window: int = 20, clock=time.monotonic):
        self.hosts = list(hosts)
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.patience = patience
        self.clock = clock
        self.last_seen = {h: clock() for h in self.hosts}
        self.lat = {h: deque(maxlen=window) for h in self.hosts}
        self.slow_streak = defaultdict(int)

    def beat(self, host, step_latency_s: float):
        self.last_seen[host] = self.clock()
        self.lat[host].append(step_latency_s)

    def _p50(self):
        vals = sorted(v for d in self.lat.values() for v in d)
        return vals[len(vals) // 2] if vals else 0.0

    def poll(self) -> dict:
        """host -> HostStatus; updates straggler streaks."""
        now = self.clock()
        p50 = self._p50()
        out = {}
        for h in self.hosts:
            alive = (now - self.last_seen[h]) < self.timeout_s
            mine = self.lat[h][-1] if self.lat[h] else 0.0
            ratio = (mine / p50) if p50 > 0 else 1.0
            if alive and p50 > 0 and ratio > self.straggler_factor:
                self.slow_streak[h] += 1
            else:
                self.slow_streak[h] = 0
            out[h] = HostStatus(
                alive=alive,
                straggler=self.slow_streak[h] >= self.patience,
                last_seen=self.last_seen[h],
                p50_ratio=ratio,
            )
        return out

    def dead_hosts(self):
        return [h for h, s in self.poll().items() if not s.alive]

    def stragglers(self):
        return [h for h, s in self.poll().items() if s.straggler]


def plan_remesh(n_healthy_hosts: int, chips_per_host: int = 4,
                model_parallel: int = 16) -> tuple:
    """Largest (data, model) mesh that fits the healthy fleet, keeping the
    model-parallel degree fixed (params must still fit) and data parallel a
    power-of-two for collective efficiency. Returns (data, model)."""
    chips = n_healthy_hosts * chips_per_host
    data = chips // model_parallel
    p = 1
    while p * 2 <= data:
        p *= 2
    if p < 1:
        raise RuntimeError("not enough healthy chips for one model replica")
    return (p, model_parallel)


@dataclasses.dataclass
class ElasticPlan:
    """Restart plan after failures: new mesh shape + which checkpoint step
    to restore + how the global batch is re-tiled."""
    mesh_shape: tuple
    restore_step: int | None
    global_batch: int
    note: str


def make_elastic_plan(monitor: HeartbeatMonitor, ckpt_steps,
                      global_batch: int, chips_per_host: int = 4,
                      model_parallel: int = 16) -> ElasticPlan | None:
    dead = set(monitor.dead_hosts()) | set(monitor.stragglers())
    if not dead:
        return None
    healthy = [h for h in monitor.hosts if h not in dead]
    shape = plan_remesh(len(healthy), chips_per_host, model_parallel)
    step = max(ckpt_steps) if ckpt_steps else None
    dp = shape[0]
    batch = max(dp, (global_batch // dp) * dp)
    return ElasticPlan(
        mesh_shape=shape, restore_step=step, global_batch=batch,
        note=f"evicting {sorted(dead)}; resharding to mesh {shape}")
