"""Tests for the benchmark harness plumbing (ISSUE 3 satellites):
the ERROR-row exit-code path of ``benchmarks.run`` (previously
untested), ``--only`` filtering, and the ``benchmarks/diff.py``
bench-artifact regression gate CI runs between consecutive uploads.
"""
import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks import diff as bench_diff  # noqa: E402
from benchmarks import run as bench_run  # noqa: E402


def _run_main(monkeypatch, argv):
    monkeypatch.setattr(sys, "argv", ["benchmarks.run"] + argv)
    bench_run.main()


# ---------------------------------------------------------------------------
# benchmarks.run
# ---------------------------------------------------------------------------


def test_error_row_exits_nonzero_and_reports(monkeypatch, capsys, tmp_path):
    from benchmarks import tables

    def boom(quick):
        raise RuntimeError("synthetic bench failure")

    monkeypatch.setattr(tables, "table1_group_size", boom)
    out_json = tmp_path / "bench.json"
    with pytest.raises(SystemExit) as exc:
        _run_main(monkeypatch, ["--only", "table1",
                                "--json", str(out_json)])
    assert exc.value.code == 1
    captured = capsys.readouterr()
    # ERROR row lands in the CSV (in-band) and on stderr with traceback
    assert "table1,NaN,ERROR:" in captured.out
    assert "synthetic bench failure" in captured.err
    assert "Traceback" in captured.err
    # and in the JSON artifact with a null us_per_call
    rows = json.loads(out_json.read_text())
    assert rows["table1"]["us_per_call"] is None
    assert rows["table1"]["derived"].startswith("ERROR:")
    assert rows["table1"]["status"] == "error"


def test_only_filter_runs_exactly_the_named_benches(monkeypatch, capsys):
    from benchmarks import beyond, tables

    called = []

    def fake(name):
        def bench(quick):
            called.append(name)
            return [(f"{name}/row", 1.0, "ok")]

        return bench

    monkeypatch.setattr(tables, "table1_group_size", fake("table1"))
    monkeypatch.setattr(tables, "table5_dynamic_choice", fake("table5"))
    monkeypatch.setattr(beyond, "moe_dispatch", fake("moe"))
    monkeypatch.setattr(beyond, "moe_tuner_gap", fake("moe_tuner"))
    monkeypatch.setattr(beyond, "selector_quality", fake("selector"))
    _run_main(monkeypatch, ["--only", "moe,moe_tuner"])
    assert called == ["moe", "moe_tuner"]
    out = capsys.readouterr().out
    assert "moe/row,1.0,ok" in out
    assert "table1" not in out


def test_only_filter_rejects_unknown_names(monkeypatch, capsys):
    with pytest.raises(SystemExit) as exc:
        _run_main(monkeypatch, ["--only", "not_a_bench"])
    assert exc.value.code == 2  # argparse usage error


def test_resolve_only_expands_tags_and_normalizes():
    """``--only`` entries are bench names first, else tags, with '-' and
    '_' interchangeable in both (CI invokes ``--only ci-smoke``)."""
    smoke, unknown = bench_run.resolve_only(["ci-smoke"])
    assert not unknown
    assert smoke == [n for n, (_, _, t) in bench_run.BENCHES.items()
                     if "ci_smoke" in t]
    assert "table5" in smoke and "table2" not in smoke

    dist, unknown = bench_run.resolve_only(["dist"])
    assert not unknown
    assert set(dist) == {"dist_attention", "dist_moe", "joint_dist"}

    # a bench name wins over tag lookup, and hyphens normalize
    names, unknown = bench_run.resolve_only(["dist-attention", "table1"])
    assert not unknown and names == ["table1", "dist_attention"]

    _, unknown = bench_run.resolve_only(["nope", "table1"])
    assert unknown == ["nope"]


def test_dist_benches_are_dual_lane():
    """The dist benches run in BOTH lanes: degenerate 1-device rows in
    the smoke lane, real 8-way rows in the dist lane (separate
    trajectories never cross-compare)."""
    for name in ("dist_attention", "dist_moe", "joint_dist"):
        _, _, tags = bench_run.BENCHES[name]
        assert {"ci_smoke", "dist"} <= tags


def test_default_diff_groups_are_ci_smoke_tagged():
    """Every group the diff gate tracks by default must be produced by a
    ci_smoke-tagged bench, else the smoke artifact silently stops
    carrying the rows the gate wants to compare."""
    for group in bench_diff.DEFAULT_GROUPS:
        name = group.split("/", 1)[-1] if group.startswith("beyond/") \
            else group
        assert name in bench_run.BENCHES, (group, name)
        _, _, tags = bench_run.BENCHES[name]
        assert "ci_smoke" in tags, (group, name)


def test_json_rows_carry_ok_status(monkeypatch, capsys, tmp_path):
    """Success rows get ``status: ok`` — the machine-readable flag the
    CI lanes gate on instead of grepping the CSV for "ERROR"."""
    from benchmarks import tables

    monkeypatch.setattr(tables, "table1_group_size",
                        lambda quick: [("table1/row", 2.0, "fine")])
    out_json = tmp_path / "bench.json"
    _run_main(monkeypatch, ["--only", "table1", "--json", str(out_json)])
    rows = json.loads(out_json.read_text())
    assert rows["table1/row"]["status"] == "ok"
    assert rows[bench_run.PROBE_ROW]["status"] == "ok"


# ---------------------------------------------------------------------------
# benchmarks.diff
# ---------------------------------------------------------------------------


def _bench(rows):
    return {name: {"us_per_call": us, "derived": derived}
            for name, us, derived in rows}


def _write(tmp_path, name, bench):
    p = tmp_path / name
    p.write_text(json.dumps(bench))
    return str(p)


def test_diff_green_within_threshold(tmp_path, capsys):
    old = _write(tmp_path, "old.json", _bench([
        ("table5/a", 100.0, ""), ("table5/b", 200.0, ""),
        ("beyond/tuner_gap", 0.0, "tuned_vs_auto_geomean=1.200"),
    ]))
    new = _write(tmp_path, "new.json", _bench([
        ("table5/a", 105.0, ""), ("table5/b", 207.0, ""),
        ("beyond/tuner_gap", 0.0, "tuned_vs_auto_geomean=1.150"),
    ]))
    assert bench_diff.main([old, new, "--threshold", "0.10"]) == 0
    assert "ok" in capsys.readouterr().out


def test_diff_fails_on_synthetic_regression(tmp_path, capsys):
    """>10% geomean us regression exits non-zero (acceptance crit.)."""
    old = _write(tmp_path, "old.json", _bench([
        ("table5/a", 100.0, ""), ("table5/b", 200.0, "")]))
    new = _write(tmp_path, "new.json", _bench([
        ("table5/a", 115.0, ""), ("table5/b", 230.0, "")]))
    assert bench_diff.main([old, new, "--threshold", "0.10"]) == 1
    assert "REGRESSED" in capsys.readouterr().out


def test_diff_fails_on_derived_geomean_drop(tmp_path):
    """The tuner-gap win ratio dropping >threshold also gates."""
    old = _write(tmp_path, "old.json", _bench([
        ("beyond/moe_tuner_gap", 0.0, "tuned_vs_default_geomean=1.500")]))
    new = _write(tmp_path, "new.json", _bench([
        ("beyond/moe_tuner_gap", 0.0, "tuned_vs_default_geomean=1.200")]))
    assert bench_diff.main([old, new]) == 1


def test_diff_oracle_slowdown_ratios_are_informational(tmp_path, capsys):
    """`*_vs_oracle_geomean` is a slowdown ratio (lower = better): an
    *improvement* must not trip the lower-is-worse win-ratio gate, and
    a worsening is reported but does not gate either (direction-aware
    gating only covers the allowlisted win ratios)."""
    old = _write(tmp_path, "old.json", _bench([
        ("beyond/tuner_gap", 0.0,
         "tuned_vs_auto_geomean=1.200,auto_vs_oracle_geomean=1.400")]))
    improved = _write(tmp_path, "improved.json", _bench([
        ("beyond/tuner_gap", 0.0,
         "tuned_vs_auto_geomean=1.200,auto_vs_oracle_geomean=1.050")]))
    assert bench_diff.main([old, improved]) == 0
    assert "info" in capsys.readouterr().out
    worse = _write(tmp_path, "worse.json", _bench([
        ("beyond/tuner_gap", 0.0,
         "tuned_vs_auto_geomean=1.200,auto_vs_oracle_geomean=1.900")]))
    assert bench_diff.main([old, worse]) == 0


def test_diff_skips_disjoint_and_error_rows(tmp_path, capsys):
    """First run of a fresh bench set (no shared rows) stays green, and
    ERROR rows (null us) never poison a geomean."""
    old = _write(tmp_path, "old.json", _bench([
        ("table5/gone", 100.0, "")]))
    new = _write(tmp_path, "new.json", _bench([
        ("table5/fresh", 500.0, ""),
        ("beyond/tuner/x", None, "ERROR:boom")]))
    assert bench_diff.main([old, new]) == 0
    assert "SKIP" in capsys.readouterr().out


def test_diff_compare_is_importable_for_local_use(tmp_path):
    old = _bench([("table5/a", 100.0, "")])
    new = _bench([("table5/a", 200.0, "")])
    findings = bench_diff.compare(old, new, threshold=0.10)
    kinds = {(k, reg) for k, _, _, _, _, reg in findings}
    assert ("us", True) in kinds


# ---------------------------------------------------------------------------
# runner-speed probe normalization + trajectory window (ISSUE 4)
# ---------------------------------------------------------------------------


def test_diff_probe_normalizes_runner_speed(tmp_path):
    """A 2x slower runner doubles raw us across the board — with the
    probe row present on both sides the normalized gate stays green,
    while a real 2x regression (probe unchanged) still fails."""
    old = _write(tmp_path, "old.json", _bench([
        (bench_diff.PROBE_ROW, 50.0, ""),
        ("table5/a", 100.0, ""), ("table5/b", 200.0, "")]))
    slow_runner = _write(tmp_path, "slow.json", _bench([
        (bench_diff.PROBE_ROW, 100.0, ""),
        ("table5/a", 200.0, ""), ("table5/b", 400.0, "")]))
    assert bench_diff.main([old, slow_runner, "--threshold", "0.10"]) == 0
    real_regression = _write(tmp_path, "reg.json", _bench([
        (bench_diff.PROBE_ROW, 50.0, ""),
        ("table5/a", 200.0, ""), ("table5/b", 400.0, "")]))
    assert bench_diff.main([old, real_regression,
                            "--threshold", "0.10"]) == 1


def test_diff_without_probe_still_gates_raw(tmp_path):
    """Artifacts predating the probe keep the raw-us behavior."""
    old = _write(tmp_path, "old.json", _bench([("table5/a", 100.0, "")]))
    new = _write(tmp_path, "new.json", _bench([("table5/a", 130.0, "")]))
    assert bench_diff.main([old, new, "--threshold", "0.10"]) == 1


def _traj(tmp_path, name, runs, window=5):
    p = tmp_path / name
    bench_diff.save_trajectory(str(p), runs, window)
    return str(p)


def test_diff_trajectory_catches_slow_drift(tmp_path, capsys):
    """+6%/run passes every pairwise diff but accumulates past the
    threshold against the window median."""
    runs = [_bench([("table5/a", 100.0 * 1.06 ** i, "")])
            for i in range(4)]
    traj = _traj(tmp_path, "traj.json", runs)
    new = _write(tmp_path, "new.json",
                 _bench([("table5/a", 100.0 * 1.06 ** 4, "")]))
    # pairwise vs the last run alone would pass...
    prev = _write(tmp_path, "prev.json", runs[-1])
    assert bench_diff.main([prev, new, "--threshold", "0.10"]) == 0
    # ...the window median catches the drift
    assert bench_diff.main(["--trajectory", traj, new,
                            "--threshold", "0.10"]) == 1
    out = capsys.readouterr().out
    assert "REGRESSED" in out and "run median" in out


def test_diff_trajectory_skips_preprobe_runs_in_median(tmp_path):
    """A legacy (probe-less) run in the window must not mix its raw us
    into the normalized median baseline — it is skipped, and the gate
    still catches a real regression against the probed runs."""
    legacy = _bench([("table5/a", 100.0, "")])  # raw us, no probe
    probed = _bench([(bench_diff.PROBE_ROW, 50.0, ""),
                     ("table5/a", 100.0, "")])  # normalized value: 2.0
    traj = _traj(tmp_path, "traj.json", [legacy, probed, probed])
    bad = _write(tmp_path, "bad.json", _bench([
        (bench_diff.PROBE_ROW, 50.0, ""),
        ("table5/a", 130.0, "")]))  # 1.3x regression, same probe
    assert bench_diff.main(["--trajectory", traj, bad,
                            "--threshold", "0.10"]) == 1
    ok = _write(tmp_path, "ok.json", _bench([
        (bench_diff.PROBE_ROW, 50.0, ""),
        ("table5/a", 103.0, "")]))
    assert bench_diff.main(["--trajectory", traj, ok,
                            "--threshold", "0.10"]) == 0


def test_diff_trajectory_update_appends_and_trims(tmp_path):
    runs = [_bench([("table5/a", 100.0, "")]) for _ in range(5)]
    traj = _traj(tmp_path, "traj.json", runs)
    new = _write(tmp_path, "new.json", _bench([("table5/a", 101.0, "")]))
    assert bench_diff.main(["--trajectory", traj, new, "--window", "5",
                            "--update"]) == 0
    kept = bench_diff.load_trajectory(traj)
    assert len(kept) == 5  # trimmed to the window
    assert kept[-1]["table5/a"]["us_per_call"] == 101.0


def test_diff_empty_trajectory_seeds_green(tmp_path):
    new = _write(tmp_path, "new.json", _bench([("table5/a", 100.0, "")]))
    traj = str(tmp_path / "fresh.json")
    assert bench_diff.main(["--trajectory", traj, new, "--update"]) == 0
    assert len(bench_diff.load_trajectory(traj)) == 1


def test_diff_trajectory_accepts_bare_artifact_seed(tmp_path):
    """A pre-trajectory BENCH_ci.json seeds a 1-run window (the CI
    migration path)."""
    seed = _write(tmp_path, "seed.json", _bench([("table5/a", 100.0, "")]))
    new = _write(tmp_path, "new.json", _bench([("table5/a", 103.0, "")]))
    assert bench_diff.main(["--trajectory", seed, new]) == 0


def test_fused_attention_win_ratio_reports_without_gating(tmp_path,
                                                          capsys):
    """The fused-vs-unfused geomean is tracked as info: its magnitude
    swings with runner load (sequential multi-second timings), so a
    drop reports but does not fail the diff."""
    old = _write(tmp_path, "old.json", _bench([
        ("beyond/fused_attention_gap", 0.0,
         "fused_vs_unfused_geomean=2.500")]))
    new = _write(tmp_path, "new.json", _bench([
        ("beyond/fused_attention_gap", 0.0,
         "fused_vs_unfused_geomean=1.800")]))
    assert bench_diff.main([old, new]) == 0
    assert "fused_vs_unfused_geomean" in capsys.readouterr().out
