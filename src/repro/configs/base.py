"""Model/config dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 128
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    # mlp
    d_ff: int = 0
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_kernel: int = 4
    ssm_chunk: int = 128
    # encdec
    n_encoder_layers: int = 0
    encoder_seq: int = 1500
    # vlm
    n_vision_tokens: int = 256
    # execution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    q_chunk: int = 512
    kv_chunk: int = 512
    # Decode cache via fori_loop carry + dynamic-update-slice instead of
    # scan xs→ys (§Perf hillclimb): lets XLA forward the cache buffer
    # in place rather than double-buffering old/new caches.
    decode_inplace_cache: bool = False
    # Megatron-style sequence parallelism for attention (§Perf hillclimb):
    # residual stream + q seq-sharded over 'model', K/V all-gathered, FFN
    # all-gather/reduce-scatter inserted by GSPMD. Requires ambient mesh.
    seq_parallel_attn: bool = False
    # cost-measurement knobs (see launch/dryrun._extrapolated_costs): XLA
    # cost_analysis counts while bodies once, so measurement compiles
    # unroll the layer/SSD scans and run attention single-chunk.
    scan_unroll: bool = False
    ssd_unroll: bool = False
    # when True the MoE dispatch path calls the Pallas grouped_matmul
    # kernel (interpret mode on CPU); False keeps the einsum path that the
    # XLA SPMD dry-run lowers. Math is identical (tested).
    moe_pallas_dispatch: bool = False

    # ------------------------------------------------------------ derived
    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True when long_500k decode is runnable (SSM/hybrid state models)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are decoder-bearing

    def scaled(self, **overrides) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch
