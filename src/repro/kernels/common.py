"""Shared in-kernel building blocks for the segment-group kernels.

``group_reduce_scatter`` is the Pallas dispatcher over the reduction-
strategy registry (``repro.core.schedule``): it looks up the strategy by
name and runs its in-kernel realization.  The built-in realizations live
here and are attached to the registry at import time; a user strategy
registered with only a pure-JAX spec falls back to running that spec on
the whole tile and combining the result (correct, not tuned).

Every realization is written against the strategy's reduction *monoid*
(``repro.core.Monoid``): the combine op, its identity, and the derived
reducers.  Sum is the ``add`` instance; ``op="max"``/``"min"`` run the
same machinery (graph pooling, the fused-attention row max).  The only
monoid-conditional code is the MXU fast path: the one-hot matmul reduce
is *algebraically* a masked sum, so it is used exactly when
``monoid.matmul_ok`` — any other monoid takes the masked-``where``
reduce.

The built-in 'segment' realization is the TPU form of the paper's segment
group (DESIGN.md §2): within each width-G group it

1. finds segment runs (boundary cumsum — replaces the GPU's runtime
   writeback-thread election),
2. reduces the run partials with a (G × G) one-hot matmul (add monoid;
   masked reduce otherwise) — the MXU analogue of the warp shuffle tree,
3. writes each live run back with a read-modify-write into the output
   block — the analogue of the paper's multiple writeback threads; the
   sequential TPU grid makes the RMW race-free ("atomic" for free).

Strategy variants:
  'segment'     full machinery above (runtime writeback targets);
  'parallel'    contract: all lanes of a group share one segment -> plain
                within-group reduce + single writeback (one writeback
                thread);
  'accumulate'  per-lane RMW (the atomicAdd baseline).

``apply_epilogue`` is the shared last-grid-step epilogue applier
(``core.Epilogue``): bias / activation / residual / dtype cast fused
onto the output block (DESIGN.md §8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schedule import (
    MONOIDS,
    Epilogue,
    Monoid,
    call_pallas_fn,
    attach_pallas_impl,
    get_strategy,
)

_ADD = MONOIDS["add"]

#: Finite masked-lane score floor shared by the attention kernels.  The
#: value is deliberately representable in float32 but NOT in float16
#: (fp16 max ~6.5e4): any kernel that compared or accumulated scores in
#: a low-precision input dtype would overflow it to -inf and poison the
#: online-softmax rescale (exp(-inf - -inf) = NaN).  Kernels must
#: therefore run score arithmetic through :func:`upcast_f32` — the floor
#: doubles as a tripwire for precision regressions.
NEG_INF = -1e30


def upcast_f32(*xs):
    """Force float32 compute for (possibly fp16/bf16) kernel operands.

    Score accumulation, online-softmax statistics and the probability
    algebra must happen in f32 regardless of the storage dtype: besides
    the :data:`NEG_INF` floor overflowing fp16, bf16's 8-bit mantissa
    loses the `exp(s - m)` cancellation.  Returns one array for one
    argument, a tuple otherwise.
    """
    out = tuple(x.astype(jnp.float32) for x in xs)
    return out[0] if len(out) == 1 else out


def _rmw_row(out_ref, row, delta, combine):
    """out_ref[row, :] = combine(out_ref[row, :], delta); delta (1, C),
    dynamic row index."""
    idx = (pl.dslice(row, 1), slice(None))
    out_ref[idx] = combine(out_ref[idx], delta).astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# Built-in in-kernel realizations.  Registry contract:
#     pallas_fn(rows (T,), partial (T, C), out_ref (R, C), group_size,
#               monoid=<Monoid>)
# (the monoid keyword is passed iff the signature accepts it, so 4-arg
# user realizations keep working — see core.schedule.call_pallas_fn).
# ---------------------------------------------------------------------------


def _pallas_accumulate(rows, partial, out_ref, group_size: int, *,
                       monoid: Monoid = _ADD):
    T, _ = partial.shape
    del group_size

    def lane_body(t, _):
        _rmw_row(out_ref, rows[t], partial[t][None, :], monoid.combine)
        return 0

    jax.lax.fori_loop(0, T, lane_body, 0)


def _pallas_parallel(rows, partial, out_ref, group_size: int, *,
                     monoid: Monoid = _ADD):
    T, C = partial.shape
    G = group_size

    def par_body(n, _):
        p = jax.lax.dynamic_slice(partial, (n * G, 0), (G, C))
        _rmw_row(out_ref, rows[n * G], monoid.reduce(p, 0)[None, :],
                 monoid.combine)
        return 0

    jax.lax.fori_loop(0, T // G, par_body, 0)


def _pallas_segment(rows, partial, out_ref, group_size: int, *,
                    monoid: Monoid = _ADD):
    T, C = partial.shape
    G = group_size

    def group_body(n, _):
        r = jax.lax.dynamic_slice(rows, (n * G,), (G,))
        p = jax.lax.dynamic_slice(partial, (n * G, 0), (G, C))
        # run boundaries -> local segment slots in [0, G)
        prev = jnp.concatenate([jnp.full((1,), -1, r.dtype), r[:-1]])
        local = jnp.cumsum((r != prev).astype(jnp.int32)) - 1  # (G,)
        onehot = (
            local[:, None]
            == jax.lax.broadcasted_iota(jnp.int32, (G, G), 1)
        )  # (G lanes, G slots) bool
        if monoid.matmul_ok:
            seg_tot = jnp.dot(onehot.astype(p.dtype).T, p,
                              preferred_element_type=jnp.float32)  # MXU
        else:
            # masked reduce over lanes per slot (identity off-mask)
            expanded = jnp.where(onehot.T[:, :, None], p[None, :, :],
                                 monoid.identity)  # (slots, lanes, C)
            seg_tot = monoid.reduce(expanded, 1)  # (G slots, C)
        # slot -> global row (slots past the last run get -1 = dead)
        seg_rows = jnp.max(
            jnp.where(onehot, r[:, None], -1), axis=0
        )  # (G,)

        def slot_body(s, _):
            row = seg_rows[s]

            @pl.when(row >= 0)
            def _():
                _rmw_row(out_ref, row,
                         jax.lax.dynamic_slice(seg_tot, (s, 0), (1, C)),
                         monoid.combine)
            return 0

        jax.lax.fori_loop(0, G, slot_body, 0)
        return 0

    jax.lax.fori_loop(0, T // G, group_body, 0)


def spec_fallback_pallas(entry):
    """Bridge a pure-JAX strategy spec into the in-kernel contract: run the
    spec over the whole tile (num_segments = the output block height) and
    combine into the block.  Correct for any spec; no per-group tuning."""
    from ..core.schedule import call_spec_fn

    def pallas_fn(rows, partial, out_ref, group_size: int, *,
                  monoid: Monoid = _ADD):
        tile = call_spec_fn(entry, partial, rows, out_ref.shape[0],
                            group_size)
        out_ref[...] = monoid.combine(out_ref[...], tile).astype(
            out_ref.dtype)

    return pallas_fn


def group_reduce_scatter(rows, partial, out_ref, group_size: int,
                         strategy: str = "segment", op=None):
    """Reduce ``partial`` (T, C) by ``rows`` (T,) into ``out_ref`` (R, C)
    with the registered strategy named ``strategy`` under the reduction
    monoid ``op`` names ('add' default / 'max' / 'min' / a Monoid).

    ``rows`` need not be globally sorted; sorted input minimizes writebacks
    (each unsorted transition opens a new run — correct, just more RMWs),
    which is exactly the paper's "writeback thread decided at runtime".
    """
    T, _ = partial.shape
    assert T % group_size == 0, (T, group_size)
    entry = get_strategy(strategy, op=op)
    fn = entry.pallas_fn or spec_fallback_pallas(entry)
    call_pallas_fn(fn, rows, partial, out_ref, group_size, entry.monoid)


def split_epilogue_refs(refs, epilogue: Epilogue, narrowed: bool):
    """Unpack a kernel's trailing refs under the shared epilogue operand
    layout ``[bias?][residual?] out [f32 acc scratch if narrowed]`` —
    one place encodes the positional contract for every epilogued
    kernel.  Returns ``(bias_ref, res_ref, out_ref, acc_ref)`` with
    ``acc_ref is None`` when the output block doubles as the
    accumulator."""
    acc_ref = refs[-1] if narrowed else None
    extras = list(refs[:-2] if narrowed else refs[:-1])
    out_ref = refs[-2] if narrowed else refs[-1]
    bias_ref = extras.pop(0) if epilogue.bias else None
    res_ref = extras.pop(0) if epilogue.residual else None
    return bias_ref, res_ref, out_ref, acc_ref


def apply_epilogue(out_ref, epilogue: Epilogue, bias_ref=None,
                   res_ref=None, acc_ref=None):
    """Apply an :class:`~repro.core.Epilogue` to a kernel's output block
    in place — called on the *last* reduction grid step (under
    ``pl.when``), when the accumulator holds the fully-reduced f32
    result.  ``acc_ref`` is the f32 scratch accumulator kernels use when
    ``out_dtype`` narrows the output (accumulation must stay f32; only
    the final store casts); without it the output block doubles as the
    accumulator."""
    src = out_ref if acc_ref is None else acc_ref
    acc = src[...].astype(jnp.float32)
    acc = epilogue.apply(
        acc,
        bias=None if bias_ref is None else bias_ref[...],
        residual=None if res_ref is None else res_ref[...],
    )
    out_ref[...] = acc.astype(out_ref.dtype)


attach_pallas_impl("accumulate", _pallas_accumulate)
attach_pallas_impl("parallel", _pallas_parallel)
attach_pallas_impl("segment", _pallas_segment)
