"""Synthetic sparse matrix generators (uniform and power-law row lengths).

The paper evaluates on the DA-SpMM matrix suite (SuiteSparse-derived).
Offline we synthesize matrices with controlled statistics instead: density,
row-length skew (CV), and size — the three features the data-aware selector
conditions on.
"""
from __future__ import annotations

import numpy as np

from .formats import COO, CSR


def random_csr(
    n_rows: int,
    n_cols: int,
    density: float = 0.01,
    skew: float = 0.0,
    seed: int = 0,
    dtype=np.float32,
) -> CSR:
    """Random CSR with expected ``density`` and row-length skew.

    skew = 0.0 -> uniform Bernoulli rows; skew > 0 -> power-law row lengths
    (a few very long rows), the regime where nnz-split + segment reduction
    wins in the paper.
    """
    rng = np.random.default_rng(seed)
    target_nnz = max(1, int(n_rows * n_cols * density))
    if skew <= 0.0:
        lengths = rng.multinomial(target_nnz, np.full(n_rows, 1.0 / n_rows))
    else:
        w = rng.pareto(1.0 / max(skew, 1e-3), size=n_rows) + 1e-6
        w = w / w.sum()
        lengths = rng.multinomial(target_nnz, w)
    lengths = np.minimum(lengths, n_cols)

    indptr = np.zeros(n_rows + 1, np.int64)
    np.cumsum(lengths, out=indptr[1:])
    nnz = int(indptr[-1])
    indices = np.empty(nnz, np.int32)
    for r in range(n_rows):
        k = lengths[r]
        if k:
            indices[indptr[r]: indptr[r + 1]] = np.sort(
                rng.choice(n_cols, size=k, replace=False)
            )
    vals = rng.standard_normal(nnz).astype(dtype)
    import jax.numpy as jnp

    return CSR(
        indptr=jnp.asarray(indptr, jnp.int32),
        indices=jnp.asarray(indices),
        vals=jnp.asarray(vals),
        shape=(n_rows, n_cols),
    )


def random_coo(n_rows, n_cols, density=0.01, skew=0.0, seed=0) -> COO:
    """Random COO with the same parameters as :func:`random_csr`."""
    return random_csr(n_rows, n_cols, density, skew, seed).tocoo()


def _csr_from_lengths(lengths, n_cols: int, rng, dtype=np.float32) -> CSR:
    """CSR with the given per-row nnz counts and random sorted column
    picks — the shared materialization step of every generator here."""
    lengths = np.minimum(np.asarray(lengths, np.int64), n_cols)
    n_rows = lengths.shape[0]
    indptr = np.zeros(n_rows + 1, np.int64)
    np.cumsum(lengths, out=indptr[1:])
    nnz = int(indptr[-1])
    indices = np.empty(nnz, np.int32)
    for r in range(n_rows):
        k = lengths[r]
        if k:
            indices[indptr[r]: indptr[r + 1]] = np.sort(
                rng.choice(n_cols, size=k, replace=False))
    vals = rng.standard_normal(nnz).astype(dtype)
    import jax.numpy as jnp

    return CSR(indptr=jnp.asarray(indptr, jnp.int32),
               indices=jnp.asarray(indices), vals=jnp.asarray(vals),
               shape=(n_rows, n_cols))


def power_law_csr(n_rows: int, n_cols: int, *, avg_degree: float = 8.0,
                  alpha: float = 2.0, seed: int = 0) -> CSR:
    """Power-law (Zipf-degree) CSR — the web/social-graph regime the
    two-level skew schedule targets (DESIGN.md §11).

    Row ``r`` (after a random permutation) draws its expected degree from
    ``(r+1)^-alpha``, normalized so the mean degree is ``avg_degree``: a
    handful of hub rows hold a large share of the nnz while most rows
    keep one or two entries.  Smaller ``alpha`` flattens the curve.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_rows + 1, dtype=np.float64)
    w = ranks ** -alpha
    w *= (avg_degree * n_rows) / w.sum()
    lengths = rng.poisson(w)
    lengths[0] = max(lengths[0], 1)  # keep at least one hub non-empty
    rng.shuffle(lengths)
    return _csr_from_lengths(lengths, n_cols, rng)


#: Degree-profile presets mirroring common real-graph families:
#: (avg_degree, alpha).  'web'/'social' are heavy-hub power laws (web
#: link graphs are the more extreme), 'roadnet' is near-regular (planar
#: graphs have degree ~2-4 and no hubs) — the control case where skew
#: scheduling should *not* win.
GRAPH_PATTERNS = {
    "web": (10.0, 2.2),
    "social": (16.0, 1.6),
    "roadnet": (3.0, 0.05),
}


def graph_pattern_csr(pattern: str, n_rows: int, n_cols: int | None = None,
                      *, seed: int = 0) -> CSR:
    """CSR with the degree profile of a named real-graph family
    (:data:`GRAPH_PATTERNS`); square adjacency shape unless ``n_cols``
    is given."""
    try:
        avg_degree, alpha = GRAPH_PATTERNS[pattern]
    except KeyError:
        raise ValueError(f"unknown graph pattern {pattern!r}; "
                         f"known: {sorted(GRAPH_PATTERNS)}") from None
    return power_law_csr(n_rows, n_cols if n_cols is not None else n_rows,
                         avg_degree=avg_degree, alpha=alpha, seed=seed)


#: Row-length quantile levels exposed in :func:`matrix_stats` (as
#: percent keys): the skew candidate generator reads q50/q90/q99 to
#: place split/merge thresholds, and the cost model interpolates the
#: curve to estimate how many rows each threshold captures.
_STAT_QUANTILES = (50, 90, 99)


def matrix_stats(csr: CSR) -> dict:
    """Features used by the data-aware schedule selector and the tuner.

    ``row_quantiles`` is a tuple of ``(percent, length)`` pairs over the
    *non-empty* row-length histogram — the same histogram the cache
    fingerprint hashes, so any schedule decision derived from it replays
    measurement-free on a fingerprint hit.
    """
    lengths = np.asarray(csr.row_lengths())
    mean = float(lengths.mean()) if lengths.size else 0.0
    std = float(lengths.std()) if lengths.size else 0.0
    nonzero = lengths[lengths > 0]
    if nonzero.size:
        quants = tuple(
            (p, int(round(float(np.quantile(nonzero, p / 100.0)))))
            for p in _STAT_QUANTILES)
    else:
        quants = tuple((p, 0) for p in _STAT_QUANTILES)
    return {
        "n_rows": csr.shape[0],
        "n_cols": csr.shape[1],
        "nnz": csr.nnz,
        "density": csr.nnz / max(1, csr.shape[0] * csr.shape[1]),
        "row_mean": mean,
        "row_cv": (std / mean) if mean > 0 else 0.0,
        "row_max": int(lengths.max()) if lengths.size else 0,
        "row_quantiles": quants,
    }
