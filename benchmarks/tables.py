"""Paper-table benchmarks (Sgap Tables 1–5) on the TPU-mapped schedule
space, measured as XLA-CPU wall clock over the synthetic suite.

Each function returns a list of CSV rows ``(name, us_per_call, derived)``.
"""
from __future__ import annotations

import numpy as np

from repro.core import candidate_schedules, group_waste_fraction
from repro.sparse import random_csr

from ._util import geomean, make_eb_runner, make_rb_runner, suite, time_fn


def table1_group_size(quick=True):
    """Paper Table 1 — flexible group size r vs the static 32.

    The paper's 2.0–2.5× comes from *recovering wasted parallel lanes*:
    short rows inside a width-32 group leave lanes idle. A parallel
    machine's time ∝ padded lanes, so the waste model predicts
    speedup(G) = (1−waste_G)/(1−waste_32). We report (a) the measured
    per-matrix waste fractions -> analytic parallel speedup (the
    mechanism the paper measures on GPU), and (b) the serial-CPU wall
    clock, where the effect is *expected* to invert (no idle lanes to
    recover; smaller G only adds bookkeeping) — recorded for honesty.
    """
    rows = []
    mats = suite(sizes=((2048, 2048),) if quick else ((4096, 4096),),
                 densities=(0.002,), skews=(1.0, 2.0))
    analytic = {4: [], 8: []}
    for (m, n, d, s), csr in mats:
        t = {}
        for r in (4, 8, 32):
            fn, args = make_eb_runner(csr, 4, group_size=r,
                                      strategy="segment")
            t[r] = time_fn(fn, *args)
        lengths = np.asarray(csr.row_lengths())
        w32 = group_waste_fraction(lengths, 32)
        for r in (4, 8):
            wr = group_waste_fraction(lengths, r)
            par = (1 - wr) / (1 - w32)
            analytic[r].append(par)
            rows.append((f"table1/G{r}_vs_G32/skew{s}",
                         t[r] * 1e6,
                         f"analytic_parallel_speedup={par:.3f},"
                         f"waste32={w32:.2f},waste{r}={wr:.2f},"
                         f"cpu_wallclock_ratio={t[32] / t[r]:.3f}"))
    for r in (4, 8):
        rows.append((f"table1/geomean_G{r}", 0.0,
                     f"analytic_parallel_speedup={geomean(analytic[r]):.3f}"
                     f" (paper Table 1: 2.09-2.46x)"))
    return rows


def table2_segment_vs_atomic(quick=True):
    """Paper Table 2 — segment reduction vs the original (atomic) one.

    The GPU speedup (1.0–1.38×, growing with r and c) comes from fewer
    serialized writebacks: atomic does one RMW per nnz; segment does one
    per row-run per group. We report the measured writeback-reduction
    factor (the paper's mechanism — grows with r exactly as Table 2) and
    the serial-CPU wall clock alongside.
    """
    import jax.numpy as jnp

    from repro.core import group_writeback_counts
    from repro.sparse.formats import GroupedCOO

    rows = []
    csr = random_csr(2048 if quick else 8192, 2048, density=0.005, skew=1.0,
                     seed=7)
    for c in (1, 2, 4):
        n_dense = 4 * c
        fn_a, args_a = make_eb_runner(csr, n_dense, group_size=32,
                                      strategy="accumulate")
        t_atomic = time_fn(fn_a, *args_a)
        for r in (4, 8, 16, 32):
            g = GroupedCOO.fromcsr(csr, max(256, r))
            wb = float(jnp.sum(group_writeback_counts(g.rows, r)))
            reduction = g.nnz_padded / wb
            fn_s, args_s = make_eb_runner(csr, n_dense, group_size=r,
                                          strategy="segment")
            t_seg = time_fn(fn_s, *args_s)
            rows.append((f"table2/c{c}_r{r}", t_seg * 1e6,
                         f"writeback_reduction={reduction:.3f},"
                         f"cpu_norm_speedup="
                         f"{max(1.0, t_atomic / t_seg):.3f}"))
    rows.append(("table2/note", 0.0,
                 "paper Table 2: 1.008-1.381x growing with r and c; the "
                 "writeback_reduction column reproduces that monotone "
                 "r-dependence"))
    return rows


def table3_new_vs_original(quick=True):
    """Paper Table 3 / Fig. 11 — the two new segment-group algorithms vs
    TACO's two original (serial-reduction) algorithms, best-of per side.

    Two views: (a) the parallel cost model (core/selector.predict_cost —
    work + zero-extension waste + writebacks + gather), which encodes the
    lane economics the paper measures on GPU; (b) CPU wall clock for the
    *work-based* part of the claim (EB vs per-row-padded ELL on skewed
    matrices), which a serial machine does reflect.
    """
    from repro.core.selector import predict_cost
    from repro.core import Schedule
    from repro.sparse.random import matrix_stats

    rows = []
    mats = suite(sizes=((2048, 2048),) if quick else ((4096, 4096),))
    for n_dense in (4, 8):
        model_sps, wall_sps = [], []
        for (m, n, d, s), csr in mats:
            stats = matrix_stats(csr)
            orig = [Schedule("eb", group_size=32,
                                   strategy="accumulate"),
                    Schedule("rb")]
            new = [Schedule("eb", group_size=g, strategy="segment")
                   for g in (4, 8, 16, 32)]
            c_orig = min(predict_cost(stats, sc, n_dense) for sc in orig)
            c_new = min(predict_cost(stats, sc, n_dense) for sc in new)
            model_sps.append(c_orig / c_new)

            # work-based wall clock: segment-group EB vs padded-ELL RB
            fn_e, a_e = make_eb_runner(csr, n_dense, group_size=32,
                                       strategy="segment")
            fn_r, a_r = make_rb_runner(csr, n_dense)
            t_eb = time_fn(fn_e, *a_e, warmup=1, iters=3)
            t_rb = time_fn(fn_r, *a_r, warmup=1, iters=3)
            wall_sps.append(t_rb / t_eb)
            rows.append((f"table3/N{n_dense}/d{d}_skew{s}", t_eb * 1e6,
                         f"model_speedup={c_orig / c_new:.3f},"
                         f"eb_vs_ell_wallclock={t_rb / t_eb:.3f}"))
        rows.append((f"table3/geomean_N{n_dense}", 0.0,
                     f"model_norm_speedup="
                     f"{geomean([max(1.0, x) for x in model_sps]):.3f} "
                     f"(paper: 1.098-1.223x), "
                     f"eb_vs_ell_wallclock_geomean={geomean(wall_sps):.3f}"))
    return rows


def table4_tuning(quick=True):
    """Paper Table 4 — 4-parameter tuning (<G, blockSz, tileSz, workerDimR>
    -> <G, nnz/row tile, col tile>) vs the library-default schedule, under
    the parallel cost model AND CPU wall clock over the same grid."""
    from repro.core.selector import predict_cost
    from repro.core import Schedule
    from repro.sparse.random import matrix_stats

    rows = []
    mats = suite(sizes=((2048, 2048),) if quick else ((4096, 4096),),
                 densities=(0.005,), skews=(0.0, 1.5))
    for n_dense in (4, 16) if quick else (4, 16, 64, 128):
        model_sps, wall_sps, best_names = [], [], []
        for (m, n, d, s), csr in mats:
            stats = matrix_stats(csr)
            default = Schedule("eb", group_size=32,
                                     strategy="segment", nnz_tile=256,
                                     col_tile=max(8, min(128, n_dense)))
            c_def = predict_cost(stats, default, n_dense)
            cands = candidate_schedules(n_dense)
            costs = [predict_cost(stats, sc, n_dense) for sc in cands]
            j = int(np.argmin(costs))
            model_sps.append(c_def / costs[j])
            best_names.append(f"{cands[j].kernel}/G{cands[j].group_size}")

            fn_d, args_d = make_eb_runner(csr, n_dense, group_size=32,
                                          strategy="segment", nnz_tile=256)
            t_default = time_fn(fn_d, *args_d, warmup=1, iters=3)
            best_t = np.inf
            for sched in cands:
                if sched.kernel == "eb":
                    fn, args = make_eb_runner(
                        csr, n_dense, group_size=sched.group_size,
                        strategy=sched.strategy, nnz_tile=sched.nnz_tile)
                else:
                    fn, args = make_rb_runner(csr, n_dense,
                                              row_tile=sched.row_tile)
                best_t = min(best_t, time_fn(fn, *args, warmup=1, iters=2))
            wall_sps.append(t_default / best_t)
        rows.append((f"table4/N{n_dense}", 0.0,
                     f"model_geomean={geomean(model_sps):.3f},"
                     f"model_max={max(model_sps):.3f},"
                     f"cpu_geomean={geomean(wall_sps):.3f} "
                     f"(paper: 1.693-2.307x geomean),best={best_names}"))
    return rows


def table5_dynamic_choice(quick=True):
    """Paper Table 5 — per-matrix dynamic schedule vs the best single
    static schedule, under cost model + CPU wall clock."""
    from repro.core.selector import predict_cost
    from repro.sparse.random import matrix_stats

    mats = suite(sizes=((2048, 2048),) if quick else ((4096, 4096),))
    n_dense = 4
    scheds = candidate_schedules(n_dense)

    model = np.zeros((len(mats), len(scheds)))
    times = np.zeros((len(mats), len(scheds)))
    for i, ((m, n, d, s), csr) in enumerate(mats):
        stats = matrix_stats(csr)
        for j, sched in enumerate(scheds):
            model[i, j] = predict_cost(stats, sched, n_dense)
            if sched.kernel == "eb":
                fn, args = make_eb_runner(
                    csr, n_dense, group_size=sched.group_size,
                    strategy=sched.strategy, nnz_tile=sched.nnz_tile)
            else:
                fn, args = make_rb_runner(csr, n_dense,
                                          row_tile=sched.row_tile)
            times[i, j] = time_fn(fn, *args, warmup=1, iters=2)

    out = []
    for name, mat in (("model", model), ("cpu", times)):
        static_j = int(np.argmin([geomean(mat[:, j])
                                  for j in range(len(scheds))]))
        dynamic = mat.min(axis=1)
        speedup = geomean(mat[:, static_j] / dynamic)
        out.append((f"table5/dynamic_vs_static_{name}", 0.0,
                    f"geomean={speedup:.3f},"
                    f"best_static={scheds[static_j].kernel}/"
                    f"G{scheds[static_j].group_size}"
                    + (" (paper: 1.095-1.406x)" if name == "model" else "")))
    return out
