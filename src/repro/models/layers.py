"""Shared neural-net layers (functional, pytree params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def layer_scan(cfg, step, init, xs):
    """scan over stacked layers; unrolls when cfg.scan_unroll (so the
    dry-run cost-measurement compiles count every layer — XLA cost
    analysis counts while bodies exactly once)."""
    unroll = cfg.n_layers if cfg.scan_unroll else 1
    return jax.lax.scan(step, init, xs, unroll=unroll)


def seq_shard(cfg, x, axis: int = 1):
    """Megatron-SP constraint: pin the sequence dim to the 'model' mesh
    axis (no-op unless cfg.seq_parallel_attn; requires an ambient mesh)."""
    if not getattr(cfg, "seq_parallel_attn", False):
        return x
    from jax.sharding import PartitionSpec as P

    u = P.UNCONSTRAINED
    spec = [u] * x.ndim
    spec[axis] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def seq_unshard(cfg, x, axis: int = 1):
    """Force the sequence dim unsharded (the K/V all-gather of
    seq-parallel attention)."""
    if not getattr(cfg, "seq_parallel_attn", False):
        return x
    from jax.sharding import PartitionSpec as P

    u = P.UNCONSTRAINED
    spec = [u] * x.ndim
    spec[axis] = None
    return jax.lax.with_sharding_constraint(x, P(*spec))

# ---------------------------------------------------------------- norms


def rmsnorm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def init_norm(cfg, d):
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), cfg.param_dtype)}
    return {"scale": jnp.ones((d,), cfg.param_dtype),
            "bias": jnp.zeros((d,), cfg.param_dtype)}


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


# ------------------------------------------------------- fused sparse


def gcn_layer(adj, x, w, b=None, *, activation="relu", residual=None,
              schedule="auto", interpret: bool = True):
    """One GCN layer, fused: ``act(Ã (x @ w) + b) [+ residual]`` runs as a
    *single* scheduled SpMM kernel with an in-kernel epilogue
    (DESIGN.md §8) instead of three HBM passes (spmm → bias-add → act).
    Differentiable in ``x``/``w``/``b``/``residual`` through the sparse
    custom VJP."""
    from ..core.schedule import Epilogue
    from ..sparse import spmm

    ep = Epilogue(activation=activation, bias=b is not None,
                  residual=residual is not None)
    return spmm(adj, x @ w, schedule=schedule, bias=b, residual=residual,
                epilogue=ep, interpret=interpret)


def gcn_two_layer(adj, x, w0, w1, b0=None, b1=None, *,
                  activation="relu", final_activation=None, schedule=None,
                  plan=None, interpret: bool = True):
    """Two-layer GCN — ``Ã act(Ã (x @ w0) + b0) @ w1 [+ b1]`` — built as
    a ``repro.fuse`` chain and executed by the fusion planner: the
    activations/biases fold into their producing SpMM's epilogue, so the
    whole model is **2 Pallas launches** (DESIGN.md §10).

    ``plan`` overrides the greedy plan (e.g. a
    :func:`repro.fuse.tuned_plan` replay or an explicit split for A/B
    timing); ``schedule`` rides on both SpMM anchors (``None`` →
    per-matrix auto selection).  Differentiable in ``x``/weights/biases
    through the planned launches' custom VJPs."""
    from ..fuse import gcn_chain
    from ..fuse import plan as plan_chain
    from ..fuse import run_plan

    chain, params = gcn_chain(adj, (w0, w1), (b0, b1),
                              activation=activation,
                              final_activation=final_activation,
                              schedule=schedule)
    p = plan_chain(chain) if plan is None else plan
    return run_plan(p, x, params, interpret=interpret)


# ---------------------------------------------------------------- linear


def dense(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def init_dense(key, d_in, d_out, dtype, bias=False, scale=None):
    if scale is None:
        scale = d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_dense(p, x):
    return dense(x, p["w"], p.get("b"))


# ---------------------------------------------------------------- rope


def rope_freqs(dh: int, theta: float):
    return theta ** (-jnp.arange(0, dh, 2, dtype=jnp.float32) / dh)


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, S, H, Dh); positions: (S,) or (B, S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- mlp


def init_mlp(cfg, key, d_model=None, d_ff=None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wi": init_dense(k1, d, f, cfg.param_dtype)["w"],
            "wg": init_dense(k2, d, f, cfg.param_dtype)["w"],
            "wo": init_dense(k3, f, d, cfg.param_dtype, scale=f ** -0.5)["w"],
        }
    return {
        "wi": init_dense(k1, d, f, cfg.param_dtype)["w"],
        "wo": init_dense(k3, f, d, cfg.param_dtype, scale=f ** -0.5)["w"],
    }


def apply_mlp(cfg, p, x):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(dense(x, p["wg"])) * dense(x, p["wi"])
    else:
        h = jax.nn.gelu(dense(x, p["wi"]))
    return dense(h, p["wo"])


# ---------------------------------------------------------------- embed / loss


def init_embedding(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def embed(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(table, x):
    return jnp.einsum("...d,vd->...v", x, table)


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token NLL. logits (..., V) f32-upcast; labels int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    return _masked_mean(nll, mask)


def _masked_mean(nll, mask):
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss_from_features(table, x, labels, mask=None):
    """Vocab-sharding-friendly LM loss from final features.

    Avoids gathering the full (B, S, V) logits across the vocab shards:
    logsumexp reduces the sharded logits in place (psum under SPMD) and
    the gold logit is recomputed as <x, E[label]> — a label-row gather of
    the embedding table instead of a label-column gather of the logits
    (the latter forced a 20-40 GB/chip all-gather + f32 copy at 152k
    vocab).
    """
    logits = unembed(table, x).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)  # (B, S)
    gold_emb = jnp.take(table, labels, axis=0)  # (B, S, D)
    gold = jnp.einsum("bsd,bsd->bs", x.astype(jnp.float32),
                      gold_emb.astype(jnp.float32))
    return _masked_mean(logz - gold, mask)
