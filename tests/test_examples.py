"""The runnable examples must actually run (subprocess, quick settings)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _run(script, *args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout[-3000:]}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


@pytest.mark.slow
def test_quickstart():
    assert "done" in _run("quickstart.py")


@pytest.mark.slow
def test_gcn_spmm():
    assert "gcn_spmm complete" in _run("gcn_spmm.py")


@pytest.mark.slow
def test_serve_lm():
    assert "serve_lm complete" in _run("serve_lm.py")


@pytest.mark.slow
def test_train_lm_quick():
    out = _run("train_lm.py", "--steps", "25", "--batch", "4",
               "--seq", "128")
    assert "train_lm complete" in out
