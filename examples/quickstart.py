"""Quickstart: the Sgap segment-group SpMM in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax

from repro.core import KernelSchedule, select_schedule
from repro.sparse import random_csr
from repro.sparse.ops import spmm
from repro.sparse.random import matrix_stats

# A skewed sparse matrix (a few very long rows) — the regime where the
# paper's flexible reduction wins.
A = random_csr(512, 512, density=0.02, skew=1.5, seed=0)
B = jax.random.normal(jax.random.PRNGKey(0), (512, 8))

# 1. Let the data-aware selector pick a schedule (paper Table 5 made a
#    library default).
stats = matrix_stats(A)
sched = select_schedule(stats, n_dense_cols=B.shape[1])
print(f"matrix: {stats['nnz']} nnz, row CV {stats['row_cv']:.2f}")
print(f"selected schedule: {sched}")

# 2. Run the Pallas segment-group kernel (interpret mode on CPU) and check
#    against the pure-jnp oracle.
out = spmm(A, B, sched)
ref = spmm(A, B, impl="ref")
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4,
                           atol=1e-4)
print("kernel matches oracle ✓")

# 3. Try explicit atomic-parallelism points {<1 nnz, c col>, r}.
for r in (8, 32):
    s = KernelSchedule("eb", nnz_tile=256, col_tile=8, group_size=r,
                       strategy="segment")
    out_r = spmm(A, B, s)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    print(f"group size r={r}: OK")
print("done")
