"""Model registry: family -> (init, loss, prefill, decode_step, init_cache).

All entries share the same functional API so the trainer / server / dry-run
are family-agnostic:

    api = get_model(cfg)
    params = api.init(key)
    loss   = api.loss(params, batch)          # batch: dict of arrays
    logits, cache = api.prefill(params, batch, max_len)
    logits, cache = api.decode_step(params, cache, tokens)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

from . import encdec, hybrid, ssm_lm, transformer, vlm


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: object
    init: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def _lm_prefill(mod, cfg, params, batch, max_len, ctx=None):
    return mod.prefill(cfg, params, batch["tokens"], max_len, ctx)


def get_model(cfg) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "moe"):
        mod = transformer
        prefill = functools.partial(_lm_prefill, mod, cfg)
    elif fam == "ssm":
        mod = ssm_lm
        prefill = functools.partial(_lm_prefill, mod, cfg)
    elif fam == "hybrid":
        mod = hybrid
        prefill = functools.partial(_lm_prefill, mod, cfg)
    elif fam == "encdec":
        mod = encdec
        prefill = functools.partial(mod.prefill, cfg)
    elif fam == "vlm":
        mod = vlm
        prefill = functools.partial(mod.prefill, cfg)
    else:
        raise ValueError(f"unknown family {fam!r}")

    return ModelApi(
        cfg=cfg,
        init=functools.partial(mod.init_params, cfg),
        loss=functools.partial(mod.loss_fn, cfg),
        prefill=prefill,
        decode_step=functools.partial(mod.decode_step, cfg),
        init_cache=functools.partial(mod.init_cache, cfg),
    )
