"""The one budgeted search driver behind every tuner (DESIGN.md §14).

:func:`drive` runs the shared loop all six ``tune_*`` entry points used
to hand-roll: cache replay → seed/ranked pool fill (top-k cut) →
per-axis pool expansion → measure → winner-stage axis variants
(parity/legality-gated) → per-axis hillclimb → persist a unified
:class:`~.cache.TuneRecord`.  A tuner is now a thin wrapper that
declares its :class:`~.space.SearchSpace`, its measurement closure and
its cache key/namespace, then calls :func:`drive` — joint axis search
(collective × value_dtype in one pass, per-boundary fuse bits) falls
out of composing axes instead of writing a seventh bespoke loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

from .cache import ScheduleCache, TuneRecord
from .space import SearchContext, SearchSpace

__all__ = ["TuneResult", "drive"]


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of one tuning run (or cache replay)."""

    schedule: object  # Schedule / MoeDispatchSchedule / FuseDecision
    us_per_call: float
    from_cache: bool
    key: str
    measured: Dict[str, float]  # point key -> us/call this run
    #: point key -> the measured point object (empty on replay; feeds
    #: ``calibrate.samples_from_results`` — not serialized to the cache).
    points: Dict[str, object] = dataclasses.field(default_factory=dict,
                                                  repr=False)

    @property
    def n_measurements(self) -> int:
        """Timing measurements this run paid for (0 on cache replay)."""
        return 0 if self.from_cache else len(self.measured)


class _Memo:
    """Measure-at-most-once memo over search points (shared by all
    tuners): ``memo(s)`` returns us/call, measuring on first sight.
    ``key_fn`` stringifies a point (``schedule_key`` for SpMM /
    segment-reduce, ``moe_schedule_key`` for MoE dispatch, the decision
    tag for fuse plans)."""

    def __init__(self, measure: Callable[[object], float],
                 key_fn: Callable[[object], str]):
        self._measure = measure
        self._key_fn = key_fn
        self.timings: Dict[str, float] = {}
        self.points: Dict[str, object] = {}

    def __call__(self, s) -> float:
        k = self._key_fn(s)
        if k not in self.timings:
            self.timings[k] = float(self._measure(s)) * 1e6
            self.points[k] = s
        return self.timings[k]

    def seen(self, s) -> bool:
        """True when ``s`` has already been measured this run."""
        return self._key_fn(s) in self.timings


def _persist(cache: ScheduleCache, key: str, best, memo: _Memo,
             *, record=None) -> TuneResult:
    """Record the winner and write the cache through (shared epilogue).
    ``record`` overrides what is persisted/reported as ``.schedule``
    (the fuse space stores the plan's decision, not the plan)."""
    record = best if record is None else record
    result = TuneResult(schedule=record, us_per_call=memo(best),
                        from_cache=False, key=key,
                        measured=dict(memo.timings),
                        points=dict(memo.points))
    cache.put(key, TuneRecord(schedule=record,
                              us_per_call=result.us_per_call,
                              measured=result.measured))
    cache.save()
    return result


def _replay(cache: ScheduleCache, key: str) -> Optional[TuneResult]:
    rec = cache.get(key)
    if rec is None:
        return None
    return TuneResult(schedule=rec.schedule, us_per_call=rec.us_per_call,
                      from_cache=True, key=key, measured={})


def drive(
    space: SearchSpace,
    ctx: SearchContext,
    *,
    cache: ScheduleCache,
    key: str,
    measure: Callable[[object], float],
    seeds: Sequence = (),
    ranked: Sequence = (),
    top_k: Optional[int] = None,
    hill_steps: int = 0,
) -> TuneResult:
    """Run the budgeted search and persist the winner under ``key``.

    seeds       always-measured points (e.g. the static selector's pick
                — the tuned choice can never lose to it beyond noise).
    ranked      cost-model-ranked candidates; taken in order until the
                pool exceeds ``top_k`` (``None`` = measure them all).
    hill_steps  max hillclimb rounds around the measured winner, moves
                supplied by the space's axes.

    The loop: a cache hit replays with **zero** measurements; otherwise
    the pool is seeds + top-k ranked + per-axis expansions (dedupe by
    ``space.dedupe``), every pool point is measured, each axis may then
    propose gated variants of the winner (measured head-to-head), and
    hillclimb refines until no fresh neighbor improves.
    """
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    memo = _Memo(measure, key_fn=space.key_fn)
    pool: list = []
    seen: set = set()

    def _admit(point) -> None:
        sig = space.dedupe(ctx, point)
        if sig not in seen:
            seen.add(sig)
            pool.append(point)

    for s in seeds:
        _admit(s)
    for s in ranked:
        if top_k is not None and len(pool) > top_k:
            break
        _admit(s)
    # per-axis pool expansion (kernel-family diversity, skew entry
    # points, ...) — each axis sees the pool its predecessors built
    for ax in space.axes:
        for s in ax.expand(ctx, pool, ranked):
            _admit(s)

    best = min(pool, key=memo)

    # winner-stage axis variants (e.g. the dtype axis, DESIGN.md §13):
    # gated by the axis, measured head-to-head with the pool winner.
    # Runs before hillclimb so refinement happens at the chosen variant.
    variants = space.variants(ctx, best, memo)
    if variants:
        best = min([best] + variants, key=memo)

    for _ in range(hill_steps):
        nbs = [s for s in space.neighbors(ctx, best)
               if not memo.seen(s) and space.dedupe(ctx, s) not in seen]
        if not nbs:
            break
        seen.update(space.dedupe(ctx, s) for s in nbs)
        contender = min(nbs, key=memo)
        if memo(contender) >= memo(best):
            break
        best = contender

    return _persist(cache, key, best, memo, record=space.record_of(best))
