"""Training step assembly: loss -> grads -> optimizer, with optional
gradient-accumulation microbatching and gradient compression.

``TrainState`` is a plain NamedTuple pytree so jit/pjit shard it directly.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..distributed.collectives import compress_tree, decompress_tree
from .optimizer import AdamState, AdamW


class TrainState(NamedTuple):
    params: dict
    opt: AdamState


def init_state(api, optimizer: AdamW, key) -> TrainState:
    params = api.init(key)
    return TrainState(params=params, opt=optimizer.init(params))


def make_train_step(api, optimizer: AdamW, ctx=None, *,
                    microbatches: int = 1, grad_compression: str | None = None):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 splits the batch on dim 0 and accumulates grads with
    ``lax.scan`` (sequential — overlaps with the next microbatch's compute
    under XLA latency hiding). grad_compression ∈ {None, 'bf16', 'int8'}
    compresses gradients before the (XLA-inserted) data-parallel
    all-reduce; see distributed/collectives.py.
    """

    loss_fn = functools.partial(api.loss, ctx=ctx)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(state: TrainState, batch):
        if microbatches > 1:
            def micro(carry, mb):
                acc, loss_acc = carry
                loss, g = grads_of(state.params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None

            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches) + x.shape[1:]),
                batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, loss_sum), _ = jax.lax.scan(
                micro, (zero, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = loss_sum / microbatches
        else:
            loss, grads = grads_of(state.params, batch)

        if grad_compression:
            grads = decompress_tree(compress_tree(grads, grad_compression))

        new_params, new_opt, gnorm = optimizer.update(
            grads, state.opt, state.params)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm,
                   "step": new_opt.step}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step
