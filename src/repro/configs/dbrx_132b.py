"""DBRX-132B [hf:databricks/dbrx-base]: 16 experts top-4, GQA kv=8."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_head=128,
    d_ff=0, moe_d_ff=10752, vocab_size=100352,
    n_experts=16, experts_per_token=4, capacity_factor=1.25,
    norm="layernorm", mlp_type="swiglu", rope_theta=5e5,
)
