"""repro: Sgap (segment group + atomic parallelism) as a production JAX/
Pallas framework — sparse kernels, model zoo, multi-pod distribution."""

__version__ = "0.1.0"
