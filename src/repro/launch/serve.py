"""Serving launcher: continuous-batching engine over random prompts.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        [--requests 16] [--slots 4] [--max-new 16]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from ..configs import ARCHS, smoke_config
from ..models import get_model
from ..serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ARCHS))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.scale == "smoke":
        cfg = smoke_config(cfg)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    engine = ServeEngine(api, params, slots=args.slots,
                         max_len=args.max_len,
                         temperature=args.temperature)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        engine.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(4, 16)),
                                dtype=np.int32),
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    results = engine.run_to_completion()
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
