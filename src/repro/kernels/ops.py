"""jit'd wrappers around the Pallas kernels: format glue, padding (zero
extension), and result cropping. These are what the rest of the framework
calls; the raw kernels stay shape-strict.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

import jax

from ..core.dtypes import operand_dtype, operand_itemsize, storage_dtype
from ..core.schedule import ACTIVATIONS, Epilogue, Schedule
from ..sparse.formats import (
    CSR,
    ELL,
    GroupedCOO,
    QuantizedCSR,
    _memoized,
    round_up,
)
from . import ref
from .grouped_matmul import grouped_matmul as _gmm_pallas
from .sddmm import sddmm as _sddmm_kernel
from .spmm_eb import spmm_eb as _spmm_eb
from .spmm_rb import spmm_rb as _spmm_rb

_NOOP_EP = Epilogue()

_VMEM_BYTES = 16 * 1024 * 1024  # v5e per-core VMEM


def _pad_cols(b, col_tile):
    k, n = b.shape
    n_pad = round_up(n, col_tile)
    if n_pad != n:
        b = jnp.pad(b, ((0, 0), (0, n_pad - n)))
    return b, n


def vmem_footprint_eb(k, n_rows, sched: Schedule, itemsize=4) -> int:
    """Working set the EB kernel claims per grid cell (see spmm_eb.py)."""
    return itemsize * (
        k * sched.col_tile            # B block
        + sched.nnz_tile * sched.col_tile  # partials
        + n_rows * sched.col_tile     # out block
        + 3 * sched.nnz_tile          # triplets
    )


def vmem_footprint_rb(k, width, sched: Schedule, itemsize=4,
                      width_tile: int = 64) -> int:
    """Working set the RB kernel claims per grid cell (see spmm_rb.py):
    the whole-K B block plus the (row_tile × width_tile) ELL slabs, their
    gathered expansion, and the output block."""
    wt = min(max(width, 1), width_tile)
    return itemsize * (
        k * sched.col_tile                       # B block
        + 2 * sched.row_tile * wt                # ecols + evals slabs
        + sched.row_tile * wt * sched.col_tile   # gathered B rows
        + sched.row_tile * sched.col_tile        # out block
    )


def schedule_fits_vmem(sched: Schedule, *, n_rows: int, n_cols: int,
                       row_max: int = 0, itemsize: int | None = None,
                       budget: int = _VMEM_BYTES) -> bool:
    """Whether a schedule's per-cell working set fits the VMEM budget —
    the feasibility predicate the autotuner prunes candidates with before
    spending measurement time on them.  ``itemsize=None`` derives the
    element width from the schedule's ``value_dtype`` (the B block and
    its gathered expansion dominate the cell, so the operand width is
    the honest bound)."""
    if itemsize is None:
        itemsize = operand_itemsize(sched.value_dtype)
    if sched.kernel == "eb":
        need = vmem_footprint_eb(n_cols, n_rows, sched, itemsize)
    else:
        need = vmem_footprint_rb(n_cols, max(row_max, 1), sched, itemsize)
    return need <= budget


def _pad_epilogue_operands(ep, bias, residual, n_rows, n_pad):
    """Pad the epilogue's array operands to the kernel layout: bias
    (1, n_pad), residual (n_rows, n_pad).  Presence was validated by
    ``spmm`` before the impl branch (ref and pallas fail identically)."""
    bias_p = res_p = None
    if ep.bias:
        bias_p = jnp.reshape(bias, (1, -1))
        bias_p = jnp.pad(bias_p, ((0, 0), (0, n_pad - bias_p.shape[1])))
    if ep.residual:
        res_p = jnp.pad(residual, ((0, n_rows - residual.shape[0]),
                                   (0, n_pad - residual.shape[1])))
    return bias_p, res_p


def _cast_stream(fmt, vals, dt):
    """Memoized cast of a format's value stream to storage dtype ``dt``
    (keyed on the format instance, so a serving loop casts once)."""
    if vals.dtype == dt:
        return vals
    return _memoized(fmt, (vals,), ("vals_astype", str(jnp.dtype(dt))),
                     lambda: vals.astype(dt))


def spmm(a, b, schedule: Schedule | None = None, *,
         bias=None, residual=None, impl: str = "pallas",
         interpret: bool = True):
    """out = A @ B for sparse A (CSR / QuantizedCSR / GroupedCOO / ELL)
    and dense B, with the schedule's fused epilogue applied in-kernel.

    impl='ref' runs the pure-jnp oracle (epilogue applied via its
    executable spec); impl='pallas' runs the kernel the schedule selects
    (eb -> GroupedCOO path, rb -> ELL path).  CSR inputs convert through
    the per-(format, tile) cache on CSR.  ``bias`` (N,) / ``residual``
    (n_rows, N) are required exactly when ``schedule.epilogue`` declares
    them.

    ``schedule.value_dtype`` (DESIGN.md §13) selects the storage width
    the kernel *moves*: narrow floats cast the value stream and B to
    that dtype (memoized per instance); 'int8' routes through the
    quantized path — a CSR is quantized once (per-row scales, memoized),
    a :class:`QuantizedCSR` feeds its codes directly, and B narrows to
    bf16.  Accumulation stays f32 either way (``upcast_f32``).
    """
    if schedule is None:
        schedule = Schedule("eb")
    ep = schedule.epilogue
    if ep.bias and bias is None:
        raise ValueError("schedule epilogue declares bias=True but no "
                         "bias array was passed")
    if ep.residual and residual is None:
        raise ValueError("schedule epilogue declares residual=True but "
                         "no residual array was passed")

    if impl == "ref":
        if isinstance(a, QuantizedCSR):
            a = a.dequantize()
        if isinstance(a, GroupedCOO):
            out = ref.spmm_coo_ref(a.rows, a.cols, a.vals, b, a.shape[0])
        elif isinstance(a, CSR):
            coo = a.tocoo()
            out = ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, b,
                                   a.shape[0])
        elif isinstance(a, ELL):
            out = ref.spmm_ell_ref(a.cols, a.vals, b, a.shape[0])
        else:
            raise TypeError(type(a))
        if ep.is_noop:
            return out
        return ep.apply(out, bias=bias, residual=residual)

    vd = schedule.value_dtype
    scales = None
    if isinstance(a, QuantizedCSR) or vd == "int8":
        if isinstance(a, CSR):
            a = a.quantized()  # memoized host-side calibration pass
        if not isinstance(a, QuantizedCSR):
            raise TypeError(
                "value_dtype='int8' needs a CSR or QuantizedCSR input "
                "(the per-row scales are a CSR-level calibration); got "
                f"{type(a).__name__}")
        scales = a.scales
        a = a.csr  # int8 codes on the original pattern
        b = b.astype(operand_dtype("int8"))
    elif vd is not None:
        b = b.astype(operand_dtype(vd))

    col_tile = min(schedule.col_tile, round_up(b.shape[1], 8))
    b_pad, n = _pad_cols(b, col_tile)
    n_pad = b_pad.shape[1]

    if schedule.kernel == "eb":
        skew_kw = dict(group_size=schedule.group_size,
                       split_threshold=schedule.split_threshold,
                       merge_threshold=schedule.merge_threshold)
        if isinstance(a, CSR):
            a = a.grouped(schedule.nnz_tile, **skew_kw)
        assert isinstance(a, GroupedCOO), type(a)
        a = a.regrouped(schedule.nnz_tile, **skew_kw)  # memoized; no-op
        vals = (a.vals if vd is None or scales is not None
                else _cast_stream(a, a.vals, storage_dtype(vd)))
        bias_p, res_p = _pad_epilogue_operands(ep, bias, residual,
                                               a.shape[0], n_pad)
        out = _spmm_eb(
            a.rows, a.cols, vals, b_pad, n_rows=a.shape[0],
            nnz_tile=schedule.nnz_tile, col_tile=col_tile,
            group_size=schedule.group_size, strategy=schedule.strategy,
            heavy_tiles=a.heavy_tiles, epilogue=ep, scales=scales,
            bias=bias_p, residual=res_p, interpret=interpret)
        return out[:, :n]

    # rb path
    if isinstance(a, CSR):
        a = a.ell(row_tile=schedule.row_tile)
    assert isinstance(a, ELL), type(a)
    r_pad = round_up(a.n_rows_padded, schedule.row_tile)
    ecols, evals = a.cols, a.vals
    if vd is not None and scales is None:
        evals = _cast_stream(a, evals, storage_dtype(vd))
    if r_pad != a.n_rows_padded:
        pad = r_pad - a.n_rows_padded
        ecols = jnp.pad(ecols, ((0, pad), (0, 0)))
        evals = jnp.pad(evals, ((0, pad), (0, 0)))
    scales_p = None
    if scales is not None:
        # per-row scales aligned to the padded row axis; padded rows
        # carry val 0, so the filler scale value is never observable
        scales_p = jnp.pad(scales, (0, r_pad - scales.shape[0]),
                           constant_values=1.0)
    bias_p, res_p = _pad_epilogue_operands(ep, bias, residual, r_pad, n_pad)
    out = _spmm_rb(ecols, evals, b_pad, row_tile=schedule.row_tile,
                   col_tile=col_tile, epilogue=ep, scales=scales_p,
                   bias=bias_p, residual=res_p, interpret=interpret)
    return out[: a.shape[0], :n]


def sddmm(rows, cols, a, b, scale=None, *, nnz_tile: int = 256,
          impl: str = "pallas", interpret: bool = True):
    """vals[t] = <A[rows[t]], B[cols[t]]> (* scale[t]); rows/cols (nnz,).

    ``scale=None`` skips the scale operand entirely (no ``ones((nnz,))``
    materialized per call): padded lanes are legal by the zero-extension
    rule — padding is strictly trailing and cropped by ``out[:nnz]``.
    """
    if impl == "ref":
        return ref.sddmm_ref(rows, cols, a, b, scale)
    nnz = rows.shape[0]
    nnz_pad = round_up(max(nnz, 1), nnz_tile)
    pad = nnz_pad - nnz
    rows_p = jnp.pad(rows, (0, pad))
    cols_p = jnp.pad(cols, (0, pad))
    # zero scale masks padded lanes (None: trailing garbage is cropped)
    scale_p = None if scale is None else jnp.pad(scale, (0, pad))
    d = a.shape[1]
    d_tile = min(128, round_up(d, 8))
    d_pad = round_up(d, d_tile)
    if d_pad != d:
        a = jnp.pad(a, ((0, 0), (0, d_pad - d)))
        b = jnp.pad(b, ((0, 0), (0, d_pad - d)))
    out = _sddmm_kernel(rows_p, cols_p, a, b, scale_p, nnz_tile=nnz_tile,
                        d_tile=d_tile, interpret=interpret)
    return out[:nnz]


def expert_tile_map(group_sizes: np.ndarray, token_tile: int) -> np.ndarray:
    """tile -> expert map for capacity-padded grouped matmul: expert e owns
    ceil(group_sizes[e] / token_tile) consecutive tiles."""
    tiles = []
    for e, g in enumerate(group_sizes):
        tiles.extend([e] * int(np.ceil(g / token_tile)))
    return np.asarray(tiles, np.int32)


def grouped_matmul_ref(x, tile_experts, weights, *, bias=None,
                       epilogue: Epilogue = _NOOP_EP,
                       token_tile: int = 128):
    """Pure-jnp oracle for the epilogued grouped matmul: per token tile i
    with expert e = tile_experts[i],
    ``y = epilogue(x_tile @ weights[e], bias=bias[e])``."""
    t_pad, d = x.shape
    xt = x.reshape(-1, token_tile, d).astype(jnp.float32)
    wt = weights[tile_experts].astype(jnp.float32)  # (NT, D, F)
    z = jnp.einsum("ntd,ndf->ntf", xt, wt)
    b = (None if bias is None
         else bias[tile_experts][:, None, :].astype(jnp.float32))
    y = epilogue.apply(z, bias=b)
    return y.reshape(t_pad, -1)


def grouped_matmul(x, tile_experts, weights, *, bias=None,
                   epilogue: Epilogue = _NOOP_EP, token_tile: int = 128,
                   f_tile: int = 128, d_tile: int = 128,
                   impl: str = "pallas", interpret: bool = True):
    """Differentiable epilogued grouped matmul — the MoE expert GEMM as
    one Pallas launch per tile (GEMM + bias/activation/cast fused onto
    the output block; ``repro.fuse`` routes ``grouped_matmul`` chain
    nodes here).

    x (T_pad, D) expert-sorted tokens, tile_experts (T_pad//token_tile,)
    int32, weights (E, D, F), bias (E, F) iff ``epilogue.bias``.
    Differentiable in x, weights and bias: Pallas forward, pure-JAX ref
    backward (recompute z, activation VJP, segment scatter-add into the
    expert axis).  ``tile_experts`` is routing data, not an operand.
    """
    assert epilogue.bias == (bias is not None)
    if impl == "ref":
        return grouped_matmul_ref(x, tile_experts, weights, bias=bias,
                                  epilogue=epilogue, token_tile=token_tile)

    def run(xx, ww, bb):
        return _gmm_pallas(xx, tile_experts, ww, bias=bb,
                           epilogue=epilogue, token_tile=token_tile,
                           f_tile=f_tile, d_tile=d_tile,
                           interpret=interpret)

    @jax.custom_vjp
    def fn(xx, ww, bb):
        return run(xx, ww, bb)

    def fwd(xx, ww, bb):
        return run(xx, ww, bb), (xx, ww, bb)

    def bwd(res, dout):
        xx, ww, bb = res
        t_pad, d = xx.shape
        f = ww.shape[2]
        xt = xx.reshape(-1, token_tile, d).astype(jnp.float32)
        wt = ww[tile_experts].astype(jnp.float32)  # (NT, D, F)
        dz = dout.astype(jnp.float32).reshape(-1, token_tile, f)
        if epilogue.activation is not None:
            z = jnp.einsum("ntd,ndf->ntf", xt, wt)
            if epilogue.bias:
                z = z + bb[tile_experts][:, None, :].astype(jnp.float32)
            _, act_vjp = jax.vjp(ACTIVATIONS[epilogue.activation], z)
            dz, = act_vjp(dz)
        dx = jnp.einsum("ntf,ndf->ntd", dz, wt).reshape(t_pad, d).astype(
            xx.dtype)
        dw = jnp.zeros(ww.shape, jnp.float32).at[tile_experts].add(
            jnp.einsum("ntd,ntf->ndf", xt, dz)).astype(ww.dtype)
        db = None
        if epilogue.bias:
            db = jnp.zeros(bb.shape, jnp.float32).at[tile_experts].add(
                jnp.sum(dz, axis=1)).astype(bb.dtype)
        return dx, dw, db

    fn.defvjp(fwd, bwd)
    return fn(x, weights, bias)
