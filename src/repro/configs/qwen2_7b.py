"""Qwen2-7B [arXiv:2407.10671]: dense GQA with QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_head=128,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, norm="rmsnorm", mlp_type="swiglu", rope_theta=1e6,
)
