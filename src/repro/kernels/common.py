"""Shared in-kernel building blocks for the segment-group kernels.

``group_reduce_scatter`` is the TPU realization of the paper's segment
group (DESIGN.md §2): within each width-G group it

1. finds segment runs (boundary cumsum — replaces the GPU's runtime
   writeback-thread election),
2. reduces the run partials with a (G × G) one-hot matmul — the MXU
   analogue of the warp shuffle tree,
3. writes each live run back with a read-modify-write into the output
   block — the analogue of the paper's multiple writeback threads; the
   sequential TPU grid makes the RMW race-free ("atomic" for free).

Strategy variants:
  'segment'     full machinery above (runtime writeback targets);
  'parallel'    contract: all lanes of a group share one segment -> plain
                sum + single writeback (one writeback thread);
  'accumulate'  per-lane RMW (the atomicAdd baseline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmw_row(out_ref, row, delta):
    """out_ref[row, :] += delta  (delta shape (1, C)), dynamic row index."""
    idx = (pl.dslice(row, 1), slice(None))
    out_ref[idx] = out_ref[idx] + delta


def group_reduce_scatter(rows, partial, out_ref, group_size: int,
                         strategy: str = "segment"):
    """Reduce ``partial`` (T, C) by ``rows`` (T,) into ``out_ref`` (R, C).

    ``rows`` need not be globally sorted; sorted input minimizes writebacks
    (each unsorted transition opens a new run — correct, just more RMWs),
    which is exactly the paper's "writeback thread decided at runtime".
    """
    T, C = partial.shape
    G = group_size
    assert T % G == 0, (T, G)
    n_groups = T // G

    if strategy == "accumulate":
        def lane_body(t, _):
            _rmw_row(out_ref, rows[t], partial[t][None, :])
            return 0
        jax.lax.fori_loop(0, T, lane_body, 0)
        return

    if strategy == "parallel":
        def par_body(n, _):
            p = jax.lax.dynamic_slice(partial, (n * G, 0), (G, C))
            _rmw_row(out_ref, rows[n * G], jnp.sum(p, axis=0)[None, :])
            return 0
        jax.lax.fori_loop(0, n_groups, par_body, 0)
        return

    assert strategy == "segment", strategy

    def group_body(n, _):
        r = jax.lax.dynamic_slice(rows, (n * G,), (G,))
        p = jax.lax.dynamic_slice(partial, (n * G, 0), (G, C))
        # run boundaries -> local segment slots in [0, G)
        prev = jnp.concatenate([jnp.full((1,), -1, r.dtype), r[:-1]])
        local = jnp.cumsum((r != prev).astype(jnp.int32)) - 1  # (G,)
        onehot = (
            local[:, None]
            == jax.lax.broadcasted_iota(jnp.int32, (G, G), 1)
        ).astype(p.dtype)  # (G lanes, G slots)
        seg_tot = jnp.dot(onehot.T, p,
                          preferred_element_type=jnp.float32)  # (G, C) MXU
        # slot -> global row (slots past the last run get -1 = dead)
        seg_rows = jnp.max(
            jnp.where(onehot > 0, r[:, None], -1), axis=0
        )  # (G,)

        def slot_body(s, _):
            row = seg_rows[s]

            @pl.when(row >= 0)
            def _():
                _rmw_row(out_ref, row,
                         jax.lax.dynamic_slice(seg_tot, (s, 0), (1, C)))
            return 0

        jax.lax.fori_loop(0, G, slot_body, 0)
        return 0

    jax.lax.fori_loop(0, n_groups, group_body, 0)
