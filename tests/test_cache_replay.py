"""ISSUE 10 satellite 5: cache-replay smoke across every tuner surface.

Each test tunes a small *deterministic* workload against the cache file
named by ``REPRO_TUNE_CACHE`` (a per-session tmpdir fallback keeps local
runs hermetic) and persists the winner.  CI runs this module twice
against ONE shared ``REPRO_TUNE_CACHE`` tmpdir; the second pass sets
``REPRO_EXPECT_REPLAY=1``, under which every tuner call must resolve
from the cache with **zero measurements** — the measure callback raises
if it is ever invoked.  That pins the end-to-end invariant the whole
tuner stack is built on: tune once, replay everywhere.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Schedule
from repro.sparse import power_law_csr, random_csr
from repro.tune import (
    ScheduleCache,
    tune_dist_spmm,
    tune_moe_dispatch,
    tune_schedule,
    tune_segment_reduce,
    tune_sparse_attention,
)

EXPECT_REPLAY = os.environ.get("REPRO_EXPECT_REPLAY") == "1"


@pytest.fixture(scope="module")
def cache_path(tmp_path_factory):
    """The shared cache file: ``REPRO_TUNE_CACHE`` when the harness set
    one (the CI double-run), else a module-scoped tmpdir (hermetic local
    runs — first pass tunes, nothing asserts replay)."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    if EXPECT_REPLAY:
        pytest.fail("REPRO_EXPECT_REPLAY=1 requires REPRO_TUNE_CACHE "
                    "to point at the first pass's cache file")
    return str(tmp_path_factory.mktemp("tune") / "replay_cache.json")


def _measure(record):
    """Deterministic objective; hard-fails if the replay pass measures."""

    def m(point):
        if EXPECT_REPLAY:
            raise AssertionError(
                f"replay pass ran a measurement for {point!r}")
        record.append(point)
        return 1e-6 * (1 + len(record) % 3)

    return m


def _finish(cache, res, calls):
    if EXPECT_REPLAY:
        assert res.from_cache, res.key
        assert res.n_measurements == 0 and not calls
    else:
        assert res.schedule is not None
        cache.save()


def test_replay_tune_schedule(cache_path):
    csr = random_csr(96, 96, density=0.08, seed=0)
    cache = ScheduleCache(path=cache_path)
    calls = []
    res = tune_schedule(csr, 8, cache=cache, measure=_measure(calls),
                        top_k=1, hill_steps=1)
    _finish(cache, res, calls)


def test_replay_tune_segment_reduce(cache_path):
    rng = np.random.default_rng(1)
    seg_ids = np.sort(rng.integers(0, 24, 600)).astype(np.int32)
    cache = ScheduleCache(path=cache_path)
    calls = []
    res = tune_segment_reduce(seg_ids, 4, 24, cache=cache,
                              measure=_measure(calls))
    _finish(cache, res, calls)


def test_replay_tune_dist_spmm(cache_path):
    csr = power_law_csr(64, 48, avg_degree=5.0, alpha=1.5, seed=2)
    mesh = jax.make_mesh((jax.device_count(),), ("shards",))
    cache = ScheduleCache(path=cache_path)
    calls = []
    res = tune_dist_spmm(csr, 8, mesh=mesh, axis="shards", cache=cache,
                         measure=_measure(calls), top_k=1, hill_steps=1)
    _finish(cache, res, calls)


def test_replay_tune_moe_dispatch(cache_path):
    lengths = np.asarray([96, 32, 64, 64], np.int64)
    cache = ScheduleCache(path=cache_path)
    calls = []
    res = tune_moe_dispatch(lengths, 32, 32, cache=cache,
                            measure=_measure(calls), top_k=1,
                            hill_steps=1)
    _finish(cache, res, calls)


def test_replay_tune_sparse_attention(cache_path):
    rng = np.random.default_rng(3)
    rows = jnp.asarray(np.sort(rng.integers(0, 24, 60)).astype(np.int32))
    cols = jnp.asarray(rng.integers(0, 20, 60).astype(np.int32))
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (24, 8))
    k = jax.random.normal(kk, (20, 8))
    v = jax.random.normal(kv, (20, 6))
    cache = ScheduleCache(path=cache_path)
    calls = []
    res = tune_sparse_attention(rows, cols, q, k, v, n_rows=24,
                                cache=cache, measure=_measure(calls))
    _finish(cache, res, calls)


def test_replay_tune_plan(cache_path):
    from repro.fuse import gcn_chain, tune_plan

    rng = np.random.default_rng(4)
    n, d = 32, 4
    adj = random_csr(n, n, density=0.15, seed=4)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    b0 = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    chain, params = gcn_chain(adj, (w0, w1), (b0, None),
                              schedule=Schedule("eb", nnz_tile=64,
                                                group_size=8))
    cache = ScheduleCache(path=cache_path)
    calls = []
    res = tune_plan(chain, x, params, cache=cache,
                    measure=_measure(calls))
    _finish(cache, res, calls)
