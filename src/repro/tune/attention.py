"""Fused-sparse-attention schedule tuning (DESIGN.md §9).

The fused attention kernels expose the same (nnz_tile, group_size,
strategy) axes as ``segment_reduce`` — but the *objective* differs per
direction: the forward is a (H, nnz_tiles, dv_tiles) grid with the
probability carry, the backward a (H, 2, nnz_tiles) two-phase grid with
twice the scatter traffic.  A schedule tuned for one is not evidence
about the other, and batching H heads into one launch changes the
arithmetic intensity per pattern byte.  The cache key therefore carries
the **direction** (``fwd``/``bwd``), the **head count**, the feature
widths and the bias-operand flag alongside the row-histogram
fingerprint — a fwd record never replays for a bwd query, nor an H=1
record for an H=8 one.

Like ``tune_segment_reduce``, the objective times the *actual* Pallas
kernels (there is no cheaper analogue that still observes the tile
axis); 'parallel' is excluded from the pool (``sparse_attention``
rejects it).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from ..core import Schedule
from .cache import ScheduleCache, default_cache, fingerprint_from_lengths
from .driver import TuneResult, _replay, drive
from .measure import time_fn
from .space import (SearchContext, SearchSpace, StrategyAxis, TilingAxis,
                    schedule_key)

__all__ = [
    "attention_cache_key",
    "tune_sparse_attention",
]

#: (nnz_tile, group_size, strategy) pool measured per pattern — the EB
#: half of the grid minus 'parallel' (rejected for attention rows).
_POOL = [Schedule("eb", nnz_tile=tile, group_size=g, strategy=st)
         for tile in (128, 512)
         for g in (8, 32)
         for st in ("segment", "accumulate")]


def attention_cache_key(rows, n_rows: int, *, n_cols: int, d: int,
                        dv: int, n_heads: int, direction: str,
                        has_bias: bool = False) -> str:
    """Cache key for a fused-attention tuning record.

    Distinguishes forward from backward and the head count (plus the
    feature widths and whether a bias operand rides along): the two
    directions run different grids with different traffic patterns, so
    their winners must never alias.  ``n_cols`` (the key/value count) is
    part of the fingerprint shape — the kernel holds (n_kv, ·) resident
    blocks, so patterns differing only in n_kv must not share records.
    """
    if direction not in ("fwd", "bwd"):
        raise ValueError(f"direction must be 'fwd' or 'bwd', "
                         f"got {direction!r}")
    rows_np = np.asarray(rows)
    lengths = np.bincount(rows_np, minlength=max(n_rows, 1))
    fp = fingerprint_from_lengths(lengths, (n_rows, n_cols),
                                  rows_np.shape[0])
    b = "|b" if has_bias else ""
    return f"attn:{fp}|d{d}|dv{dv}|H{n_heads}|{direction}{b}"


def tune_sparse_attention(
    rows,
    cols,
    q,
    k,
    v,
    *,
    n_rows: int,
    bias=None,
    scale: Optional[float] = None,
    direction: str = "fwd",
    cache: Optional[ScheduleCache] = None,
    measure: Optional[Callable[[Schedule], float]] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
    backend: Optional[str] = None,
    interpret: bool = True,
) -> TuneResult:
    """Empirically pick (nnz_tile, group_size, strategy) for the fused
    sparse-attention kernel over this pattern.

    ``direction='fwd'`` times :func:`~repro.kernels.fused_attention.
    fused_sparse_attention`; ``'bwd'`` times the fused backward (running
    one forward per candidate first to obtain the (m, l) residuals the
    backward consumes).  q/k/v may be 2-D (single head) or (n, H, ·) —
    the head count is part of the cache key.  A cache hit replays with
    zero measurements."""
    import jax
    import jax.numpy as jnp

    from ..kernels.fused_attention import (
        fused_sparse_attention,
        fused_sparse_attention_bwd,
    )
    from ..sparse.formats import round_up
    from ..sparse.ops import _attn_heads

    qh, kh, vh, _ = _attn_heads(q, k, v)
    n_heads, _, d = qh.shape
    n_cols, dv = vh.shape[1], vh.shape[-1]
    if scale is None:
        scale = float(d) ** -0.5
    key = attention_cache_key(rows, n_rows, n_cols=n_cols, d=d, dv=dv,
                              n_heads=n_heads, direction=direction,
                              has_bias=bias is not None)
    if cache is None:
        cache = default_cache(backend)
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    if measure is None:
        nnz = int(np.asarray(rows).shape[0])
        dv_tile = min(128, round_up(dv, 8))
        dv_pad = round_up(dv, dv_tile)
        v_p = (jnp.pad(vh, ((0, 0), (0, 0), (0, dv_pad - dv)))
               if dv_pad != dv else vh)
        # the cotangent has the OUTPUT's shape — (H, n_rows, dv), not
        # v's (H, n_cols, dv); they only coincide on square patterns
        dout = jax.random.normal(jax.random.PRNGKey(0),
                                 (n_heads, n_rows, dv))

        def measure(s: Schedule) -> float:
            nnz_pad = max(round_up(max(nnz, 1), s.nnz_tile), s.nnz_tile)
            pad = nnz_pad - nnz
            rows_p = jnp.pad(jnp.asarray(rows), (0, pad))
            cols_p = jnp.pad(jnp.asarray(cols), (0, pad))
            bias_p = (None if bias is None
                      else jnp.pad(bias.astype(jnp.float32), (0, pad)))

            def fwd(qq, kk, vv):
                return fused_sparse_attention(
                    rows_p, cols_p, qq, kk, vv, n_rows=n_rows, nnz=nnz,
                    nnz_tile=s.nnz_tile, dv_tile=dv_tile, scale=scale,
                    group_size=s.group_size, strategy=s.strategy,
                    bias=bias_p, interpret=interpret)

            if direction == "fwd":
                return time_fn(lambda qq, kk, vv: fwd(qq, kk, vv)[0],
                               qh, kh, v_p, warmup=warmup, iters=iters)
            _, m, l = fwd(qh, kh, v_p)

            def bwd(qq, kk, vv, do):
                return fused_sparse_attention_bwd(
                    rows_p, cols_p, qq, kk, vv, do, m, l, n_rows=n_rows,
                    nnz=nnz, nnz_tile=s.nnz_tile, scale=scale,
                    group_size=s.group_size, strategy=s.strategy,
                    bias=bias_p, interpret=interpret)

            return time_fn(bwd, qh, kh, vh, dout,
                           warmup=warmup, iters=iters)

    # exhaustive over the fixed pool: the driver measures every ranked
    # point (top_k=None) and skips hillclimb/variant stages
    space = SearchSpace((StrategyAxis(), TilingAxis()), key_fn=schedule_key)
    return drive(space, SearchContext(), cache=cache, key=key,
                 measure=measure, ranked=_POOL)
