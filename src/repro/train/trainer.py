"""Training loop: jit'd step + checkpoint/restart + heartbeat/straggler
hooks + elastic restart plan. Runs on any mesh (CPU tests use 1 device).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..distributed.fault_tolerance import HeartbeatMonitor, make_elastic_plan
from .optimizer import AdamW
from .train_step import TrainState, init_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    microbatches: int = 1
    grad_compression: str | None = None


class Trainer:
    def __init__(self, api, optimizer: AdamW, data_iter, *,
                 ckpt_dir, tcfg: TrainerConfig = TrainerConfig(),
                 ctx=None, hosts=("host0",), host_index: int = 0):
        self.api = api
        self.optimizer = optimizer
        self.data = data_iter
        self.tcfg = tcfg
        self.ckpt = CheckpointManager(ckpt_dir, keep=tcfg.keep_ckpts)
        self.monitor = HeartbeatMonitor(hosts)
        self.host = hosts[host_index]
        self.step_fn = jax.jit(make_train_step(
            api, optimizer, ctx, microbatches=tcfg.microbatches,
            grad_compression=tcfg.grad_compression))
        self.history: list[dict] = []

    def init_or_restore(self, key) -> TrainState:
        state = init_state(self.api, self.optimizer, key)
        latest = self.ckpt.latest_step()
        if latest is not None:
            state, step = self.ckpt.restore(state)
            print(f"[trainer] restored checkpoint step {step}")
        return state

    def run(self, state: TrainState) -> TrainState:
        t = self.tcfg
        start = int(state.opt.step)
        for step in range(start, t.total_steps):
            batch = next(self.data)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])  # also blocks until ready
            dt = time.perf_counter() - t0
            self.monitor.beat(self.host, dt)
            self.history.append({"step": step + 1, "loss": loss,
                                 "grad_norm": float(metrics["grad_norm"]),
                                 "dt_s": dt})
            if (step + 1) % t.log_every == 0:
                print(f"[trainer] step {step + 1} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt:.3f}s")
            if (step + 1) % t.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
            plan = make_elastic_plan(self.monitor, self.ckpt.all_steps(),
                                     global_batch=batch["tokens"].shape[0])
            if plan is not None:
                print(f"[trainer] ELASTIC RESTART NEEDED: {plan.note}")
                break
        self.ckpt.wait()
        return state

    def losses(self) -> np.ndarray:
        return np.asarray([h["loss"] for h in self.history])
