"""Distributed sparse ops via shard_map — the paper's reduction-strategy
choice *elevated to the collective level* (DESIGN.md §12).

Three partitionings of ``out = A @ B`` (and of sparse attention):

row         A row-partitioned over the axis; no collectives (each shard
            owns whole output rows) — the collective analogue of parallel
            reduction / one writeback thread.
nnz_ar      A nnz-partitioned; each shard computes a full-height partial
            and an **all-reduce** combines — the analogue of atomicAdd
            (every shard "writes" every row).
nnz_rs      A nnz-partitioned; partials combined with **reduce-scatter**
            so each shard finalizes its own row block — the analogue of
            segment reduction (multiple writeback shards, targets decided
            by data layout). Moves 1/P the bytes of nnz_ar on the wire per
            shard output.

All three compute identical results; they differ in collective bytes and
balance, which is exactly the axis the paper tunes.  The mode is carried
by ``Schedule.collective`` so the distributed tuner
(:func:`repro.tune.tune_dist_spmm`) picks kernel tiling and wire strategy
in one pass; ``repro.roofline.analysis.predict_collective_bytes``
predicts the wire traffic each mode compiles to.

Shard-local compute runs the *tuned Pallas kernels* (``kernels.ops.spmm``
over a shard-local :class:`GroupedCOO`, ``fused_sparse_attention`` for
attention) — not the pure-jnp reference — so the distributed path keeps
the schedule work of DESIGN.md §6–§11.

Padding contract: attention has no values to zero-extend with, so the
partition helpers route pad lanes to a **phantom row** appended after the
real rows; each shard computes it like any other row and the wrappers
crop it before (row mode) or alongside (nnz modes) the collective.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on jax version
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(fn, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off (pallas_call has no
    replication rule), tolerant of the check kwarg's rename across jax
    versions (``check_rep`` -> ``check_vma``)."""
    for kw in ({"check_rep": False}, {"check_vma": False}, {}):
        try:
            return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)
        except TypeError:
            continue
    raise TypeError("no compatible shard_map signature found")

from ..core import COLLECTIVES, Schedule
from ..kernels import ops as kops
from ..kernels.fused_attention import NEG_INF, fused_sparse_attention
from .formats import GroupedCOO, round_up

__all__ = [
    "COLLECTIVES",
    "dist_attention_shard_map",
    "dist_spmm",
    "partition_nnz_coo",
    "partition_rows_coo",
    "shard_nnz_counts",
    "spmm_shard_map",
]


# ---------------------------------------------------------------------------
# Host-side partition helpers (concrete arrays in, shardable arrays out)
# ---------------------------------------------------------------------------


def _np_triplet(csr, pattern_only: bool):
    coo = csr.tocoo()
    rows = np.asarray(coo.rows, np.int32)
    cols = np.asarray(coo.cols, np.int32)
    vals = None if pattern_only else np.asarray(coo.vals)
    return rows, cols, vals


def partition_nnz_coo(csr, axis_size: int, nnz_tile: int = 256, *,
                      pattern_only: bool = False, phantom_row: bool = False):
    """Row-sorted COO triplets padded so every shard of an
    ``axis_size``-way nnz split gets an equal, ``nnz_tile``-aligned slice.

    ``pattern_only`` drops the value stream (attention patterns);
    ``phantom_row`` targets pad lanes at row ``n_rows`` (one past the
    end) instead of zero-extending into row ``n_rows - 1`` — required
    whenever pad lanes have no zero value to neutralize them (attention).
    Returns ``(rows, cols, vals_or_None, nnz)``.
    """
    rows, cols, vals = _np_triplet(csr, pattern_only)
    nnz = int(rows.shape[0])
    per = round_up(max(nnz, 1), nnz_tile * axis_size)
    pad = per - nnz
    pad_row = csr.shape[0] if phantom_row else csr.shape[0] - 1
    rows = np.concatenate([rows, np.full((pad,), pad_row, np.int32)])
    cols = np.concatenate([cols, np.zeros((pad,), np.int32)])
    if vals is not None:
        vals = np.concatenate([vals, np.zeros((pad,), vals.dtype)])
    return (jnp.asarray(rows), jnp.asarray(cols),
            None if vals is None else jnp.asarray(vals), nnz)


def partition_rows_coo(csr, axis_size: int, nnz_tile: int = 256, *,
                       pattern_only: bool = False, phantom_row: bool = False):
    """Bucket the triplets by contiguous row blocks of ``n_rows /
    axis_size`` and pad every bucket to one common ``nnz_tile``-aligned
    length, re-indexing rows to bucket-local ids.

    The concatenation shards evenly over the mesh axis, giving each shard
    the triplets of exactly its own output rows (the 'row' / parallel
    collective).  Pad lanes target the bucket's last local row
    (``phantom_row=False``, zero-extension) or the local phantom row
    ``n_rows_local`` (``phantom_row=True``).  Returns ``(rows, cols,
    vals_or_None, shard_nnz)`` with ``shard_nnz`` the per-bucket true
    lane counts (the balance statistic the tuner seeds from).
    """
    n_rows = csr.shape[0]
    if n_rows % axis_size:
        raise ValueError(
            f"row partitioning needs n_rows ({n_rows}) divisible by the "
            f"axis size ({axis_size})")
    rows, cols, vals = _np_triplet(csr, pattern_only)
    block = n_rows // axis_size
    bucket = rows // block
    counts = np.bincount(bucket, minlength=axis_size)
    per = round_up(max(int(counts.max()), 1), nnz_tile)
    pad_row = block if phantom_row else block - 1
    out_r = np.full((axis_size, per), pad_row, np.int32)
    out_c = np.zeros((axis_size, per), np.int32)
    out_v = (None if vals is None
             else np.zeros((axis_size, per), vals.dtype))
    for s in range(axis_size):
        sel = bucket == s
        k = int(counts[s])
        out_r[s, :k] = rows[sel] - s * block
        out_c[s, :k] = cols[sel]
        if out_v is not None:
            out_v[s, :k] = vals[sel]
    return (jnp.asarray(out_r.reshape(-1)), jnp.asarray(out_c.reshape(-1)),
            None if out_v is None else jnp.asarray(out_v.reshape(-1)),
            [int(c) for c in counts])


def shard_nnz_counts(csr, axis_size: int, collective: str):
    """Per-shard true-nnz counts under ``collective``'s partitioning —
    the balance statistic ``tune_dist_spmm`` seeds candidates from.
    nnz splits are balanced by construction; row splits inherit the
    matrix's row-block skew."""
    if collective == "row":
        n_rows = csr.shape[0]
        if n_rows % axis_size:
            return None  # row mode infeasible on this mesh
        block = n_rows // axis_size
        lengths = np.asarray(csr.row_lengths())
        return [int(lengths[s * block:(s + 1) * block].sum())
                for s in range(axis_size)]
    base, extra = divmod(int(csr.nnz), axis_size)
    return [base + (1 if s < extra else 0) for s in range(axis_size)]


# ---------------------------------------------------------------------------
# Distributed SpMM
# ---------------------------------------------------------------------------


def _local_spmm(rows, cols, vals, b, n_rows, schedule: Schedule,
                interpret: bool = True):
    """Shard-local tuned Pallas SpMM over a (traced) padded COO slice.

    The skew layout is a host-side pass over concrete indices, and the
    rb kernel needs an ELL conversion — neither is traceable inside
    shard_map, so skew thresholds are stripped and rb schedules fall
    back to the eb kernel at the same column tile.
    """
    s = schedule
    if s.is_skew:
        s = s.replace(split_threshold=None, merge_threshold=None)
    if s.kernel != "eb":
        s = Schedule("eb", col_tile=s.col_tile)
    nnz_local = int(rows.shape[0])
    pad = round_up(max(nnz_local, 1), s.nnz_tile) - nnz_local
    if pad:
        rows = jnp.concatenate(
            [rows, jnp.full((pad,), n_rows - 1, jnp.int32)])
        cols = jnp.concatenate([cols, jnp.zeros((pad,), jnp.int32)])
        vals = jnp.concatenate([vals, jnp.zeros((pad,), vals.dtype)])
    g = GroupedCOO(rows=rows, cols=cols, vals=vals,
                   shape=(n_rows, int(b.shape[0])),
                   nnz=nnz_local, nnz_tile=s.nnz_tile)
    return kops.spmm(g, b, s, interpret=interpret)


def _resolve_collective(mode, schedule):
    if schedule is not None and schedule.collective is not None:
        if mode is not None and mode != schedule.collective:
            raise ValueError(
                f"mode={mode!r} conflicts with schedule.collective="
                f"{schedule.collective!r}; pass one or the other")
        return schedule.collective
    if mode is None:
        return "nnz_rs"
    if mode not in COLLECTIVES:
        raise ValueError(f"unknown mode {mode!r}; known: {COLLECTIVES}")
    return mode


def spmm_shard_map(rows, cols, vals, b, *, n_rows: int, mesh, axis: str,
                   mode: str | None = None,
                   schedule: Schedule | None = None,
                   interpret: bool = True):
    """rows/cols/vals: (nnz_pad,) padded COO (pad val=0); b: (K, N).

    Sharding contract (enforced via shard_map in/out specs):
      row:     triplets already row-partitioned; rows are *local* indices
               (:func:`partition_rows_coo` builds this layout).
      nnz_*:   triplets nnz-partitioned (any rows anywhere); rows global.
    Returns out (n_rows, N) sharded over ``axis`` on rows (row/nnz_rs) or
    replicated (nnz_ar).

    ``schedule`` drives the shard-local Pallas kernel (tiling, group
    size, strategy) and — via ``schedule.collective`` — the wire mode;
    the legacy ``mode=`` keyword still selects the mode when the
    schedule leaves it unset.  Defaults: library schedule, 'nnz_rs'.
    """
    sched = Schedule() if schedule is None else schedule
    mode = _resolve_collective(mode, schedule)
    axis_size = mesh.shape[axis]
    if mode == "row":
        if n_rows % axis_size:
            raise ValueError(
                f"row mode needs n_rows ({n_rows}) divisible by the axis "
                f"size ({axis_size})")

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(axis),
        )
        def _row(r, c, v, bb):
            return _local_spmm(r, c, v, bb, n_rows // axis_size, sched,
                               interpret)

        return _row(rows, cols, vals, b)

    if mode == "nnz_ar":

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(),
        )
        def _ar(r, c, v, bb):
            partial = _local_spmm(r, c, v, bb, n_rows, sched, interpret)
            return jax.lax.psum(partial, axis)  # atomic-style combine

        return _ar(rows, cols, vals, b)

    # nnz_rs
    if n_rows % axis_size:
        raise ValueError(
            f"nnz_rs mode needs n_rows ({n_rows}) divisible by the axis "
            f"size ({axis_size})")

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=P(axis),
    )
    def _rs(r, c, v, bb):
        partial = _local_spmm(r, c, v, bb, n_rows, sched, interpret)
        # segment-style combine: each shard finalizes its row block
        return jax.lax.psum_scatter(
            partial, axis, scatter_dimension=0, tiled=True)

    return _rs(rows, cols, vals, b)


def dist_spmm(csr, b, *, mesh, axis: str, schedule=None,
              cache=None, backend=None, interpret: bool = True):
    """``csr @ b`` under shard_map, partitioning chosen by the schedule.

    ``schedule`` accepts a :class:`Schedule` (its ``collective`` picks
    the partitioning, default 'nnz_rs'), or ``"tune"`` — run/replay the
    distributed tuner (:func:`repro.tune.tune_dist_spmm`, per-backend
    cache namespace) so one call picks kernel tiling, wire mode *and*
    value storage dtype in a single joint search.  A narrow tuned
    ``value_dtype`` narrows the sharded value feed (and the dense
    operand) host-side, so deployment moves the bytes the tuner timed.
    """
    if schedule == "tune":
        from ..tune import tune_dist_spmm

        schedule = tune_dist_spmm(csr, int(b.shape[1]), mesh=mesh,
                                  axis=axis, cache=cache,
                                  backend=backend).schedule
    sched = Schedule() if schedule is None else schedule
    axis_size = mesh.shape[axis]
    mode = sched.collective or "nnz_rs"
    if mode == "row":
        rows, cols, vals, _ = partition_rows_coo(csr, axis_size,
                                                 sched.nnz_tile)
    else:
        rows, cols, vals, _ = partition_nnz_coo(csr, axis_size,
                                                sched.nnz_tile)
    if sched.value_dtype is not None:
        from ..tune.measure import _storage_feed

        vals, b = _storage_feed(vals, b, sched.value_dtype)
    return spmm_shard_map(rows, cols, vals, b, n_rows=csr.shape[0],
                          mesh=mesh, axis=axis, mode=mode,
                          schedule=sched.replace(collective=mode),
                          interpret=interpret)


# ---------------------------------------------------------------------------
# Distributed fused sparse attention
# ---------------------------------------------------------------------------


def _local_attention(rows, cols, q, k, v, *, n_rows, dv_tile, scale,
                     sched, bias=None, interpret=True):
    """Run the fused kernel over a shard's lanes at height ``n_rows`` + 1
    phantom row (pad lanes land there; the caller crops it)."""
    strategy = (sched.strategy
                if sched.strategy in ("segment", "accumulate")
                else "segment")
    nnz_local = int(rows.shape[0])
    pad = round_up(max(nnz_local, 1), sched.nnz_tile) - nnz_local
    if pad:  # extra pad lanes join the phantom row too
        rows = jnp.concatenate(
            [rows, jnp.full((pad,), n_rows, jnp.int32)])
        cols = jnp.concatenate([cols, jnp.zeros((pad,), jnp.int32)])
        if bias is not None:
            bias = jnp.concatenate([bias, jnp.zeros((pad,), bias.dtype)])
    q_ph = jnp.pad(q, ((0, 0), (0, 1), (0, 0)))
    out, m, l = fused_sparse_attention(
        rows, cols, q_ph, k, v, n_rows=n_rows + 1,
        nnz=int(rows.shape[0]), nnz_tile=sched.nnz_tile,
        dv_tile=dv_tile, scale=scale,
        group_size=sched.group_size, strategy=strategy, bias=bias,
        interpret=interpret)
    return out[:, :n_rows], m[:, :n_rows], l[:, :n_rows]


def _combine_partials(out_s, m_s, l_s, axis, *, scatter):
    """Merge per-shard online-softmax partials over the mesh axis.

    Each shard holds (out_s, m_s, l_s) of its lane subset at full height
    (out_s already normalized by its local l_s).  The global result
    rescales every shard to the global row max and sums: the same
    m/l/alpha algebra the kernel runs per nnz tile, one level up.
    ``scatter=True`` is the segment realization — l and the accumulator
    combine with reduce-scatter so each shard finalizes its row block
    (the row max still needs the cheap (H, R) all-reduce pmax).
    """
    m = jax.lax.pmax(m_s, axis)
    scale = jnp.where(m_s <= NEG_INF / 2, 0.0, jnp.exp(m_s - m))
    lw = l_s * scale                      # (H, R)
    acc = out_s * lw[..., None]           # (H, R, dv)
    if scatter:
        lw = jax.lax.psum_scatter(lw, axis, scatter_dimension=1,
                                  tiled=True)
        acc = jax.lax.psum_scatter(acc, axis, scatter_dimension=1,
                                   tiled=True)
    else:
        lw = jax.lax.psum(lw, axis)
        acc = jax.lax.psum(acc, axis)
    return acc / jnp.maximum(lw, 1e-30)[..., None]


def dist_attention_shard_map(rows, cols, q, k, v, *, n_rows: int, mesh,
                             axis: str, mode: str | None = None,
                             schedule: Schedule | None = None,
                             scale: float | None = None, bias=None,
                             interpret: bool = True):
    """Sparse attention under shard_map with the row/nnz_ar/nnz_rs trio.

    rows/cols: (nnz_pad,) adjacency lane streams built by the partition
    helpers with ``phantom_row=True`` (pad lanes have no zero value, so
    they target the phantom row and are cropped, never masked).  q/k/v
    are head-major — q (H, n_rows, d), k (H, n_kv, d), v (H, n_kv, dv)
    with dv a multiple of 8; 2-D inputs are treated as one head.

    row      rows pre-bucketed per shard (local indices,
             :func:`partition_rows_coo`), q row-sharded, k/v replicated;
             no collectives — each shard owns its output rows whole.
    nnz_*    lanes nnz-partitioned (:func:`partition_nnz_coo`), q/k/v
             replicated; shards compute full-height online-softmax
             partials and merge them with psum (nnz_ar) or psum_scatter
             (nnz_rs) over the per-row statistics — the same
             rescale-and-sum algebra the kernel's nnz-tile carry runs,
             elevated to the mesh.

    Returns out (H, n_rows, dv) (squeezed back to 2-D for 2-D inputs),
    row-sharded over ``axis`` for row/nnz_rs, replicated for nnz_ar.
    """
    sched = Schedule() if schedule is None else schedule
    if sched.kernel != "eb":  # attention tiling is eb-shaped
        sched = Schedule(collective=sched.collective)
    mode = _resolve_collective(mode, schedule)
    axis_size = mesh.shape[axis]
    squeeze = q.ndim == 2
    if squeeze:
        q, k, v = q[None], k[None], v[None]
    if scale is None:
        scale = 1.0 / float(np.sqrt(q.shape[-1]))
    dv = int(v.shape[2])
    dv_tile = min(128, round_up(dv, 8))
    dv_pad = round_up(dv, dv_tile)
    if dv_pad != dv:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, dv_pad - dv)))
    has_bias = bias is not None
    lane_specs = (P(axis), P(axis)) + ((P(axis),) if has_bias else ())

    if mode == "row":
        if n_rows % axis_size:
            raise ValueError(
                f"row mode needs n_rows ({n_rows}) divisible by the "
                f"axis size ({axis_size})")
        block = n_rows // axis_size

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=lane_specs + (P(None, axis, None), P(), P()),
            out_specs=P(None, axis, None),
        )
        def _row(r, c, *rest):
            b = rest[0] if has_bias else None
            qq, kk, vv = rest[-3:]
            out, _, _ = _local_attention(r, c, qq, kk, vv, n_rows=block,
                                         dv_tile=dv_tile, scale=scale,
                                         sched=sched, bias=b,
                                         interpret=interpret)
            return out

        args = (rows, cols) + ((bias,) if has_bias else ()) + (q, k, v)
        out = _row(*args)
    else:
        if mode == "nnz_rs" and n_rows % axis_size:
            raise ValueError(
                f"nnz_rs mode needs n_rows ({n_rows}) divisible by the "
                f"axis size ({axis_size})")

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=lane_specs + (P(), P(), P()),
            out_specs=(P(None, axis, None) if mode == "nnz_rs" else P()),
        )
        def _nnz(r, c, *rest):
            b = rest[0] if has_bias else None
            qq, kk, vv = rest[-3:]
            out_s, m_s, l_s = _local_attention(
                r, c, qq, kk, vv, n_rows=n_rows, dv_tile=dv_tile,
                scale=scale, sched=sched, bias=b, interpret=interpret)
            return _combine_partials(out_s, m_s, l_s, axis,
                                     scatter=mode == "nnz_rs")

        args = (rows, cols) + ((bias,) if has_bias else ()) + (q, k, v)
        out = _nnz(*args)

    out = out[..., :dv]
    return out[0] if squeeze else out
