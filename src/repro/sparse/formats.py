"""Sparse matrix storage formats as JAX pytrees.

Formats
-------
COO              (rows, cols, vals) unsorted triplets — interchange format.
CSR              classic compressed-sparse-row — canonical logical format.
GroupedCOO       row-sorted COO padded to a multiple of ``nnz_tile`` — the
                 feed format of the nnz-split (EB) segment-group kernel.
                 Padding uses ``val = 0`` so padded lanes are *zero
                 extension* in the paper's sense: they flow through the
                 vector/MXU datapath and contribute nothing.
ELL              per-row padded (blocked-ELL when viewed in row tiles) —
                 the feed format of the row-split (RB) kernel.

All formats carry their dense ``shape`` and padding parameters as static
metadata so they can cross ``jit`` boundaries.

``CSR`` memoizes its kernel-feed conversions per ``(format, tile)`` —
``csr.grouped(nnz_tile)`` / ``csr.ell(row_tile)`` / ``csr.tocoo()`` — so
training loops that call ``spmm`` on the same matrix every step don't
re-convert.  The cache only engages on concrete (non-traced) arrays; it is
deliberately not part of the pytree, so transformed copies start cold.

Skew-partitioned grouping (DESIGN.md §11): ``grouped`` / ``regrouped``
accept ``group_size=`` plus ``split_threshold=`` / ``merge_threshold=``
and emit a *two-level* layout for power-law matrices — heavy rows are
split across dedicated width-G groups up front (combined across groups
by the registry's accumulate-style read-modify-write), light rows are
merged into shared groups behind them.  The layout is carried in the
static ``skew`` metadata; each parameter combination is its own memo
key, so a tuner sweeping thresholds converts each layout once.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["COO", "CSR", "GroupedCOO", "ELL", "QuantizedCSR",
           "quantize_csr", "dequantize", "round_up"]


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x`` (tile padding)."""
    return ((x + m - 1) // m) * m


def _instance_cache(obj, arrays):
    """Per-instance conversion memo, or None while being traced (caching
    tracers would leak them across jit traces).  Deliberately not part of
    the pytree: transformed copies start cold."""
    if any(isinstance(x, jax.core.Tracer) for x in arrays):
        return None
    cache = obj.__dict__.get("_convcache")
    if cache is None:
        cache = {}
        object.__setattr__(obj, "_convcache", cache)
    return cache


def _memoized(obj, arrays, key, build):
    cache = _instance_cache(obj, arrays)
    if cache is None:
        return build()
    if key not in cache:
        cache[key] = build()
    return cache[key]


def _csr_scatter_index(indptr):
    """(row_ids, positions) int arrays: nnz t of CSR row r lands in ELL
    slot ``t - indptr[r]``.  Shared by ``ELL.fromcsr`` and
    ``CSR.ell_scatter_index``."""
    indptr = np.asarray(indptr).astype(np.int64)
    lengths = indptr[1:] - indptr[:-1]
    row_ids = np.repeat(np.arange(lengths.shape[0]), lengths)
    pos = np.arange(indptr[-1]) - np.repeat(indptr[:-1], lengths)
    return row_ids, pos


def _concrete_np(x, what: str):
    """``np.asarray(x)`` with a readable error under a jit tracer — the
    host-side skew layout pass needs concrete index arrays."""
    if isinstance(x, jax.core.Tracer):
        raise TypeError(
            f"{what} requires concrete (non-traced) arrays: the two-level "
            "skew layout is a host-side format pass — build the grouped "
            "format outside jit (it is memoized, so once is enough)")
    return np.asarray(x)


def _skew_layout(indptr, indices, shape, nnz_tile: int,
                 group_size: int, split_threshold: int | None,
                 merge_threshold: int | None):
    """Host-side two-level layout pass (DESIGN.md §11).

    Returns ``(rows, cols, positions, heavy_tiles)`` numpy arrays:
    a padded COO stream whose first ``heavy_tiles`` nnz tiles hold the
    *heavy* rows (``length >= split_threshold``), each split across
    dedicated width-``group_size`` groups padded with the row's own id —
    so every heavy group is single-row and reduces with the registry's
    'parallel' realization, cross-group partials combining through its
    accumulate-style read-modify-write.  The remaining tiles hold the
    tail: rows in row order, runs of light rows (``length <=
    merge_threshold``) merged into shared groups, longer tail rows
    aligned to a group boundary (padding the gap with the previous row's
    id, val 0 — zero extension).  ``positions[t]`` is the padded slot of
    original CSR lane ``t`` — values (which may be jit tracers) are
    scattered through it by the caller, so only the *index* arrays need
    to be concrete here.
    """
    assert nnz_tile % group_size == 0, (nnz_tile, group_size)
    indptr = np.asarray(indptr).astype(np.int64)
    indices = np.asarray(indices)
    n_rows = shape[0]
    lengths = indptr[1:] - indptr[:-1]
    pad_row = n_rows - 1
    G = group_size
    S = np.iinfo(np.int64).max if split_threshold is None else split_threshold
    M = np.iinfo(np.int64).max if merge_threshold is None else merge_threshold

    heavy = lengths >= S
    h_ids = np.nonzero(heavy)[0]
    h_lens = lengths[h_ids]
    h_pad = -(-h_lens // G) * G  # per-row round up to the group width
    h_starts = np.concatenate([[0], np.cumsum(h_pad)])[:-1]
    heavy_total = int(h_pad.sum())
    heavy_region = round_up(heavy_total, nnz_tile) if heavy_total else 0

    t_ids = np.nonzero(~heavy & (lengths > 0))[0]
    t_starts = np.empty(len(t_ids), np.int64)
    gaps = []  # (offset, pad lanes, filler row id) alignment gaps
    off = 0
    prev_row = 0
    for i, r in enumerate(t_ids):
        length = int(lengths[r])
        if length > M and off % G:
            pad = G - off % G
            gaps.append((off, pad, prev_row))
            off += pad
        t_starts[i] = off
        off += length
        prev_row = int(r)
    tail_region = round_up(off, nnz_tile) if off else 0

    total = heavy_region + tail_region
    if total == 0:
        total = nnz_tile  # empty matrix: one all-pad tile (as fromcsr)
    rows = np.full(total, pad_row, np.int32)
    cols = np.zeros(total, np.int32)

    starts = np.zeros(n_rows, np.int64)
    starts[h_ids] = h_starts
    starts[t_ids] = heavy_region + t_starts
    row_ids, pos = _csr_scatter_index(indptr)
    positions = (starts[row_ids] + pos).astype(np.int64)
    rows[positions] = row_ids
    cols[positions] = indices
    # heavy per-row padding keeps the row's own id: every heavy group is
    # single-row, so 'parallel' may reduce it with one writeback
    spans = h_pad - h_lens
    if spans.sum():
        base = np.repeat(h_starts + h_lens, spans)
        local = np.arange(int(spans.sum())) - np.repeat(
            np.concatenate([[0], np.cumsum(spans)])[:-1], spans)
        rows[base + local] = np.repeat(h_ids, spans)
    for g_off, g_pad, filler in gaps:
        rows[heavy_region + g_off: heavy_region + g_off + g_pad] = filler

    return (rows, cols, positions.astype(np.int32),
            heavy_region // nnz_tile)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "cols", "vals"],
    meta_fields=["shape"],
)
@dataclasses.dataclass(frozen=True)
class COO:
    """Unordered triplet format. ``shape`` is the dense (n_rows, n_cols)."""

    rows: jax.Array  # (nnz,) int32
    cols: jax.Array  # (nnz,) int32
    vals: jax.Array  # (nnz,) float
    shape: tuple

    @property
    def nnz(self) -> int:
        """Stored-triplet count."""
        return self.vals.shape[0]

    def todense(self) -> jax.Array:
        """Scatter-add the triplets into a dense ``shape`` array."""
        out = jnp.zeros(self.shape, self.vals.dtype)
        return out.at[self.rows, self.cols].add(self.vals)

    @staticmethod
    def fromdense(mat) -> "COO":
        """Dense array -> row-major-sorted COO of its nonzeros."""
        mat = np.asarray(mat)
        rows, cols = np.nonzero(mat)
        order = np.lexsort((cols, rows))
        return COO(
            rows=jnp.asarray(rows[order], jnp.int32),
            cols=jnp.asarray(cols[order], jnp.int32),
            vals=jnp.asarray(mat[rows[order], cols[order]]),
            shape=mat.shape,
        )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["indptr", "indices", "vals"],
    meta_fields=["shape"],
)
@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed sparse row — the canonical input format.  Conversions
    (``tocoo``/``grouped``/``ell``) are memoized per instance, so a
    serving loop converts once however many calls reuse the matrix."""

    indptr: jax.Array  # (n_rows + 1,) int32
    indices: jax.Array  # (nnz,) int32 column ids
    vals: jax.Array  # (nnz,)
    shape: tuple

    @property
    def nnz(self) -> int:
        """Stored-value count."""
        return self.vals.shape[0]

    def row_lengths(self) -> jax.Array:
        """(n_rows,) per-row nnz counts — the histogram the fingerprint
        and the skew thresholds are derived from."""
        return self.indptr[1:] - self.indptr[:-1]

    # -- conversion caching ------------------------------------------------

    def _cached(self, key, build):
        return _memoized(self, (self.indptr, self.indices, self.vals),
                         key, build)

    def tocoo(self) -> "COO":
        """Memoized CSR -> COO expansion (format-time searchsorted
        replaces the paper's per-thread taco_binarySearchBefore)."""
        def _build():
            rows = jnp.searchsorted(
                self.indptr, jnp.arange(self.nnz, dtype=jnp.int32),
                side="right",
            ).astype(jnp.int32) - 1
            return COO(rows=rows, cols=self.indices, vals=self.vals,
                       shape=self.shape)

        return self._cached("coo", _build)

    def grouped(self, nnz_tile: int, *, group_size: int | None = None,
                split_threshold: int | None = None,
                merge_threshold: int | None = None) -> "GroupedCOO":
        """EB-kernel feed format, memoized per parameter tuple.

        With ``split_threshold`` / ``merge_threshold`` set (and the
        schedule's ``group_size``), the conversion runs the two-level
        skew layout (:func:`_skew_layout`): heavy rows split across
        dedicated groups up front, light rows merged into shared groups
        behind.  Each distinct ``(nnz_tile, group_size, split, merge)``
        is its own cache entry, so a tuner sweeping thresholds converts
        each layout exactly once per matrix.
        """
        if split_threshold is None and merge_threshold is None:
            return self._cached(("grouped", nnz_tile),
                                lambda: GroupedCOO.fromcsr(self, nnz_tile))
        key = ("grouped", nnz_tile, group_size, split_threshold,
               merge_threshold)
        return self._cached(
            key, lambda: GroupedCOO.fromcsr(
                self, nnz_tile, group_size=group_size,
                split_threshold=split_threshold,
                merge_threshold=merge_threshold))

    def ell(self, row_tile: int = 8, width: int | None = None) -> "ELL":
        """RB-kernel feed format, memoized per (row_tile, width)."""
        return self._cached(("ell", row_tile, width),
                            lambda: ELL.fromcsr(self, width=width,
                                                row_tile=row_tile))

    def ell_scatter_index(self):
        """(row_ids, positions) int32 arrays scattering the flat CSR value
        stream into the ELL (row, slot) layout — lets callers rebuild
        ``ELL.vals`` from fresh values (e.g. inside autodiff) without a
        Python loop.  Requires concrete arrays."""
        def _build():
            row_ids, pos = _csr_scatter_index(self.indptr)
            return (jnp.asarray(row_ids, jnp.int32),
                    jnp.asarray(pos, jnp.int32))

        return self._cached("ell_scatter", _build)

    def astype(self, dtype) -> "CSR":
        """This matrix with values stored in ``dtype``, memoized per
        target (DESIGN.md §13).

        Returns ``self`` when the dtype already matches.  Memoization
        makes the cast instance *stable*, so its own conversion memos
        (``grouped``/``ell``) warm up exactly once per (matrix, dtype) —
        a serving loop running a ``value_dtype`` schedule pays the cast
        and re-grouping on the first call only.
        """
        dt = np.dtype(dtype)
        if dt == self.vals.dtype:
            return self
        return self._cached(
            ("astype", str(dt)),
            lambda: CSR(indptr=self.indptr, indices=self.indices,
                        vals=self.vals.astype(dt), shape=self.shape))

    def quantized(self, *, method: str = "absmax",
                  percentile: float = 99.9) -> "QuantizedCSR":
        """Memoized int8 quantization of this matrix — see
        :func:`quantize_csr` (host-side pass; requires concrete
        arrays)."""
        return self._cached(
            ("quantized", method, percentile),
            lambda: quantize_csr(self, method=method,
                                 percentile=percentile))

    def todense(self) -> jax.Array:
        """Dense (n_rows, n_cols) array of this matrix."""
        return self.tocoo().todense()

    @staticmethod
    def fromdense(mat) -> "CSR":
        """Dense array -> CSR of its nonzeros (host-side numpy pass)."""
        mat = np.asarray(mat)
        # np.nonzero is C-ordered: already sorted by (row, col).
        rows, cols = np.nonzero(mat)
        counts = np.bincount(rows, minlength=mat.shape[0])
        indptr = np.concatenate([[0], np.cumsum(counts)])
        return CSR(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(cols, jnp.int32),
            vals=jnp.asarray(mat[rows, cols]),
            shape=mat.shape,
        )

    @staticmethod
    def fromcoo(coo: COO) -> "CSR":
        """COO (any order) -> row-major CSR (host-side numpy sort)."""
        rows = np.asarray(coo.rows)
        cols = np.asarray(coo.cols)
        vals = np.asarray(coo.vals)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        counts = np.bincount(rows, minlength=coo.shape[0])
        indptr = np.concatenate([[0], np.cumsum(counts)])
        return CSR(
            indptr=jnp.asarray(indptr, jnp.int32),
            indices=jnp.asarray(cols, jnp.int32),
            vals=jnp.asarray(vals),
            shape=coo.shape,
        )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["rows", "cols", "vals"],
    meta_fields=["shape", "nnz", "nnz_tile", "skew"],
)
@dataclasses.dataclass(frozen=True)
class GroupedCOO:
    """Row-sorted COO padded to a multiple of ``nnz_tile``.

    Feed format for the nnz-split segment-group kernel: a grid cell owns one
    ``nnz_tile`` slice; ``rows`` is the precomputed per-nnz row-id stream.
    Padded lanes have ``val == 0`` (zero extension — they reduce into a
    live row but contribute nothing); trailing padding targets row
    ``shape[0] - 1``.

    ``skew`` is ``None`` for the standard trailing-padded layout, or the
    static tuple ``(split_threshold, merge_threshold, group_size,
    heavy_tiles)`` for the two-level layout (:func:`_skew_layout`): the
    first ``heavy_tiles`` nnz tiles hold split heavy rows (single-row
    groups), the rest the merged tail.  Skew layouts interleave padding
    with data, so value updates must go through :meth:`skew_positions`
    rather than slicing ``vals[:nnz]``.
    """

    rows: jax.Array  # (nnz_padded,) int32, non-decreasing
    cols: jax.Array  # (nnz_padded,) int32
    vals: jax.Array  # (nnz_padded,)
    shape: tuple
    nnz: int  # true nnz (static)
    nnz_tile: int
    skew: "tuple | None" = None

    @property
    def nnz_padded(self) -> int:
        """Total lane count including padding (a ``nnz_tile`` multiple)."""
        return self.vals.shape[0]

    @property
    def num_tiles(self) -> int:
        """Grid extent along the nnz axis: ``nnz_padded / nnz_tile``."""
        return self.nnz_padded // self.nnz_tile

    @property
    def heavy_tiles(self) -> int:
        """Leading nnz tiles holding split heavy rows (0 for the standard
        layout) — the EB kernel runs these under the 'parallel'
        realization regardless of the schedule's tail strategy."""
        return self.skew[3] if self.skew is not None else 0

    def skew_positions(self) -> jax.Array:
        """(nnz,) int32 scatter index: padded slot of original CSR lane t.

        Only skew layouts carry one (standard layouts are trailing-padded,
        so ``[:nnz]`` slicing suffices); it lets autodiff rebuild
        ``vals`` from a fresh value stream without re-running the layout
        pass.  Lost on pytree-transformed copies — rebuild the format
        from its source CSR in that case."""
        pos = self.__dict__.get("_skew_positions")
        if pos is None:
            raise ValueError(
                "this GroupedCOO carries no skew scatter index (standard "
                "layout, or a transformed copy); rebuild it via "
                "CSR.grouped(..., split_threshold=...)")
        return pos

    @staticmethod
    def fromcsr(csr: CSR, nnz_tile: int, *, group_size: int | None = None,
                split_threshold: int | None = None,
                merge_threshold: int | None = None) -> "GroupedCOO":
        """Convert a CSR; thresholds select the two-level skew layout.

        The skew path is a host-side numpy pass over concrete index
        arrays (it raises under jit tracers — convert outside jit; the
        per-instance memo on ``CSR.grouped`` makes that a one-time
        cost)."""
        if split_threshold is None and merge_threshold is None:
            coo = csr.tocoo()
            nnz = csr.nnz
            padded = max(round_up(max(nnz, 1), nnz_tile), nnz_tile)
            pad = padded - nnz
            pad_row = csr.shape[0] - 1
            rows = jnp.concatenate(
                [coo.rows, jnp.full((pad,), pad_row, jnp.int32)])
            cols = jnp.concatenate([coo.cols, jnp.zeros((pad,), jnp.int32)])
            vals = jnp.concatenate(
                [coo.vals, jnp.zeros((pad,), coo.vals.dtype)])
            return GroupedCOO(rows=rows, cols=cols, vals=vals,
                              shape=csr.shape, nnz=nnz, nnz_tile=nnz_tile)
        if group_size is None:
            raise ValueError(
                "skew grouping needs the schedule's group_size= (heavy "
                "rows are split at group granularity)")
        indptr = _concrete_np(csr.indptr, "skew grouping")
        rows, cols, pos, heavy_tiles = _skew_layout(
            indptr, _concrete_np(csr.indices, "skew grouping"),
            csr.shape, nnz_tile, group_size, split_threshold,
            merge_threshold)
        pos_j = jnp.asarray(pos)
        vals = jnp.zeros((rows.shape[0],),
                         csr.vals.dtype).at[pos_j].set(csr.vals)
        g = GroupedCOO(
            rows=jnp.asarray(rows), cols=jnp.asarray(cols),
            vals=vals, shape=csr.shape, nnz=csr.nnz,
            nnz_tile=nnz_tile,
            skew=(split_threshold, merge_threshold, group_size,
                  heavy_tiles))
        object.__setattr__(g, "_skew_positions", pos_j)
        return g

    def _compact(self):
        """(rows, cols, vals) original-order unpadded triplet views —
        ``[:nnz]`` slices for the trailing-padded layout, a
        :meth:`skew_positions` gather for skew layouts."""
        if self.skew is None:
            return (self.rows[: self.nnz], self.cols[: self.nnz],
                    self.vals[: self.nnz])
        pos = self.skew_positions()
        return self.rows[pos], self.cols[pos], self.vals[pos]

    def regrouped(self, nnz_tile: int, *, group_size: int | None = None,
                  split_threshold: int | None = None,
                  merge_threshold: int | None = None) -> "GroupedCOO":
        """This GroupedCOO re-laid-out for a different tile size and/or
        skew partition, memoized per ``(nnz_tile, group_size, split,
        merge)`` target (the same per-``(format, tile)`` conversion
        cache ``CSR`` has) — a serving loop whose tuned schedule differs
        from the feed's converts once, not per call.  A matching target
        (including a matching skew tuple) returns ``self`` unchanged."""
        want_skew = (split_threshold is not None
                     or merge_threshold is not None)
        if want_skew and group_size is None:
            raise ValueError(
                "skew regrouping needs the schedule's group_size=")
        if nnz_tile == self.nnz_tile:
            if not want_skew and self.skew is None:
                return self
            if (want_skew and self.skew is not None
                    and self.skew[:3] == (split_threshold, merge_threshold,
                                          group_size)):
                return self

        def _build():
            rows_c, cols_c, vals_c = self._compact()
            if not want_skew:
                nnz = self.nnz
                padded = max(round_up(max(nnz, 1), nnz_tile), nnz_tile)
                pad = padded - nnz
                return GroupedCOO(
                    rows=jnp.concatenate(
                        [rows_c,
                         jnp.full((pad,), self.shape[0] - 1, jnp.int32)]),
                    cols=jnp.concatenate(
                        [cols_c, jnp.zeros((pad,), jnp.int32)]),
                    vals=jnp.concatenate(
                        [vals_c, jnp.zeros((pad,), self.vals.dtype)]),
                    shape=self.shape, nnz=nnz, nnz_tile=nnz_tile)
            rows_np = _concrete_np(rows_c, "skew regrouping")
            lengths = np.bincount(rows_np, minlength=self.shape[0])
            indptr = np.concatenate([[0], np.cumsum(lengths)])
            rows, cols, pos, heavy_tiles = _skew_layout(
                indptr, _concrete_np(cols_c, "skew regrouping"),
                self.shape, nnz_tile, group_size, split_threshold,
                merge_threshold)
            pos_j = jnp.asarray(pos)
            vals = jnp.zeros((rows.shape[0],),
                             self.vals.dtype).at[pos_j].set(vals_c)
            g = GroupedCOO(
                rows=jnp.asarray(rows), cols=jnp.asarray(cols),
                vals=vals, shape=self.shape, nnz=self.nnz,
                nnz_tile=nnz_tile,
                skew=(split_threshold, merge_threshold, group_size,
                      heavy_tiles))
            object.__setattr__(g, "_skew_positions", pos_j)
            return g

        return _memoized(self, (self.rows, self.cols, self.vals),
                         ("regrouped", nnz_tile, group_size,
                          split_threshold, merge_threshold), _build)

    def todense(self) -> jax.Array:
        """Scatter-add the (padded) triplets into a dense array — padded
        lanes contribute zero by the zero-extension rule."""
        out = jnp.zeros(self.shape, self.vals.dtype)
        return out.at[self.rows, self.cols].add(self.vals)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["cols", "vals"],
    meta_fields=["shape", "width"],
)
@dataclasses.dataclass(frozen=True)
class ELL:
    """Per-row padded format (rows also padded to a row-tile multiple by the
    kernel wrapper). Feed format for the row-split kernel: a grid cell owns
    ``ROW_TILE`` whole rows. Padding cols point at column 0 with val 0."""

    cols: jax.Array  # (n_rows_padded, width) int32
    vals: jax.Array  # (n_rows_padded, width)
    shape: tuple
    width: int

    @property
    def n_rows_padded(self) -> int:
        """Row count padded up to the row tile."""
        return self.vals.shape[0]

    @staticmethod
    def fromcsr(csr: CSR, width: int | None = None, row_tile: int = 8) -> "ELL":
        """CSR -> ELL with rows padded to ``width`` (default: the max row
        length) and the row count to ``row_tile`` (host-side numpy pass —
        requires concrete arrays)."""
        indptr = np.asarray(csr.indptr).astype(np.int64)
        indices = np.asarray(csr.indices)
        vals = np.asarray(csr.vals)
        n_rows = csr.shape[0]
        lengths = indptr[1:] - indptr[:-1]
        w = int(lengths.max()) if len(lengths) and lengths.max() > 0 else 1
        if width is not None:
            if width < w:
                raise ValueError(f"width {width} < max row length {w}")
            w = width
        w = max(w, 1)
        n_pad = round_up(max(n_rows, 1), row_tile)
        ecols = np.zeros((n_pad, w), np.int32)
        # always the source dtype: the empty-vals np.float32 fallback this
        # used to carry silently widened empty bf16/int8 matrices
        evals = np.zeros((n_pad, w), vals.dtype)
        row_ids, pos = _csr_scatter_index(indptr)
        ecols[row_ids, pos] = indices
        evals[row_ids, pos] = vals
        return ELL(cols=jnp.asarray(ecols), vals=jnp.asarray(evals),
                   shape=csr.shape, width=w)

    def todense(self) -> jax.Array:
        """Dense (n_rows, n_cols) array (padding slots contribute 0)."""
        n_rows, _ = self.shape
        rows = jnp.repeat(jnp.arange(self.n_rows_padded), self.width)
        out = jnp.zeros((self.n_rows_padded, self.shape[1]), self.vals.dtype)
        out = out.at[rows, self.cols.reshape(-1)].add(self.vals.reshape(-1))
        return out[:n_rows]


# ---------------------------------------------------------------------------
# Int8 quantized values (DESIGN.md §13)
# ---------------------------------------------------------------------------


@partial(
    jax.tree_util.register_dataclass,
    data_fields=["csr", "scales"],
    meta_fields=[],
)
@dataclasses.dataclass(frozen=True)
class QuantizedCSR:
    """Symmetric per-row int8 quantization of a CSR's values.

    ``csr`` holds the original sparsity pattern with int8 codes as
    values; ``scales`` is the (n_rows,) float32 per-row step so lane
    ``t`` dequantizes as ``vals[t] * scales[row(t)]``.  Scales are
    *segment-aligned*: every lane of a row shares one scale, so the
    kernels dequantize per lane **before** the segment reduction and the
    scatter stays monoid-correct — partial sums combine exactly as in
    the f32 kernel, whichever reduction strategy runs.

    The pattern conversions (``grouped``/``ell``/``tocoo``) live on the
    inner ``csr`` and memoize there as usual; the int8 value stream
    flows through them unchanged (the dtype-preserving padding rule).
    """

    csr: CSR  # int8 values, original pattern
    scales: jax.Array  # (n_rows,) float32

    @property
    def shape(self) -> tuple:
        """Dense (n_rows, n_cols) of the underlying matrix."""
        return self.csr.shape

    @property
    def nnz(self) -> int:
        """Stored-value count."""
        return self.csr.nnz

    def row_lengths(self) -> jax.Array:
        """(n_rows,) per-row nnz counts (fingerprint input)."""
        return self.csr.row_lengths()

    def dequantize(self) -> CSR:
        """Float32 CSR with values ``codes * scales[row]`` (the spec-
        oracle view of this matrix; memoized on the inner CSR)."""
        def _build():
            rows = self.csr.tocoo().rows
            vals = (self.csr.vals.astype(jnp.float32)
                    * jnp.take(self.scales, rows))
            return CSR(indptr=self.csr.indptr, indices=self.csr.indices,
                       vals=vals, shape=self.csr.shape)

        return _memoized(self, (self.csr.vals, self.scales),
                         "dequantized", _build)

    def todense(self) -> jax.Array:
        """Dense f32 array of the dequantized matrix."""
        return self.dequantize().todense()


def quantize_csr(csr: CSR, *, method: str = "absmax",
                 percentile: float = 99.9) -> QuantizedCSR:
    """Quantize a CSR's values to int8 with per-row symmetric scales.

    Calibration (host-side numpy pass; requires concrete arrays):

    - ``"absmax"``    — scale each row by its exact |max| / 127: lossless
      range, precision limited by outliers.
    - ``"percentile"`` — clip the calibration statistic at the global
      ``percentile``-th magnitude before the per-row absmax, so a few
      outlier values don't inflate every scale; quantization saturates
      the clipped outliers at ±127.

    Empty rows get scale 1.0 (nothing to represent; avoids div-by-zero
    on dequant).  Returns a :class:`QuantizedCSR`.
    """
    if method not in ("absmax", "percentile"):
        raise ValueError(
            f"unknown calibration method {method!r}; "
            "expected 'absmax' or 'percentile'")
    vals = _concrete_np(csr.vals, "int8 quantization").astype(np.float32)
    indptr = _concrete_np(csr.indptr, "int8 quantization").astype(np.int64)
    n_rows = csr.shape[0]
    lengths = indptr[1:] - indptr[:-1]
    row_ids = np.repeat(np.arange(n_rows), lengths)
    absv = np.abs(vals)
    if method == "percentile" and absv.size:
        absv = np.minimum(absv, np.percentile(absv, percentile))
    amax = np.zeros(n_rows, np.float32)
    np.maximum.at(amax, row_ids, absv)
    scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    codes = np.clip(np.rint(vals / scales[row_ids]), -127, 127)
    inner = CSR(indptr=csr.indptr, indices=csr.indices,
                vals=jnp.asarray(codes.astype(np.int8)), shape=csr.shape)
    return QuantizedCSR(csr=inner, scales=jnp.asarray(scales))


def dequantize(q: QuantizedCSR) -> CSR:
    """Module-level alias of :meth:`QuantizedCSR.dequantize`."""
    return q.dequantize()
