"""Distributed SpMM via shard_map — the paper's reduction-strategy choice
*elevated to the collective level* (DESIGN.md §2, changed assumption 2).

Three partitionings of ``out = A @ B``:

row         A row-partitioned over the axis; no collectives (each shard
            owns whole output rows) — the collective analogue of parallel
            reduction / one writeback thread.
nnz_ar      A nnz-partitioned; each shard computes a full-height partial
            and an **all-reduce** combines — the analogue of atomicAdd
            (every shard "writes" every row).
nnz_rs      A nnz-partitioned; partials combined with **reduce-scatter**
            so each shard finalizes its own row block — the analogue of
            segment reduction (multiple writeback shards, targets decided
            by data layout). Moves 1/P the bytes of nnz_ar on the wire per
            shard output.

All three compute identical results; they differ in collective bytes and
balance, which is exactly the axis the paper tunes. ``dryrun``/roofline
quantifies the difference per mesh.
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import PartitionSpec as P

from ..kernels import ref


def _local_spmm(rows, cols, vals, b, n_rows):
    return ref.spmm_coo_ref(rows, cols, vals, b, n_rows)


def spmm_shard_map(rows, cols, vals, b, *, n_rows: int, mesh, axis: str,
                   mode: str = "nnz_rs"):
    """rows/cols/vals: (nnz_pad,) padded COO (pad val=0); b: (K, N).

    Sharding contract (enforced via shard_map in/out specs):
      row:     triplets already row-partitioned; rows are *local* indices.
      nnz_*:   triplets nnz-partitioned (any rows anywhere); rows global.
    Returns out (n_rows, N) sharded over ``axis`` on rows (row/nnz_rs) or
    replicated (nnz_ar).
    """
    axis_size = mesh.shape[axis]
    if mode == "row":
        assert n_rows % axis_size == 0

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(axis),
        )
        def _row(r, c, v, bb):
            return _local_spmm(r, c, v, bb, n_rows // axis_size)

        return _row(rows, cols, vals, b)

    if mode == "nnz_ar":

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(),
        )
        def _ar(r, c, v, bb):
            partial = _local_spmm(r, c, v, bb, n_rows)
            return jax.lax.psum(partial, axis)  # atomic-style combine

        return _ar(rows, cols, vals, b)

    if mode == "nnz_rs":
        assert n_rows % axis_size == 0

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P()),
            out_specs=P(axis),
        )
        def _rs(r, c, v, bb):
            partial = _local_spmm(r, c, v, bb, n_rows)
            # segment-style combine: each shard finalizes its row block
            return jax.lax.psum_scatter(
                partial, axis, scatter_dimension=0, tiled=True)

        return _rs(rows, cols, vals, b)

    raise ValueError(f"unknown mode {mode!r}")
