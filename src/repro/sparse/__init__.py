"""repro.sparse — the single public sparse API.

Formats (`CSR`, `COO`, `GroupedCOO`, `ELL`), generators (`random_csr`,
`power_law_csr`, `graph_pattern_csr`),
the unified ops (`spmm`, `sddmm`, `segment_reduce`, `sparse_attention`,
all taking ``schedule=``), and the scheduling surface re-exported from
core (`Schedule`, `Epilogue`, `register_strategy`).
"""
from ..core.schedule import (  # noqa: F401
    Epilogue,
    Schedule,
    as_schedule,
    available_strategies,
    register_strategy,
)
from .distributed import (  # noqa: F401
    COLLECTIVES,
    dist_attention_shard_map,
    dist_spmm,
    partition_nnz_coo,
    partition_rows_coo,
    shard_nnz_counts,
    spmm_shard_map,
)
from .formats import (  # noqa: F401
    COO,
    CSR,
    ELL,
    GroupedCOO,
    QuantizedCSR,
    dequantize,
    quantize_csr,
)
from .ops import sddmm, segment_reduce, sparse_attention, spmm  # noqa: F401
from .random import (  # noqa: F401
    GRAPH_PATTERNS,
    graph_pattern_csr,
    matrix_stats,
    power_law_csr,
    random_coo,
    random_csr,
)
