"""2-layer GCN on a synthetic graph with the Sgap SpMM at its core —
the paper's own motivating workload family (GNN aggregation).

Each layer runs the *fused* path (DESIGN.md §8): ``act(Ã(XW) + b)`` is
ONE scheduled Pallas kernel — the bias add and activation execute as an
in-kernel epilogue on the last reduction grid step instead of separate
HBM passes.  The backward closes the paper's algebra family on itself
(dz = act'(z)·dOut, dvals = SDDMM(dz, X), dX = Ãᵀ·dz) via the built-in
custom VJP, so the training loop differentiates through the same fused
kernel it serves with.  Feed-format conversion happens once
(per-(format, tile) cache on CSR), not per step.

    PYTHONPATH=src python examples/gcn_spmm.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.models.layers import gcn_layer
from repro.sparse import CSR, Schedule, matrix_stats, random_csr, spmm

N_NODES, N_FEAT, N_CLASS = 256, 32, 4

# synthetic graph: random adjacency + self loops, symmetric-normalized
adj = random_csr(N_NODES, N_NODES, density=0.02, seed=0)
dense = np.asarray(adj.todense())
dense = ((dense + dense.T) > 0).astype(np.float32)
np.fill_diagonal(dense, 1.0)
deg = dense.sum(1)
norm = dense / np.sqrt(np.outer(deg, deg))
A = CSR.fromdense(norm)

sched = Schedule.auto(matrix_stats(A), N_FEAT)
print(f"selected aggregation schedule: {sched}")

rng = np.random.default_rng(0)
feats = jnp.asarray(rng.standard_normal((N_NODES, N_FEAT)), jnp.float32)
# learnable task: labels from a random teacher GCN (graph-correlated)
w_teacher = jnp.asarray(rng.standard_normal((N_FEAT, N_CLASS)), jnp.float32)
labels = jnp.argmax(jnp.asarray(norm, jnp.float32) @ feats @ w_teacher,
                    axis=-1)
params = {
    "w1": jnp.asarray(rng.standard_normal((N_FEAT, 64)) * 0.1, jnp.float32),
    "b1": jnp.zeros((64,), jnp.float32),
    "w2": jnp.asarray(rng.standard_normal((64, N_CLASS)) * 0.1, jnp.float32),
}


def gcn_fwd(params, x):
    # layer 1: act(Ã X W1 + b1) — ONE fused kernel (epilogue: bias+relu)
    h = gcn_layer(A, x, params["w1"], params["b1"], activation="relu",
                  schedule=sched)
    # layer 2: logits, no activation — plain scheduled SpMM
    return spmm(A, h @ params["w2"], schedule=sched)


def loss_fn(params, x, y):
    logits = gcn_fwd(params, x)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(N_NODES), y])


# sanity: the scheduled Pallas kernel agrees with the pure-jnp oracle
h0 = feats @ params["w1"]
np.testing.assert_allclose(
    np.asarray(spmm(A, h0, schedule=sched)),
    np.asarray(spmm(A, h0, impl="ref")),
    rtol=1e-4, atol=1e-4)
print("pallas aggregation matches oracle ✓")

step = jax.jit(jax.value_and_grad(loss_fn))
lr = 0.5
losses = []
for i in range(40):
    loss, grads = step(params, feats, labels)
    params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    losses.append(float(loss))
print(f"GCN loss {losses[0]:.3f} -> {losses[-1]:.3f}")
assert losses[-1] < losses[0] - 0.1
print("gcn_spmm complete ✓")
