"""Attention: GQA flash attention (chunked, custom-VJP) + decode step.

``flash_attention`` never materializes the (Sq × Skv) score matrix: forward
runs a scan over KV chunks with online softmax; backward recomputes
probabilities per chunk from the saved (o, lse) — O(S·D) residual memory
instead of O(S²). This is what keeps prefill_32k / train_4k inside HBM on
the dry-run meshes.

Layout: q (B, Sq, H, Dh), k/v (B, Skv, K, Dh) with H = K·G (GQA).
Internally (B, K, G, S, Dh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.common import NEG_INF  # shared masked-lane floor


def _chunk(x, axis, size):
    """Split axis into (n_chunks, size) and move n_chunks to the front."""
    n = x.shape[axis] // size
    shape = x.shape[:axis] + (n, size) + x.shape[axis + 1:]
    x = x.reshape(shape)
    return jnp.moveaxis(x, axis, 0)


def _mask(qpos, kpos, causal):
    if not causal:
        return None
    return qpos[:, None] >= kpos[None, :]  # (qc, kc)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 512):
    o, _ = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk)
    return o


def _pad_seq(x, chunk, axis):
    s = x.shape[axis]
    pad = (-s) % chunk
    if pad:
        cfgpad = [(0, 0)] * x.ndim
        cfgpad[axis] = (0, pad)
        x = jnp.pad(x, cfgpad)
    return x, s


def _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk):
    b, sq0, h, dh = q.shape
    _, skv0, kh, _ = k.shape
    g = h // kh
    q_chunk = min(q_chunk, sq0) if sq0 % min(q_chunk, sq0) == 0 else sq0
    kv_chunk = min(kv_chunk, skv0) if skv0 % min(kv_chunk, skv0) == 0 else skv0

    qi = jnp.moveaxis(q.reshape(b, sq0, kh, g, dh), 1, 3)  # (B,K,G,Sq,Dh)
    ki = jnp.moveaxis(k, 1, 2)  # (B,K,Skv,Dh)
    vi = jnp.moveaxis(v, 1, 2)
    scale = dh ** -0.5

    qcs = _chunk(qi, 3, q_chunk)      # (nq, B,K,G,qc,Dh)
    kcs = _chunk(ki, 2, kv_chunk)     # (nk, B,K,kc,Dh)
    vcs = _chunk(vi, 2, kv_chunk)
    nq, nk = qcs.shape[0], kcs.shape[0]

    def q_step(_, qin):
        qc, iq = qin  # (B,K,G,qc,Dh), scalar chunk index
        qpos = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kin):
            m, l, acc = carry
            kc, vc, ik = kin
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            if causal:
                s = jnp.where(_mask(qpos, kpos, True)[None, None, None], s,
                              NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, vc.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, kh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kcs, vcs, jnp.arange(nk)))
        l_safe = jnp.maximum(l, 1e-30)
        o = acc / l_safe[..., None]
        lse = m + jnp.log(l_safe)
        return None, (o.astype(q.dtype), lse)

    _, (ocs, lses) = jax.lax.scan(q_step, None, (qcs, jnp.arange(nq)))
    # (nq, B,K,G,qc,Dh) -> (B, Sq, H, Dh)
    o = jnp.moveaxis(ocs, 0, 3).reshape(b, kh, g, sq0, dh)
    o = jnp.moveaxis(o, 3, 1).reshape(b, sq0, h, dh)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, kh, g, sq0)
    return o, lse


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk):
    o, lse = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, q_chunk, kv_chunk, res, do):
    q, k, v, o, lse = res
    b, sq0, h, dh = q.shape
    _, skv0, kh, _ = k.shape
    g = h // kh
    q_chunk = min(q_chunk, sq0) if sq0 % min(q_chunk, sq0) == 0 else sq0
    kv_chunk = min(kv_chunk, skv0) if skv0 % min(kv_chunk, skv0) == 0 else skv0
    scale = dh ** -0.5

    qi = jnp.moveaxis(q.reshape(b, sq0, kh, g, dh), 1, 3).astype(jnp.float32)
    ki = jnp.moveaxis(k, 1, 2).astype(jnp.float32)
    vi = jnp.moveaxis(v, 1, 2).astype(jnp.float32)
    oi = jnp.moveaxis(do.reshape(b, sq0, kh, g, dh), 1, 3).astype(jnp.float32)
    ooi = jnp.moveaxis(o.reshape(b, sq0, kh, g, dh), 1, 3).astype(jnp.float32)
    delta = jnp.sum(oi * ooi, axis=-1)  # (B,K,G,Sq)

    qcs = _chunk(qi, 3, q_chunk)
    docs = _chunk(oi, 3, q_chunk)
    lcs = _chunk(lse, 3, q_chunk)
    dcs = _chunk(delta, 3, q_chunk)
    kcs = _chunk(ki, 2, kv_chunk)
    vcs = _chunk(vi, 2, kv_chunk)
    nq, nk = qcs.shape[0], kcs.shape[0]

    def q_step(carry, qin):
        dk_all, dv_all = carry  # (nk, B,K,kc,Dh) each
        qc, doc, lc, dc, iq = qin
        qpos = iq * q_chunk + jnp.arange(q_chunk)

        def kv_step(dq_c, kin):
            kc, vc, dk_c, dv_c, ik = kin
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qc, kc) * scale
            if causal:
                s = jnp.where(_mask(qpos, kpos, True)[None, None, None], s,
                              NEG_INF)
            p = jnp.exp(s - lc[..., None])  # (B,K,G,qc,kc)
            dp = jnp.einsum("bkgqd,bkcd->bkgqc", doc, vc)
            ds = p * (dp - dc[..., None]) * scale
            dq_c = dq_c + jnp.einsum("bkgqc,bkcd->bkgqd", ds, kc)
            dk_c = dk_c + jnp.einsum("bkgqc,bkgqd->bkcd", ds, qc)
            dv_c = dv_c + jnp.einsum("bkgqc,bkgqd->bkcd", p, doc)
            return dq_c, (dk_c, dv_c)

        dq0 = jnp.zeros_like(qc)
        dq_c, (dk_all, dv_all) = jax.lax.scan(
            kv_step, dq0, (kcs, vcs, dk_all, dv_all, jnp.arange(nk)))
        return (dk_all, dv_all), dq_c

    dk0 = jnp.zeros((nk, b, kh, kv_chunk, dh), jnp.float32)
    dv0 = jnp.zeros_like(dk0)
    (dk_all, dv_all), dq_cs = jax.lax.scan(
        q_step, (dk0, dv0), (qcs, docs, lcs, dcs, jnp.arange(nq)))

    dq = jnp.moveaxis(dq_cs, 0, 3).reshape(b, kh, g, sq0, dh)
    dq = jnp.moveaxis(dq, 3, 1).reshape(b, sq0, h, dh).astype(q.dtype)
    dk = jnp.moveaxis(dk_all, 0, 2).reshape(b, kh, skv0, dh)
    dk = jnp.moveaxis(dk, 2, 1).astype(k.dtype)
    dv = jnp.moveaxis(dv_all, 0, 2).reshape(b, kh, skv0, dh)
    dv = jnp.moveaxis(dv, 2, 1).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def graph_attention(adj, q, k, v, *, schedule=None, scale=None,
                    interpret: bool = True):
    """Sparse (graph) attention over an adjacency pattern through the
    fused one-pass SDDMM→softmax→SpMM kernel
    (``repro.sparse.sparse_attention``), fused in both directions.

    Single-head: q (n_rows, d), k/v (n_cols, d/dv).  Multi-head: q
    (n_rows, H, d) with k/v (n_cols, H, ·) — heads share the sparsity
    pattern and ALL run in one kernel launch (the head axis is folded
    into the fused kernel's grid; no Python head loop).  A CSR
    adjacency's stored values act as an additive score bias (edge
    features); see ``repro.sparse.sparse_attention``.
    """
    from ..sparse import sparse_attention

    return sparse_attention(adj, q, k, v, schedule=schedule, scale=scale,
                            interpret=interpret)


def attention_ref(q, k, v, causal=True):
    """Naive reference for tests."""
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    qi = q.reshape(b, sq, kh, g, dh)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qi.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(k.shape[1])[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token decode. q: (B, H, Dh); caches: (B, S, K, Dh); pos: ()
    current position (tokens at index <= pos are valid).

    Caches stay in their storage dtype; f32 happens in the MXU accumulator
    (preferred_element_type), not as materialized copies.
    """
    b, s, kh, dh = k_cache.shape
    g = q.shape[1] // kh
    qi = q.reshape(b, kh, g, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qi, k_cache,
                        preferred_element_type=jnp.float32) * dh ** -0.5
    valid = jnp.arange(s)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, q.shape[1], dh).astype(q.dtype)
