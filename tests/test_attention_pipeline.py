"""Fused sparse attention full-pipeline tests (ISSUE 5): the fused
*backward* Pallas kernel (dQ/dK/dV parity vs the spec-recompute VJP),
one-launch multi-head batching, the probability carry on multi-dv-tile
grids, CSR stored values as an additive score bias, f32-forced score
accumulation for low-precision inputs, and the fused-attention tuner's
direction/head-count cache keys.

Property tests run under hypothesis when installed; without it they
degrade to a fixed seed sweep covering the same edge cases (empty rows,
single-nnz patterns, ragged sizes) instead of skipping.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in the lean container
    HAVE_HYPOTHESIS = False

from repro.core import Schedule  # noqa: E402
from repro.kernels.fused_attention import (  # noqa: E402
    fused_sparse_attention,
    fused_sparse_attention_bwd,
    sparse_attention_bwd_ref,
    sparse_attention_ref,
)
from repro.sparse import random_csr, sparse_attention  # noqa: E402
from repro.sparse.formats import round_up  # noqa: E402

RTOL = ATOL = 1e-5
GRAD_TOL = 1e-4

SCHEDS = [
    Schedule("eb", nnz_tile=64, group_size=8, strategy="segment"),
    Schedule("eb", nnz_tile=64, group_size=32, strategy="accumulate"),
]


def _pattern(n_rows, n_cols, nnz, seed):
    rng = np.random.default_rng(seed)
    rows = np.sort(rng.integers(0, n_rows, nnz)).astype(np.int32)
    cols = rng.integers(0, n_cols, nnz).astype(np.int32)
    return jnp.asarray(rows), jnp.asarray(cols)


def _property(strategy_fn, examples, max_examples=10):
    if HAVE_HYPOTHESIS:
        def deco(f):
            return settings(max_examples=max_examples, deadline=None)(
                given(prob=strategy_fn())(f))

        return deco
    return pytest.mark.parametrize("prob", examples)


if HAVE_HYPOTHESIS:
    @st.composite
    def attn_grad_problem(draw):
        n_rows = draw(st.integers(4, 32))
        n_cols = draw(st.integers(4, 32))
        # sparse enough that empty rows and single-nnz rows are routine
        nnz = draw(st.integers(1, 3 * n_rows))
        d = draw(st.sampled_from([4, 8]))
        dv = draw(st.sampled_from([4, 8]))
        seed = draw(st.integers(0, 2 ** 16))
        return n_rows, n_cols, nnz, d, dv, seed
else:
    attn_grad_problem = None

GRAD_EXAMPLES = [
    (4, 4, 1, 4, 4, 0),             # single nnz in the whole pattern
    (32, 20, 22, 8, 8, 1),          # most rows empty
    (20, 32, 60, 8, 4, 2),          # dense-ish rows
    (13, 9, 40, 4, 8, 3),           # ragged sizes
]


# ---------------------------------------------------------------------------
# Backward kernel: dQ/dK/dV parity vs the spec-recompute VJP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sched", SCHEDS, ids=lambda s: s.strategy)
@_property(attn_grad_problem, GRAD_EXAMPLES, max_examples=10)
def test_fused_backward_grad_parity(sched, prob):
    n_rows, n_cols, nnz, d, dv, seed = prob
    rows, cols = _pattern(n_rows, n_cols, nnz, seed)
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kt = jax.random.split(key, 4)
    q = jax.random.normal(kq, (n_rows, d))
    k = jax.random.normal(kk, (n_cols, d))
    v = jax.random.normal(kv, (n_cols, dv))
    tgt = jax.random.normal(kt, (n_rows, dv))

    def loss_fused(qkv):
        out = sparse_attention((rows, cols, n_rows), *qkv, schedule=sched)
        return jnp.sum((out - tgt) ** 2)

    def loss_spec(qkv):
        out = sparse_attention_ref(rows, cols, *qkv, n_rows=n_rows)
        return jnp.sum((out - tgt) ** 2)

    g_f = jax.grad(loss_fused)((q, k, v))
    g_s = jax.grad(loss_spec)((q, k, v))
    for gf, gs in zip(g_f, g_s):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gs),
                                   rtol=GRAD_TOL, atol=GRAD_TOL)


def test_fused_backward_kernel_matches_spec_vjp_directly():
    """Kernel-level parity (no autodiff plumbing): the fused backward's
    dQ/dK/dV against ``sparse_attention_bwd_ref`` over a multi-nnz-tile
    pattern, with and without a score bias."""
    rng = np.random.default_rng(11)
    R, C, nnz, d, dv = 19, 15, 70, 8, 6
    rows, cols = _pattern(R, C, nnz, 11)
    nnz_tile = 32
    nnz_pad = round_up(nnz, nnz_tile)
    rows_p = jnp.pad(rows, (0, nnz_pad - nnz))
    cols_p = jnp.pad(cols, (0, nnz_pad - nnz))
    q = jax.random.normal(jax.random.PRNGKey(0), (1, R, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, C, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, C, dv))
    dout = jax.random.normal(jax.random.PRNGKey(3), (1, R, dv))
    bias = jnp.asarray(rng.standard_normal(nnz).astype(np.float32))
    scale = d ** -0.5
    for b in (None, bias):
        b_p = None if b is None else jnp.pad(b, (0, nnz_pad - nnz))
        _, m, l = fused_sparse_attention(
            rows_p, cols_p, q, k, v, n_rows=R, nnz=nnz, nnz_tile=nnz_tile,
            dv_tile=dv, scale=scale, group_size=8, bias=b_p)
        dq, dk, dv_ = fused_sparse_attention_bwd(
            rows_p, cols_p, q, k, v, dout, m, l, n_rows=R, nnz=nnz,
            nnz_tile=nnz_tile, scale=scale, group_size=8, bias=b_p)
        wq, wk, wv = sparse_attention_bwd_ref(
            rows, cols, q[0], k[0], v[0], dout[0], n_rows=R, scale=scale,
            bias=b)
        np.testing.assert_allclose(np.asarray(dq[0]), np.asarray(wq),
                                   rtol=GRAD_TOL, atol=GRAD_TOL)
        np.testing.assert_allclose(np.asarray(dk[0]), np.asarray(wk),
                                   rtol=GRAD_TOL, atol=GRAD_TOL)
        np.testing.assert_allclose(np.asarray(dv_[0]), np.asarray(wv),
                                   rtol=GRAD_TOL, atol=GRAD_TOL)


def test_fused_backward_empty_and_single_nnz_rows():
    """Empty rows get exact-zero dQ rows; untouched columns get
    exact-zero dK/dV rows; a single-nnz row's softmax is constant 1 so
    its dQ/dK contribution vanishes and dV passes dout straight
    through."""
    rows = jnp.asarray([1, 3, 3], jnp.int32)
    cols = jnp.asarray([0, 1, 2], jnp.int32)
    q = jax.random.normal(jax.random.PRNGKey(0), (5, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (6, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (6, 4))
    tgt = jax.random.normal(jax.random.PRNGKey(3), (5, 4))

    def loss(qkv):
        out = sparse_attention((rows, cols, 5), *qkv)
        return jnp.sum((out - tgt) ** 2)

    dq, dk, dv_ = jax.grad(loss)((q, k, v))
    g_s = jax.grad(lambda qkv: jnp.sum(
        (sparse_attention_ref(rows, cols, *qkv, n_rows=5) - tgt) ** 2))(
        (q, k, v))
    for gf, gs in zip((dq, dk, dv_), g_s):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gs),
                                   rtol=GRAD_TOL, atol=GRAD_TOL)
    assert np.all(np.asarray(dq)[[0, 2, 4]] == 0)  # empty rows
    assert np.all(np.asarray(dk)[[3, 4, 5]] == 0)  # untouched cols
    assert np.all(np.asarray(dv_)[[3, 4, 5]] == 0)
    # row 1 has a single nnz: w == 1 identically -> softmax backward
    # kills dQ for that row, and dV[0] receives dout[1] verbatim
    np.testing.assert_allclose(np.asarray(dq)[1], 0.0, atol=GRAD_TOL)


# ---------------------------------------------------------------------------
# Multi-dv-tile grids: the probability carry
# ---------------------------------------------------------------------------


def test_forward_multi_dv_tile_probability_carry():
    """dv spanning several dv tiles must match the oracle exactly — the
    (nnz_tile, 1) carry replays the tile's probabilities at dv steps > 0
    instead of recomputing scores."""
    rows, cols = _pattern(14, 10, 33, 7)
    nnz_pad = round_up(33, 32)
    rows_p = jnp.pad(rows, (0, nnz_pad - 33))
    cols_p = jnp.pad(cols, (0, nnz_pad - 33))
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 14, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 10, 24))
    out, _, _ = fused_sparse_attention(
        rows_p, cols_p, q, k, v, n_rows=14, nnz=33, nnz_tile=32,
        dv_tile=8, scale=0.5, group_size=8)  # 3 dv tiles
    for h in range(2):
        want = sparse_attention_ref(rows, cols, q[h], k[h], v[h],
                                    n_rows=14, scale=0.5)
        np.testing.assert_allclose(np.asarray(out[h]), np.asarray(want),
                                   rtol=RTOL, atol=ATOL)


def test_public_api_multi_dv_tile_forward_and_grads():
    """dv > 128 drives the public path onto a multi-dv-tile grid
    (dv_tile caps at 128); forward and grads must still match the
    spec."""
    rows, cols = _pattern(10, 8, 25, 5)
    q = jax.random.normal(jax.random.PRNGKey(0), (10, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (8, 160))
    got = np.asarray(sparse_attention((rows, cols, 10), q, k, v))
    want = np.asarray(sparse_attention_ref(rows, cols, q, k, v, n_rows=10))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    g_f = jax.grad(lambda qq: jnp.sum(
        sparse_attention((rows, cols, 10), qq, k, v) ** 2))(q)
    g_s = jax.grad(lambda qq: jnp.sum(
        sparse_attention_ref(rows, cols, qq, k, v, n_rows=10) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_s),
                               rtol=GRAD_TOL, atol=GRAD_TOL)


# ---------------------------------------------------------------------------
# Multi-head: one launch, forward + grads
# ---------------------------------------------------------------------------


def test_graph_attention_is_one_kernel_launch(monkeypatch):
    from repro.models.attention import graph_attention
    from repro.sparse import ops as sops

    adj = random_csr(12, 12, density=0.25, seed=2)
    q = jax.random.normal(jax.random.PRNGKey(0), (12, 4, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (12, 4, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (12, 4, 4))
    calls = []
    orig = sops._fused_attn_fwd

    def counting(*args, **kw):
        calls.append(1)
        return orig(*args, **kw)

    monkeypatch.setattr(sops, "_fused_attn_fwd", counting)
    out = graph_attention(adj, q, k, v)
    assert out.shape == (12, 4, 4)
    assert len(calls) == 1  # H=4 heads, ONE fused kernel launch


@pytest.mark.parametrize("sched", SCHEDS, ids=lambda s: s.strategy)
def test_multihead_grads_match_per_head_spec(sched):
    rows, cols = _pattern(16, 12, 40, 4)
    H = 3
    q = jax.random.normal(jax.random.PRNGKey(0), (16, H, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (12, H, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (12, H, 6))
    tgt = jax.random.normal(jax.random.PRNGKey(3), (16, H, 6))

    def loss_fused(qkv):
        out = sparse_attention((rows, cols, 16), *qkv, schedule=sched)
        return jnp.sum((out - tgt) ** 2)

    def loss_spec(qkv):
        qq, kk, vv = qkv
        outs = [sparse_attention_ref(rows, cols, qq[:, h], kk[:, h],
                                     vv[:, h], n_rows=16)
                for h in range(H)]
        return jnp.sum((jnp.stack(outs, axis=1) - tgt) ** 2)

    g_f = jax.grad(loss_fused)((q, k, v))
    g_s = jax.grad(loss_spec)((q, k, v))
    for gf, gs in zip(g_f, g_s):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gs),
                                   rtol=GRAD_TOL, atol=GRAD_TOL)


def test_multihead_rejects_mismatched_head_counts():
    rows, cols = _pattern(8, 8, 10, 0)
    q = jax.random.normal(jax.random.PRNGKey(0), (8, 2, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (8, 3, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (8, 2, 4))
    with pytest.raises(ValueError, match="head"):
        sparse_attention((rows, cols, 8), q, k, v)
    # mixed 2-D / 3-D operands get the same clear error, not a shape
    # unpack failure deep inside the kernel wrapper
    with pytest.raises(ValueError, match="head"):
        sparse_attention((rows, cols, 8), q[:, 0], k, v)


# ---------------------------------------------------------------------------
# CSR stored values = additive score bias (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


def test_csr_values_bias_scores_and_all_ones_is_pure_pattern():
    from repro.sparse.formats import CSR

    adj = random_csr(14, 14, density=0.2, seed=3)
    coo = adj.tocoo()
    q = jax.random.normal(jax.random.PRNGKey(0), (14, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (14, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (14, 4))
    got = np.asarray(sparse_attention(adj, q, k, v))
    biased = np.asarray(sparse_attention_ref(coo.rows, coo.cols, q, k, v,
                                             n_rows=14, bias=coo.vals))
    plain = np.asarray(sparse_attention_ref(coo.rows, coo.cols, q, k, v,
                                            n_rows=14))
    np.testing.assert_allclose(got, biased, rtol=RTOL, atol=ATOL)
    # random values genuinely move the result (they used to be ignored)
    assert not np.allclose(got, plain, rtol=1e-3, atol=1e-3)
    # an all-ones "pattern" CSR shifts every score in a row equally,
    # which the softmax cancels -> identical to the pure pattern
    ones = CSR(indptr=adj.indptr, indices=adj.indices,
               vals=jnp.ones_like(adj.vals), shape=adj.shape)
    got_ones = np.asarray(sparse_attention(ones, q, k, v))
    np.testing.assert_allclose(got_ones, plain, rtol=RTOL, atol=ATOL)
    # ref impl honors the bias identically
    np.testing.assert_allclose(
        np.asarray(sparse_attention(adj, q, k, v, impl="ref")), biased,
        rtol=RTOL, atol=ATOL)


def test_csr_values_bias_flows_through_grads():
    adj = random_csr(12, 12, density=0.25, seed=6)
    coo = adj.tocoo()
    q = jax.random.normal(jax.random.PRNGKey(0), (12, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (12, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (12, 4))
    g_f = jax.grad(lambda qq: jnp.sum(sparse_attention(adj, qq, k, v) ** 2))(q)
    g_s = jax.grad(lambda qq: jnp.sum(sparse_attention_ref(
        coo.rows, coo.cols, qq, k, v, n_rows=12, bias=coo.vals) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_s),
                               rtol=GRAD_TOL, atol=GRAD_TOL)


# ---------------------------------------------------------------------------
# Low-precision inputs: f32-forced score accumulation (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_low_precision_inputs_match_f32_upcasting_oracle(dtype):
    """The NEG_INF = -1e30 masked-lane floor overflows fp16 to -inf (and
    bf16 loses the exp cancellation) unless scores accumulate in f32;
    the kernel must match the (already f32-upcasting) spec oracle to a
    low-precision rounding, forward and backward, with no NaN/inf."""
    rows, cols = _pattern(20, 16, 50, 8)
    q = jax.random.normal(jax.random.PRNGKey(0), (20, 8)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (16, 8)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (16, 4)).astype(dtype)
    got = np.asarray(sparse_attention((rows, cols, 20), q, k, v),
                     np.float32)
    want = np.asarray(sparse_attention_ref(rows, cols, q, k, v, n_rows=20))
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    # backward: finite and parity with the spec VJP on the same inputs
    g_f = jax.grad(lambda qq: jnp.sum(sparse_attention(
        (rows, cols, 20), qq, k, v).astype(jnp.float32) ** 2))(q)
    g_s = jax.grad(lambda qq: jnp.sum(sparse_attention_ref(
        rows, cols, qq, k, v, n_rows=20) ** 2))(q)
    assert np.all(np.isfinite(np.asarray(g_f, np.float32)))
    np.testing.assert_allclose(np.asarray(g_f, np.float32),
                               np.asarray(g_s, np.float32),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# Tuner: fwd/bwd + head count are distinct cache keys
# ---------------------------------------------------------------------------


def test_attention_tuner_keys_and_replay():
    from repro.tune import (
        ScheduleCache,
        attention_cache_key,
        tune_sparse_attention,
    )

    rows, cols = _pattern(24, 20, 60, 9)
    kf = attention_cache_key(rows, 24, n_cols=20, d=8, dv=6, n_heads=1,
                             direction="fwd")
    kb = attention_cache_key(rows, 24, n_cols=20, d=8, dv=6, n_heads=1,
                             direction="bwd")
    k4 = attention_cache_key(rows, 24, n_cols=20, d=8, dv=6, n_heads=4,
                             direction="fwd")
    kbias = attention_cache_key(rows, 24, n_cols=20, d=8, dv=6,
                                n_heads=1, direction="fwd", has_bias=True)
    kkv = attention_cache_key(rows, 24, n_cols=4096, d=8, dv=6,
                              n_heads=1, direction="fwd")
    assert len({kf, kb, k4, kbias, kkv}) == 5  # all distinct
    assert kf.endswith("fwd") and "|H4|" in k4 and "bwd" in kb
    with pytest.raises(ValueError, match="direction"):
        attention_cache_key(rows, 24, n_cols=20, d=8, dv=6, n_heads=1,
                            direction="sideways")

    q = jax.random.normal(jax.random.PRNGKey(0), (24, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (20, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (20, 6))
    cache = ScheduleCache(path=None)
    measured = []

    def fake_measure(s):
        measured.append(s)
        # prefer one specific point so the winner is deterministic
        return 1e-6 if (s.nnz_tile, s.group_size) == (128, 32) else 2e-6

    res_f = tune_sparse_attention(rows, cols, q, k, v, n_rows=24,
                                  cache=cache, measure=fake_measure)
    res_b = tune_sparse_attention(rows, cols, q, k, v, n_rows=24,
                                  direction="bwd", cache=cache,
                                  measure=fake_measure)
    assert res_f.key == kf and res_b.key == kb
    assert res_f.schedule.nnz_tile == 128
    assert not res_f.from_cache and not res_b.from_cache
    # replay: zero measurements on a second identical query
    n = len(measured)
    hit = tune_sparse_attention(rows, cols, q, k, v, n_rows=24,
                                cache=cache, measure=fake_measure)
    assert hit.from_cache and len(measured) == n


def test_attention_tuner_bwd_measures_rectangular_pattern():
    """direction='bwd' with the real kernel objective on a rectangular
    pattern (n_rows != n_cols): the cotangent must take the OUTPUT's
    shape, not v's (regression — they only coincide on square
    patterns)."""
    from repro.tune import ScheduleCache, tune_sparse_attention

    rows, cols = _pattern(10, 7, 15, 4)
    q = jax.random.normal(jax.random.PRNGKey(0), (10, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (7, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (7, 4))
    res = tune_sparse_attention(rows, cols, q, k, v, n_rows=10,
                                direction="bwd",
                                cache=ScheduleCache(path=None),
                                warmup=0, iters=1)
    assert res.key.endswith("bwd") and res.us_per_call > 0


def test_sparse_attention_schedule_tune_end_to_end():
    """schedule="tune" measures the real fused kernel and the tuned
    schedule reproduces the oracle."""
    from repro.tune import ScheduleCache, set_default_cache

    rows, cols = _pattern(16, 12, 30, 2)
    q = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (12, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (12, 4))
    set_default_cache(ScheduleCache(path=None))
    try:
        got = np.asarray(sparse_attention((rows, cols, 16), q, k, v,
                                          schedule="tune"))
    finally:
        set_default_cache(None)
    want = np.asarray(sparse_attention_ref(rows, cols, q, k, v, n_rows=16))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
