"""Fused sparse attention: SDDMM → segment softmax → SpMM in ONE kernel,
forward AND backward, batched over heads (DESIGN.md §8–§9).

The motivating chain (graph attention / sparse transformer): for a
sparsity pattern (rows, cols) over queries Q (n_rows, d), keys
K (n_cols, d) and values V (n_cols, dv),

    s[t]   = <Q[rows[t]], K[cols[t]]> * scale (+ bias[t])   (SDDMM)
    w[t]   = softmax over {t' : rows[t'] = rows[t]}         (segment softmax)
    out[r] = Σ_{t: rows[t]=r} w[t] * V[cols[t]]             (SpMM)

Composed as separate ops this costs three HBM round trips and
materializes two (nnz,)-sized intermediates.  The fused forward makes
one pass over the nonzeros with FlashAttention-style *online
renormalization* per output row: a running row max ``m`` and denominator
``l`` carried through the race-free sequential nnz grid —

    per nnz tile i:   m_new = max(m, rowmax_i(s))          (max monoid
                      α     = exp(m - m_new)                through the
                      l     = l·α + rowsum_i(exp(s-m_new))  strategy
                      acc   = acc·α + Σ exp(s-m_new)·V      registry)
    last tile:        out   = acc / l

**Head batching.**  H heads run in ONE kernel launch: the grid is
(H, nnz_tiles, dv_tiles) and every per-head operand is flattened to a
2-D head-major buffer ((H·n_rows, d) queries, (H·n_rows, 1) row stats,
…) whose BlockSpec selects head h's slab — so the in-kernel blocks stay
2-D and ``group_reduce_scatter`` is reused unchanged.  The pattern
(rows/cols/bias) is shared across heads.

**Probability carry.**  The per-tile probabilities are computed once per
nnz tile (at dv step 0, together with the row statistics) and stashed in
an (nnz_tile, 1) carry block revisited by every grid step; later dv
steps of the same nnz tile read the carry instead of redoing the
d-length SDDMM dots (the PR-4 kernel recomputed scores per dv step).

**Backward.**  ``_fused_attn_bwd_kernel`` is one launch over the grid
(H, 2, nnz_tiles): the softmax backward needs the completed row dot
``δ[r] = Σ_t w_t · <dout[r], V[c_t]>`` before any dQ/dK lane can be
scattered, so the nnz grid is walked twice inside the same kernel —

    phase 0 (per tile): recompute w from the carried forward stats
                        (m, l — O(n_rows) residuals, FlashAttention
                        style), stash (w, dw) in (nnz_pad, 1) carries,
                        scatter δ (add monoid through the registry) and
                        the transpose writes dV[c] += w·dout[r];
    phase 1 (per tile): ds = w·(dw − δ[r])·scale from the carries (no
                        score recompute), scatter dQ[r] += ds·K[c] and
                        the transpose dK[c] += ds·Q[r].

All scatters run through ``group_reduce_scatter``; the dK/dV transpose
scatters hand it the *cols* as segment ids — unsorted ids are correct by
the strategy contract (each transition opens a new run), just more
writebacks.

Scores, statistics and probabilities are **forced to float32** via
``common.upcast_f32`` whatever the q/k/v/dout storage dtype: the
``NEG_INF = -1e30`` masked-lane floor overflows fp16 to -inf (NaN after
the online rescale), and bf16 loses the exp cancellation.  Padded lanes
(trailing, from the nnz tile round-up) are masked by the static true
``nnz``: scores floored to NEG_INF, probabilities zeroed, so they
contribute nothing to any row or column.  Empty rows come out as exact
zeros (matching the spec oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import NEG_INF, group_reduce_scatter, upcast_f32

__all__ = [
    "NEG_INF",
    "fused_sparse_attention",
    "fused_sparse_attention_bwd",
    "sparse_attention_bwd_ref",
    "sparse_attention_ref",
    "sparse_softmax_weights",
]


# ---------------------------------------------------------------------------
# Pure-JAX spec oracles
# ---------------------------------------------------------------------------


def sparse_softmax_weights(rows, cols, q, k, *, n_rows: int,
                           scale: float, bias=None):
    """Spec of the SDDMM→segment-softmax front half: the normalized
    per-nnz attention weights ``w``.  Shared by the forward oracle and
    the spec VJP, so the numerically load-bearing details (the empty-row
    isfinite guard, the 1e-30 denominator floor) cannot desynchronize
    between forward and backward.  ``bias`` is an optional (nnz,)
    additive score term (a CSR adjacency's stored values)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.sum(qf[rows] * kf[cols], axis=-1) * scale  # (nnz,)
    if bias is not None:
        s = s + bias.astype(jnp.float32)
    m = jax.ops.segment_max(s, rows, num_segments=n_rows)
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # empty rows: any finite value
    p = jnp.exp(s - m[rows])
    l = jax.ops.segment_sum(p, rows, num_segments=n_rows)
    return p / jnp.maximum(l[rows], 1e-30)


def sparse_attention_ref(rows, cols, q, k, v, *, n_rows: int,
                         scale: float | None = None, bias=None):
    """Executable specification of the fused kernel (the oracle the
    kernel and its VJP are tested against).  Empty rows -> zero rows."""
    if scale is None:
        scale = float(q.shape[-1]) ** -0.5
    w = sparse_softmax_weights(rows, cols, q, k, n_rows=n_rows,
                               scale=scale, bias=bias)
    return jax.ops.segment_sum(w[:, None] * v.astype(jnp.float32)[cols],
                               rows, num_segments=n_rows)


def sparse_attention_bwd_ref(rows, cols, q, k, v, dout, *, n_rows: int,
                             scale: float, bias=None):
    """Spec-recompute VJP (the PR-4 backward): pure-JAX softmax backward
    + SDDMM / transpose-SpMM through segment ops, recomputing the
    weights from scratch.  Returns ``(dq, dk, dv)``.  Kept as the oracle
    the fused backward kernel is tested against and as the unfused
    baseline ``beyond/fused_attention_bwd`` times."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    do = dout.astype(jnp.float32)
    w = sparse_softmax_weights(rows, cols, q, k, n_rows=n_rows,
                               scale=scale, bias=bias)  # (nnz,)
    # value gradient: transpose-SpMM of the weighted cotangent
    dv_ = jax.ops.segment_sum(w[:, None] * do[rows], cols,
                              num_segments=v.shape[0])
    # softmax backward per row: ds = w (dw - δ),  δ[r] = Σ_row w dw
    dw = jnp.sum(do[rows] * vf[cols], axis=-1)  # SDDMM(dout, V)
    delta = jax.ops.segment_sum(w * dw, rows, num_segments=n_rows)
    ds = w * (dw - delta[rows]) * scale
    dq = jax.ops.segment_sum(ds[:, None] * kf[cols], rows,
                             num_segments=n_rows)
    dk = jax.ops.segment_sum(ds[:, None] * qf[rows], cols,
                             num_segments=k.shape[0])
    return dq, dk, dv_


# ---------------------------------------------------------------------------
# The fused forward kernel
# ---------------------------------------------------------------------------


def _fused_attn_fwd_kernel(*refs, nnz: int, nnz_tile: int, scale: float,
                           group_size: int, strategy: str, has_bias: bool):
    if has_bias:
        (rows_ref, cols_ref, bias_ref, q_ref, k_ref, v_ref,
         out_ref, m_ref, l_ref, a_ref, p_ref) = refs
    else:
        (rows_ref, cols_ref, q_ref, k_ref, v_ref,
         out_ref, m_ref, l_ref, a_ref, p_ref) = refs
        bias_ref = None
    i = pl.program_id(1)  # nnz tile (sequential carry within each head)
    j = pl.program_id(2)  # dv tile (innermost)

    @pl.when((i == 0) & (j == 0))
    def _init_stats():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(i == 0)
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    rows = rows_ref[...]
    cols = cols_ref[...]
    lane = i * nnz_tile + jax.lax.broadcasted_iota(
        jnp.int32, (nnz_tile,), 0)
    valid = lane < nnz

    @pl.when(j == 0)
    def _scores_and_stats():
        # SDDMM front-end, once per nnz tile: f32-forced scores, padded
        # lanes floored to NEG_INF
        q, k = upcast_f32(q_ref[...], k_ref[...])
        s = jnp.sum(jnp.take(q, rows, axis=0) * jnp.take(k, cols, axis=0),
                    axis=-1) * scale
        if bias_ref is not None:
            s = s + upcast_f32(bias_ref[...])
        s = jnp.where(valid, s, NEG_INF)
        m_old = m_ref[...]  # (R, 1)
        # running row max: the max-monoid scatter through the registry
        group_reduce_scatter(rows, s[:, None], m_ref, group_size,
                             strategy, op="max")
        m_new = m_ref[...]
        alpha = jnp.where(m_old <= NEG_INF / 2, 0.0,
                          jnp.exp(m_old - m_new))  # (R, 1)
        a_ref[...] = alpha
        p = jnp.where(valid,
                      jnp.exp(jnp.where(valid, s, 0.0)
                              - jnp.take(m_new[:, 0], rows)), 0.0)
        # the probability carry: later dv steps of this nnz tile replay
        # p instead of redoing the d-length dots above
        p_ref[...] = p[:, None]
        l_ref[...] = l_ref[...] * alpha
        group_reduce_scatter(rows, p[:, None], l_ref, group_size,
                             strategy)

    # SpMM back-end (every dv step): rescale the accumulator by this nnz
    # tile's α, then scatter-add the carried-probability-weighted values
    p = p_ref[...][:, 0]
    vj = upcast_f32(v_ref[...])  # (n_cols, dv_tile)
    out_ref[...] = out_ref[...] * a_ref[...]
    group_reduce_scatter(rows, p[:, None] * jnp.take(vj, cols, axis=0),
                         out_ref, group_size, strategy)

    @pl.when(i == pl.num_programs(1) - 1)
    def _normalize():
        out_ref[...] = out_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "nnz", "nnz_tile", "dv_tile", "scale",
                     "group_size", "strategy", "interpret"),
)
def fused_sparse_attention(rows, cols, q, k, v, *, n_rows: int, nnz: int,
                           nnz_tile: int = 256, dv_tile: int = 128,
                           scale: float, group_size: int = 32,
                           strategy: str = "segment", bias=None,
                           interpret: bool = True):
    """One-launch SDDMM→softmax→SpMM over all heads.

    Inputs pre-padded by the wrapper: rows/cols (and bias) (nnz_pad,)
    with nnz_pad % nnz_tile == 0 (``nnz`` is the true count — trailing
    pad lanes are masked in-kernel); q/k/v carry an explicit head axis —
    q (H, n_rows, d), k (H, n_kv, d), v (H, n_kv, dv_pad) with
    dv_pad % dv_tile == 0.  ``bias`` is an optional (nnz_pad,) additive
    score term shared across heads.  Returns ``(out, m, l)`` with out
    (H, n_rows, dv_pad) final and m/l (H, n_rows) the per-row softmax
    statistics — the O(H·n_rows) residuals the fused backward recomputes
    probabilities from.
    """
    nnz_pad = rows.shape[0]
    n_heads, n_q, d = q.shape
    _, n_kv, dv = v.shape
    assert nnz_pad % nnz_tile == 0 and dv % dv_tile == 0, (nnz_pad, dv)
    assert n_q == n_rows and k.shape == (n_heads, n_kv, d)
    grid = (n_heads, nnz_pad // nnz_tile, dv // dv_tile)

    # head-major flat buffers: blocks stay 2-D, head h = block-row h
    qf = q.reshape(n_heads * n_rows, d)
    kf = k.reshape(n_heads * n_kv, d)
    vf = v.reshape(n_heads * n_kv, dv)

    kernel = functools.partial(
        _fused_attn_fwd_kernel, nnz=nnz, nnz_tile=nnz_tile, scale=scale,
        group_size=group_size, strategy=strategy,
        has_bias=bias is not None)
    lane_spec = pl.BlockSpec((nnz_tile,), lambda h, i, j: (i,))
    stat_spec = pl.BlockSpec((n_rows, 1), lambda h, i, j: (h, 0))
    in_specs = [lane_spec, lane_spec]
    operands = [rows, cols]
    if bias is not None:
        in_specs.append(lane_spec)
        operands.append(bias)
    in_specs += [
        pl.BlockSpec((n_rows, d), lambda h, i, j: (h, 0)),
        pl.BlockSpec((n_kv, d), lambda h, i, j: (h, 0)),
        pl.BlockSpec((n_kv, dv_tile), lambda h, i, j: (h, j)),
    ]
    out, m, l, _alpha, _p = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((n_rows, dv_tile), lambda h, i, j: (h, j)),
            stat_spec, stat_spec, stat_spec,
            # the (nnz_tile, 1) probability carry: one resident block
            # revisited by every grid step
            pl.BlockSpec((nnz_tile, 1), lambda h, i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_heads * n_rows, dv), jnp.float32),
            jax.ShapeDtypeStruct((n_heads * n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_heads * n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_heads * n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((nnz_tile, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands, qf, kf, vf)
    return (out.reshape(n_heads, n_rows, dv),
            m.reshape(n_heads, n_rows), l.reshape(n_heads, n_rows))


# ---------------------------------------------------------------------------
# The fused backward kernel
# ---------------------------------------------------------------------------


def _fused_attn_bwd_kernel(*refs, nnz: int, nnz_tile: int, scale: float,
                           group_size: int, strategy: str, has_bias: bool):
    if has_bias:
        (rows_ref, cols_ref, bias_ref, q_ref, k_ref, v_ref, do_ref,
         m_ref, l_ref,
         dq_ref, dk_ref, dv_ref, delta_ref, w_ref, dw_ref) = refs
    else:
        (rows_ref, cols_ref, q_ref, k_ref, v_ref, do_ref,
         m_ref, l_ref,
         dq_ref, dk_ref, dv_ref, delta_ref, w_ref, dw_ref) = refs
        bias_ref = None
    ph = pl.program_id(1)  # phase: 0 = δ + dV, 1 = dQ + dK
    i = pl.program_id(2)   # nnz tile

    @pl.when((ph == 0) & (i == 0))
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)
        dk_ref[...] = jnp.zeros_like(dk_ref)
        dv_ref[...] = jnp.zeros_like(dv_ref)
        delta_ref[...] = jnp.zeros_like(delta_ref)

    rows = rows_ref[...]
    cols = cols_ref[...]
    lane = i * nnz_tile + jax.lax.broadcasted_iota(
        jnp.int32, (nnz_tile,), 0)
    valid = lane < nnz

    @pl.when(ph == 0)
    def _delta_and_dv():
        # recompute the probabilities from the carried forward stats
        # (FlashAttention-style: O(n_rows) residuals, no (nnz,) weights
        # saved across the fwd/bwd boundary), f32-forced
        q, k, v, do = upcast_f32(q_ref[...], k_ref[...], v_ref[...],
                                 do_ref[...])
        s = jnp.sum(jnp.take(q, rows, axis=0) * jnp.take(k, cols, axis=0),
                    axis=-1) * scale
        if bias_ref is not None:
            s = s + upcast_f32(bias_ref[...])
        m_lane = jnp.take(m_ref[...][:, 0], rows)
        m_safe = jnp.where(m_lane <= NEG_INF / 2, 0.0, m_lane)
        linv = jnp.take(1.0 / jnp.maximum(l_ref[...][:, 0], 1e-30), rows)
        w = jnp.where(valid,
                      jnp.exp(jnp.where(valid, s, NEG_INF) - m_safe) * linv,
                      0.0)
        dw = jnp.sum(jnp.take(do, rows, axis=0)
                     * jnp.take(v, cols, axis=0), axis=-1)  # SDDMM(dout, V)
        # (nnz_pad, 1) carries: phase 1 replays (w, dw) with no recompute
        w_ref[...] = w[:, None]
        dw_ref[...] = dw[:, None]
        # the softmax-backward row dot δ[r] = Σ w·dw — add-monoid scatter
        group_reduce_scatter(rows, (w * dw)[:, None], delta_ref,
                             group_size, strategy)
        # dV[c] += w · dout[r] — scatter-transpose (cols as segment ids)
        group_reduce_scatter(cols, w[:, None] * jnp.take(do, rows, axis=0),
                             dv_ref, group_size, strategy)

    @pl.when(ph == 1)
    def _dq_and_dk():
        q, k = upcast_f32(q_ref[...], k_ref[...])
        w = w_ref[...][:, 0]
        dw = dw_ref[...][:, 0]
        ds = w * (dw - jnp.take(delta_ref[...][:, 0], rows)) * scale
        group_reduce_scatter(rows, ds[:, None] * jnp.take(k, cols, axis=0),
                             dq_ref, group_size, strategy)
        # dK[c] += ds · Q[r] — scatter-transpose
        group_reduce_scatter(cols, ds[:, None] * jnp.take(q, rows, axis=0),
                             dk_ref, group_size, strategy)


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "nnz", "nnz_tile", "scale", "group_size",
                     "strategy", "interpret"),
)
def fused_sparse_attention_bwd(rows, cols, q, k, v, dout, m, l, *,
                               n_rows: int, nnz: int, nnz_tile: int = 256,
                               scale: float, group_size: int = 32,
                               strategy: str = "segment", bias=None,
                               interpret: bool = True):
    """One-launch fused backward: ``(dq, dk, dv)`` for all heads.

    Grid (H, 2, nnz_tiles) — the nnz grid is walked twice inside one
    kernel: phase 0 recomputes the probabilities from the forward's
    (m, l) row stats, accumulates the softmax-backward row dot δ and the
    dV transpose scatter, and stashes (w, dw) in (nnz_pad, 1) carries;
    phase 1 forms ds from the carries and scatters dQ/dK.  Layouts match
    :func:`fused_sparse_attention`: rows/cols/bias (nnz_pad,), q/k/v
    (H, n, ·), dout (H, n_rows, dv), m/l (H, n_rows) as the forward
    returned them.  No dv tiling: the backward holds whole per-head
    feature blocks, like the forward holds whole q/k blocks.
    """
    nnz_pad = rows.shape[0]
    n_heads, n_q, d = q.shape
    _, n_kv, dv = v.shape
    assert nnz_pad % nnz_tile == 0 and n_q == n_rows
    assert dout.shape == (n_heads, n_rows, dv) and m.shape == (n_heads, n_q)
    grid = (n_heads, 2, nnz_pad // nnz_tile)

    qf = q.reshape(n_heads * n_rows, d)
    kf = k.reshape(n_heads * n_kv, d)
    vf = v.reshape(n_heads * n_kv, dv)
    dof = dout.reshape(n_heads * n_rows, dv)
    mf = m.reshape(n_heads * n_rows, 1)
    lf = l.reshape(n_heads * n_rows, 1)

    kernel = functools.partial(
        _fused_attn_bwd_kernel, nnz=nnz, nnz_tile=nnz_tile, scale=scale,
        group_size=group_size, strategy=strategy,
        has_bias=bias is not None)
    lane_spec = pl.BlockSpec((nnz_tile,), lambda h, p, i: (i,))
    carry_spec = pl.BlockSpec((nnz_tile, 1), lambda h, p, i: (i, 0))
    stat_spec = pl.BlockSpec((n_rows, 1), lambda h, p, i: (h, 0))
    in_specs = [lane_spec, lane_spec]
    operands = [rows, cols]
    if bias is not None:
        in_specs.append(lane_spec)
        operands.append(bias)
    in_specs += [
        pl.BlockSpec((n_rows, d), lambda h, p, i: (h, 0)),
        pl.BlockSpec((n_kv, d), lambda h, p, i: (h, 0)),
        pl.BlockSpec((n_kv, dv), lambda h, p, i: (h, 0)),
        pl.BlockSpec((n_rows, dv), lambda h, p, i: (h, 0)),
        stat_spec, stat_spec,
    ]
    dq, dk, dv_, _delta, _w, _dw = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((n_rows, d), lambda h, p, i: (h, 0)),
            pl.BlockSpec((n_kv, d), lambda h, p, i: (h, 0)),
            pl.BlockSpec((n_kv, dv), lambda h, p, i: (h, 0)),
            stat_spec,
            carry_spec, carry_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_heads * n_rows, d), jnp.float32),
            jax.ShapeDtypeStruct((n_heads * n_kv, d), jnp.float32),
            jax.ShapeDtypeStruct((n_heads * n_kv, dv), jnp.float32),
            jax.ShapeDtypeStruct((n_heads * n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((nnz_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((nnz_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands, qf, kf, vf, dof, mf, lf)
    return (dq.reshape(n_heads, n_rows, d),
            dk.reshape(n_heads, n_kv, d),
            dv_.reshape(n_heads, n_kv, dv))
