"""Per-architecture smoke tests: reduced same-family config, one forward +
train-grad step, one prefill + decode step. Asserts shapes + finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import get_model

B, S = 2, 32
MAXLEN = 48


def _batch(cfg, key=jax.random.PRNGKey(0)):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                          jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["encoder_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def _get(name):
        if name not in cache:
            cfg = smoke_config(ARCHS[name])
            api = get_model(cfg)
            params = api.init(jax.random.PRNGKey(42))
            cache[name] = (cfg, api, params)
        return cache[name]

    return _get


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_finite(built, name):
    cfg, api, params = built(name)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(api.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss not finite"
    leaves = jax.tree.leaves(grads)
    assert leaves, f"{name}: no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), (
            f"{name}: non-finite grad")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_shapes(built, name):
    cfg, api, params = built(name)
    batch = _batch(cfg)
    logits, cache = api.prefill(params, batch, MAXLEN)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = api.decode_step(params, cache, nxt)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("name", ["mamba2-2.7b", "hymba-1.5b", "qwen2-7b",
                                  "whisper-large-v3"])
def test_decode_matches_prefill(built, name):
    """Teacher-forced decode must reproduce the prefill logits: feed the
    same tokens one-by-one and compare against prefill of the longer
    prompt."""
    cfg, api, params = built(name)
    batch = _batch(cfg)
    tokens = batch["tokens"]
    # prefill on the first S-1 tokens, then decode token S-1
    short = dict(batch, tokens=tokens[:, :-1])
    _, cache = api.prefill(params, short, MAXLEN)
    logits_dec, _ = api.decode_step(params, cache, tokens[:, -1])
    logits_full, _ = api.prefill(params, batch, MAXLEN)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32), rtol=2e-3, atol=2e-3)


def test_decode_inplace_matches_scan(built):
    """The fori_loop in-place-cache decode must equal the scan decode."""
    import dataclasses

    cfg, api, params = built("qwen2-7b")
    batch = _batch(cfg)
    _, cache = api.prefill(params, batch, MAXLEN)
    tok = jnp.zeros((B,), jnp.int32)
    want, cache_w = api.decode_step(params, cache, tok)
    cfg2 = dataclasses.replace(cfg, decode_inplace_cache=True)
    from repro.models import get_model as _gm

    api2 = _gm(cfg2)
    got, cache_g = api2.decode_step(params, cache, tok)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache_g["k"], np.float32),
                               np.asarray(cache_w["k"], np.float32),
                               rtol=2e-4, atol=2e-4)
