"""``repro.fuse`` — the sparse fusion IR and planner (DESIGN.md §10).

Every fusion in the library now goes through one pipeline::

    chain = [spmm_node(), ewise("relu", bias=True), spmm_node()]
    p     = plan(chain)            # legality + greedy fusion
    out   = run_plan(p, x, params) # ≤2 Pallas launches for this chain
    tune_plan(chain, x, params)    # measure fused-vs-split, cache it

The IR (:mod:`~repro.fuse.ir`) describes chains of
``{sparse op, monoid, epilogue}`` nodes; the rule registry
(:mod:`~repro.fuse.rules`) decides per boundary whether a consumer may
fold into the producer's launch (``core.Epilogue`` and the monoid
registry are the rules' targets); the planner
(:mod:`~repro.fuse.planner`) emits launches and the tuner measures
fuse-vs-split, fingerprint-keyed like every other schedule cache.
"""
from .execute import moe_combine, run_chain_ref, run_plan
from .ir import (
    EPILOGUE_CAPABLE,
    PALLAS_KINDS,
    FuseDecision,
    FuseNode,
    FusePlan,
    Launch,
    chain_sig,
    combine_node,
    ewise,
    gcn_chain,
    grouped_matmul_node,
    moe_expert_chain,
    segment_reduce_node,
    spmm_node,
)
from .legality import can_fuse
from .planner import plan, plan_key, split_all, tune_plan, tuned_plan
from .rules import available_rules, register_rule, unregister_rule

__all__ = [
    "EPILOGUE_CAPABLE",
    "PALLAS_KINDS",
    "FuseDecision",
    "FuseNode",
    "FusePlan",
    "Launch",
    "available_rules",
    "can_fuse",
    "chain_sig",
    "combine_node",
    "ewise",
    "gcn_chain",
    "grouped_matmul_node",
    "moe_combine",
    "moe_expert_chain",
    "plan",
    "plan_key",
    "register_rule",
    "run_chain_ref",
    "run_plan",
    "segment_reduce_node",
    "split_all",
    "spmm_node",
    "tune_plan",
    "tuned_plan",
    "unregister_rule",
]
