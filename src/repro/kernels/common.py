"""Shared in-kernel building blocks for the segment-group kernels.

``group_reduce_scatter`` is the Pallas dispatcher over the reduction-
strategy registry (``repro.core.schedule``): it looks up the strategy by
name and runs its in-kernel realization.  The built-in realizations live
here and are attached to the registry at import time; a user strategy
registered with only a pure-JAX spec falls back to running that spec on
the whole tile and accumulating the result (correct, not tuned).

The built-in 'segment' realization is the TPU form of the paper's segment
group (DESIGN.md §2): within each width-G group it

1. finds segment runs (boundary cumsum — replaces the GPU's runtime
   writeback-thread election),
2. reduces the run partials with a (G × G) one-hot matmul — the MXU
   analogue of the warp shuffle tree,
3. writes each live run back with a read-modify-write into the output
   block — the analogue of the paper's multiple writeback threads; the
   sequential TPU grid makes the RMW race-free ("atomic" for free).

Strategy variants:
  'segment'     full machinery above (runtime writeback targets);
  'parallel'    contract: all lanes of a group share one segment -> plain
                sum + single writeback (one writeback thread);
  'accumulate'  per-lane RMW (the atomicAdd baseline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schedule import attach_pallas_impl, get_strategy


def _rmw_row(out_ref, row, delta):
    """out_ref[row, :] += delta  (delta shape (1, C)), dynamic row index."""
    idx = (pl.dslice(row, 1), slice(None))
    out_ref[idx] = out_ref[idx] + delta


# ---------------------------------------------------------------------------
# Built-in in-kernel realizations.  Registry contract:
#     pallas_fn(rows (T,), partial (T, C), out_ref (R, C), group_size)
# ---------------------------------------------------------------------------


def _pallas_accumulate(rows, partial, out_ref, group_size: int):
    T, _ = partial.shape
    del group_size

    def lane_body(t, _):
        _rmw_row(out_ref, rows[t], partial[t][None, :])
        return 0

    jax.lax.fori_loop(0, T, lane_body, 0)


def _pallas_parallel(rows, partial, out_ref, group_size: int):
    T, C = partial.shape
    G = group_size

    def par_body(n, _):
        p = jax.lax.dynamic_slice(partial, (n * G, 0), (G, C))
        _rmw_row(out_ref, rows[n * G], jnp.sum(p, axis=0)[None, :])
        return 0

    jax.lax.fori_loop(0, T // G, par_body, 0)


def _pallas_segment(rows, partial, out_ref, group_size: int):
    T, C = partial.shape
    G = group_size

    def group_body(n, _):
        r = jax.lax.dynamic_slice(rows, (n * G,), (G,))
        p = jax.lax.dynamic_slice(partial, (n * G, 0), (G, C))
        # run boundaries -> local segment slots in [0, G)
        prev = jnp.concatenate([jnp.full((1,), -1, r.dtype), r[:-1]])
        local = jnp.cumsum((r != prev).astype(jnp.int32)) - 1  # (G,)
        onehot = (
            local[:, None]
            == jax.lax.broadcasted_iota(jnp.int32, (G, G), 1)
        ).astype(p.dtype)  # (G lanes, G slots)
        seg_tot = jnp.dot(onehot.T, p,
                          preferred_element_type=jnp.float32)  # (G, C) MXU
        # slot -> global row (slots past the last run get -1 = dead)
        seg_rows = jnp.max(
            jnp.where(onehot > 0, r[:, None], -1), axis=0
        )  # (G,)

        def slot_body(s, _):
            row = seg_rows[s]

            @pl.when(row >= 0)
            def _():
                _rmw_row(out_ref, row,
                         jax.lax.dynamic_slice(seg_tot, (s, 0), (1, C)))
            return 0

        jax.lax.fori_loop(0, G, slot_body, 0)
        return 0

    jax.lax.fori_loop(0, T // G, group_body, 0)


def spec_fallback_pallas(spec_fn):
    """Bridge a pure-JAX strategy spec into the in-kernel contract: run the
    spec over the whole tile (num_segments = the output block height) and
    accumulate.  Correct for any spec; no per-group tuning."""

    def pallas_fn(rows, partial, out_ref, group_size: int):
        out_ref[...] += spec_fn(partial, rows, out_ref.shape[0], group_size)

    return pallas_fn


def group_reduce_scatter(rows, partial, out_ref, group_size: int,
                         strategy: str = "segment"):
    """Reduce ``partial`` (T, C) by ``rows`` (T,) into ``out_ref`` (R, C)
    with the registered strategy named ``strategy``.

    ``rows`` need not be globally sorted; sorted input minimizes writebacks
    (each unsorted transition opens a new run — correct, just more RMWs),
    which is exactly the paper's "writeback thread decided at runtime".
    """
    T, _ = partial.shape
    assert T % group_size == 0, (T, group_size)
    entry = get_strategy(strategy)
    fn = entry.pallas_fn or spec_fallback_pallas(entry.spec_fn)
    fn(rows, partial, out_ref, group_size)


attach_pallas_impl("accumulate", _pallas_accumulate)
attach_pallas_impl("parallel", _pallas_parallel)
attach_pallas_impl("segment", _pallas_segment)
