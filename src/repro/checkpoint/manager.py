"""Sharded checkpointing with integrity manifest, atomic commit, async
save, and retention.

Layout (one directory per step):

    <dir>/step_000100.tmp/...   (written)
    <dir>/step_000100/          (atomic rename on commit)
        manifest.json           {leaf path -> file, shape, dtype, checksum}
        arr_00000.npy ...

Arrays are gathered to host per leaf (`jax.device_get` handles sharded
arrays), saved as .npy with a crc32 recorded in the manifest; restore
verifies checksums and re-places leaves under the target shardings —
which may belong to a *different mesh size* than the save-time mesh, so
this doubles as the elastic re-shard path (fault_tolerance.remesh).
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import pathlib
import shutil
import zlib

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return leaves, treedef


def _key_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1) if async_save else None
        self._pending: cf.Future | None = None

    # ------------------------------------------------------------ save

    def save(self, step: int, tree) -> None:
        """Gather to host synchronously (cheap vs train step), write to
        disk asynchronously, commit atomically."""
        leaves, _ = _flatten(tree)
        host = [(p, np.asarray(jax.device_get(v))) for p, v in leaves]
        if self._pending is not None:
            self._pending.result()  # one in-flight save at a time
        if self._pool is not None:
            self._pending = self._pool.submit(self._write, step, host)
        else:
            self._write(step, host)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_leaves) -> None:
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, (path, arr) in enumerate(host_leaves):
            fname = f"arr_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append({
                "key": _key_str(path),
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            })
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic commit
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------- restore

    def all_steps(self):
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp") and (p / "manifest.json").exists())

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``tree_like``. ``shardings`` (same
        structure) re-places each leaf — pass shardings built on the
        *current* mesh to reshard an old checkpoint elastically."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_key = {rec["key"]: rec for rec in manifest["leaves"]}

        leaves, treedef = _flatten(tree_like)
        shard_leaves = (jax.tree_util.tree_leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        out = []
        for (path, like), sh in zip(leaves, shard_leaves):
            rec = by_key[_key_str(path)]
            arr = np.load(d / rec["file"])
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != rec["crc32"]:
                raise IOError(
                    f"checksum mismatch for {rec['key']} in step {step}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
