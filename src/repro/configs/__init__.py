"""Architecture catalog: the 10 assigned configs + reduced smoke variants."""
from __future__ import annotations

from .base import ModelConfig, ShapeConfig  # noqa: F401
from .dbrx_132b import CONFIG as _dbrx
from .deepseek_coder_33b import CONFIG as _deepseek
from .hymba_1p5b import CONFIG as _hymba
from .mamba2_2p7b import CONFIG as _mamba2
from .paligemma_3b import CONFIG as _paligemma
from .qwen2_7b import CONFIG as _qwen2
from .qwen3_moe_235b import CONFIG as _qwen3moe
from .shapes import (SHAPES, batch_from_specs, cell_is_runnable,  # noqa: F401
                     decode_specs, train_batch_specs)
from .starcoder2_7b import CONFIG as _starcoder2
from .whisper_large_v3 import CONFIG as _whisper
from .yi_34b import CONFIG as _yi

ARCHS = {
    c.name: c
    for c in [_starcoder2, _deepseek, _yi, _qwen2, _paligemma, _mamba2,
              _qwen3moe, _dbrx, _hymba, _whisper]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    over = dict(
        n_layers=2, d_model=64, vocab_size=128,
        param_dtype="float32", compute_dtype="float32",
        q_chunk=32, kv_chunk=32, remat=False,
    )
    if cfg.n_heads:
        over.update(n_heads=4, n_kv_heads=max(1, min(2, cfg.n_kv_heads)),
                    d_head=16)
    if cfg.d_ff:
        over.update(d_ff=128)
    if cfg.family == "moe":
        over.update(n_experts=4, experts_per_token=2, moe_d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        over.update(ssm_state=8, ssm_head_dim=16, ssm_chunk=16)
    if cfg.family == "encdec":
        over.update(n_encoder_layers=2, encoder_seq=24)
    if cfg.family == "vlm":
        over.update(n_vision_tokens=8)
    return cfg.scaled(**over)
