"""Row-split (RB) SpMM Pallas kernel — the paper's ``{<g row, c col>, 1}``
family (parallel reduction: exactly one writeback per row).

Feed format: ELL (per-row padded, see ``formats.ELL``) — padding is the
zero extension the paper legitimizes: padded slots gather B[0] scaled by
0.0 and flow through the vector datapath unpredicated.

Grid: (row_tiles, col_tiles, width_tiles) — width innermost, accumulating
into the same (ROW_TILE × COL_TILE) output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_rb_kernel(cols_ref, vals_ref, b_ref, out_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    cols = cols_ref[...]  # (R, Wt)
    vals = vals_ref[...].astype(jnp.float32)  # (R, Wt)
    b = b_ref[...].astype(jnp.float32)  # (K, C)

    r, wt = cols.shape
    gathered = jnp.take(b, cols.reshape(-1), axis=0).reshape(r, wt, -1)
    out_ref[...] += jnp.sum(vals[..., None] * gathered, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=("row_tile", "col_tile", "width_tile", "interpret"),
)
def spmm_rb(ecols, evals, b, *, row_tile: int = 8, col_tile: int = 128,
            width_tile: int | None = None, interpret: bool = True):
    """out (R_pad, N) from ELL arrays (R_pad, W) and dense B (K, N).

    R_pad % row_tile == 0 and N % col_tile == 0 are the wrapper's job
    (``ops.spmm``); W is padded to width_tile here.
    """
    r_pad, w = ecols.shape
    k, n = b.shape
    if width_tile is None:
        width_tile = min(w, 64)
    w_pad = ((w + width_tile - 1) // width_tile) * width_tile
    if w_pad != w:
        pad = w_pad - w
        ecols = jnp.pad(ecols, ((0, 0), (0, pad)))
        evals = jnp.pad(evals, ((0, 0), (0, pad)))
    assert r_pad % row_tile == 0 and n % col_tile == 0

    grid = (r_pad // row_tile, n // col_tile, w_pad // width_tile)
    return pl.pallas_call(
        _spmm_rb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, width_tile), lambda i, j, u: (i, u)),
            pl.BlockSpec((row_tile, width_tile), lambda i, j, u: (i, u)),
            pl.BlockSpec((k, col_tile), lambda i, j, u: (0, j)),
        ],
        out_specs=pl.BlockSpec((row_tile, col_tile), lambda i, j, u: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r_pad, n), jnp.float32),
        interpret=interpret,
    )(ecols, evals, b)
