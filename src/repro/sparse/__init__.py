"""repro.sparse — the single public sparse API.

Formats (`CSR`, `COO`, `GroupedCOO`, `ELL`), generators (`random_csr`),
the unified ops (`spmm`, `sddmm`, `segment_reduce`, `sparse_attention`,
all taking ``schedule=``), and the scheduling surface re-exported from
core (`Schedule`, `Epilogue`, `register_strategy`).
"""
from ..core.schedule import (  # noqa: F401
    Epilogue,
    Schedule,
    as_schedule,
    available_strategies,
    register_strategy,
)
from .formats import COO, CSR, ELL, GroupedCOO  # noqa: F401
from .ops import sddmm, segment_reduce, sparse_attention, spmm  # noqa: F401
from .random import matrix_stats, random_coo, random_csr  # noqa: F401
