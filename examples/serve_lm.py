"""Batched serving demo: continuous batching over KV-cache slots.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

import jax

from repro.configs import ARCHS, smoke_config
from repro.models import get_model
from repro.serve.engine import Request, ServeEngine

cfg = smoke_config(ARCHS["qwen2-7b"]).scaled(d_model=128, n_layers=4)
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0))

engine = ServeEngine(api, params, slots=4, max_len=96, temperature=0.0)
rng = np.random.default_rng(0)
for rid in range(10):
    prompt = rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12),
                          dtype=np.int32)
    engine.submit(Request(rid=rid, prompt=prompt, max_new_tokens=8))

results = engine.run_to_completion()
for rid in sorted(results):
    print(f"request {rid}: {results[rid]}")
assert len(results) == 10
print("serve_lm complete ✓ (10 requests, 4 slots, continuous batching)")
