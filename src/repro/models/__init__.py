from .registry import ModelApi, get_model  # noqa: F401
