"""Decoder-only transformer LM (dense GQA + optional MoE FFN).

Layers are stacked (leading L dim) and applied with ``jax.lax.scan`` to
keep the HLO size mesh-compile friendly; ``cfg.remat`` wraps the layer in
``jax.checkpoint``. Covers families: dense, moe, and the text towers of
vlm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import decode_attention, flash_attention
from .layers import (apply_dense, apply_mlp, apply_norm, apply_rope,
                     embed, init_dense, init_embedding,
                     init_mlp, init_norm, layer_scan, lm_loss_from_features,
                     rmsnorm, seq_shard, seq_unshard, unembed)
from .moe import apply_moe, init_moe

AUX_WEIGHT = 0.01


# ------------------------------------------------------------------ init


def init_attn(cfg, key):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": init_dense(kq, d, cfg.attn_dim, cfg.param_dtype, bias=cfg.qkv_bias),
        "wk": init_dense(kk, d, cfg.kv_dim, cfg.param_dtype, bias=cfg.qkv_bias),
        "wv": init_dense(kv, d, cfg.kv_dim, cfg.param_dtype, bias=cfg.qkv_bias),
        "wo": init_dense(ko, cfg.attn_dim, d, cfg.param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.d_head,), cfg.param_dtype)
        p["k_norm"] = jnp.zeros((cfg.d_head,), cfg.param_dtype)
    return p


def init_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": init_norm(cfg, cfg.d_model),
        "attn": init_attn(cfg, k1),
        "ln2": init_norm(cfg, cfg.d_model),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(cfg, k2)
    else:
        p["mlp"] = init_mlp(cfg, k2)
    return p


def init_params(cfg, key):
    ke, kl, kf = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(cfg, k))(layer_keys)
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                cfg.param_dtype),
        "layers": layers,
        "final_norm": init_norm(cfg, cfg.d_model),
    }


# -------------------------------------------------------------- forward


def _qkv(cfg, p, x, positions):
    b, s, _ = x.shape
    q = apply_dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = apply_dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = apply_dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(cfg, p, x, positions, causal=True):
    q, k, v = _qkv(cfg, p, x, positions)
    if cfg.seq_parallel_attn:
        # q stays seq-sharded (local S/tp rows per chip); K/V all-gather
        # over 'model' (small GQA tensors). q_chunk = full seq so the
        # chunk reshape never crosses the shard layout.
        q = seq_shard(cfg, q)
        k = seq_unshard(cfg, k)
        v = seq_unshard(cfg, v)
        q_chunk = q.shape[1]
    else:
        q_chunk = cfg.q_chunk
    o = flash_attention(q, k, v, causal, q_chunk, cfg.kv_chunk)
    b, s, _, _ = o.shape
    return apply_dense(p["wo"], o.reshape(b, s, cfg.attn_dim)), (k, v)


def ffn_block(cfg, p, x, ctx=None):
    if cfg.family == "moe":
        b, s, d = x.shape
        out, aux = apply_moe(cfg, p["moe"], x.reshape(b * s, d), ctx)
        return out.reshape(b, s, d), aux
    return apply_mlp(cfg, p["mlp"], x), jnp.zeros((), jnp.float32)


def layer_fwd(cfg, p, x, positions, ctx=None):
    x = seq_shard(cfg, x)  # pin the residual stream (no-op unless SP)
    a, _ = attn_block(cfg, p["attn"], apply_norm(cfg, p["ln1"], x), positions)
    x = seq_shard(cfg, x + a)
    f, aux = ffn_block(cfg, p, apply_norm(cfg, p["ln2"], x), ctx)
    return seq_shard(cfg, x + f), aux


def forward_features(cfg, params, tokens, ctx=None, inputs_embeds=None):
    """tokens (B, S) -> (final features (B, S, D), aux loss)."""
    x = embed(params["embed"], tokens) if inputs_embeds is None else inputs_embeds
    x = x.astype(cfg.compute_dtype)
    # under SP: pin the embedding output (and its cotangent) unsharded —
    # the table-scatter vjp miscomputes with a seq-sharded cotangent
    # (XLA SPMD uneven/masked scatter issue); layers reshard right after.
    x = seq_unshard(cfg, x)
    positions = jnp.arange(x.shape[1])

    # ctx is closure-bound (not a positional arg): jax.checkpoint treats
    # positional args as arrays to differentiate through.
    def layer(p_l, x, positions):
        return layer_fwd(cfg, p_l, x, positions, ctx)

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    def step(x, p_l):
        x, aux = layer(p_l, x, positions)
        return x, aux

    x, auxs = layer_scan(cfg, step, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    # under SP, hand the loss head an unsharded-seq tensor: the uneven
    # x[:, :-1] slice of a seq-sharded dim miscomputes the embed grad
    # (XLA SPMD uneven-shard scatter); one (B,S,D) all-gather is cheap.
    x = seq_unshard(cfg, x)
    return x, jnp.sum(auxs)


def forward(cfg, params, tokens, ctx=None, inputs_embeds=None):
    """tokens (B, S) -> logits (B, S, V)."""
    x, aux = forward_features(cfg, params, tokens, ctx, inputs_embeds)
    return unembed(params["embed"], x), aux


def loss_fn(cfg, params, batch, ctx=None):
    x, aux = forward_features(cfg, params, batch["tokens"], ctx)
    loss = lm_loss_from_features(params["embed"], x[:, :-1],
                                 batch["tokens"][:, 1:], batch.get("mask"))
    return loss + AUX_WEIGHT * aux


# --------------------------------------------------------------- serving


def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(cfg, params, tokens, max_len, ctx=None, inputs_embeds=None):
    """Run the full prompt, return (last-token logits, populated cache)."""
    x = (embed(params["embed"], tokens)
         if inputs_embeds is None else inputs_embeds)
    x = x.astype(cfg.compute_dtype)
    b, s = x.shape[:2]
    positions = jnp.arange(s)

    def step(x, p_l):
        a, (k, v) = attn_block(cfg, p_l["attn"],
                               apply_norm(cfg, p_l["ln1"], x), positions)
        x = x + a
        f, _ = ffn_block(cfg, p_l, apply_norm(cfg, p_l["ln2"], x), ctx)
        return x + f, (k, v)

    x, (ks, vs) = layer_scan(cfg, step, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x[:, -1])
    pad = max_len - s
    cache = {
        "k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "pos": jnp.asarray(s, jnp.int32),
    }
    return logits, cache


def decode_step(cfg, params, cache, tokens, ctx=None):
    """One decode step. tokens (B,) int32; cache from init_cache/prefill.
    Returns (logits (B, V), new cache)."""
    if cfg.decode_inplace_cache:
        return _decode_step_inplace(cfg, params, cache, tokens, ctx)
    pos = cache["pos"]
    x = embed(params["embed"], tokens)[:, None, :].astype(cfg.compute_dtype)
    positions = pos[None, None].astype(jnp.float32) + jnp.zeros(
        (x.shape[0], 1), jnp.float32)

    def step(x, inp):
        p_l, k_c, v_c = inp
        h = apply_norm(cfg, p_l["ln1"], x)
        q, k, v = _qkv(cfg, p_l["attn"], h, positions)
        k_c = jax.lax.dynamic_update_slice(k_c, k, (0, pos, 0, 0))
        v_c = jax.lax.dynamic_update_slice(v_c, v, (0, pos, 0, 0))
        o = decode_attention(q[:, 0], k_c, v_c, pos)
        a = apply_dense(p_l["attn"]["wo"],
                        o.reshape(x.shape[0], 1, cfg.attn_dim)[:, 0])
        x = x + a[:, None, :]
        f, _ = ffn_block(cfg, p_l, apply_norm(cfg, p_l["ln2"], x), ctx)
        return x + f, (k_c, v_c)

    x, (ks, vs) = layer_scan(cfg, step, x, (params["layers"], cache["k"],
                                            cache["v"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x[:, 0])
    return logits, {"k": ks, "v": vs, "pos": pos + 1}


def _tree_index(tree, i):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
        tree)


def _decode_step_inplace(cfg, params, cache, tokens, ctx=None):
    """Decode with the stacked caches as fori_loop carry updated via
    dynamic-update-slice — XLA forwards the buffer in place instead of
    double-buffering a second full cache through scan ys."""
    pos = cache["pos"]
    b = tokens.shape[0]
    x = embed(params["embed"], tokens)[:, None, :].astype(cfg.compute_dtype)
    positions = pos[None, None].astype(jnp.float32) + jnp.zeros(
        (b, 1), jnp.float32)

    def body(l, carry):
        x, kc, vc = carry
        p_l = _tree_index(params["layers"], l)
        h = apply_norm(cfg, p_l["ln1"], x)
        q, k, v = _qkv(cfg, p_l["attn"], h, positions)
        kc = jax.lax.dynamic_update_slice(kc, k[None].astype(kc.dtype),
                                          (l, 0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v[None].astype(vc.dtype),
                                          (l, 0, pos, 0, 0))
        kl = jax.lax.dynamic_index_in_dim(kc, l, 0, keepdims=False)
        vl = jax.lax.dynamic_index_in_dim(vc, l, 0, keepdims=False)
        o = decode_attention(q[:, 0], kl, vl, pos)
        a = apply_dense(p_l["attn"]["wo"], o.reshape(b, cfg.attn_dim))
        x = x + a[:, None, :]
        f, _ = ffn_block(cfg, p_l, apply_norm(cfg, p_l["ln2"], x), ctx)
        return (x + f, kc, vc)

    x, kc, vc = jax.lax.fori_loop(
        0, cfg.n_layers, body, (x, cache["k"], cache["v"]),
        unroll=cfg.n_layers if cfg.scan_unroll else 1)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x[:, 0])
    return logits, {"k": kc, "v": vc, "pos": pos + 1}
