"""Atomic parallelism — the paper's design-space model (Sgap §3).

An SpMM algorithm point is ``{<x sparse-work, c col>, r}``:

* ``split``      what the sparse-work unit is: ``nnz`` or ``row``;
* ``x``          minimal sparse data per thread: ``g`` units, ``1`` unit, or
                 ``1/g`` of a unit (g threads collaborate on one unit);
* ``c``          minimal dense columns per thread (coarsen factor);
* ``r``          reduction parallelism — how many threads synchronize per
                 reduction step (the paper's group size).

Legality rules (paper §3.3, Fig. 8):

1. ``<1/g nnz, ...>`` and ``<..., 1/c col>`` with nnz split are illegal: a
   non-zero must be multiplied by at least one whole dense element.
2. ``{<1/g row, x col>, r}`` with ``r < g`` is illegal: parallel reduction
   has a single writeback thread, so the sync width must cover the row
   group.
3. ``<1/g row, 1/c col>`` is illegal: resource parallelism may multiply
   only one element of the atomic parallelism.

The mapping to TPU kernel schedules is in :func:`to_schedule` — see
DESIGN.md §2/§3 for the semantics of each field on TPU.

DA-SpMM's space embeds as:
    EB+PR = {<1 nnz, c col>, 32}     EB+SR = {<32 nnz, c col>, 1}
    RB+PR = {<1/32 row, c col>, 32}  RB+SR = {<1 row, c col>, 1}
"""
from __future__ import annotations

import dataclasses
import itertools
from fractions import Fraction
from typing import Iterable, List

__all__ = [
    "AtomicParallelism",
    "KernelSchedule",
    "is_legal",
    "enumerate_space",
    "to_schedule",
    "DA_SPMM_POINTS",
]

REDUCTION_PARALLELISMS = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass(frozen=True)
class AtomicParallelism:
    """One point ``{<x split, c col>, r}`` in the design space."""

    split: str  # 'nnz' | 'row'
    x: Fraction  # minimal sparse data: Fraction(g), Fraction(1), Fraction(1, g)
    c: int  # dense columns per thread (>= 1)
    r: int  # reduction parallelism

    def __post_init__(self):
        if self.split not in ("nnz", "row"):
            raise ValueError(f"split must be 'nnz' or 'row', got {self.split}")
        object.__setattr__(self, "x", Fraction(self.x))
        if self.c < 1:
            raise ValueError("fractional dense columns are expressed via "
                             "split='row' collaboration, not c < 1")

    def __str__(self):
        return f"{{<{self.x} {self.split}, {self.c} col>, {self.r}}}"


def is_legal(p: AtomicParallelism) -> bool:
    # Rule 1: no fractional nnz.
    if p.split == "nnz" and p.x < 1:
        return False
    # Rule 2: row collaboration (1/g row) forces parallel reduction whose
    # sync width must cover the g collaborators.
    if p.split == "row" and p.x < 1 and p.r < 1 / p.x:
        return False
    # Rule 3 is structurally unrepresentable here (c >= 1 enforced), kept
    # for documentation parity with the paper.
    if p.r not in REDUCTION_PARALLELISMS:
        return False
    return True


def enumerate_space(
    g_values: Iterable[int] = (1, 2, 4, 8, 16, 32),
    c_values: Iterable[int] = (1, 2, 4, 8),
    r_values: Iterable[int] = REDUCTION_PARALLELISMS,
) -> List[AtomicParallelism]:
    """All legal points over the given tunable ranges (deduplicated)."""
    xs = set()
    for g in g_values:
        xs.add(Fraction(g))
        xs.add(Fraction(1, g))
    points = set()
    for split, x, c, r in itertools.product(("nnz", "row"), xs, c_values, r_values):
        p = AtomicParallelism(split, x, c, r)
        if is_legal(p):
            points.add(p)
    return sorted(points, key=lambda p: (p.split, p.x, p.c, p.r))


# The four DA-SpMM algorithms (paper §3.3), row-major variants.
DA_SPMM_POINTS = {
    "EB+PR": AtomicParallelism("nnz", Fraction(1), 4, 32),
    "EB+SR": AtomicParallelism("nnz", Fraction(32), 4, 1),
    "RB+PR": AtomicParallelism("row", Fraction(1, 32), 4, 32),
    "RB+SR": AtomicParallelism("row", Fraction(1), 4, 1),
}


@dataclasses.dataclass(frozen=True)
class KernelSchedule:
    """TPU-side realization of an atomic-parallelism point.

    kernel      'eb' (nnz-split, segment strategy) or 'rb' (row-split,
                parallel strategy).
    nnz_tile    nnz per grid cell ('eb').
    row_tile    rows per grid cell ('rb').
    col_tile    dense columns per grid cell (coarsen × lane width).
    group_size  segment-group width G — sub-tile one-hot reduce width
                ('eb'); vestigial for 'rb' (single writeback per row).
    strategy    'segment' | 'parallel' | 'accumulate'.
    """

    kernel: str
    nnz_tile: int = 256
    row_tile: int = 8
    col_tile: int = 128
    group_size: int = 32
    strategy: str = "segment"

    def __post_init__(self):
        if self.kernel not in ("eb", "rb"):
            raise ValueError(self.kernel)
        if self.strategy not in ("segment", "parallel", "accumulate"):
            raise ValueError(self.strategy)
        if self.kernel == "eb" and self.nnz_tile % self.group_size != 0:
            raise ValueError("nnz_tile must be a multiple of group_size")


def to_schedule(
    p: AtomicParallelism,
    *,
    lane_width: int = 128,
    base_nnz_tile: int = 256,
    base_row_tile: int = 8,
) -> KernelSchedule:
    """Map a design-space point to a concrete TPU kernel schedule.

    GPU threads disappear on TPU; what survives is (a) how much sparse work
    a grid cell owns, (b) the reduction granularity G inside the cell, and
    (c) the dense-column tile. ``x = g nnz`` scales the nnz tile; ``x = 1/g
    row`` means g-wide collaboration on a row, which on TPU is simply the
    row-split kernel (whole rows per cell, MXU does the intra-row
    reduction). ``r`` becomes the segment-group width for nnz-split.
    """
    col_tile = max(lane_width, p.c * lane_width // 4)
    if p.split == "nnz":
        g = int(p.x) if p.x >= 1 else 1
        nnz_tile = base_nnz_tile * max(1, g // 8)
        group = p.r if p.r > 1 else min(32, nnz_tile)
        strategy = "segment" if p.r > 1 else "accumulate"
        # group must divide nnz_tile
        while nnz_tile % group:
            group //= 2
        return KernelSchedule(
            kernel="eb", nnz_tile=nnz_tile, col_tile=col_tile,
            group_size=max(group, 1), strategy=strategy,
        )
    else:
        if p.x >= 1:
            row_tile = base_row_tile * int(p.x)
        else:
            # 1/g row: g-wide collaboration -> narrower row tile, wider
            # reduce; on TPU both land in the same row-split kernel.
            row_tile = base_row_tile
        return KernelSchedule(
            kernel="rb", row_tile=row_tile, col_tile=col_tile,
            group_size=p.r, strategy="parallel",
        )
