"""Segment group — the paper's new compiler abstraction (Sgap §4/§5).

A *segment group* separates the two roles the GPU warp used to conflate:

* tiling semantics   -> on TPU: the Pallas grid / BlockSpec decomposition;
* synchronization    -> on TPU: the width-G one-hot reduce inside a tile
  semantics             plus the writeback strategy.

Built-in strategies (each a registered :class:`~.schedule.ReductionStrategy`;
users add their own with ``repro.core.register_strategy``):

SEGMENT     multiple writeback lanes per group, decided at runtime by the
            segment ids (the paper's segment reduction). TPU realization:
            one-hot matmul ``Sᵀ·P`` over each G-wide group, then carry
            accumulation across group boundaries.
PARALLEL    exactly one writeback lane per group; all lanes share one
            segment (the paper's parallel reduction). TPU realization: a
            plain within-group sum (MXU row reduce).
ACCUMULATE  no intra-group combine; every lane writes back with ``+=``
            (the paper's atomicAdd). TPU realization: scatter-add — legal
            because the TPU grid is sequential; across cores it becomes a
            psum. Used as the correctness fallback.

The ``spec_*`` functions here are the *pure-JAX executable specification*
of each strategy — the oracle any kernel realization is tested against.
``segment_group_reduce`` dispatches through the strategy registry
(``core.schedule``), so user-registered strategies run through the same
spec path; ``repro.kernels.common.group_reduce_scatter`` is the Pallas
dispatcher over the same registry.
"""
from __future__ import annotations

import dataclasses
import enum
from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "GroupReduceStrategy",
    "SegmentGroup",
    "segment_group_reduce",
    "segment_sum_ref",
    "spec_accumulate",
    "spec_parallel",
    "spec_segment",
    "group_writeback_counts",
    "group_waste_fraction",
]


class GroupReduceStrategy(enum.Enum):
    SEGMENT = "segment"
    PARALLEL = "parallel"
    ACCUMULATE = "accumulate"


@dataclasses.dataclass(frozen=True)
class SegmentGroup:
    """User-facing reduction handle: ``parallelize(j, GPUGroup, r, strategy)``
    in the paper's CIN becomes ``SegmentGroup(group_size=r, strategy=...)``
    here.  ``strategy`` is a :class:`GroupReduceStrategy` or the name of
    any registered strategy; lift into a full :class:`~.schedule.Schedule`
    with ``Schedule.from_group``."""

    group_size: int = 32
    strategy: "GroupReduceStrategy | str" = GroupReduceStrategy.SEGMENT

    def __post_init__(self):
        if self.group_size < 1:
            raise ValueError("group_size must be >= 1")
        if isinstance(self.strategy, str):
            try:
                object.__setattr__(self, "strategy",
                                   GroupReduceStrategy(self.strategy))
            except ValueError:
                pass  # user-registered strategy: keep the name


def segment_sum_ref(partials: jax.Array, seg_ids: jax.Array, num_segments: int) -> jax.Array:
    """Ground-truth oracle: plain segment sum (strategy-independent math)."""
    return jax.ops.segment_sum(partials, seg_ids, num_segments=num_segments)


# ---------------------------------------------------------------------------
# Per-strategy executable specs.  Common signature (the registry contract):
#     spec(partials (T, C), seg_ids (T,), num_segments, group_size) -> (S, C)
# ---------------------------------------------------------------------------


def spec_accumulate(partials, seg_ids, num_segments, group_size):
    """ACCUMULATE: no intra-group combine; per-lane '+=' writeback."""
    del group_size
    return segment_sum_ref(partials, seg_ids, num_segments)


def spec_parallel(partials, seg_ids, num_segments, group_size):
    """PARALLEL: one writeback lane per group.  *Asserts* (by construction)
    the single-writeback contract: every lane in a group must share the
    group's first segment id — lanes violating it are dropped, mirroring
    the GPU kernel where they would simply never be accumulated by the one
    writeback thread."""
    T, C = partials.shape
    G = group_size
    n_groups = T // G
    gp = partials.reshape(n_groups, G, C)
    gs = seg_ids.reshape(n_groups, G)
    leader = gs[:, :1]  # single writeback segment per group
    mask = (gs == leader).astype(partials.dtype)[..., None]
    group_tot = jnp.sum(gp * mask, axis=1)  # (n_groups, C)
    return jax.ops.segment_sum(group_tot, leader[:, 0],
                               num_segments=num_segments)


def spec_segment(partials, seg_ids, num_segments, group_size):
    """SEGMENT: per-group one-hot reduce (what the Pallas kernel does on
    the MXU), then cross-group carry accumulation.  Local segment ids are
    offsets from the group's first segment, clamped into [0, G): with
    non-decreasing seg_ids a group of G lanes spans at most G distinct
    segments, but sparse matrices can skip ids, so lanes whose offset
    overflows the local window fall back to accumulate-writeback."""
    T, C = partials.shape
    G = group_size
    n_groups = T // G
    gp = partials.reshape(n_groups, G, C)
    gs = seg_ids.reshape(n_groups, G)
    first = gs[:, :1]
    local = gs - first  # (n_groups, G) >= 0
    in_window = local < G
    local_c = jnp.clip(local, 0, G - 1)
    onehot = jax.nn.one_hot(local_c, G, dtype=partials.dtype)
    onehot = onehot * in_window[..., None].astype(partials.dtype)
    seg_tot = jnp.einsum("ngs,ngc->nsc", onehot, gp)  # (n_groups, G, C)
    # writeback: local slot s of group n targets global segment first[n]+s
    targets = jnp.clip(first + jnp.arange(G)[None, :], 0, num_segments - 1)
    out = jax.ops.segment_sum(
        seg_tot.reshape(-1, C), targets.reshape(-1), num_segments=num_segments
    )
    # overflow lanes (rare: segment-id jumps > G inside one group)
    ov_mask = (~in_window).astype(partials.dtype)[..., None]
    ov = jax.ops.segment_sum(
        (gp * ov_mask).reshape(-1, C),
        jnp.clip(gs, 0, num_segments - 1).reshape(-1),
        num_segments=num_segments,
    )
    return out + ov


@partial(jax.jit, static_argnames=("num_segments", "group_size", "entry"))
def _dispatch_spec(partials, seg_ids, *, num_segments, group_size, entry):
    return entry.spec_fn(partials, seg_ids, num_segments, group_size)


def segment_group_reduce(
    partials: jax.Array,  # (T, C) per-lane partial results
    seg_ids: jax.Array,  # (T,) int32 non-decreasing segment ids
    num_segments: int,
    group_size: int = 32,
    strategy: "GroupReduceStrategy | str" = GroupReduceStrategy.SEGMENT,
) -> jax.Array:
    """Executable spec of grouped reduction with explicit group structure.

    ``strategy`` may be a :class:`GroupReduceStrategy`, the name of any
    registered strategy, or a registry entry; dispatch goes through the
    strategy registry, so user strategies registered with
    ``repro.core.register_strategy`` run here unchanged.  Mathematically
    equals ``segment_sum`` for SEGMENT/ACCUMULATE; see the per-strategy
    ``spec_*`` docstrings for the contracts.
    """
    from .schedule import get_strategy

    T = partials.shape[0]
    if T % group_size:
        raise ValueError(f"T={T} not a multiple of group_size={group_size}")
    entry = get_strategy(strategy)
    return _dispatch_spec(partials, seg_ids, num_segments=num_segments,
                          group_size=group_size, entry=entry)


def group_writeback_counts(seg_ids, group_size: int):
    """Analytic model input: distinct segments per group = number of
    writebacks a SEGMENT-strategy group performs. Drives the selector's
    napkin math and the Table-1/2 benchmarks."""
    T = seg_ids.shape[0]
    G = group_size
    gs = seg_ids.reshape(T // G, G)
    changes = jnp.concatenate(
        [jnp.ones((gs.shape[0], 1), jnp.int32),
         (gs[:, 1:] != gs[:, :-1]).astype(jnp.int32)], axis=1)
    return jnp.sum(changes, axis=1)


def group_waste_fraction(row_lengths, group_size: int) -> float:
    """Paper challenge (1): fraction of lanes wasted when rows shorter than
    the group still occupy a full group (zero-extension padding waste)."""
    import numpy as np

    lengths = np.asarray(row_lengths)
    lengths = lengths[lengths > 0]
    if lengths.size == 0:
        return 0.0
    padded = group_size * np.ceil(lengths / group_size)
    return float(1.0 - lengths.sum() / padded.sum())
