"""Sharding rules: logical param/activation axes -> mesh axes.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod. Policy (Megatron-style TP + DP, see DESIGN.md §5):

* attention qkv projections column-parallel, output row-parallel on
  ``model``;
* MLP wi/wg column-, wo row-parallel on ``model``;
* MoE experts expert-parallel on ``model`` (E dim);
* mamba in/out projections row-parallel on ``model`` (contraction dim);
* embeddings vocab-sharded on ``model`` when divisible, else replicated
  (mamba2 50280 / hymba 32001 / whisper 51866 are not 16-divisible);
* norms / scalars replicated;
* batch over ``(pod, data)``; decode KV caches shard *sequence* over
  ``model`` (online-softmax combines become small all-reduces);
* any proposed sharded dim that does not divide its mesh axis falls back
  to replication for that dim (logged by the dry-run).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fit(mesh, spec: P, shape) -> P:
    """Drop sharding on dims that don't divide the assigned axis size."""
    fixed = []
    for dim, axes in enumerate(spec):
        if axes is None:
            fixed.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax_tuple:
            size *= mesh.shape[a]
        fixed.append(axes if shape[dim] % size == 0 else None)
    return P(*fixed)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


DATA = "__data__"  # sentinel resolved to the mesh's data axes


def _param_spec(path: str, ndim: int) -> P:
    """Logical rule table. Layer params carry a leading stacked-L dim, so
    rules address the trailing dims and we left-pad with None."""

    def pad(spec_tail):
        return P(*([None] * (ndim - len(spec_tail)) + list(spec_tail)))

    if path.endswith("embed"):
        return pad([MODEL_AXIS, None])
    if "router" in path:
        return pad([None, None])
    # MoE experts: (E, D, F) / (E, F, D)
    if any(f"moe/{n}" in path for n in ("wg", "wi", "wo")):
        return pad([MODEL_AXIS, None, None])
    # Attention: replicated over model at baseline (no assigned arch has
    # 16-divisible kv heads; partial head sharding makes GSPMD all-reduce
    # the score tensors — measured 22 GB/layer on qwen2). The weights are
    # FSDP-sharded over the data axes (d_model dim) so the 33B dense
    # models fit HBM; XLA inserts the per-layer all-gather. Seq-parallel
    # attention is the §Perf hillclimb.
    if "attn/" in path:
        if path.endswith("/w"):
            return pad([DATA, None])
        return P(*([None] * ndim))
    # MLP projections (bare kernels, no bias sub-dict)
    if path.endswith(("wi", "wg")):
        return pad([None, MODEL_AXIS])
    if path.endswith("wo"):
        return pad([MODEL_AXIS, None])
    # mamba mixer (split projections; the Mamba-2 TP scheme)
    if path.endswith(("z_proj", "x_proj")):
        return pad([None, MODEL_AXIS])
    if path.endswith("dt_proj"):
        return pad([None, MODEL_AXIS])  # H dim; dropped when indivisible
    if path.endswith("bc_proj"):
        return P(*([None] * ndim))
    if path.endswith(("conv_x_w",)):
        return pad([None, MODEL_AXIS])
    if path.endswith(("conv_x_b",)):
        return pad([MODEL_AXIS])
    if "mixer" in path and path.endswith("norm"):
        return pad([MODEL_AXIS])
    if path.endswith(("A_log", "D", "dt_bias")):
        return pad([MODEL_AXIS])
    if path.endswith("out_proj"):
        return pad([MODEL_AXIS, None])
    # everything else (norms, conv_bc, betas): replicated
    return P(*([None] * ndim))


def param_shardings(mesh, params_shape):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""
    dp = data_axes(mesh)

    def rule(path, leaf):
        spec = _param_spec(_path_str(path), len(leaf.shape))
        spec = P(*[dp if a == DATA else a for a in spec])
        return NamedSharding(mesh, _fit(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_shardings(mesh, specs: dict):
    dp = data_axes(mesh)
    out = {}
    for name, leaf in specs.items():
        spec = P(dp, *([None] * (len(leaf.shape) - 1)))
        out[name] = NamedSharding(mesh, _fit(mesh, spec, leaf.shape))
    return out


def cache_shardings(mesh, cfg, cache_shape):
    """Serve-cache shardings. KV caches (L, B, S, K, dh): batch over data
    axes, sequence over model. SSM state (L, B, H, N, P): heads over model.
    Cross-attn caches (L, B, 1500, K, dh): head_dim over model (1500 and
    K=20 don't divide 16). Conv state: channel over model."""
    dp = data_axes(mesh)

    def rule(path, leaf):
        name = _path_str(path)
        nd = len(leaf.shape)
        if name.endswith("pos"):
            spec = P()
        elif name in ("k", "v"):
            spec = P(None, dp, MODEL_AXIS, None, None)
        elif name in ("ck", "cv"):
            spec = P(None, dp, None, None, MODEL_AXIS)
        elif "ssm" in name:
            spec = P(*([None, dp, MODEL_AXIS, None, None][:nd]))
        elif "conv" in name:
            spec = P(*([None, dp, None, MODEL_AXIS][:nd]))
        else:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, _fit(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def replicated(mesh):
    return NamedSharding(mesh, P())
