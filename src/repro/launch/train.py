"""Training launcher.

Real-hardware entry point (also runs on CPU at reduced scale):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b \
        [--scale smoke] [--steps 100] [--ckpt-dir /tmp/ckpt] \
        [--microbatches 8] [--compress bf16]

``--scale smoke`` runs the reduced same-family config (CPU-friendly);
``--scale full`` builds the exact assigned config (needs a real pod —
on CPU it will OOM, use the dry-run instead).
"""
from __future__ import annotations

import argparse

import jax

from ..configs import ARCHS, smoke_config
from ..data.synthetic import ShardedTokenStream
from ..models import get_model
from ..train.optimizer import AdamW, cosine_schedule
from ..train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=sorted(ARCHS))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", default=None, choices=[None, "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.scale == "smoke":
        cfg = smoke_config(cfg)
    api = get_model(cfg)

    data = ShardedTokenStream(cfg.vocab_size, args.seq, args.batch,
                              host_index=jax.process_index(),
                              host_count=jax.process_count())
    opt = AdamW(lr=cosine_schedule(args.lr, warmup=min(100, args.steps // 10
                                                       or 1),
                                   total=args.steps))
    trainer = Trainer(
        api, opt, iter(data), ckpt_dir=args.ckpt_dir,
        tcfg=TrainerConfig(total_steps=args.steps,
                           ckpt_every=args.ckpt_every,
                           microbatches=args.microbatches,
                           grad_compression=args.compress))
    state = trainer.init_or_restore(jax.random.PRNGKey(0))
    trainer.run(state)


if __name__ == "__main__":
    main()
