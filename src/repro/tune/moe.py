"""Empirical tuning of the MoE grouped-matmul dispatch (ISSUE 3).

MoE expert dispatch *is* the paper's DF formulation (sparse routing ⊗
expert GEMM ⊕ segment-sum), so its schedule — token tile, per-expert
capacity, and the GEMM's (f_tile, d_tile) blocking — gets the same
empirical treatment ``tune.search`` gives CSR SpMM:

* the workload fingerprint is the **expert-segment histogram** (how many
  routed tokens each expert received), pushed through the same quantile
  machinery as row lengths (:func:`~.cache.fingerprint_from_lengths`) and
  keyed by ``(n_experts, total routed tokens, histogram quantiles,
  d_model, d_ff, dtype)``;
* the search space is ``token_tile × capacity_factor × f_tile × d_tile``
  with a cost-model warm start, top-k measurement (the static default is
  always in the measured pool, so the tuned point can never lose to it),
  and a ×2 / ÷2 hillclimb — mirroring ``search.tune_schedule``;
* ``capacity_factor`` candidates are **drop-constrained**: a factor that
  would drop more routed tokens than the default does on *this*
  histogram is never offered, so tuning trades time only, never routing
  quality.  Assumed (non-observed) histograms withhold shrinking
  entirely and key a separate cache record (``|ns`` suffix), so the two
  regimes never replay each other's winners;
* winners persist in the same per-backend namespace cache
  (:mod:`~.cache`) under ``moe:``-prefixed keys;
  :func:`moe_cached_or_default` is the measurement-free serving resolver.

The measurement objective is a jitted pure-JAX analogue of
``kernels.grouped_matmul``'s blocking (capacity-gathered tokens →
blocked d→f GEMM → silu → blocked f→d GEMM): XLA compiles a genuinely
different program per (token_tile, f_tile, d_tile, capacity) point, the
same instrument philosophy as ``tune.measure``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from ..kernels.grouped_matmul import fit_tile as _fit_tile
from ..sparse.formats import round_up as _round_up
from .cache import (
    ScheduleCache,
    default_cache,
    fingerprint_from_lengths,
)
from .driver import TuneResult, _replay, drive
from .measure import time_fn
from .space import CapacityAxis, MoeTilingAxis, SearchContext, SearchSpace

__all__ = [
    "CAPACITY_FACTORS",
    "MoeDispatchSchedule",
    "dropped_tokens",
    "make_moe_runner",
    "measure_moe_dispatch",
    "moe_cache_key",
    "moe_cached_or_default",
    "moe_capacity",
    "moe_cost",
    "moe_schedule_key",
    "tune_moe_dispatch",
]

_TILES = (32, 64, 128, 256)
CAPACITY_FACTORS = (1.0, 1.25, 1.5, 2.0)


@dataclasses.dataclass(frozen=True)
class MoeDispatchSchedule:
    """One point of the MoE dispatch schedule space.

    token_tile       tokens per grid cell of the grouped matmul (each
                     tile belongs to exactly one expert).
    capacity_factor  per-expert capacity multiplier (capacity =
                     mean routed tokens per expert × factor).
    f_tile, d_tile   GEMM blocking of the expert weight (D, F) axes.
    collective       expert-parallel writeback mode (DESIGN.md §12):
                     ``None`` keeps the deployment default ('nnz_ar'),
                     'nnz_ar' all-reduces the partial token block
                     (atomic-style psum), 'nnz_rs' reduce-scatters it so
                     each model shard finalizes a token slice.  'row'
                     has no expert-parallel analogue — every expert's
                     partial covers all local tokens, so a combine is
                     mandatory.
    """

    token_tile: int = 128
    capacity_factor: float = 1.25
    f_tile: int = 128
    d_tile: int = 128
    collective: Optional[str] = None

    def __post_init__(self):
        for name in ("token_tile", "f_tile", "d_tile"):
            v = getattr(self, name)
            if not (isinstance(v, int) and v >= 8):
                raise ValueError(f"{name} must be an int >= 8, got {v!r}")
        if not self.capacity_factor > 0:
            raise ValueError("capacity_factor must be positive, "
                             f"got {self.capacity_factor!r}")
        if self.collective not in (None, "nnz_ar", "nnz_rs"):
            raise ValueError(
                f"unknown collective {self.collective!r}; MoE dispatch "
                "knows 'nnz_ar', 'nnz_rs' (or None for the default)")

    def replace(self, **kw) -> "MoeDispatchSchedule":
        """Copy with the given fields replaced (re-validates)."""
        return dataclasses.replace(self, **kw)


def moe_schedule_key(s: MoeDispatchSchedule) -> str:
    """Stable string identity of a dispatch point (JSON-safe dict key).
    The collective mode is part of the identity — the same GEMM tiling
    under psum and psum_scatter are different SPMD programs."""
    wire = "" if s.collective is None else f":w[{s.collective}]"
    return (f"moe:tt{s.token_tile}:cf{s.capacity_factor:g}"
            f":f{s.f_tile}:d{s.d_tile}{wire}")


def moe_cache_key(expert_lengths, d_model: int, d_ff: int,
                  dtype: str = "float32", *, shrink: bool = True,
                  max_tokens: Optional[int] = None) -> str:
    """Cache key of a dispatch workload: the expert-segment histogram
    fingerprint (n_experts × total routed tokens × quantiles × CV) plus
    the GEMM dims and dtype.  Backend lives in the cache namespace, not
    the key.  ``shrink=False`` (assumed-histogram tuning, where capacity
    shrinking is withheld) keys a *separate* record: the two regimes
    search different spaces, so a shrunk winner cached from observed
    routing must never replay for an assumed-histogram caller (and an
    assumed no-shrink winner must not block an observed tune).
    ``max_tokens`` — the deployed capacity clamp — is part of the key
    too: identical histograms under different token budgets measure
    different programs and must not share a record."""
    lengths = np.asarray(expert_lengths)
    fp = fingerprint_from_lengths(lengths, (int(lengths.shape[0]), d_model),
                                  int(lengths.sum()))
    tok = f"|T{int(max_tokens)}" if max_tokens is not None else ""
    ns = "" if shrink else "|ns"
    return f"moe:{fp}|F{int(d_ff)}|{dtype}{tok}{ns}"


# ---------------------------------------------------------------------------
# Capacity / cost model
# ---------------------------------------------------------------------------


def moe_capacity(expert_lengths, capacity_factor: float, *,
                 max_tokens: Optional[int] = None) -> int:
    """Per-expert capacity implied by a factor on this histogram: mean
    routed tokens per expert × factor, floored at 8 (mirrors
    ``models.moe._capacity``).  ``max_tokens`` is the deployed upper
    clamp — the local token count ``_capacity`` caps at; without it the
    total routed-assignment count stands in (a looser bound that only
    differs when ``experts_per_token × factor > n_experts``)."""
    lengths = np.asarray(expert_lengths, np.float64)
    e = max(int(lengths.shape[0]), 1)
    cap = int(float(lengths.sum()) * capacity_factor / e)
    upper = int(max_tokens) if max_tokens is not None else int(lengths.sum())
    return min(max(8, cap), max(upper, 8))


def dropped_tokens(expert_lengths, capacity: int) -> int:
    """Routed tokens that do not fit their expert's capacity (the
    routing-quality price of a small capacity factor)."""
    lengths = np.asarray(expert_lengths, np.int64)
    return int(np.maximum(lengths - capacity, 0).sum())




def _token_tiling(capacity: int, token_tile: int) -> tuple:
    """``(tile, cap_pad)`` exactly as the deployed dispatch computes it
    (``models.moe._expert_ffn``): the tile is clamped to the capacity and
    the capacity is padded *up* to the tile — so the cost prior and the
    measurement objective see the padding a deployed tile choice pays."""
    tile = min(max(capacity, 8), token_tile)
    return tile, _round_up(max(capacity, 8), tile)


def _effective_program(expert_lengths, s: MoeDispatchSchedule,
                       d_model: int, d_ff: int,
                       max_tokens: Optional[int] = None) -> tuple:
    """The compiled shape a schedule actually produces: ``(tile,
    cap_pad, d_tile, f_tile)`` after capacity and tile fitting.  Several
    nominal grid points collapse to one program (e.g. d_tile 128 and 256
    both fit to 128 when d_model=128) — the search dedupes on this so
    timing noise never arbitrates between byte-identical programs."""
    cap = moe_capacity(expert_lengths, s.capacity_factor,
                       max_tokens=max_tokens)
    tile, cap_pad = _token_tiling(cap, s.token_tile)
    return (tile, cap_pad, _fit_tile(int(d_model), s.d_tile),
            _fit_tile(int(d_ff), s.f_tile))


def moe_cost(expert_lengths, s: MoeDispatchSchedule, d_model: int,
             d_ff: int, max_tokens: Optional[int] = None) -> float:
    """Static cost prior over the dispatch space (warm start only —
    measurement decides).  Terms: useful + padding flops of the
    capacity-padded grouped GEMM, tile-granularity memory traffic
    (smaller tiles re-fetch weight blocks more often), and a per-program
    launch overhead."""
    lengths = np.asarray(expert_lengths, np.float64)
    e = max(int(lengths.shape[0]), 1)
    d, f = int(d_model), int(d_ff)
    cap = moe_capacity(lengths, s.capacity_factor, max_tokens=max_tokens)
    tt, cap_pad = _token_tiling(cap, s.token_tile)
    dt, ft = _fit_tile(d, s.d_tile), _fit_tile(f, s.f_tile)

    occupied = float(np.minimum(lengths, cap).sum())
    work = occupied * d * f
    waste = (e * cap_pad - occupied) * d * f
    grid = (e * cap_pad // tt) * (f // ft) * (d // dt)
    traffic = grid * (tt * dt + dt * ft + tt * ft)
    return work + waste + 8.0 * traffic + 500.0 * grid


def candidate_moe_schedules(
        expert_lengths, *,
        default: Optional[MoeDispatchSchedule] = None,
        allow_capacity_shrink: bool = True,
        max_tokens: Optional[int] = None,
) -> List[MoeDispatchSchedule]:
    """The tuning grid.  Capacity factors that would drop more routed
    tokens than the default factor does on this histogram are excluded
    (time-for-quality trades are not the tuner's to make).  When the
    histogram is *assumed* rather than observed, pass
    ``allow_capacity_shrink=False``: the drop constraint is only
    trustworthy on real routing counts, so sub-default factors — safe on
    the assumed histogram, token-dropping on a skewed live batch — are
    withheld entirely."""
    default = default or MoeDispatchSchedule()
    budget = dropped_tokens(
        expert_lengths, moe_capacity(expert_lengths,
                                     default.capacity_factor,
                                     max_tokens=max_tokens))
    factors = sorted({default.capacity_factor} | {
        cf for cf in CAPACITY_FACTORS
        if cf >= default.capacity_factor or (
            allow_capacity_shrink
            and dropped_tokens(
                expert_lengths,
                moe_capacity(expert_lengths, cf,
                             max_tokens=max_tokens)) <= budget)})
    return [MoeDispatchSchedule(token_tile=tt, capacity_factor=cf,
                                f_tile=ft, d_tile=dt)
            for cf in factors
            for tt in _TILES
            for ft in _TILES
            for dt in _TILES]


# ---------------------------------------------------------------------------
# Measurement: jitted blocked-GEMM analogue of kernels.grouped_matmul
# ---------------------------------------------------------------------------


def make_moe_runner(expert_lengths, d_model: int, d_ff: int,
                    s: MoeDispatchSchedule, dtype: str = "float32",
                    max_tokens: Optional[int] = None):
    """Build ``(fn, args)`` timing one dispatch pass: capacity-gathered
    tokens through a blocked d→f GEMM, silu, and a blocked f→d GEMM,
    with the expert weight selected per token tile — the pure-JAX
    analogue of the Pallas kernel's grid."""
    import jax
    import jax.numpy as jnp

    lengths = np.asarray(expert_lengths)
    e = max(int(lengths.shape[0]), 1)
    d, f = int(d_model), int(d_ff)
    cap = moe_capacity(lengths, s.capacity_factor, max_tokens=max_tokens)
    tt, cap_pad = _token_tiling(cap, s.token_tile)
    dt, ft = _fit_tile(d, s.d_tile), _fit_tile(f, s.f_tile)
    n_tiles = e * cap_pad // tt
    tile_experts = np.repeat(np.arange(e, dtype=np.int32), cap_pad // tt)

    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (e * cap_pad, d), dtype=jnp.float32)
    w1 = jax.random.normal(k2, (e, d, f), dtype=jnp.float32)
    w2 = jax.random.normal(k3, (e, f, d), dtype=jnp.float32)
    x, w1, w2 = (a.astype(dtype) for a in (x, w1, w2))
    emap = jnp.asarray(tile_experts)

    def _run(x, w1, w2):
        xt = x.reshape(n_tiles, tt, d // dt, dt)
        w1t = w1[emap].reshape(n_tiles, d // dt, dt, f // ft, ft)
        h = jnp.einsum("ntkc,nkcmf->ntmf", xt, w1t,
                       preferred_element_type=jnp.float32)
        h = jax.nn.silu(h).astype(x.dtype)  # (n_tiles, tt, f//ft, ft)
        w2t = w2[emap].reshape(n_tiles, f // ft, ft, d // dt, dt)
        y = jnp.einsum("ntmc,nmckd->ntkd", h, w2t,
                       preferred_element_type=jnp.float32)
        return y.reshape(e * cap_pad, d)

    return jax.jit(_run), (x, w1, w2)


def measure_moe_dispatch(expert_lengths, d_model: int, d_ff: int,
                         s: MoeDispatchSchedule, *, dtype: str = "float32",
                         warmup: Optional[int] = None,
                         iters: Optional[int] = None,
                         max_tokens: Optional[int] = None) -> float:
    """Seconds/call of one dispatch pass under schedule ``s`` — the MoE
    tuner's objective function."""
    fn, args = make_moe_runner(expert_lengths, d_model, d_ff, s, dtype,
                               max_tokens)
    return time_fn(fn, *args, warmup=warmup, iters=iters)


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


def tune_moe_dispatch(
    expert_lengths,
    d_model: int,
    d_ff: int,
    *,
    dtype: str = "float32",
    default: Optional[MoeDispatchSchedule] = None,
    cache: Optional[ScheduleCache] = None,
    top_k: int = 4,
    hill_steps: int = 3,
    measure: Optional[Callable[[MoeDispatchSchedule], float]] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
    backend: Optional[str] = None,
    allow_capacity_shrink: bool = True,
    max_tokens: Optional[int] = None,
) -> TuneResult:
    """Empirically pick the dispatch schedule for this expert histogram;
    same phases as :func:`~.search.tune_schedule` (cache replay → cost
    warm start → top-k measurement with the static default always in the
    pool → hillclimb → persist).

    expert_lengths  routed tokens per expert (the segment histogram);
    d_model / d_ff  GEMM dims of the expert FFN;
    default         the static point tuning must never lose to
                    (``MoeDispatchSchedule()`` with the config's
                    capacity factor, normally);
    measure         override objective ``schedule -> seconds`` (tests);
    allow_capacity_shrink
                    pass False when ``expert_lengths`` is assumed, not
                    observed (see :func:`candidate_moe_schedules`); the
                    flag is part of the cache key, so the two regimes
                    never replay each other's records;
    max_tokens      the deployed local token count (deployment clamps
                    capacity at it — see :func:`moe_capacity`).
    """
    if cache is None:
        cache = default_cache(backend)
    default = default or MoeDispatchSchedule()
    key = moe_cache_key(expert_lengths, d_model, d_ff, dtype,
                        shrink=allow_capacity_shrink,
                        max_tokens=max_tokens)
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    if measure is None:
        def measure(s: MoeDispatchSchedule) -> float:
            return measure_moe_dispatch(expert_lengths, d_model, d_ff, s,
                                        dtype=dtype, warmup=warmup,
                                        iters=iters, max_tokens=max_tokens)

    cands = candidate_moe_schedules(
        expert_lengths, default=default,
        allow_capacity_shrink=allow_capacity_shrink, max_tokens=max_tokens)
    factors = sorted({c.capacity_factor for c in cands})
    ranked = sorted(cands, key=lambda s: moe_cost(expert_lengths, s,
                                                  d_model, d_ff, max_tokens))

    def _eff(s: MoeDispatchSchedule) -> tuple:
        return _effective_program(expert_lengths, s, d_model, d_ff,
                                  max_tokens)

    # the dispatch space dedupes on the *effective* program: nominal
    # points that fit to the same (tile, cap_pad, dt, ft) compile
    # identically, so measuring two of them would let timing noise pick
    # a "winner"
    space = SearchSpace(
        (MoeTilingAxis(_TILES), CapacityAxis(factors)),
        key_fn=moe_schedule_key,
        dedupe=lambda c, s: _eff(s),
    )
    return drive(space, SearchContext(workload=expert_lengths),
                 cache=cache, key=key, measure=measure,
                 seeds=[default], ranked=ranked, top_k=top_k,
                 hill_steps=hill_steps)


def moe_cached_or_default(
        expert_lengths, d_model: int, d_ff: int, *,
        dtype: str = "float32",
        default: Optional[MoeDispatchSchedule] = None,
        cache: Optional[ScheduleCache] = None,
        backend: Optional[str] = None,
        allow_capacity_shrink: bool = True,
        max_tokens: Optional[int] = None,
) -> MoeDispatchSchedule:
    """Cache-hit dispatch schedule if one exists, else the static
    default — **never measures** (the serving-path resolver; tune ahead
    of time with :func:`tune_moe_dispatch`, ``ServeEngine.prepare_moe``
    or ``launch.hillclimb --moe``).  ``allow_capacity_shrink`` and
    ``max_tokens`` must match the tuning call — they select which
    record to replay."""
    if cache is None:
        cache = default_cache(backend)
    rec = cache.get(moe_cache_key(expert_lengths, d_model, d_ff, dtype,
                                  shrink=allow_capacity_shrink,
                                  max_tokens=max_tokens))
    if rec is not None and isinstance(rec.schedule, MoeDispatchSchedule):
        return rec.schedule
    return default or MoeDispatchSchedule()
