"""Distributed-optimization helpers: gradient compression + overlap notes.

Gradient compression (for the data-parallel all-reduce): gradients are
quantized *before* the XLA-inserted all-reduce — because the all-reduce
operates on whatever dtype the gradient tree carries at that point, a
bf16/int8 tree moves 2×/4× fewer bytes on the wire. int8 uses per-tensor
symmetric scaling (scale carried in f32, negligible traffic).

Compute/comm overlap itself is delegated to XLA's latency-hiding scheduler
(collective ops are asynchronous on TPU; the scan-over-layers structure
exposes per-layer all-reduces that overlap with the next layer's matmuls).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_tree(grads, method: str):
    if method == "bf16":
        return {"m": "bf16",
                "data": jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)}
    if method == "int8":
        def q(g):
            g = g.astype(jnp.float32)
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            return (jnp.clip(jnp.round(g / scale), -127, 127)
                    .astype(jnp.int8), scale)
        return {"m": "int8", "data": jax.tree.map(q, grads)}
    raise ValueError(f"unknown compression {method!r}")


def decompress_tree(packed):
    if packed["m"] == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), packed["data"])
    if packed["m"] == "int8":
        return jax.tree.map(
            lambda qs: qs[0].astype(jnp.float32) * qs[1], packed["data"],
            is_leaf=lambda x: isinstance(x, tuple))
    raise ValueError(packed["m"])
