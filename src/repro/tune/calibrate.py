"""Calibrate the static cost model against measured timings.

``predict_cost`` is a weighted sum of four raw terms
(``core.selector.cost_terms``); the hand-set napkin weights are a prior,
not a measurement.  This module closes the loop: collect
(terms, measured-seconds) samples over a matrix suite, solve the
non-negative least-squares problem

    min_w || T @ w - t ||^2,   w >= 0

(T the terms matrix, t the measured timings), and install the fit via
``core.selector.set_cost_weights`` so ``Schedule.auto`` itself improves
from tuning data.  The quality metric is *regret*: per matrix, the
measured time of the model's argmin divided by the measured oracle
minimum (1.0 = the model always picks the empirical winner); reported as
a geomean over the suite.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..core import Schedule, candidate_schedules
from ..core.selector import cost_terms, get_cost_weights, set_cost_weights
from .measure import measure_schedule

__all__ = [
    "CalibrationSample",
    "CalibrationResult",
    "collect_samples",
    "fit_weights",
    "model_regret",
    "calibrate",
    "samples_from_results",
]


@dataclasses.dataclass(frozen=True)
class CalibrationSample:
    """One (matrix, schedule) observation: the model terms and the
    measured seconds/call.  ``group`` identifies the matrix so regret can
    be computed per-matrix."""

    group: int
    terms: Tuple[float, float, float, float]
    seconds: float


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    """Fitted cost-model weights plus before/after ranking regret on
    the calibration sample set."""

    weights: Tuple[float, float, float, float]
    regret_before: float
    regret_after: float
    n_samples: int


def collect_samples(
    mats: Sequence,
    n_dense_cols: int = 4,
    *,
    schedules: Optional[Sequence[Schedule]] = None,
    measure: Optional[Callable] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
) -> List[CalibrationSample]:
    """Measure every (matrix, schedule) pair.

    mats        CSR matrices (or (tag, csr) pairs — tags are dropped).
    measure     override objective ``(csr, schedule) -> seconds``.
    """
    from ..sparse.random import matrix_stats

    if schedules is None:
        schedules = candidate_schedules(n_dense_cols)
    if measure is None:
        def measure(csr, s):
            return measure_schedule(csr, n_dense_cols, s,
                                    warmup=warmup, iters=iters)

    samples = []
    for gi, m in enumerate(mats):
        csr = m[1] if isinstance(m, tuple) else m
        stats = matrix_stats(csr)
        for s in schedules:
            samples.append(CalibrationSample(
                group=gi, terms=cost_terms(stats, s, n_dense_cols),
                seconds=float(measure(csr, s))))
    return samples


def samples_from_results(
    entries: Sequence,
) -> List[CalibrationSample]:
    """Turn unified-driver tuning runs into calibration samples.

    ``entries`` are ``(csr, n_dense_cols, TuneResult)`` triples as
    returned by ``tune_schedule`` — the driver's :class:`TuneResult`
    carries every measured point in ``.points`` (key → Schedule) next to
    its timing in ``.measured`` (key → us/call), so a tuning sweep
    doubles as a calibration corpus with no extra measurements.  Replayed
    results (``from_cache=True``) contribute nothing — they carry no
    fresh timings.  Non-Schedule points (e.g. a fuse plan's decisions)
    are skipped: ``cost_terms`` is defined on the SpMM schedule space.
    """
    from ..sparse.random import matrix_stats

    samples: List[CalibrationSample] = []
    for gi, (csr, n_dense_cols, res) in enumerate(entries):
        if res.from_cache or not res.points:
            continue
        stats = matrix_stats(csr)
        for k, us in res.measured.items():
            point = res.points.get(k)
            if not isinstance(point, Schedule):
                continue
            samples.append(CalibrationSample(
                group=gi, terms=cost_terms(stats, point, n_dense_cols),
                seconds=us * 1e-6))
    return samples


def fit_weights(
    samples: Sequence[CalibrationSample],
) -> Tuple[float, float, float, float]:
    """Non-negative least squares of measured seconds on the four terms.

    Each matrix group is scaled by one scalar (its mean measured time),
    applied to *both* the terms rows and the target, so every matrix
    votes with comparable residual weight while an exactly-linear
    relationship stays exactly solvable (the model only ever ranks
    schedules within one matrix, so relative fit is what matters).
    """
    if not samples:
        raise ValueError("no calibration samples")
    groups = sorted({s.group for s in samples})
    rows, targets = [], []
    for g in groups:
        gs = [s for s in samples if s.group == g]
        scale = np.mean([s.seconds for s in gs]) or 1.0
        for s in gs:
            rows.append(np.asarray(s.terms, np.float64) / scale)
            targets.append(s.seconds / scale)
    a = np.asarray(rows)
    t = np.asarray(targets)
    try:
        from scipy.optimize import nnls

        w, _ = nnls(a, t)
    except ImportError:  # pragma: no cover - scipy is in the image
        w, *_ = np.linalg.lstsq(a, t, rcond=None)
        w = np.clip(w, 0.0, None)
    if not np.any(w > 0):
        # degenerate fit (e.g. constant timings): keep the prior
        return get_cost_weights()
    # scale is irrelevant for argmin; normalize so work weight ~ 1
    ref = w[0] if w[0] > 0 else np.max(w)
    return tuple(float(x / ref) for x in w)


def model_regret(samples: Sequence[CalibrationSample],
                 weights: Sequence[float]) -> float:
    """Geomean over matrices of measured(model argmin) / measured(best).
    1.0 means the weighted model always picks the empirical winner."""
    w = np.asarray(weights, np.float64)
    ratios = []
    for g in sorted({s.group for s in samples}):
        gs = [s for s in samples if s.group == g]
        costs = np.asarray([np.dot(w, s.terms) for s in gs])
        secs = np.asarray([s.seconds for s in gs])
        ratios.append(secs[int(np.argmin(costs))] / secs.min())
    return float(np.exp(np.mean(np.log(np.maximum(ratios, 1e-12)))))


def calibrate(
    mats: Sequence,
    n_dense_cols: int = 4,
    *,
    apply: bool = False,
    measure: Optional[Callable] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
) -> CalibrationResult:
    """Collect samples over ``mats``, fit weights, report regret before
    (active weights) vs after (fitted); ``apply=True`` installs the fit
    process-wide via ``set_cost_weights``."""
    samples = collect_samples(mats, n_dense_cols, measure=measure,
                              warmup=warmup, iters=iters)
    before = model_regret(samples, get_cost_weights())
    weights = fit_weights(samples)
    after = model_regret(samples, weights)
    if after > before:
        # never ship a fit that ranks worse than the prior on its own data
        weights, after = get_cost_weights(), before
    if apply:
        set_cost_weights(weights)
    return CalibrationResult(weights=weights, regret_before=before,
                             regret_after=after, n_samples=len(samples))
