"""Data-aware schedule selector (DA-SpMM-style, Sgap §7.2 Table 5).

Given matrix statistics and the dense-column count N, pick an
(atomic-parallelism) schedule. The decision mirrors the paper's findings:

* few dense columns (N <= 8): *balance*-bound -> nnz-split (EB) wins when
  row lengths are skewed; group size should shrink when rows are short
  (challenge 1: parallelism waste).
* many dense columns: *workload*-bound -> row-split (RB) with wide column
  tiles reuses the loaded sparse row across columns.
* segment strategy when writeback targets are runtime-dependent (high CV),
  parallel strategy when rows are long and regular.

Also exposes :func:`predict_cost` — the napkin-math cost model used both
here and by the §Perf hillclimb loop.
"""
from __future__ import annotations

import math
from typing import Dict

from .schedule import Schedule
from .segment_group import group_waste_fraction

__all__ = ["select_schedule", "predict_cost", "candidate_schedules"]


def candidate_schedules(n_dense_cols: int) -> list[Schedule]:
    """The tuning grid from the paper's dgSPARSE experiment, TPU-mapped:
    <groupSz, blockSz, tileSz, workerDimR> -> <G, nnz/row tile, col tile>."""
    cands = []
    col_tile = max(8, min(128, n_dense_cols))
    for g in (8, 16, 32, 64):
        for nnz_tile in (128, 256, 512):
            if nnz_tile % g:
                continue
            cands.append(Schedule("eb", nnz_tile=nnz_tile,
                                  col_tile=col_tile, group_size=g,
                                  strategy="segment"))
    for row_tile in (8, 16, 32):
        cands.append(Schedule("rb", row_tile=row_tile,
                              col_tile=col_tile, strategy="parallel"))
    return cands


def predict_cost(stats: Dict, sched: Schedule, n_dense_cols: int) -> float:
    """Relative cost model (lower = better). Terms:

    work        nnz * C multiply-adds (same for every schedule);
    waste       zero-extension padding lanes (rb: rows padded to ELL width;
                eb: nnz padded to tile);
    writeback   segment writeback traffic ~ rows touched per tile;
    gather      dense-row gather traffic ~ nnz * col_tile.
    """
    nnz = max(1, stats["nnz"])
    C = max(1, n_dense_cols)
    row_mean = max(stats["row_mean"], 1e-3)
    row_max = max(stats["row_max"], 1)
    n_rows = max(1, stats["n_rows"])

    work = nnz * C
    if sched.kernel == "rb":
        # ELL pads every row to row_max
        waste = (row_max * n_rows - nnz) * C
        writeback = n_rows * C
    else:
        waste_frac = group_waste_fraction(
            [max(1, int(row_mean))], sched.group_size
        )
        waste = work * waste_frac
        # one writeback per distinct row per group (>= 1 per group)
        groups = nnz / sched.group_size
        rows_per_group = max(1.0, sched.group_size / row_mean)
        writeback = groups * rows_per_group * C
    gather = nnz * min(C, sched.col_tile)
    return work + waste + 2.0 * writeback + 0.25 * gather


def select_schedule(stats: Dict, n_dense_cols: int) -> Schedule:
    """Pick the argmin of the cost model over the candidate grid, with the
    paper's qualitative rules as a prior (they also act as tie-breakers)."""
    cands = candidate_schedules(n_dense_cols)
    best, best_cost = None, math.inf
    for s in cands:
        c = predict_cost(stats, s, n_dense_cols)
        # prior: high row-CV strongly prefers nnz-split + segment
        if stats.get("row_cv", 0.0) > 1.0 and s.kernel == "rb":
            c *= 1.0 + stats["row_cv"]
        if c < best_cost:
            best, best_cost = s, c
    return best
