"""Empirical schedule search over the atomic-parallelism space.

The paper's dgSPARSE result (1.6x–2.3x, Table 4) comes from *tuning*
``<groupSz, blockSz, tileSz, workerDim>``, not from a fixed heuristic.
:func:`tune_schedule` makes that search a library call.  Since the §14
refactor the search loop itself lives in :func:`repro.tune.driver.drive`
— this module only *declares* the SpMM / segment-reduce / distributed
spaces (which axes, which cost model, which cache key) and hands them to
the driver:

1. **warm start** — rank :func:`~repro.core.candidate_schedules` by the
   static cost model (:func:`~repro.core.predict_cost`), prune points
   whose working set overflows VMEM;
2. **measure** — time the top-k candidates plus the selector's own pick
   (``Schedule.auto`` is always in the measured pool, so the tuned
   choice can never lose to it beyond timing noise);
3. **dtype axis** — re-measure the winner under each narrow value dtype
   (``DEFAULT_VALUE_DTYPES``) whose storage-parity error fits the
   ``error_budget`` — precision is a tuned knob, not a global switch
   (DESIGN.md §13);
4. **hillclimb** — take x2 / /2 steps on ``group_size`` and the tile
   fields around the measured winner until no neighbor improves;
5. **cache** — persist the winner in the :class:`~.cache.ScheduleCache`
   under the matrix fingerprint, so serving/training loops tune once and
   replay (a hit performs *zero* measurements).

``measure=`` is injectable (schedule -> seconds) for tests and for
calibration replays.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from ..core import (COLLECTIVES, Schedule, candidate_schedules, predict_cost,
                    predict_dist_cost, select_schedule)
from ..kernels.ops import schedule_fits_vmem
from ..sparse.random import matrix_stats
from .cache import ScheduleCache, cache_key, default_cache
from .driver import TuneResult, _replay, drive
from .measure import measure_dist_schedule, measure_schedule, time_fn
from .space import (CollectiveAxis, EpilogueAxis, SearchContext, SearchSpace,
                    SkewAxis, StrategyAxis, TilingAxis, ValueDtypeAxis,
                    schedule_key)

__all__ = [
    "DEFAULT_VALUE_DTYPES",
    "DIST_VALUE_DTYPES",
    "TuneResult",
    "cached_or_auto",
    "schedule_key",
    "tune_dist_spmm",
    "tune_schedule",
    "tune_segment_reduce",
]

#: Dtype-axis candidates measured by default (DESIGN.md §13).  fp8 is
#: deliberately absent: on backends without native fp8 it silently
#: degrades to bf16 (``core.dtypes.storage_dtype``), so tuning would
#: just measure bf16 twice; pass ``value_dtypes=("float8_e4m3fn", ...)``
#: explicitly on hardware that has it.
DEFAULT_VALUE_DTYPES = ("bfloat16", "float16", "int8")

#: Dtype-axis candidates for the *distributed* search.  int8 is
#: excluded: the shard-local kernel consumes partitioned GroupedCOO
#: shards, and the int8 path needs the per-row scales a CSR/
#: QuantizedCSR carries (``kops.spmm`` rejects the combination).
DIST_VALUE_DTYPES = ("bfloat16", "float16")


def _feasible(cands: List[Schedule], stats: dict) -> List[Schedule]:
    kept = [s for s in cands
            if schedule_fits_vmem(s, n_rows=stats["n_rows"],
                                  n_cols=stats["n_cols"],
                                  row_max=stats["row_max"])]
    return kept or cands  # never let pruning empty the pool


def _dtype_parity_error(csr, n_dense_cols: int, vd: str) -> float:
    """Relative L2 error of the ``vd`` storage analogue vs the f32
    oracle on a deterministic dense B (the same ``_dense_b`` the
    runners feed).

    Measures storage-precision loss only — the analogue accumulates in
    f32 like the kernels (``upcast_f32`` contract), so the number is a
    property of (matrix, dtype), independent of tiling/strategy, and is
    computed once per dtype per tuning run.  int8 goes through the real
    quantize/dequantize path (per-row symmetric scales)."""
    import jax.numpy as jnp

    from ..core.dtypes import operand_dtype, storage_dtype
    from ..kernels import ref
    from .measure import _dense_b

    coo = csr.tocoo()
    b = _dense_b(csr, n_dense_cols)
    out32 = ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, b, csr.shape[0])
    if vd == "int8":
        vals = csr.quantized().dequantize().tocoo().vals
    else:
        vals = coo.vals.astype(storage_dtype(vd))
    out = ref.spmm_coo_ref(coo.rows, coo.cols, vals,
                           b.astype(operand_dtype(vd)), csr.shape[0])
    num = float(jnp.linalg.norm((out - out32).ravel()))
    den = float(jnp.linalg.norm(out32.ravel()))
    return num / (den + 1e-12)


def _storage_parity(ctx: SearchContext, vd: str) -> float:
    """The :class:`ValueDtypeAxis` admission gate for CSR workloads."""
    return _dtype_parity_error(ctx.workload, ctx.n_dense_cols, vd)


def _vmem_filter(ctx: SearchContext, cands: List[Schedule]) -> List[Schedule]:
    return _feasible(cands, ctx.stats)


# ---------------------------------------------------------------------------
# The tuner
# ---------------------------------------------------------------------------


def tune_schedule(
    csr,
    n_dense_cols: int,
    *,
    cache: Optional[ScheduleCache] = None,
    top_k: int = 4,
    hill_steps: int = 3,
    measure: Optional[Callable[[Schedule], float]] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
    backend: Optional[str] = None,
    epilogue=None,
    value_dtypes: Optional[tuple] = None,
    error_budget: float = 0.05,
) -> TuneResult:
    """Empirically pick the best schedule for ``csr @ B`` (B with
    ``n_dense_cols`` columns); see the module docstring for the phases.

    cache       ScheduleCache to consult/update (default: the process
                cache at ``REPRO_TUNE_CACHE``); a hit replays with zero
                measurements.
    top_k       cost-model-ranked candidates to measure beyond the
                selector's pick.
    hill_steps  max hillclimb rounds around the measured winner.
    measure     override objective ``schedule -> seconds`` (tests,
                calibration replays); default wall-clocks the jitted
                schedule analogue via ``tune.measure``.
    epilogue    fused :class:`~repro.core.Epilogue` the workload will run
                — attached to every measured candidate so the fused work
                is *part of the objective*, and folded into the cache key
                (an epilogued workload never replays a plain record or
                vice versa).  The returned/tuned schedule carries it.
    value_dtypes  dtype-axis candidates (DESIGN.md §13); default
                :data:`DEFAULT_VALUE_DTYPES`, ``()`` disables the axis.
                Each candidate is admitted only if its storage-parity
                error vs the f32 oracle is within ``error_budget``, then
                measured as a variant of the pool winner (the dtype
                rescales traffic uniformly across tilings, so crossing
                the full grid with every dtype would waste measurements).
    error_budget  max relative L2 parity error an admitted narrow dtype
                may introduce (default 5%).
    """
    if cache is None:
        cache = default_cache(backend)
    if epilogue is not None and epilogue.is_noop:
        epilogue = None
    key = cache_key(csr, n_dense_cols)
    if epilogue is not None:
        key = f"{key}|ep:{epilogue.tag}"
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    stats = matrix_stats(csr)
    if measure is None:
        def measure(s: Schedule) -> float:
            return measure_schedule(csr, n_dense_cols, s,
                                    warmup=warmup, iters=iters)

    def _with_ep(s: Schedule) -> Schedule:
        return s if epilogue is None else s.replace(epilogue=epilogue)

    if value_dtypes is None:
        value_dtypes = DEFAULT_VALUE_DTYPES
    # the SpMM space: the paper's (strategy × tiling) core, the skew
    # axis (§11) and the parity-gated dtype axis (§13); hillclimb moves
    # are vmem-pruned like the candidate grid
    space = SearchSpace(
        (StrategyAxis(), TilingAxis(), SkewAxis(),
         ValueDtypeAxis(value_dtypes, error_budget=error_budget,
                        parity=_storage_parity),
         EpilogueAxis()),
        key_fn=schedule_key,
        neighbor_filter=_vmem_filter,
    )
    ctx = SearchContext(stats=stats, n_dense_cols=n_dense_cols, workload=csr)
    ranked = space.rank(ctx, _feasible(candidate_schedules(n_dense_cols),
                                       stats),
                        lambda s: predict_cost(stats, s, n_dense_cols))
    ranked = [_with_ep(s) for s in ranked]
    seeds = [_with_ep(select_schedule(stats, n_dense_cols))]
    return drive(space, ctx, cache=cache, key=key, measure=measure,
                 seeds=seeds, ranked=ranked, top_k=top_k,
                 hill_steps=hill_steps)


def cached_or_auto(csr, n_dense_cols: int, *,
                   cache: Optional[ScheduleCache] = None,
                   backend: Optional[str] = None,
                   key: Optional[str] = None) -> Schedule:
    """Cache-hit schedule if one exists, else the static selector's pick —
    **never measures**.  This is the serving-path resolver: a latency-
    sensitive loop consults tuning done ahead of time (e.g. by
    ``ServeEngine.prepare_sparse`` or ``launch.hillclimb --spmm``) and
    must not stall a request on a tuning run."""
    if cache is None:
        cache = default_cache(backend)
    rec = cache.get(key if key is not None
                    else cache_key(csr, n_dense_cols))
    if rec is not None:
        return rec.schedule
    return Schedule.auto(matrix_stats(csr), n_dense_cols)


# ---------------------------------------------------------------------------
# segment_reduce tuning (no CSR matrix: segments play the role of rows)
# ---------------------------------------------------------------------------


def tune_segment_reduce(
    seg_ids,
    n_cols: int,
    num_segments: int,
    *,
    cache: Optional[ScheduleCache] = None,
    measure: Optional[Callable[[Schedule], float]] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
    backend: Optional[str] = None,
) -> TuneResult:
    """Tune (tile, group_size, strategy) for a standalone segment reduce.

    The segment-length histogram stands in for the row-length histogram
    in the fingerprint (keys prefixed ``segred:``); candidates are the
    EB half of the grid (the RB kernel has no segment-reduce analogue).
    The objective times the *actual* segment-reduce kernel wrapper —
    unlike SpMM tuning there is no cheaper analogue that still observes
    the tile axis, and the kernel is the op being tuned.  The space is
    exhaustive (every grid point measured, no hillclimb), so the driver
    runs with ``top_k=None, hill_steps=0``."""
    from .cache import fingerprint_from_lengths

    seg = np.asarray(seg_ids)
    t = int(seg.shape[0])
    lengths = np.bincount(seg, minlength=max(num_segments, 1))
    fp = fingerprint_from_lengths(lengths, (num_segments, n_cols), t)
    key = f"segred:{fp}|N{n_cols}"

    if cache is None:
        cache = default_cache(backend)
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    if measure is None:
        import jax
        import jax.numpy as jnp

        from ..kernels.segment_reduce import segment_reduce as _segred

        data = jax.random.normal(jax.random.PRNGKey(0), (t, n_cols))
        seg_j = jnp.asarray(seg, jnp.int32)

        def measure(s: Schedule) -> float:
            def fn(ss, d):
                return _segred(ss, d, num_segments=num_segments,
                               tile=s.nnz_tile, group_size=s.group_size,
                               strategy=s.strategy)

            return time_fn(fn, seg_j, data, warmup=warmup, iters=iters)

    space = SearchSpace((StrategyAxis(), TilingAxis()), key_fn=schedule_key)
    pool = [Schedule("eb", nnz_tile=tile, group_size=g, strategy=st)
            for tile in (128, 512)
            for g in (8, 32)
            for st in ("segment", "accumulate")]
    return drive(space, SearchContext(), cache=cache, key=key,
                 measure=measure, ranked=pool)


# ---------------------------------------------------------------------------
# Distributed tuning: one search over (local tiling × collective × dtype)
# ---------------------------------------------------------------------------


def _feasible_collectives(stats: dict, axis_size: int) -> List[str]:
    """Collective modes the mesh/shape combination can realize: 'nnz_ar'
    always works; 'row' and 'nnz_rs' finalize a row block per shard, so
    they need ``n_rows % axis_size == 0`` (DESIGN.md §12)."""
    modes = ["nnz_ar"]
    if axis_size <= 1 or stats["n_rows"] % axis_size == 0:
        modes += ["nnz_rs", "row"]
    return modes


def tune_dist_spmm(
    csr,
    n_dense_cols: int,
    *,
    mesh,
    axis: str,
    cache: Optional[ScheduleCache] = None,
    top_k: int = 2,
    hill_steps: int = 2,
    measure: Optional[Callable[[Schedule], float]] = None,
    warmup: Optional[int] = None,
    iters: Optional[int] = None,
    backend: Optional[str] = None,
    interpret: bool = True,
    value_dtypes: Optional[tuple] = None,
    error_budget: float = 0.05,
) -> TuneResult:
    """One empirical search over (kernel tiling × collective mode ×
    value dtype) for a sharded ``csr @ B`` on ``mesh`` — the tentpole of
    DESIGN.md §12 extended by §14's joint axis search: the wire strategy
    *and* the storage precision are :class:`Schedule` axes, not separate
    knobs, so the tuner can trade local tile shape against collective
    bytes against value-traffic width in a single objective
    (``measure_dist_schedule`` times the real shard_map program).

    Candidates are the top-ranked *local* eb tilings (the shard-local
    kernel only takes the eb path) crossed with every feasible collective
    mode, pre-ranked by :func:`~repro.core.predict_dist_cost` — the
    per-shard cost model plus the ``WIRE_COST_WEIGHT`` wire term and the
    ``shard_nnz`` straggler factor — then measured.  The parity-gated
    narrow dtypes (:data:`DIST_VALUE_DTYPES`; ``value_dtypes=()``
    recovers the single-axis search) are measured as variants of the
    pool winner with its collective held, and a short hillclimb refines
    the winner's local axes with the collective held fixed (a collective
    flip re-partitions the operands, so it is a pool move, not a
    neighbor move).  The cache key folds in the mesh extent:
    ``dist:<fingerprint>|mesh:<P>`` — the same matrix on a different
    mesh is a different tuning problem.
    """
    axis_size = int(mesh.shape[axis])
    if cache is None:
        cache = default_cache(backend)
    key = f"dist:{cache_key(csr, n_dense_cols)}|mesh:{axis_size}"
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    from ..sparse.distributed import shard_nnz_counts

    stats = matrix_stats(csr)
    if measure is None:
        def measure(s: Schedule) -> float:
            return measure_dist_schedule(csr, n_dense_cols, s, mesh=mesh,
                                         axis=axis, warmup=warmup,
                                         iters=iters, interpret=interpret)

    if value_dtypes is None:
        value_dtypes = DIST_VALUE_DTYPES
    modes = _feasible_collectives(stats, axis_size)
    # the distributed space: no skew axis — ``_local_spmm`` strips skew
    # from shard-local schedules, so a skew point would measure the same
    # program twice
    space = SearchSpace(
        (StrategyAxis(), TilingAxis(), CollectiveAxis(modes),
         ValueDtypeAxis(value_dtypes, error_budget=error_budget,
                        parity=_storage_parity),
         EpilogueAxis()),
        key_fn=schedule_key,
        neighbor_filter=lambda c, cands: [
            s for s in _feasible(cands, c.stats)
            if s.collective in COLLECTIVES],
    )
    ctx = SearchContext(stats=stats, n_dense_cols=n_dense_cols,
                        axis_size=axis_size, workload=csr)

    eb = [s for s in _feasible(candidate_schedules(n_dense_cols), stats)
          if s.kernel == "eb"]
    eb.sort(key=lambda s: predict_cost(stats, s, n_dense_cols))
    auto = select_schedule(stats, n_dense_cols)
    seeds = ([auto] if auto.kernel == "eb" else []) + eb[:max(1, top_k)]
    pool = space.rank(ctx, space.cross(ctx, seeds),
                      lambda s: predict_dist_cost(
                          stats, s, n_dense_cols, axis_size=axis_size,
                          shard_nnz=shard_nnz_counts(csr, axis_size,
                                                     s.collective)))
    return drive(space, ctx, cache=cache, key=key, measure=measure,
                 ranked=pool, hill_steps=hill_steps)
