"""Mesh-elevated reduction strategies (DESIGN.md §12).

Parity of the three collective modes (row / nnz_ar / nnz_rs) against
single-device oracles for distributed SpMM, fused attention and MoE
dispatch, plus the tuner plumbing that makes the collective a cached
`Schedule` axis: measurement-free replay, the v2 -> v3 cache schema
migration, and the degenerate 1-device mesh.

The 8-device parity tests run through ``conftest.run_distributed`` (a
subprocess with forced host devices) so the main pytest process keeps
its single-device view; everything else runs in-process.
"""
import json

import jax
import pytest

from conftest import run_distributed as _run

from repro.core import COLLECTIVES, Schedule
from repro.tune import ScheduleCache, TuneRecord, tune_dist_spmm
from repro.tune.cache import SCHEMA_VERSION, cache_key
from repro.tune.moe import MoeDispatchSchedule, moe_schedule_key
from repro.tune.search import schedule_key


# ---------------------------------------------------------------------------
# 8-device subprocess parity: each collective mode vs a single-device oracle
# ---------------------------------------------------------------------------

DIST_SPMM_MODES = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_reduction_mesh
from repro.sparse import power_law_csr, Schedule, dist_spmm
from repro.sparse.distributed import (partition_nnz_coo, partition_rows_coo,
                                      spmm_shard_map)
from repro.kernels import ref

mesh = make_reduction_mesh()
# power-law rows: shard nnz counts are deliberately uneven, and the total
# nnz is whatever the sampler produced (not a multiple of 8), so the
# padded-partition path is exercised too
csr = power_law_csr(128, 96, avg_degree=6.0, alpha=1.6, seed=0)
coo = csr.tocoo()
b = jax.random.normal(jax.random.PRNGKey(1), (96, 20))
want = ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, b, 128)

for mode in ("nnz_ar", "nnz_rs", "row"):
    sched = Schedule(nnz_tile=64, group_size=8, collective=mode)
    if mode == "row":
        r, c, v, _ = partition_rows_coo(csr, 8, 64)
    else:
        r, c, v, _ = partition_nnz_coo(csr, 8, 64)
    out = spmm_shard_map(r, c, v, b, n_rows=128, mesh=mesh, axis="shards",
                         schedule=sched)
    err = float(jnp.max(jnp.abs(out - want)))
    assert err < 1e-4, (mode, err)
    print(mode, "spmm OK", err)

# end-to-end: schedule="tune" picks (tiling x collective) in one pass and
# a second call replays the cached record without measuring
from repro.tune import ScheduleCache, tune_dist_spmm
cache = ScheduleCache(path=None)
out = dist_spmm(csr, b, mesh=mesh, axis="shards", schedule="tune",
                cache=cache)
err = float(jnp.max(jnp.abs(out - want)))
assert err < 1e-4, err
res = tune_dist_spmm(csr, 20, mesh=mesh, axis="shards", cache=cache)
assert res.from_cache and res.n_measurements == 0, res
assert res.schedule.collective in ("row", "nnz_ar", "nnz_rs")
print("tune OK", res.schedule.collective)
"""


DIST_ATTENTION_MODES = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_reduction_mesh
from repro.sparse import power_law_csr, Schedule
from repro.sparse.distributed import (dist_attention_shard_map,
                                      partition_nnz_coo, partition_rows_coo)
from repro.kernels.fused_attention import sparse_attention_ref

mesh = make_reduction_mesh()
H, d, dv, n_rows, n_kv = 2, 16, 24, 128, 96
csr = power_law_csr(n_rows, n_kv, avg_degree=6.0, alpha=1.6, seed=0)
coo = csr.tocoo()
q = jax.random.normal(jax.random.PRNGKey(0), (H, n_rows, d))
k = jax.random.normal(jax.random.PRNGKey(2), (H, n_kv, d))
v = jax.random.normal(jax.random.PRNGKey(3), (H, n_kv, dv))
scale = 1.0 / np.sqrt(d)
want = jnp.stack([sparse_attention_ref(coo.rows, coo.cols, q[h], k[h], v[h],
                                       n_rows=n_rows, scale=scale)
                  for h in range(H)])

for mode in ("nnz_ar", "nnz_rs", "row"):
    sched = Schedule(nnz_tile=64, group_size=8, collective=mode)
    if mode == "row":
        r, c, _, _ = partition_rows_coo(csr, 8, 64, pattern_only=True,
                                        phantom_row=True)
    else:
        r, c, _, _ = partition_nnz_coo(csr, 8, 64, pattern_only=True,
                                       phantom_row=True)
    out = dist_attention_shard_map(r, c, q, k, v, n_rows=n_rows, mesh=mesh,
                                   axis="shards", schedule=sched, scale=scale)
    err = float(jnp.max(jnp.abs(out - want)))
    assert err < 1e-3, (mode, err)
    print(mode, "attn OK", err)
"""


MOE_COLLECTIVES = """
import jax, jax.numpy as jnp
from repro.configs import ARCHS, smoke_config
from repro.models.moe import (ShardingCtx, apply_moe, default_dispatch,
                              init_moe, moe_tune_collective)
from repro.tune import ScheduleCache

# capacity_factor large enough that no token drops in either layout, so
# every collective mode must match the single-shard oracle exactly
cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"]).scaled(capacity_factor=4.0)
p = init_moe(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
want, _ = apply_moe(cfg, p, x, None)

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardingCtx(mesh=mesh, data_axes=("data",), model_axis="model")
# None defaults to nnz_ar (the historical psum); nnz_rs reduce-scatters
# the expert partials and must agree bit-for-bit in math terms
for coll in (None, "nnz_ar", "nnz_rs"):
    d = default_dispatch(cfg).replace(collective=coll)
    out, _ = apply_moe(cfg, p, x, ctx, dispatch=d)
    err = float(jnp.abs(out - want).max())
    assert err < 1e-4, (coll, err)
    print(coll, "moe OK", err)

cache = ScheduleCache(path=None)
res = moe_tune_collective(cfg, p, x, ctx, cache=cache)
assert res.schedule.collective in ("nnz_ar", "nnz_rs")
res2 = moe_tune_collective(cfg, p, x, ctx, cache=cache)
assert res2.from_cache and res2.n_measurements == 0
assert res2.schedule == res.schedule
print("moe tune OK", res.schedule.collective)
"""


@pytest.mark.slow
def test_dist_spmm_modes_match_oracle():
    out = _run(DIST_SPMM_MODES)
    for mode in ("nnz_ar", "nnz_rs", "row"):
        assert f"{mode} spmm OK" in out
    assert "tune OK" in out


@pytest.mark.slow
def test_dist_attention_modes_match_oracle():
    out = _run(DIST_ATTENTION_MODES)
    for mode in ("nnz_ar", "nnz_rs", "row"):
        assert f"{mode} attn OK" in out


@pytest.mark.slow
def test_moe_dispatch_collectives_match_oracle():
    out = _run(MOE_COLLECTIVES)
    for coll in ("None", "nnz_ar", "nnz_rs"):
        assert f"{coll} moe OK" in out
    assert "moe tune OK" in out


# ---------------------------------------------------------------------------
# In-process: degenerate mesh, schedule validation, cache plumbing
# ---------------------------------------------------------------------------


def test_degenerate_single_device_mesh():
    """A 1-device mesh is a plain local run: every collective mode must
    reduce to the single-device result (the collective is a no-op)."""
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.sparse import power_law_csr
    from repro.sparse.distributed import (partition_nnz_coo,
                                          partition_rows_coo, spmm_shard_map)

    mesh = jax.make_mesh((1,), ("shards",))
    csr = power_law_csr(64, 48, avg_degree=5.0, alpha=1.5, seed=0)
    coo = csr.tocoo()
    b = jax.random.normal(jax.random.PRNGKey(1), (48, 12))
    want = ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, b, 64)
    for mode in ("nnz_ar", "nnz_rs", "row"):
        sched = Schedule(nnz_tile=32, group_size=8, collective=mode)
        if mode == "row":
            r, c, v, _ = partition_rows_coo(csr, 1, 32)
        else:
            r, c, v, _ = partition_nnz_coo(csr, 1, 32)
        out = spmm_shard_map(r, c, v, b, n_rows=64, mesh=mesh, axis="shards",
                             schedule=sched)
        err = float(jnp.max(jnp.abs(out - want)))
        assert err < 1e-4, (mode, err)


def test_schedule_collective_validation():
    assert COLLECTIVES == ("row", "nnz_ar", "nnz_rs")
    for mode in COLLECTIVES:
        Schedule(collective=mode)  # must not raise
    with pytest.raises(ValueError):
        Schedule(collective="broadcast")


def test_schedule_key_carries_collective():
    base = Schedule(nnz_tile=64, group_size=8)
    assert ":w[" not in schedule_key(base)
    keyed = schedule_key(base.replace(collective="nnz_rs"))
    assert keyed.endswith(":w[nnz_rs]") or ":w[nnz_rs]:" in keyed
    # distinct modes must never collide in the cache
    keys = {schedule_key(base.replace(collective=m))
            for m in (None,) + COLLECTIVES}
    assert len(keys) == 4


def test_moe_schedule_collective():
    d = MoeDispatchSchedule(token_tile=32, capacity_factor=1.25)
    for mode in (None, "nnz_ar", "nnz_rs"):
        moe_schedule_key(d.replace(collective=mode))  # must not raise
    # "row" has no expert-parallel analogue: every expert's partial
    # output covers all local tokens, so rowwise ownership is undefined
    with pytest.raises(ValueError):
        MoeDispatchSchedule(token_tile=32, capacity_factor=1.25,
                            collective="row")
    assert ":w[nnz_rs]" in moe_schedule_key(d.replace(collective="nnz_rs"))
    assert ":w[" not in moe_schedule_key(d)


def test_dist_tune_cache_roundtrip(tmp_path):
    """The collective survives a disk round-trip and replays without a
    single measurement (the whole point of caching the wire mode)."""
    from repro.sparse import power_law_csr

    csr = power_law_csr(64, 48, avg_degree=5.0, alpha=1.5, seed=0)
    mesh = jax.make_mesh((1,), ("shards",))
    path = tmp_path / "cache.json"

    calls = []

    def fake_measure(s):
        calls.append(s)
        # steer the pick to a deterministic non-default mode
        return 1.0 if s.collective == "nnz_rs" else 2.0

    cache = ScheduleCache(path=str(path))
    res = tune_dist_spmm(csr, 12, mesh=mesh, axis="shards", cache=cache,
                         measure=fake_measure, top_k=1, hill_steps=0)
    cache.save()
    assert calls and not res.from_cache
    assert res.schedule.collective == "nnz_rs"

    def boom(_s):
        raise AssertionError("replay must not measure")

    cache2 = ScheduleCache(path=str(path))
    res2 = tune_dist_spmm(csr, 12, mesh=mesh, axis="shards", cache=cache2,
                          measure=boom)
    assert res2.from_cache and res2.n_measurements == 0
    assert res2.schedule == res.schedule
    assert res2.schedule.collective == "nnz_rs"


def test_v2_cache_records_dropped(tmp_path):
    """Pre-collective (v2) records silently re-tune: a version mismatch
    drops the whole file instead of replaying a schedule that pins the
    wire mode to None."""
    path = tmp_path / "cache.json"
    cache = ScheduleCache(path=str(path))
    key = "dist:dummy|mesh:8"
    cache.put(key, TuneRecord(schedule=Schedule(collective="nnz_rs"),
                              us_per_call=1.0))
    cache.save()

    fresh = ScheduleCache(path=str(path))
    assert fresh.get(key) is not None  # sanity: v4 file round-trips

    raw = json.loads(path.read_text())
    assert raw["version"] == SCHEMA_VERSION == 4
    raw["version"] = 2
    path.write_text(json.dumps(raw))
    stale = ScheduleCache(path=str(path))
    assert stale.get(key) is None
    assert len(stale) == 0


def test_dist_cache_key_includes_mesh_size():
    """One matrix tuned on two mesh widths must produce two records —
    the best wire mode depends on the axis size."""
    from repro.sparse import power_law_csr

    csr = power_law_csr(64, 48, avg_degree=5.0, alpha=1.5, seed=0)
    mesh = jax.make_mesh((1,), ("shards",))
    cache = ScheduleCache(path=None)
    res = tune_dist_spmm(csr, 12, mesh=mesh, axis="shards", cache=cache,
                         measure=lambda s: 1.0, top_k=1, hill_steps=0)
    assert res.key == f"dist:{cache_key(csr, 12)}|mesh:1"
