"""Empirical schedule autotuner (ISSUE 2; paper Table 4's search, made a
library subsystem).

``tune_schedule(csr, n_dense_cols)`` warm-starts from the static cost
model, measures the top-k candidates, hillclimbs around the winner, and
persists the result in a fingerprint-keyed on-disk cache
(``REPRO_TUNE_CACHE``) so the search runs once per matrix profile.
``schedule="tune"`` on ``repro.sparse.spmm/sddmm/segment_reduce`` routes
here; ``cached_or_auto`` is the measurement-free serving-path resolver;
``calibrate`` feeds measured timings back into ``Schedule.auto``'s cost
model.  See DESIGN.md §6.
"""
from .cache import (  # noqa: F401
    SCHEMA_VERSION,
    ScheduleCache,
    TuneRecord,
    cache_key,
    default_cache,
    default_cache_path,
    fingerprint,
    fingerprint_from_lengths,
    set_default_cache,
)
from .calibrate import (  # noqa: F401
    CalibrationResult,
    CalibrationSample,
    calibrate,
    collect_samples,
    fit_weights,
    model_regret,
)
from .measure import (  # noqa: F401
    bench_iters,
    make_eb_runner,
    make_rb_runner,
    make_runner,
    measure_schedule,
    time_fn,
)
from .search import (  # noqa: F401
    TuneResult,
    cached_or_auto,
    schedule_key,
    tune_schedule,
    tune_segment_reduce,
)
