"""Empirical schedule autotuner (ISSUE 2; paper Table 4's search, made a
library subsystem).

``tune_schedule(csr, n_dense_cols)`` warm-starts from the static cost
model, measures the top-k candidates, hillclimbs around the winner, and
persists the result in a fingerprint-keyed on-disk cache
(``REPRO_TUNE_CACHE``) so the search runs once per matrix profile.
``schedule="tune"`` on ``repro.sparse.spmm/sddmm/segment_reduce`` routes
here; ``cached_or_auto`` is the measurement-free serving-path resolver;
``calibrate`` feeds measured timings back into ``Schedule.auto``'s cost
model.  ``tune_moe_dispatch`` applies the same machinery to the MoE
grouped-matmul dispatch space (token_tile × capacity × f/d tiles, keyed
by the expert-segment histogram), and the cache is namespaced per
backend + device kind so fleets ship pre-tuned files per hardware
generation.  ``tune_sparse_attention`` tunes the fused attention
kernels, keyed per direction (fwd/bwd) and head count.  See DESIGN.md
§6–§7, §9.

Since the §14 refactor every tuner is a thin wrapper over one search
framework: ``tune.space`` declares the axes (``Axis``/``SearchSpace``)
and ``tune.driver.drive`` runs the one budgeted loop (replay → seed →
cost-rank → top-k measure → gated axis variants → per-axis hillclimb →
unified ``TuneRecord``), which is what lets searches span axes jointly
(collective × value_dtype, per-boundary fuse bits).
"""
from .cache import (  # noqa: F401
    MIGRATIONS,
    SCHEMA_VERSION,
    ScheduleCache,
    TuneRecord,
    cache_key,
    cache_namespace,
    default_cache,
    default_cache_path,
    fingerprint,
    fingerprint_from_lengths,
    legacy_cache_path,
    migrate_records,
    set_default_cache,
)
from .attention import (  # noqa: F401
    attention_cache_key,
    tune_sparse_attention,
)
from .calibrate import (  # noqa: F401
    CalibrationResult,
    CalibrationSample,
    calibrate,
    collect_samples,
    fit_weights,
    model_regret,
)
from .measure import (  # noqa: F401
    bench_iters,
    make_dist_runner,
    make_eb_runner,
    make_rb_runner,
    make_runner,
    measure_dist_schedule,
    measure_schedule,
    time_fn,
)
from .moe import (  # noqa: F401
    MoeDispatchSchedule,
    dropped_tokens,
    measure_moe_dispatch,
    moe_cache_key,
    moe_cached_or_default,
    moe_capacity,
    moe_schedule_key,
    tune_moe_dispatch,
)
from .driver import (  # noqa: F401
    TuneResult,
    drive,
)
from .space import (  # noqa: F401
    Axis,
    CapacityAxis,
    CollectiveAxis,
    EpilogueAxis,
    FuseBoundaryAxis,
    MoeTilingAxis,
    SearchContext,
    SearchSpace,
    SkewAxis,
    StrategyAxis,
    TilingAxis,
    ValueDtypeAxis,
)
from .search import (  # noqa: F401
    DEFAULT_VALUE_DTYPES,
    DIST_VALUE_DTYPES,
    cached_or_auto,
    schedule_key,
    tune_dist_spmm,
    tune_schedule,
    tune_segment_reduce,
)
from .calibrate import samples_from_results  # noqa: F401
