"""Beyond-paper benchmarks: MoE segment-group dispatch and the data-aware
selector's prediction quality.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.core import select_schedule
from repro.models.moe import apply_moe, init_moe
from repro.sparse.random import matrix_stats

from ._util import geomean, make_runner, suite, time_fn


def moe_dispatch(quick=True):
    """Capacity/segment dispatch (grouped GEMM over per-expert segments)
    vs the naive per-token weight-gather formulation."""
    cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"]).scaled(
        d_model=256, moe_d_ff=256, n_experts=8, experts_per_token=2)
    p = init_moe(cfg, jax.random.PRNGKey(0))
    t_tokens = 1024 if quick else 8192
    x = jax.random.normal(jax.random.PRNGKey(1), (t_tokens, cfg.d_model))

    seg = jax.jit(lambda p, x: apply_moe(cfg, p, x, None)[0])

    def naive(p, x):
        logits = x @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        topv, topi = jax.lax.top_k(probs, cfg.experts_per_token)
        topv = topv / topv.sum(-1, keepdims=True)
        wg = p["wg"][topi]  # (T, k, D, F) weight gather — the naive path
        wi = p["wi"][topi]
        wo = p["wo"][topi]
        h = jax.nn.silu(jnp.einsum("td,tkdf->tkf", x, wg)) * jnp.einsum(
            "td,tkdf->tkf", x, wi)
        y = jnp.einsum("tkf,tkfd->tkd", h, wo)
        return jnp.einsum("tkd,tk->td", y, topv)

    naive_j = jax.jit(naive)
    t_seg = time_fn(seg, p, x)
    t_naive = time_fn(naive_j, p, x)
    return [("beyond/moe_dispatch", t_seg * 1e6,
             f"speedup_vs_weight_gather={t_naive / t_seg:.3f}")]


def moe_tuner_gap(quick=True):
    """Tuned-vs-default MoE dispatch (ISSUE 3): tune the token-tile ×
    capacity × (f_tile, d_tile) space per expert histogram (memory-only
    cache) and report the measured win over the static default point."""
    from repro.models.moe import (balanced_expert_lengths, default_dispatch,
                                  moe_tune_dispatch, skewed_expert_lengths)
    from repro.tune import ScheduleCache
    from repro.tune.moe import moe_schedule_key

    cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"]).scaled(
        d_model=128, moe_d_ff=128 if quick else 256, n_experts=8,
        experts_per_token=2)
    t_tokens = 512 if quick else 2048
    balanced = balanced_expert_lengths(cfg, t_tokens)
    skewed = skewed_expert_lengths(cfg, t_tokens)

    cache = ScheduleCache(path=None)  # never touch the user's cache
    base = default_dispatch(cfg)
    rows, wins = [], []
    for name, lengths in (("balanced", balanced), ("skewed", skewed)):
        res = moe_tune_dispatch(cfg, t_tokens, expert_lengths=lengths,
                                cache=cache, warmup=1, iters=3)
        # memory-only cache -> never a replay: the default's timing is
        # already in the tuner's own measured pool
        t_base = res.measured[moe_schedule_key(base)]
        wins.append(t_base / max(res.us_per_call, 1e-9))
        s = res.schedule
        rows.append((f"beyond/moe_tuner/{name}", res.us_per_call,
                     f"tuned=tt{s.token_tile}/cf{s.capacity_factor:g}"
                     f"/f{s.f_tile}/d{s.d_tile},default_us={t_base:.1f},"
                     f"tuned_vs_default={wins[-1]:.3f}"))
    rows.append(("beyond/moe_tuner_gap", 0.0,
                 f"tuned_vs_default_geomean={geomean(wins):.3f}"))
    return rows


def fused_attention(quick=True):
    """Fused one-pass SDDMM→segment-softmax→SpMM *kernel* vs the unfused
    3-pass kernel composition (ISSUE 4).

    Unlike the schedule benchmarks (which time jitted analogues — the
    kernel-*shape* question), fusion is a question about kernel *passes*,
    so this times the actual Pallas programs, the same way
    ``tune_segment_reduce`` times its real kernel: fused = the single
    ``kernels.fused_attention`` pass with online renormalization;
    unfused = SDDMM kernel → segment-max kernel → exp/normalize →
    segment-sum kernel → SpMM kernel over the same pattern, with the
    (nnz,)-sized score/weight intermediates materialized between passes.
    The win grows with nnz (more per-pass traffic deleted)."""
    from repro.kernels import ops as kops
    from repro.sparse import Schedule, sparse_attention
    from repro.sparse import segment_reduce as seg_reduce
    from repro.sparse.formats import GroupedCOO, round_up

    d, dv = (32, 32) if quick else (64, 64)
    # quick mode sticks to the sizes whose win is robust to a loaded
    # machine (the CI gate consumes the geomean; larger graphs win more
    # on an idle box but flap under runner contention)
    sizes = ((256, 256), (512, 512)) if quick else \
        ((1024, 1024), (2048, 2048))
    mats = suite(sizes=sizes, densities=(0.01,), skews=(0.0, 1.5))
    sched = Schedule("eb", nnz_tile=256, group_size=32)
    rows_out, wins = [], []
    for (m, n, dens, s), csr in mats:
        coo = csr.tocoo()
        rows, cols = coo.rows, coo.cols
        nnz = csr.nnz
        q = jax.random.normal(jax.random.PRNGKey(0), (m, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (n, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (n, dv))
        scale = d ** -0.5
        nnz_pad = max(round_up(max(nnz, 1), 256), 256)

        def fused(q, k, v):
            return sparse_attention((rows, cols, m), q, k, v,
                                    schedule=sched, scale=scale)

        def unfused(q, k, v):
            from repro.sparse import sddmm as sddmm_op

            sc = sddmm_op(rows, cols, q, k) * scale          # pass 1
            mx = seg_reduce(rows, sc[:, None], m, schedule=sched,
                            op="max")[:, 0]                  # pass 2
            mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
            p = jnp.exp(sc - mx[rows])
            tot = seg_reduce(rows, p[:, None], m,
                             schedule=sched)[:, 0]           # pass 3
            w = p / jnp.maximum(tot[rows], 1e-30)
            g = GroupedCOO(rows=jnp.pad(rows, (0, nnz_pad - nnz)),
                           cols=jnp.pad(cols, (0, nnz_pad - nnz)),
                           vals=jnp.pad(w, (0, nnz_pad - nnz)),
                           shape=(m, n), nnz=nnz, nnz_tile=256)
            return kops.spmm(g, v, sched)                    # pass 4

        t_fused = time_fn(fused, q, k, v, warmup=1, iters=3)
        t_unfused = time_fn(unfused, q, k, v, warmup=1, iters=3)
        wins.append(t_unfused / max(t_fused, 1e-12))
        rows_out.append((f"beyond/fused_attention/m{m}_skew{s}",
                         t_fused * 1e6,
                         f"unfused_us={t_unfused * 1e6:.1f},"
                         f"fused_vs_unfused={wins[-1]:.3f},nnz={nnz}"))
    rows_out.append(("beyond/fused_attention_gap", 0.0,
                     f"fused_vs_unfused_geomean={geomean(wins):.3f}"))
    return rows_out


def fused_attention_bwd(quick=True):
    """Fused one-launch attention *backward* vs the spec-recompute VJP
    composed of kernel passes (ISSUE 5).

    The fused side is ``kernels.fused_attention_bwd``: one (H, 2,
    nnz_tiles) launch recomputing probabilities from the forward's
    (m, l) residuals, scattering δ and dV in phase 0 and dQ/dK in phase
    1.  The unfused side realizes the PR-4 spec-recompute VJP as the
    kernel passes training actually paid: SDDMM (score recompute) →
    segment-max → segment-sum (weights) → SDDMM (dw) → segment-sum (δ)
    → three transpose/plain SpMM passes (dV, dQ, dK) — 8 kernel
    launches with (nnz,)-sized intermediates between them.  The jitted
    pure-JAX spec VJP is reported as info alongside."""
    from repro.kernels import ops as kops
    from repro.kernels.fused_attention import (
        fused_sparse_attention,
        fused_sparse_attention_bwd,
        sparse_attention_bwd_ref,
    )
    from repro.sparse import Schedule
    from repro.sparse import sddmm as sddmm_op
    from repro.sparse import segment_reduce as seg_reduce
    from repro.sparse.formats import GroupedCOO, round_up

    d, dv = (32, 32) if quick else (64, 64)
    # same size policy as the forward bench: the CI gate consumes the
    # us geomean, so quick mode sticks to contention-robust sizes
    sizes = ((256, 256), (512, 512)) if quick else \
        ((1024, 1024), (2048, 2048))
    mats = suite(sizes=sizes, densities=(0.01,), skews=(0.0, 1.5))
    sched = Schedule("eb", nnz_tile=256, group_size=32)
    rows_out, wins = [], []
    for (m, n, dens, s), csr in mats:
        coo = csr.tocoo()
        rows, cols = coo.rows, coo.cols
        nnz = csr.nnz
        q = jax.random.normal(jax.random.PRNGKey(0), (m, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (n, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (n, dv))
        dout = jax.random.normal(jax.random.PRNGKey(3), (m, dv))
        scale = d ** -0.5
        nnz_pad = max(round_up(max(nnz, 1), 256), 256)
        pad = nnz_pad - nnz
        rows_p = jnp.pad(rows, (0, pad))
        cols_p = jnp.pad(cols, (0, pad))
        # the (m, l) residuals the custom VJP carries across fwd -> bwd
        _, mst, lst = fused_sparse_attention(
            rows_p, cols_p, q[None], k[None], v[None], n_rows=m, nnz=nnz,
            nnz_tile=256, dv_tile=dv, scale=scale,
            group_size=sched.group_size, strategy=sched.strategy)

        def fused(q, k, v, do):
            return fused_sparse_attention_bwd(
                rows_p, cols_p, q[None], k[None], v[None], do[None],
                mst, lst, n_rows=m, nnz=nnz, nnz_tile=256, scale=scale,
                group_size=sched.group_size, strategy=sched.strategy)

        def unfused(q, k, v, do):
            sc = sddmm_op(rows, cols, q, k) * scale          # pass 1
            mx = seg_reduce(rows, sc[:, None], m, schedule=sched,
                            op="max")[:, 0]                  # pass 2
            mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
            p = jnp.exp(sc - mx[rows])
            tot = seg_reduce(rows, p[:, None], m,
                             schedule=sched)[:, 0]           # pass 3
            w = p / jnp.maximum(tot[rows], 1e-30)
            dw = sddmm_op(rows, cols, do, v)                 # pass 4
            delta = seg_reduce(rows, (w * dw)[:, None], m,
                               schedule=sched)[:, 0]         # pass 5
            ds = w * (dw - delta[rows]) * scale

            def grouped(r, c, vals, shape):
                return GroupedCOO(rows=r, cols=c,
                                  vals=jnp.pad(vals, (0, pad)),
                                  shape=shape, nnz=nnz, nnz_tile=256)

            dv_ = kops.spmm(grouped(cols_p, rows_p, w, (n, m)),
                            do, sched)                       # pass 6
            dq = kops.spmm(grouped(rows_p, cols_p, ds, (m, n)),
                           k, sched)                         # pass 7
            dk = kops.spmm(grouped(cols_p, rows_p, ds, (n, m)),
                           q, sched)                         # pass 8
            return dq, dk, dv_

        spec = jax.jit(lambda q, k, v, do: sparse_attention_bwd_ref(
            rows, cols, q, k, v, do, n_rows=m, scale=scale))
        t_fused = time_fn(fused, q, k, v, dout, warmup=1, iters=3)
        t_unfused = time_fn(unfused, q, k, v, dout, warmup=1, iters=3)
        t_spec = time_fn(spec, q, k, v, dout, warmup=1, iters=3)
        wins.append(t_unfused / max(t_fused, 1e-12))
        rows_out.append((f"beyond/fused_attention_bwd/m{m}_skew{s}",
                         t_fused * 1e6,
                         f"unfused_us={t_unfused * 1e6:.1f},"
                         f"spec_vjp_us={t_spec * 1e6:.1f},"
                         f"fused_bwd_vs_unfused={wins[-1]:.3f},nnz={nnz}"))
    rows_out.append(("beyond/fused_attention_bwd_gap", 0.0,
                     f"fused_bwd_vs_unfused_geomean={geomean(wins):.3f}"))
    return rows_out


def fusion_planner(quick=True):
    """Planner-fused vs fully-split execution of the landed chains
    (ISSUE 6): the two-layer GCN chain (spmm → ewise → spmm, 2 launches
    fused vs 2 launches + 1 XLA elementwise pass split) and the MoE
    expert-GEMM chain (grouped_matmul → ewise, 1 launch fused vs GEMM +
    XLA SiLU pass).  Each row times ``run_plan`` on the greedy plan
    against the ``split_all`` plan of the *same* chain; the tuner's
    pick is recorded through a memory-only cache and reported in-band
    so the bench doubles as a tune_plan smoke."""
    import numpy as _np

    import repro.fuse as F
    from repro.sparse import Schedule
    from repro.tune import ScheduleCache

    sched = Schedule("eb", nnz_tile=256, group_size=32)
    cache = ScheduleCache(path=None)  # never touch the user's cache
    rows, wins = [], []

    # two-layer GCN chains over the synthetic suite
    sizes = ((256, 256), (512, 512)) if quick else \
        ((1024, 1024), (2048, 2048))
    mats = suite(sizes=sizes, densities=(0.01,), skews=(0.0, 1.5))
    c = 32 if quick else 64
    rng = _np.random.default_rng(0)
    for (m, n, dens, s), csr in mats:
        x = jnp.asarray(rng.normal(size=(m, c)), jnp.float32)
        w0 = jnp.asarray(rng.normal(size=(c, c)) * c ** -0.5, jnp.float32)
        w1 = jnp.asarray(rng.normal(size=(c, c)) * c ** -0.5, jnp.float32)
        b0 = jnp.asarray(rng.normal(size=(c,)), jnp.float32)
        chain, params = F.gcn_chain(csr, (w0, w1), (b0, None),
                                    schedule=sched)
        fused, split = F.plan(chain), F.split_all(chain)
        t_fused = time_fn(lambda xx, p=fused, pr=params:
                          F.run_plan(p, xx, pr), x, warmup=1, iters=3)
        t_split = time_fn(lambda xx, p=split, pr=params:
                          F.run_plan(p, xx, pr), x, warmup=1, iters=3)
        res = F.tune_plan(chain, x, params, cache=cache, warmup=1, iters=2)
        wins.append(t_split / max(t_fused, 1e-12))
        rows.append((f"beyond/fusion_planner/gcn_m{m}_skew{s}",
                     t_fused * 1e6,
                     f"split_us={t_split * 1e6:.1f},"
                     f"launches={fused.n_launches},"
                     f"tuned={res.schedule.tag},"
                     f"fused_vs_split={wins[-1]:.3f}"))

    # MoE expert-GEMM chain (SiLU + per-expert bias on the output block)
    tile = 128
    t_tiles = 4 if quick else 16
    d = f = 128 if quick else 256
    e = 8
    x = jnp.asarray(rng.normal(size=(t_tiles * tile, d)), jnp.float32)
    te = jnp.asarray(rng.integers(0, e, size=(t_tiles,)), jnp.int32)
    w = jnp.asarray(rng.normal(size=(e, d, f)) * d ** -0.5, jnp.float32)
    b = jnp.asarray(rng.normal(size=(e, f)), jnp.float32)
    chain, params = F.moe_expert_chain(te, w, b, token_tile=tile)
    fused, split = F.plan(chain), F.split_all(chain)
    t_fused = time_fn(lambda xx: F.run_plan(fused, xx, params), x,
                      warmup=1, iters=3)
    t_split = time_fn(lambda xx: F.run_plan(split, xx, params), x,
                      warmup=1, iters=3)
    res = F.tune_plan(chain, x, params, cache=cache, warmup=1, iters=2)
    wins.append(t_split / max(t_fused, 1e-12))
    rows.append((f"beyond/fusion_planner/moe_t{t_tiles * tile}",
                 t_fused * 1e6,
                 f"split_us={t_split * 1e6:.1f},"
                 f"launches={fused.n_launches},tuned={res.schedule.tag},"
                 f"fused_vs_split={wins[-1]:.3f}"))

    rows.append(("beyond/fusion_planner_gap", 0.0,
                 f"fused_vs_split_geomean={geomean(wins):.3f}"))
    return rows


def selector_quality(quick=True):
    """Behavioral check of the data-aware selector (DA-SpMM-style): it
    must choose nnz-split + segment for skewed matrices (balance-bound)
    and be waste-aware for short-row regimes. Reports decisions + the
    waste the choice avoids, then the empirical tuned-vs-auto-vs-oracle
    gap (the autotuner's tracked win, ISSUE 2)."""
    from repro.core import Schedule, candidate_schedules, group_waste_fraction
    from repro.tune import ScheduleCache, measure_schedule, tune_schedule
    import numpy as _np

    mats = suite(sizes=((2048, 2048),), densities=(0.002, 0.01),
                 skews=(0.0, 2.0))
    n_dense = 4
    rows = []
    correct = 0
    for (m, n, d, s), csr in mats:
        stats = matrix_stats(csr)
        sel = select_schedule(stats, n_dense)
        lengths = _np.asarray(csr.row_lengths())
        expect_eb = stats["row_cv"] > 1.0
        ok = (sel.kernel == "eb") == expect_eb or not expect_eb
        correct += ok
        rows.append((f"beyond/selector/d{d}_skew{s}", 0.0,
                     f"picked={sel.kernel}/G{sel.group_size},"
                     f"row_cv={stats['row_cv']:.2f},"
                     f"waste32={group_waste_fraction(lengths, 32):.2f},"
                     f"wasteG={group_waste_fraction(lengths, sel.group_size):.2f},"
                     f"ok={ok}"))
    rows.append(("beyond/selector_quality", 0.0,
                 f"decision_accuracy={correct}/{len(mats)}"))

    # tuned vs auto vs measured oracle (memory-only cache: the benchmark
    # must not read or pollute the user's persistent cache)
    cache = ScheduleCache(path=None)
    gap_mats = mats if not quick else mats[:3]
    tuned_vs_auto, auto_vs_oracle, tuned_vs_oracle = [], [], []
    for (m, n, d, s), csr in gap_mats:
        res = tune_schedule(csr, n_dense, cache=cache, warmup=1, iters=3)
        auto = Schedule.auto(matrix_stats(csr), n_dense)
        t_auto = measure_schedule(csr, n_dense, auto, warmup=1,
                                  iters=3) * 1e6
        t_oracle = min([measure_schedule(csr, n_dense, sc, warmup=1, iters=2)
                        * 1e6 for sc in candidate_schedules(n_dense)]
                       + [res.us_per_call])
        tuned_vs_auto.append(t_auto / max(res.us_per_call, 1e-9))
        auto_vs_oracle.append(t_auto / max(t_oracle, 1e-9))
        tuned_vs_oracle.append(res.us_per_call / max(t_oracle, 1e-9))
        rows.append((f"beyond/tuner/d{d}_skew{s}", res.us_per_call,
                     f"tuned={res.schedule.kernel}/G{res.schedule.group_size},"
                     f"auto_us={t_auto:.1f},oracle_us={t_oracle:.1f},"
                     f"tuned_vs_auto={tuned_vs_auto[-1]:.3f}"))
    rows.append(("beyond/tuner_gap", 0.0,
                 f"tuned_vs_auto_geomean={geomean(tuned_vs_auto):.3f},"
                 f"auto_vs_oracle_geomean={geomean(auto_vs_oracle):.3f},"
                 f"tuned_vs_oracle_geomean={geomean(tuned_vs_oracle):.3f}"))
    return rows


def _dist_mesh():
    """The 1-D reduction mesh over whatever devices exist: 8 forced host
    devices in the CI ``dist`` lane, 1 elsewhere (degenerate but valid —
    collectives compile away, win ratios sit at ~1.0)."""
    from repro.launch.mesh import make_reduction_mesh

    mesh = make_reduction_mesh()
    return mesh, int(mesh.shape["shards"])


def dist_attention_gap(quick=True):
    """Tuned-vs-fixed collective mode for distributed fused attention
    (DESIGN.md §12): time ``dist_attention_shard_map`` under every
    feasible wire mode (row / nnz_ar / nnz_rs) on the real mesh, report
    the fixed atomic-style psum ('nnz_ar') vs the measured best — the
    best is the measured minimum of a pool containing the fixed mode, so
    the geomean is >= 1.0 by construction — and, on a >1-device mesh,
    the compiled nnz_rs collective bytes against the roofline
    prediction (acceptance: within 10%)."""
    from repro.roofline.analysis import (collective_bytes,
                                         predict_attention_collective_bytes)
    from repro.sparse import Schedule
    from repro.sparse.distributed import (dist_attention_shard_map,
                                          partition_nnz_coo,
                                          partition_rows_coo)
    from repro.sparse.random import power_law_csr, random_csr

    mesh, axis_size = _dist_mesh()
    n = 128 if quick else 256
    d = dv = 16 if quick else 32
    h = 2
    sched = Schedule("eb", nnz_tile=64, group_size=8)
    mats = [("powerlaw", power_law_csr(n, n, avg_degree=6.0, alpha=1.6,
                                       seed=0)),
            ("uniform", random_csr(n, n, density=0.05, seed=1))]
    modes = ["nnz_ar"]
    if n % axis_size == 0:
        modes += ["nnz_rs", "row"]

    q = jax.random.normal(jax.random.PRNGKey(0), (h, n, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (h, n, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (h, n, dv))

    rows_out, wins = [], []
    bytes_row = None
    for name, csr in mats:
        timings = {}
        for mode in modes:
            part = partition_rows_coo if mode == "row" else partition_nnz_coo
            rows, cols, _, _ = part(csr, axis_size, sched.nnz_tile,
                                    pattern_only=True, phantom_row=True)
            fn = jax.jit(lambda r, c, qq, kk, vv, _m=mode: (
                dist_attention_shard_map(r, c, qq, kk, vv, n_rows=n,
                                         mesh=mesh, axis="shards",
                                         mode=_m, schedule=sched)))
            timings[mode] = time_fn(fn, rows, cols, q, k, v,
                                    warmup=1, iters=3) * 1e6
            if (bytes_row is None and mode == "nnz_rs" and axis_size > 1):
                compiled = fn.lower(rows, cols, q, k, v).compile()
                colls = collective_bytes(compiled.as_text())
                meas = sum(rec["bytes"] for rec in colls.values())
                pred = predict_attention_collective_bytes(
                    "nnz_rs", n_heads=h, n_rows=n, dv_pad=dv,
                    axis_size=axis_size)
                bytes_row = ("beyond/dist_attention_bytes", 0.0,
                             f"mode=nnz_rs,coll_bytes_meas={meas},"
                             f"coll_bytes_pred={pred},"
                             f"meas_vs_pred={meas / max(pred, 1):.3f}")
        best_mode = min(timings, key=timings.get)
        wins.append(timings["nnz_ar"] / max(timings[best_mode], 1e-9))
        detail = ",".join(f"{m}_us={timings[m]:.1f}" for m in modes)
        rows_out.append((f"beyond/dist_attention/{name}",
                         timings[best_mode],
                         f"best={best_mode},axis={axis_size},{detail},"
                         f"tuned_vs_fixed={wins[-1]:.3f}"))
    if bytes_row is not None:
        rows_out.append(bytes_row)
    rows_out.append(("beyond/dist_attention_gap", 0.0,
                     f"tuned_vs_fixed_geomean={geomean(wins):.3f}"))
    return rows_out


def dist_moe_gap(quick=True):
    """Tuned-vs-fixed expert-parallel writeback collective (DESIGN.md
    §12): ``moe_tune_collective`` measures ``apply_moe`` end to end
    under psum ('nnz_ar', the fixed historical mode) and psum_scatter
    ('nnz_rs') on the real mesh and picks the winner; the win ratio is
    fixed/best >= 1.0 by construction.  On a >1-device mesh the
    compiled nnz_rs collective bytes are checked against the roofline
    prediction."""
    from repro.models.moe import (ShardingCtx, default_dispatch,
                                  moe_tune_collective)
    from repro.roofline.analysis import (collective_bytes,
                                         predict_collective_bytes)
    from repro.tune import ScheduleCache
    from repro.tune.moe import moe_schedule_key

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("model",))
    ctx = ShardingCtx(mesh=mesh, data_axes=(), model_axis="model")
    cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"]).scaled(
        d_model=64, moe_d_ff=64 if quick else 128, n_experts=8,
        experts_per_token=2)
    t_tokens = 256 if quick else 1024
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (t_tokens, cfg.d_model))

    cache = ScheduleCache(path=None)  # never touch the user's cache
    res = moe_tune_collective(cfg, p, x, ctx, cache=cache,
                              warmup=1, iters=3)
    base = default_dispatch(cfg)
    fixed_key = moe_schedule_key(base.replace(collective="nnz_ar"))
    t_fixed = res.measured[fixed_key]
    win = t_fixed / max(res.us_per_call, 1e-9)
    rows = [(f"beyond/dist_moe/{key.rsplit('w[', 1)[-1].rstrip(']')}",
             us, f"axis={n_dev}")
            for key, us in sorted(res.measured.items())]
    if n_dev > 1:
        sched = base.replace(collective="nnz_rs")
        fn = jax.jit(lambda xx: apply_moe(cfg, p, xx, ctx,
                                          dispatch=sched)[0])
        compiled = fn.lower(x).compile()
        colls = collective_bytes(compiled.as_text())
        meas = sum(rec["bytes"] for rec in colls.values())
        pred = predict_collective_bytes("nnz_rs", (t_tokens, cfg.d_model),
                                        axis_size=n_dev)
        rows.append(("beyond/dist_moe_bytes", 0.0,
                     f"mode=nnz_rs,coll_bytes_meas={meas},"
                     f"coll_bytes_pred={pred},"
                     f"meas_vs_pred={meas / max(pred, 1):.3f}"))
    rows.append(("beyond/dist_moe_gap", 0.0,
                 f"tuned={res.schedule.collective},"
                 f"fixed_us={t_fixed:.1f},"
                 f"tuned_vs_fixed_geomean={win:.3f}"))
    return rows


def lowprec_spmm(quick=True):
    """Low-precision value storage as a schedule axis (ISSUE 9,
    DESIGN.md §13): f32 vs bf16 vs int8 SpMM on the same patterns.

    Two numbers per (matrix, dtype), from the same jitted schedule
    analogue the tuner measures (narrow arrays genuinely fed):

    * ``us`` — XLA-CPU wall clock.  Honest but a poor proxy for the
      paper's hardware: this backend converts bf16 through a scalar
      path, so the bandwidth saving does not reach the clock here.
    * modeled traffic bytes (``roofline.predict_spmm_traffic_bytes``)
      — the gather-dominated stream model a bandwidth-bound backend
      realizes; the headline ``modeled_speedup`` geomeans come from it
      (bf16 ~2x fewer bytes than f32 on these shapes).

    The tuner's parity gate is reported alongside (``err``): every
    narrow row shown is within the 5% default ``error_budget``.
    """
    from repro.core import Schedule
    from repro.roofline.analysis import predict_spmm_traffic_bytes
    from repro.sparse.random import power_law_csr, random_csr
    from repro.tune.search import _dtype_parity_error

    n = 4096 if quick else 16384
    C = 64
    mats = [("uniform", random_csr(n, n, density=0.004, seed=0)),
            ("powerlaw", power_law_csr(n, n, avg_degree=16.0, alpha=1.8,
                                       seed=1))]
    base = Schedule("eb", nnz_tile=512, group_size=32,
                    strategy="accumulate", col_tile=C)

    rows = []
    ratios = {"bfloat16": {"us": [], "bytes": []},
              "int8": {"us": [], "bytes": []}}
    for name, csr in mats:
        per = {}
        for vd in (None, "bfloat16", "int8"):
            fn, args = make_runner(csr, C, base.replace(value_dtype=vd))
            lanes = args[0].shape[0]
            t = time_fn(fn, *args, warmup=1, iters=3) * 1e6
            by = predict_spmm_traffic_bytes(
                lanes, csr.shape[0], C, value_dtype=vd,
                scales_rows=csr.shape[0] if vd == "int8" else 0)
            per[vd] = (t, by)
        t32, b32 = per[None]
        rows.append((f"beyond/lowprec/{name}/f32", t32,
                     f"modeled_mb={b32 / 1e6:.1f},nnz={csr.nnz}"))
        for vd in ("bfloat16", "int8"):
            t, by = per[vd]
            err = _dtype_parity_error(csr, C, vd)
            ratios[vd]["us"].append(t32 / max(t, 1e-9))
            ratios[vd]["bytes"].append(b32 / by)
            rows.append((f"beyond/lowprec/{name}/{vd}", t,
                         f"modeled_mb={by / 1e6:.1f},"
                         f"modeled_speedup={b32 / by:.2f},"
                         f"us_vs_f32={t32 / max(t, 1e-9):.2f},"
                         f"err={err:.4f}"))
    rows.append((
        "beyond/lowprec_spmm", 0.0,
        f"modeled_speedup_geomean_bf16={geomean(ratios['bfloat16']['bytes']):.2f},"
        f"modeled_speedup_geomean_int8={geomean(ratios['int8']['bytes']):.2f},"
        f"us_geomean_bf16={geomean(ratios['bfloat16']['us']):.2f},"
        f"us_geomean_int8={geomean(ratios['int8']['us']):.2f}"))
    return rows


def skew_tuner_gap(quick=True):
    """Skew-aware two-level scheduling on power-law graphs (ISSUE 7).

    For each power-law / graph-pattern matrix, ``tune_schedule`` searches
    the full space *including* the split/merge thresholds (DESIGN.md
    §11) against a memory-only cache; the best *static* point is the
    fastest schedule in the same run's measured pool that carries no
    skew thresholds.  Tuned and static timings come from one ``_Memo``
    sweep, so the win ratio compares like with like — and since the
    tuner picks the measured minimum, the geomean is >= 1.0 whenever a
    skew point wins anywhere and == 1.0 where the plain layout is
    already optimal (the 'roadnet' control row should sit at ~1.0).
    """
    import re as _re

    from repro.sparse.random import graph_pattern_csr, power_law_csr
    from repro.tune import ScheduleCache, tune_schedule

    n = 1024 if quick else 4096
    n_dense = 4
    mats = [("powerlaw", power_law_csr(n, n, avg_degree=8.0, alpha=1.8,
                                       seed=0))]
    mats += [(p, graph_pattern_csr(p, n, seed=1))
             for p in ("web", "social", "roadnet")]

    cache = ScheduleCache(path=None)  # never touch the user's cache
    rows, wins = [], []
    for name, csr in mats:
        res = tune_schedule(csr, n_dense, cache=cache, warmup=1, iters=3)
        # skew points carry ':s<split>:m<merge>' in their schedule_key
        # (':segment' has no digit after ':s', so it doesn't match)
        static = {k: v for k, v in res.measured.items()
                  if not _re.search(r":s\d", k)}
        t_static = min(static.values())
        wins.append(t_static / max(res.us_per_call, 1e-9))
        s = res.schedule
        skew = (f"s{s.split_threshold}/m{s.merge_threshold}"
                if s.is_skew else "plain")
        rows.append((f"beyond/skew/{name}", res.us_per_call,
                     f"tuned={s.kernel}/G{s.group_size}/{skew},"
                     f"static_us={t_static:.1f},"
                     f"tuned_vs_static={wins[-1]:.3f},nnz={csr.nnz}"))
    rows.append(("beyond/skew_gap", 0.0,
                 f"tuned_vs_static_geomean={geomean(wins):.3f}"))
    return rows


def joint_dist_gap(quick=True):
    """Joint collective × value-dtype search for distributed SpMM (ISSUE
    10, DESIGN.md §14): one ``tune_dist_spmm`` run searches local tiling
    × wire mode × storage width in a *single* objective.  The fixed
    baseline is the fastest f32 point in the same run's measured pool
    (keys without a ``:v[..]`` fragment) — what two sequential
    single-axis searches could at best deliver for the wire mode alone —
    so the win ratio (fixed/best) is >= 1.0 by construction: the joint
    winner is the measured minimum of a superset."""
    from repro.sparse.random import power_law_csr, random_csr
    from repro.tune import ScheduleCache, tune_dist_spmm

    mesh, axis_size = _dist_mesh()
    n = 512 if quick else 2048
    n_dense = 4
    mats = [("uniform", random_csr(n, n, density=0.01, seed=0)),
            ("powerlaw", power_law_csr(n, n, avg_degree=8.0, alpha=1.6,
                                       seed=1))]

    cache = ScheduleCache(path=None)  # never touch the user's cache
    rows, wins = [], []
    for name, csr in mats:
        res = tune_dist_spmm(csr, n_dense, mesh=mesh, axis="shards",
                             cache=cache, warmup=1, iters=3)
        f32 = {k: v for k, v in res.measured.items() if ":v[" not in k}
        t_fixed = min(f32.values())
        wins.append(t_fixed / max(res.us_per_call, 1e-9))
        s = res.schedule
        rows.append((f"beyond/joint_dist/{name}", res.us_per_call,
                     f"tuned={s.collective}/v{s.value_dtype or 'f32'},"
                     f"axis={axis_size},f32_best_us={t_fixed:.1f},"
                     f"n_measured={len(res.measured)},"
                     f"tuned_vs_fixed={wins[-1]:.3f}"))
    rows.append(("beyond/joint_dist_gap", 0.0,
                 f"tuned_vs_fixed_geomean={geomean(wins):.3f}"))
    return rows


def fuse_boundary_gap(quick=True):
    """Per-boundary fuse decisions on a 3-boundary chain (ISSUE 10,
    DESIGN.md §14): ``tune_plan`` on a 4-node GCN chain seeds the two
    all-or-nothing plans (greedy-fused, fully-split) and then hillclimbs
    *individual* boundary flips — a mixed tag like ``FSS`` is reachable
    only through the per-boundary search.  The fixed baseline is the
    faster all-or-nothing seed from the same measured pool, so the win
    ratio is >= 1.0 by construction."""
    import numpy as np

    from repro.core import Schedule
    from repro.fuse import gcn_chain, split_all, tune_plan
    from repro.fuse.planner import plan
    from repro.sparse.random import random_csr
    from repro.tune import ScheduleCache

    rng = np.random.default_rng(0)
    n = 64 if quick else 256
    d = 8 if quick else 16
    adj = random_csr(n, n, density=0.1, seed=0)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    b0 = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    sched = Schedule("eb", nnz_tile=128, group_size=8)
    chain, params = gcn_chain(adj, (w0, w1), (b0, b1),
                              final_activation="relu", schedule=sched)

    cache = ScheduleCache(path=None)  # never touch the user's cache
    res = tune_plan(chain, x, params, cache=cache, warmup=1, iters=3)
    seeds = {plan(chain).decision.tag, split_all(chain).decision.tag}
    t_fixed = min(res.measured[t] for t in seeds)
    win = t_fixed / max(res.us_per_call, 1e-9)
    rows = [(f"beyond/fuse_boundary/{tag}", us,
             "seed" if tag in seeds else "flip")
            for tag, us in sorted(res.measured.items())]
    rows.append(("beyond/fuse_boundary_gap", 0.0,
                 f"tuned={res.schedule.tag},fixed_us={t_fixed:.1f},"
                 f"n_measured={len(res.measured)},"
                 f"tuned_vs_fixed_geomean={win:.3f}"))
    return rows
