"""Mamba2-2.7B [arXiv:2405.21060]: attention-free SSD."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    conv_kernel=4, ssm_chunk=128, norm="rmsnorm",
)
