"""Tests for the unified Schedule API and the reduction-strategy registry.

Covers the ISSUE acceptance surface: every ``Schedule.named(...)`` point
against the SpMM oracle, coercion of every schedule-like input, the
SegmentGroup round-trip, user-registered strategies through both the
pure-JAX spec and the Pallas kernel path, CSR conversion caching, and the
ragged segment_reduce padding glue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DA_SPMM_POINTS,
    AtomicParallelism,
    GroupReduceStrategy,
    KernelSchedule,
    Schedule,
    SegmentGroup,
    as_schedule,
    available_strategies,
    candidate_schedules,
    enumerate_space,
    register_strategy,
    segment_group_reduce,
    segment_sum_ref,
    to_schedule,
)
from repro.kernels import ref
from repro.sparse import matrix_stats, random_csr, sddmm, segment_reduce, spmm

RTOL = ATOL = 2e-5


def _want_spmm(csr, b):
    coo = csr.tocoo()
    return np.asarray(
        ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, b, csr.shape[0]))


# ---------------------------------------------------------------------------
# Schedule construction + coercion
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(DA_SPMM_POINTS))
def test_named_schedules_match_oracle(name):
    csr = random_csr(150, 120, density=0.03, skew=1.0, seed=5)
    b = jax.random.normal(jax.random.PRNGKey(0), (120, 16))
    want = _want_spmm(csr, b)
    # by Schedule object, by name string, and by raw design-space point
    for schedule in (Schedule.named(name), name, DA_SPMM_POINTS[name]):
        got = np.asarray(spmm(csr, b, schedule=schedule))
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_from_point_matches_legacy_to_schedule():
    for p in enumerate_space()[:32]:
        assert Schedule.from_point(p) == to_schedule(p)


def test_kernel_schedule_is_schedule_alias():
    assert KernelSchedule is Schedule
    s = KernelSchedule("eb", nnz_tile=64, col_tile=8, group_size=8)
    assert isinstance(s, Schedule)


def test_segment_group_round_trips_through_schedule():
    for sg in (SegmentGroup(16, GroupReduceStrategy.PARALLEL),
               SegmentGroup(8, GroupReduceStrategy.SEGMENT),
               SegmentGroup(32, "accumulate")):
        s = Schedule.from_group(sg)
        assert s.group_size == sg.group_size
        assert s.segment_group == sg
        assert as_schedule(sg) == s


def test_from_group_fixes_indivisible_tile():
    # group 48 does not divide the default nnz_tile 256 -> lifted to lcm
    s = Schedule.from_group(SegmentGroup(48, GroupReduceStrategy.SEGMENT))
    assert s.nnz_tile % 48 == 0


def test_auto_schedule_selects_and_runs():
    csr = random_csr(200, 200, density=0.01, skew=2.0, seed=9)
    s = Schedule.auto(matrix_stats(csr), 8)
    assert s in candidate_schedules(8)
    b = jax.random.normal(jax.random.PRNGKey(1), (200, 8))
    got = np.asarray(spmm(csr, b, schedule="auto"))
    np.testing.assert_allclose(got, _want_spmm(csr, b), rtol=RTOL, atol=ATOL)


def test_schedule_validation():
    with pytest.raises(ValueError):
        Schedule("xx")
    with pytest.raises(ValueError):
        Schedule("eb", nnz_tile=100, group_size=32)
    with pytest.raises(ValueError):
        Schedule("eb", strategy="not-registered")
    with pytest.raises(ValueError):
        Schedule.named("EB+XX")
    with pytest.raises(TypeError):
        as_schedule(3.14)
    # 'auto' without matrix statistics must raise, not silently default
    with pytest.raises(ValueError):
        as_schedule("auto")
    assert as_schedule("auto", stats={"nnz": 10, "row_mean": 2.0,
                                      "row_max": 4, "n_rows": 5,
                                      "row_cv": 0.1},
                       n_dense_cols=8) in candidate_schedules(8)


# ---------------------------------------------------------------------------
# Reduction-strategy registry (paper challenge 2: user-defined strategies)
# ---------------------------------------------------------------------------


def _tilewide_spec(partials, seg_ids, num_segments, group_size):
    onehot = (seg_ids[:, None]
              == jnp.arange(num_segments)[None, :]).astype(partials.dtype)
    return jnp.einsum("ts,tc->sc", onehot, partials)


def _tilewide_pallas(rows, partial, out_ref, group_size):
    s = out_ref.shape[0]
    onehot = (rows[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (rows.shape[0], s), 1)).astype(partial.dtype)
    out_ref[...] += jnp.dot(onehot.T, partial,
                            preferred_element_type=jnp.float32)


def _ensure(name, *args, **kw):
    if name not in available_strategies():
        register_strategy(name, *args, **kw)


def _seg_problem(t=256, c=8, s=30, seed=0):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, s, t)).astype(np.int32)
    data = rng.standard_normal((t, c)).astype(np.float32)
    want = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s))
    return jnp.asarray(seg), jnp.asarray(data), s, want


def test_registered_strategy_runs_through_spec_and_kernel():
    _ensure("test-tilewide", _tilewide_spec, _tilewide_pallas)
    seg, data, s, want = _seg_problem(seed=3)
    # pure-JAX spec dispatcher
    got_spec = np.asarray(segment_group_reduce(
        data, seg, s, group_size=32, strategy="test-tilewide"))
    np.testing.assert_allclose(got_spec, want, rtol=RTOL, atol=ATOL)
    # Pallas kernel dispatcher
    sched = Schedule("eb", nnz_tile=64, group_size=32,
                     strategy="test-tilewide")
    got_kernel = np.asarray(segment_reduce(seg, data, s, schedule=sched))
    np.testing.assert_allclose(got_kernel, want, rtol=RTOL, atol=ATOL)


def test_spec_only_strategy_falls_back_in_kernel():
    _ensure("test-spec-only", _tilewide_spec)  # no pallas_fn -> bridge
    seg, data, s, want = _seg_problem(seed=4)
    sched = Schedule("eb", nnz_tile=64, group_size=32,
                     strategy="test-spec-only")
    got = np.asarray(segment_reduce(seg, data, s, schedule=sched))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_registered_strategy_through_spmm():
    _ensure("test-tilewide", _tilewide_spec, _tilewide_pallas)
    csr = random_csr(80, 60, density=0.05, seed=2)
    b = jax.random.normal(jax.random.PRNGKey(2), (60, 8))
    sched = Schedule("eb", nnz_tile=64, col_tile=8, group_size=8,
                     strategy="test-tilewide")
    got = np.asarray(spmm(csr, b, schedule=sched))
    np.testing.assert_allclose(got, _want_spmm(csr, b), rtol=RTOL, atol=ATOL)


def test_builtin_strategies_registered():
    assert {"segment", "parallel", "accumulate"} <= set(
        available_strategies())


def test_duplicate_registration_requires_overwrite():
    _ensure("test-dup", _tilewide_spec)
    with pytest.raises(ValueError):
        register_strategy("test-dup", _tilewide_spec)
    register_strategy("test-dup", _tilewide_spec, overwrite=True)


# ---------------------------------------------------------------------------
# CSR conversion caching + differentiable spmm
# ---------------------------------------------------------------------------


def test_csr_conversion_cache_hits():
    csr = random_csr(64, 64, density=0.05, seed=7)
    assert csr.grouped(64) is csr.grouped(64)
    assert csr.grouped(64) is not csr.grouped(128)
    assert csr.ell(8) is csr.ell(8)
    assert csr.ell(8) is not csr.ell(16)
    assert csr.tocoo() is csr.tocoo()


def test_spmm_is_differentiable_through_kernel():
    csr = random_csr(60, 50, density=0.05, seed=11)
    b = jax.random.normal(jax.random.PRNGKey(3), (50, 8))
    coo = csr.tocoo()

    def loss_ref(bb):
        return jnp.sum(
            ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, bb, 60) ** 2)

    g_ref = np.asarray(jax.grad(loss_ref)(b))
    for sched in (Schedule("eb", nnz_tile=64, col_tile=8, group_size=8),
                  Schedule("rb", row_tile=8, col_tile=8,
                           strategy="parallel")):
        g = jax.grad(lambda bb: jnp.sum(
            spmm(csr, bb, schedule=sched) ** 2))(b)
        np.testing.assert_allclose(np.asarray(g), g_ref, rtol=1e-3,
                                   atol=1e-3)


# ---------------------------------------------------------------------------
# Unified op surface: ragged segment_reduce + sddmm schedule plumbing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t", [1, 63, 250, 256])
def test_segment_reduce_accepts_ragged_inputs(t):
    rng = np.random.default_rng(t)
    s = 12
    seg = np.sort(rng.integers(0, s, t)).astype(np.int32)
    data = rng.standard_normal((t, 5)).astype(np.float32)
    want = np.asarray(segment_sum_ref(jnp.asarray(data), jnp.asarray(seg), s))
    got = np.asarray(segment_reduce(jnp.asarray(seg), jnp.asarray(data), s))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_sddmm_accepts_schedule():
    csr = random_csr(50, 40, density=0.05, seed=6)
    coo = csr.tocoo()
    a = jax.random.normal(jax.random.PRNGKey(4), (50, 16))
    b = jax.random.normal(jax.random.PRNGKey(5), (40, 16))
    want = np.asarray(ref.sddmm_ref(coo.rows, coo.cols, a, b))
    got = np.asarray(sddmm(coo.rows, coo.cols, a, b,
                           schedule=Schedule("eb", nnz_tile=64)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_spmm_accepts_atomic_parallelism_point():
    from fractions import Fraction

    csr = random_csr(70, 70, density=0.04, seed=8)
    b = jax.random.normal(jax.random.PRNGKey(6), (70, 8))
    p = AtomicParallelism("nnz", Fraction(1), 2, 16)
    got = np.asarray(spmm(csr, b, schedule=p))
    np.testing.assert_allclose(got, _want_spmm(csr, b), rtol=RTOL, atol=ATOL)
