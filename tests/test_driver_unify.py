"""ISSUE 10: the unified Axis/SearchSpace/drive() tuner framework.

Pins the tentpole's acceptance criteria: every tuner entry point routes
through ``tune.driver.drive`` (no per-tuner top-k/hillclimb loops remain
— verified textually), ``schedule_key`` stays byte-identical to the
pre-refactor format, ``Schedule`` fields carry their axis metadata, the
cache schema-migration matrix behaves, and the two *joint* searches the
framework unlocks actually work: collective × value_dtype in one
``tune_dist_spmm`` pass, and per-boundary fuse decisions on 3+-node
chains.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core import Schedule, schedule_axes
from repro.sparse import power_law_csr, random_csr
from repro.tune import (
    SCHEMA_VERSION,
    MIGRATIONS,
    ScheduleCache,
    TuneRecord,
    migrate_records,
    schedule_key,
    tune_dist_spmm,
)

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"

#: entry point -> source file that must route it through drive()
TUNER_SOURCES = {
    "tune_schedule": SRC / "tune" / "search.py",
    "tune_segment_reduce": SRC / "tune" / "search.py",
    "tune_dist_spmm": SRC / "tune" / "search.py",
    "tune_moe_dispatch": SRC / "tune" / "moe.py",
    "tune_sparse_attention": SRC / "tune" / "attention.py",
    "tune_plan": SRC / "fuse" / "planner.py",
    "moe_tune_collective": SRC / "models" / "moe.py",
}

#: textual fingerprints of the old per-tuner search loops; none may
#: survive outside tune/driver.py (the acceptance grep-clean test)
FORBIDDEN = ("_Memo(", "min(pool, key=", "range(hill_steps)",
             "range(hill")


# ---------------------------------------------------------------------------
# grep-clean: one driver, six thin wrappers
# ---------------------------------------------------------------------------


def test_all_tuners_route_through_drive():
    for entry, path in TUNER_SOURCES.items():
        text = path.read_text()
        assert f"def {entry}" in text, (entry, path)
        assert "drive(" in text, f"{path} does not call drive()"


def test_no_private_search_loops_outside_driver():
    for path in sorted(set(TUNER_SOURCES.values())):
        text = path.read_text()
        for pat in FORBIDDEN:
            assert pat not in text, f"{path} still contains {pat!r}"


def test_driver_owns_the_loop():
    text = (SRC / "tune" / "driver.py").read_text()
    assert "class _Memo" in text and "def drive" in text


# ---------------------------------------------------------------------------
# schedule_key is the concatenation of per-axis fragments, byte-stable
# ---------------------------------------------------------------------------


def test_schedule_key_byte_format_pinned():
    s = Schedule("eb", nnz_tile=256, group_size=16, strategy="segment")
    assert schedule_key(s) == "eb:t256:c128:G16:segment"
    s2 = s.replace(split_threshold=64, merge_threshold=4,
                   collective="nnz_rs", value_dtype="bfloat16")
    assert (schedule_key(s2)
            == "eb:t256:c128:G16:segment:s64:m4:w[nnz_rs]:v[bfloat16]")
    rb = Schedule("rb", row_tile=8)
    assert schedule_key(rb).startswith("rb:t8:")


def test_schedule_key_is_axis_fragment_concatenation():
    from repro.tune.space import SCHEDULE_AXES

    s = Schedule("eb", nnz_tile=128, group_size=8, strategy="parallel",
                 collective="row", value_dtype="float16")
    frags = [ax.key_fragment(s) for ax in SCHEDULE_AXES]
    assert "".join(frags) == schedule_key(s)
    # every axis contributes a *distinct* fragment namespace
    assert any(":w[" in f for f in frags)
    assert any(":v[" in f for f in frags)


def test_schedule_fields_carry_axis_metadata():
    axes = schedule_axes()
    assert axes["tiling"] == ("kernel", "nnz_tile", "row_tile", "col_tile")
    assert axes["strategy"] == ("group_size", "strategy")
    assert axes["skew"] == ("split_threshold", "merge_threshold")
    assert axes["collective"] == ("collective",)
    assert axes["value_dtype"] == ("value_dtype",)
    assert axes["epilogue"] == ("epilogue",)
    # exhaustive: every Schedule field belongs to exactly one axis
    import dataclasses

    named = {f for fields in axes.values() for f in fields}
    assert named == {f.name for f in dataclasses.fields(Schedule)}


# ---------------------------------------------------------------------------
# joint search #1: collective × value_dtype in ONE tune_dist_spmm pass
# ---------------------------------------------------------------------------


def _joint_measure(calls):
    """Deterministic objective where the *joint* optimum (nnz_rs +
    bfloat16) is strictly better than the best of either single-axis
    sweep alone."""

    def measure(s):
        calls.append(s)
        t = 1.0 if s.collective == "nnz_rs" else 2.0
        if s.value_dtype == "bfloat16":
            t *= 0.5
        return t

    return measure


def test_joint_collective_dtype_search_finds_joint_optimum():
    csr = power_law_csr(64, 48, avg_degree=5.0, alpha=1.5, seed=0)
    mesh = jax.make_mesh((1,), ("shards",))
    calls = []
    res = tune_dist_spmm(csr, 12, mesh=mesh, axis="shards",
                         cache=ScheduleCache(path=None),
                         measure=_joint_measure(calls),
                         top_k=1, hill_steps=0)
    assert res.schedule.collective == "nnz_rs"
    assert res.schedule.value_dtype == "bfloat16"
    # the winner's key records both axes' fragments
    assert ":w[nnz_rs]" in res.key or ":w[nnz_rs]" in schedule_key(
        res.schedule)
    # both collectives AND at least one narrow dtype were measured in
    # the one pass (the old two-sequential-searches shape can't do this)
    colls = {s.collective for s in calls}
    assert {"nnz_ar", "nnz_rs"} <= colls
    assert any(s.value_dtype == "bfloat16" for s in calls)


def test_joint_search_parity_with_dtype_axis_disabled():
    """``value_dtypes=()`` reduces the joint search to the single-axis
    collective search — same winner as the pre-refactor tuner."""
    csr = power_law_csr(64, 48, avg_degree=5.0, alpha=1.5, seed=0)
    mesh = jax.make_mesh((1,), ("shards",))
    calls = []
    res = tune_dist_spmm(csr, 12, mesh=mesh, axis="shards",
                         cache=ScheduleCache(path=None),
                         measure=_joint_measure(calls),
                         top_k=1, hill_steps=0, value_dtypes=())
    assert res.schedule.collective == "nnz_rs"
    assert res.schedule.value_dtype is None
    assert all(s.value_dtype is None for s in calls)


def test_dist_dtype_winner_persists_and_replays(tmp_path):
    csr = power_law_csr(64, 48, avg_degree=5.0, alpha=1.5, seed=0)
    mesh = jax.make_mesh((1,), ("shards",))
    path = tmp_path / "cache.json"
    cache = ScheduleCache(path=str(path))
    res = tune_dist_spmm(csr, 12, mesh=mesh, axis="shards", cache=cache,
                         measure=_joint_measure([]), top_k=1,
                         hill_steps=0)
    cache.save()
    assert res.schedule.value_dtype == "bfloat16"

    def boom(_s):
        raise AssertionError("replay must not measure")

    res2 = tune_dist_spmm(csr, 12, mesh=mesh, axis="shards",
                          cache=ScheduleCache(path=str(path)),
                          measure=boom)
    assert res2.from_cache and res2.n_measurements == 0
    assert res2.schedule == res.schedule


# ---------------------------------------------------------------------------
# joint search #2: per-boundary fuse decisions on 3+-node chains
# ---------------------------------------------------------------------------


def _gcn4(n=32, d=4):
    import jax.numpy as jnp

    from repro.fuse import gcn_chain

    rng = np.random.default_rng(0)
    adj = random_csr(n, n, density=0.15, seed=0)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(d, d)) * 0.3, jnp.float32)
    b0 = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    b1 = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    sched = Schedule("eb", nnz_tile=64, group_size=8)
    chain, params = gcn_chain(adj, (w0, w1), (b0, b1),
                              final_activation="relu", schedule=sched)
    return chain, x, params


def test_per_boundary_fuse_search_reaches_mixed_plans():
    """On a 3-boundary chain the hillclimb flips single boundary bits:
    a mixed tag (reachable only per-boundary) wins when the objective
    favors it."""
    from repro.fuse import tune_plan

    chain, x, params = _gcn4()
    times = {"FSF": 4.0, "SSS": 3.0, "SSF": 2.0, "FSS": 5.0,
             "FFF": 9.0, "SFF": 9.0, "FFS": 9.0, "SFS": 9.0}
    measured = []

    def measure(p):
        measured.append(p.decision.tag)
        return times[p.decision.tag]

    res = tune_plan(chain, x, params, cache=ScheduleCache(path=None),
                    measure=measure)
    # seeds: greedy-fused (FSF — middle boundary unfusable) + all-split
    assert {"FSF", "SSS"} <= set(measured)
    # the winner is a mixed plan neither all-or-nothing seed equals
    assert res.schedule.tag == "SSF"
    assert res.schedule.fused == (False, False, True)
    # hillclimb explored single-bit flips of the best seed (SSS)
    assert "SSF" in measured and len(set(measured)) >= 3


def test_fuse_hill_steps_zero_keeps_classic_duel():
    """1-boundary chains (and hill_steps=0) keep the pre-refactor
    fused-vs-split duel: exactly the two seeds measured."""
    from repro.fuse import tune_plan

    chain, x, params = _gcn4()
    measured = []
    res = tune_plan(chain, x, params, cache=ScheduleCache(path=None),
                    measure=lambda p: (measured.append(p.decision.tag)
                                       or 1.0),
                    hill_steps=0)
    assert set(measured) == {"FSF", "SSS"}
    assert res.schedule.tag in {"FSF", "SSS"}


def test_fuse_flips_never_override_legality():
    """A flip that fuses an unfusable boundary realizes back through
    plan() and dedupes away — the middle spmm->spmm boundary can never
    measure as fused."""
    from repro.fuse import tune_plan

    chain, x, params = _gcn4()
    measured = []
    tune_plan(chain, x, params, cache=ScheduleCache(path=None),
              measure=lambda p: (measured.append(p.decision.tag) or 1.0))
    assert all(t[1] == "S" for t in measured), measured


# ---------------------------------------------------------------------------
# satellite 1: one SCHEMA_VERSION + migration table
# ---------------------------------------------------------------------------


def test_schema_version_single_source():
    assert SCHEMA_VERSION == 4
    assert set(MIGRATIONS) == {1, 2, 3}


@pytest.mark.parametrize("version", [1, 2, 3])
def test_pre_v4_records_drop_and_retune(version):
    recs = {"k": {"schedule": {}, "us_per_call": 1.0}}
    assert migrate_records(version, recs) == {}


def test_current_version_is_identity():
    recs = {"k": {"schedule": {}, "us_per_call": 1.0}}
    assert migrate_records(SCHEMA_VERSION, recs) == recs


@pytest.mark.parametrize("version", [SCHEMA_VERSION + 1, 0, -1, None,
                                     "4", 2.5])
def test_unknown_versions_drop_everything(version):
    recs = {"k": {"schedule": {}, "us_per_call": 1.0}}
    assert migrate_records(version, recs) == {}


@pytest.mark.parametrize("version", [1, 2, 3])
def test_cache_file_migration_matrix(tmp_path, version):
    """A v1/v2/v3 cache file loads as empty (drop-and-retune), never
    crashes, and a fresh record persists at the current version."""
    path = tmp_path / "cache.json"
    cache = ScheduleCache(path=str(path))
    cache.put("spmm:deadbeef|N8", TuneRecord(schedule=Schedule(),
                                             us_per_call=1.0))
    cache.save()
    raw = json.loads(path.read_text())
    raw["version"] = version
    path.write_text(json.dumps(raw))

    stale = ScheduleCache(path=str(path))
    assert len(stale) == 0
    stale.put("spmm:deadbeef|N8", TuneRecord(schedule=Schedule(),
                                             us_per_call=2.0))
    stale.save()
    assert json.loads(path.read_text())["version"] == SCHEMA_VERSION


def test_v4_cache_replays_measurement_free(tmp_path):
    """Pre-refactor (v4) records for unchanged single-axis searches
    replay measurement-free through the new driver."""
    from repro.tune import tune_schedule

    csr = random_csr(64, 64, density=0.1, seed=0)
    path = tmp_path / "cache.json"
    cache = ScheduleCache(path=str(path))
    res = tune_schedule(csr, 8, cache=cache,
                        measure=lambda s: 1.0, top_k=1, hill_steps=0)
    cache.save()
    assert not res.from_cache

    def boom(_s):
        raise AssertionError("replay must not measure")

    res2 = tune_schedule(csr, 8, cache=ScheduleCache(path=str(path)),
                         measure=boom)
    assert res2.from_cache and res2.n_measurements == 0
    assert res2.schedule == res.schedule


# ---------------------------------------------------------------------------
# satellite 3: calibration from unified-driver TuneResults
# ---------------------------------------------------------------------------


def _synthetic_machine(true_w):
    from repro.core import cost_terms
    from repro.sparse.random import matrix_stats

    true_w = np.asarray(true_w, np.float64)

    def bind(csr, n_dense):
        stats = matrix_stats(csr)

        def measure(s):
            return float(true_w @ np.asarray(
                cost_terms(stats, s, n_dense)))

        return measure

    return bind


def test_samples_from_results_strictly_lower_regret():
    """A tuning sweep doubles as a calibration corpus: the driver's
    TuneResult carries every measured point (``.points``/​``.measured``),
    and fitting cost weights from those samples strictly lowers the
    model's ranking regret on a machine the napkin prior mispredicts."""
    from repro.core import DEFAULT_COST_WEIGHTS
    from repro.tune import tune_schedule
    from repro.tune.calibrate import (fit_weights, model_regret,
                                      samples_from_results)

    mats = [random_csr(256, 256, density=d, skew=s, seed=i)
            for i, (d, s) in enumerate([(0.01, 0.0), (0.02, 1.5),
                                        (0.005, 2.5)])]
    bind = _synthetic_machine([1.0, 0.0, 8.0, 0.1])
    entries = []
    for csr in mats:
        res = tune_schedule(csr, 4, cache=ScheduleCache(path=None),
                            measure=bind(csr, 4), top_k=6, hill_steps=2,
                            value_dtypes=())
        entries.append((csr, 4, res))

    samples = samples_from_results(entries)
    assert len(samples) >= sum(e[2].n_measurements for e in entries) > 0
    before = model_regret(samples, DEFAULT_COST_WEIGHTS)
    fitted = fit_weights(samples)
    after = model_regret(samples, fitted)
    assert before > 1.0       # the prior mispredicts this machine
    assert after < before     # strict regret drop (the satellite gate)
    assert after == pytest.approx(1.0, abs=1e-9)


def test_samples_from_results_skips_replays_and_non_schedules():
    from repro.fuse import tune_plan
    from repro.tune import tune_schedule
    from repro.tune.calibrate import samples_from_results

    csr = random_csr(64, 64, density=0.1, seed=0)
    cache = ScheduleCache(path=None)
    live = tune_schedule(csr, 4, cache=cache, measure=lambda s: 1.0,
                         top_k=1, hill_steps=0)
    hit = tune_schedule(csr, 4, cache=cache, measure=lambda s: 1.0)
    assert hit.from_cache
    assert samples_from_results([(csr, 4, hit)]) == []

    # fuse results carry FuseDecision points — cost_terms is undefined
    # on them, so they contribute nothing rather than crash
    chain, x, params = _gcn4()
    fres = tune_plan(chain, x, params, cache=ScheduleCache(path=None),
                     measure=lambda p: 1.0)
    assert samples_from_results([(csr, 4, fres)]) == []
    assert len(samples_from_results([(csr, 4, live)])) == len(
        live.measured)
