"""Legality pass — when may a consumer node fuse into a producer launch?

The predicate is structural, per DESIGN.md §10: a consumer fuses only
when its work can run *inside* the producer's launch without changing
what the producer's grid writes.  Two families:

* **elementwise consumers** fuse iff the producer anchor exposes the
  in-kernel epilogue slot (:data:`~repro.fuse.ir.EPILOGUE_CAPABLE`) and
  the launch's accumulated :class:`~repro.core.Epilogue` can absorb the
  node under the fixed template order
  ``cast(act(acc + bias) + residual)`` —
  :meth:`Epilogue.extended <repro.core.Epilogue.extended>` is the single
  arbiter, so a new epilogue capability lands in ``core`` once and every
  planner rule sees it;
* **reducing consumers** (spmm / grouped_matmul / segment_reduce /
  combine) never fuse into an upstream launch: their reduction runs over
  its *own* iteration space, so its segment structure cannot align with
  the producer's output blocking — and a non-additive consumer monoid
  additionally cannot be composed from the producer's blocked partial
  sums (``min(a+b) != min(a)+min(b)``).  They anchor a new launch; the
  split reason records which of the two arguments applied.

Kernel-specific operand limits also live here (grouped_matmul has no
residual operand in the expert-sorted layout), so the planner and the
executor agree by construction on what a launch can run.
"""
from __future__ import annotations

from typing import Optional, Tuple

from ..core.schedule import Epilogue
from .ir import EPILOGUE_CAPABLE, FuseNode, Launch

__all__ = ["can_fuse", "ewise_fusable", "reduce_fusable"]


def ewise_fusable(launch: Launch,
                  node: FuseNode) -> Tuple[Optional[Epilogue], str]:
    """(merged epilogue, "") when ``node``'s elementwise work folds into
    ``launch``'s epilogue slot, else (None, reason)."""
    a = launch.anchor
    if a.kind not in EPILOGUE_CAPABLE:
        return None, (f"anchor '{a.kind}' exposes no in-kernel epilogue "
                      "slot")
    if a.kind == "grouped_matmul" and node.epilogue.residual:
        return None, ("grouped_matmul has no residual operand in the "
                      "expert-sorted layout")
    merged = launch.epilogue.extended(node.epilogue)
    if merged is None:
        return None, (f"epilogue template cast(act(acc+bias)+res) cannot "
                      f"absorb [{node.epilogue.tag}] after "
                      f"[{launch.epilogue.tag or 'noop'}]")
    return merged, ""


def reduce_fusable(launch: Launch,
                   node: FuseNode) -> Tuple[Optional[Epilogue], str]:
    """Reducing consumers always split; the reason says why (monoid
    incompatibility beats the generic iteration-space argument)."""
    if node.op not in ("sum", "mean"):
        return None, (f"consumer monoid '{node.op}' cannot be composed "
                      "from the producer's blocked partial outputs "
                      "(only additive partials compose across blocks)")
    return None, (f"consumer '{node.kind}' reduces over its own "
                  "iteration space; its segment structure does not "
                  "align with the producer's output blocking")


def can_fuse(launch: Launch,
             node: FuseNode) -> Tuple[Optional[Epilogue], str]:
    """Public legality predicate: ``(merged_epilogue, "")`` when ``node``
    may fuse into ``launch``, ``(None, reason)`` otherwise.  Dispatches
    through the rule registry (``repro.fuse.rules``), so user rules
    participate."""
    from .rules import try_fuse

    merged, reason, _rule = try_fuse(launch, node)
    return merged, reason
