"""Whisper-large-v3 [arXiv:2212.04356]: enc-dec audio backbone; conv/mel
frontend stubbed (input_specs provides frame embeddings)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_encoder_layers=32, encoder_seq=1500,
    d_model=1280, n_heads=20, n_kv_heads=20, d_head=64,
    d_ff=5120, vocab_size=51866,
    qkv_bias=True, norm="layernorm", mlp_type="gelu",
)
