"""Segment group — the paper's new compiler abstraction (Sgap §4/§5).

A *segment group* separates the two roles the GPU warp used to conflate:

* tiling semantics   -> on TPU: the Pallas grid / BlockSpec decomposition;
* synchronization    -> on TPU: the width-G one-hot reduce inside a tile
  semantics             plus the writeback strategy.

Built-in strategies (each a registered :class:`~.schedule.ReductionStrategy`;
users add their own with ``repro.core.register_strategy``):

SEGMENT     multiple writeback lanes per group, decided at runtime by the
            segment ids (the paper's segment reduction). TPU realization:
            one-hot matmul ``Sᵀ·P`` over each G-wide group, then carry
            accumulation across group boundaries.
PARALLEL    exactly one writeback lane per group; all lanes share one
            segment (the paper's parallel reduction). TPU realization: a
            plain within-group sum (MXU row reduce).
ACCUMULATE  no intra-group combine; every lane writes back with ``+=``
            (the paper's atomicAdd). TPU realization: scatter-add — legal
            because the TPU grid is sequential; across cores it becomes a
            psum. Used as the correctness fallback.

The ``spec_*`` functions here are the *pure-JAX executable specification*
of each strategy — the oracle any kernel realization is tested against.
``segment_group_reduce`` dispatches through the strategy registry
(``core.schedule``), so user-registered strategies run through the same
spec path; ``repro.kernels.common.group_reduce_scatter`` is the Pallas
dispatcher over the same registry.

Strategies are parameterized by a **reduction monoid** (``Monoid``): the
combine operator, its identity, and the axis/segment reducers derived
from it.  The built-in specs and kernel realizations are written against
the monoid — sum is just the ``add`` instance (the only one the one-hot
MXU matmul can realize, see ``Monoid.matmul_ok``); ``max``/``min`` run
the same machinery with a masked reduce, which is what graph pooling
(``segment_reduce(op="max")``) and the fused-attention row-max use.
"""
from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "GroupReduceStrategy",
    "Monoid",
    "SegmentGroup",
    "available_monoids",
    "get_monoid",
    "make_monoid",
    "segment_group_reduce",
    "segment_sum_ref",
    "spec_accumulate",
    "spec_parallel",
    "spec_segment",
    "group_writeback_counts",
    "group_waste_fraction",
]


# ---------------------------------------------------------------------------
# Reduction monoids
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Monoid:
    """A commutative reduction monoid: ``combine`` + its ``identity``.

    ``reduce(x, axis)`` and ``seg_reduce(data, seg_ids, num_segments)``
    are the derived axis / segment reducers (built-ins use the fused
    ``jnp.sum``/``jax.ops.segment_max``-style primitives; custom monoids
    get generic derivations from :func:`make_monoid`).  ``matmul_ok``
    marks monoids whose one-hot reduce may run as an MXU matmul — true
    only for ``add``, where ``dot(onehot.T, p)`` *is* the masked sum;
    every other monoid uses the masked-``where`` reduce instead.
    """

    name: str
    identity: float
    combine: Callable  # (a, b) -> elementwise combine
    reduce: Callable  # (x, axis) -> reduced along axis
    seg_reduce: Callable  # (data (T, C), seg_ids (T,), num_segments) -> (S, C)
    matmul_ok: bool = False


def _seg_sum(data, seg_ids, num_segments):
    return jax.ops.segment_sum(data, seg_ids, num_segments=num_segments)


def _seg_max(data, seg_ids, num_segments):
    return jax.ops.segment_max(data, seg_ids, num_segments=num_segments)


def _seg_min(data, seg_ids, num_segments):
    return jax.ops.segment_min(data, seg_ids, num_segments=num_segments)


MONOIDS = {
    "add": Monoid("add", 0.0, jnp.add, jnp.sum, _seg_sum, matmul_ok=True),
    "max": Monoid("max", -jnp.inf, jnp.maximum, jnp.max, _seg_max),
    "min": Monoid("min", jnp.inf, jnp.minimum, jnp.min, _seg_min),
}
MONOIDS["sum"] = MONOIDS["add"]  # alias


def get_monoid(op) -> Monoid:
    """Monoid for ``op`` (a name, a :class:`Monoid`, or ``None`` = add)."""
    if op is None:
        return MONOIDS["add"]
    if isinstance(op, Monoid):
        return op
    try:
        return MONOIDS[op]
    except KeyError:
        raise ValueError(
            f"unknown reduction op {op!r}; available: "
            f"{sorted(set(MONOIDS))} (or build one with make_monoid)"
        ) from None


def available_monoids():
    """Registered reduction-op names (sum/max/min/... plus
    ``make_monoid`` extensions), sorted."""
    return tuple(sorted(set(MONOIDS)))


def make_monoid(name: str, combine: Callable, identity: float) -> Monoid:
    """Monoid from a raw binary ``combine`` (must be commutative and
    associative) and its ``identity``; the axis / segment reducers are
    derived generically (spec-grade: the segment reduce materializes an
    (S, T, C) mask product, fine for oracles, not for hot paths)."""

    def _reduce(x, axis):
        return jax.lax.reduce(x, jnp.asarray(identity, x.dtype),
                              lambda a, b: combine(a, b), (axis,))

    def _seg_reduce(data, seg_ids, num_segments):
        mask = seg_ids[None, :] == jnp.arange(num_segments)[:, None]
        expanded = jnp.where(mask[..., None], data[None], identity)
        return _reduce(expanded, 1)

    return Monoid(name=name, identity=float(identity), combine=combine,
                  reduce=_reduce, seg_reduce=_seg_reduce)


class GroupReduceStrategy(enum.Enum):
    """The paper's three group-reduction realizations (Sgap §5): names
    are the stable identities schedules and cache records carry."""

    SEGMENT = "segment"
    PARALLEL = "parallel"
    ACCUMULATE = "accumulate"


@dataclasses.dataclass(frozen=True)
class SegmentGroup:
    """User-facing reduction handle: ``parallelize(j, GPUGroup, r, strategy)``
    in the paper's CIN becomes ``SegmentGroup(group_size=r, strategy=...)``
    here.  ``strategy`` is a :class:`GroupReduceStrategy` or the name of
    any registered strategy; lift into a full :class:`~.schedule.Schedule`
    with ``Schedule.from_group``."""

    group_size: int = 32
    strategy: "GroupReduceStrategy | str" = GroupReduceStrategy.SEGMENT

    def __post_init__(self):
        if self.group_size < 1:
            raise ValueError("group_size must be >= 1")
        if isinstance(self.strategy, str):
            try:
                object.__setattr__(self, "strategy",
                                   GroupReduceStrategy(self.strategy))
            except ValueError:
                pass  # user-registered strategy: keep the name


def segment_sum_ref(partials: jax.Array, seg_ids: jax.Array, num_segments: int) -> jax.Array:
    """Ground-truth oracle: plain segment sum (strategy-independent math)."""
    return jax.ops.segment_sum(partials, seg_ids, num_segments=num_segments)


# ---------------------------------------------------------------------------
# Per-strategy executable specs.  Common signature (the registry contract):
#     spec(partials (T, C), seg_ids (T,), num_segments, group_size) -> (S, C)
# Built-ins additionally accept ``monoid=`` (the dispatcher passes it when
# the spec's signature does — user 4-arg specs keep working unchanged).
# ---------------------------------------------------------------------------


def spec_accumulate(partials, seg_ids, num_segments, group_size, *,
                    monoid: Monoid = MONOIDS["add"]):
    """ACCUMULATE: no intra-group combine; per-lane combine-writeback."""
    del group_size
    return monoid.seg_reduce(partials, seg_ids, num_segments)


def spec_parallel(partials, seg_ids, num_segments, group_size, *,
                  monoid: Monoid = MONOIDS["add"]):
    """PARALLEL: one writeback lane per group.  *Asserts* (by construction)
    the single-writeback contract: every lane in a group must share the
    group's first segment id — lanes violating it are dropped, mirroring
    the GPU kernel where they would simply never be accumulated by the one
    writeback thread."""
    T, C = partials.shape
    G = group_size
    n_groups = T // G
    gp = partials.reshape(n_groups, G, C)
    gs = seg_ids.reshape(n_groups, G)
    leader = gs[:, :1]  # single writeback segment per group
    mask = (gs == leader)[..., None]
    group_tot = monoid.reduce(jnp.where(mask, gp, monoid.identity),
                              1)  # (n_groups, C)
    return monoid.seg_reduce(group_tot, leader[:, 0], num_segments)


def spec_segment(partials, seg_ids, num_segments, group_size, *,
                 monoid: Monoid = MONOIDS["add"]):
    """SEGMENT: per-group one-hot reduce (an MXU matmul for the add
    monoid, a masked reduce otherwise), then cross-group carry
    accumulation.  Local segment ids are offsets from the group's first
    segment, clamped into [0, G): with non-decreasing seg_ids a group of
    G lanes spans at most G distinct segments, but sparse matrices can
    skip ids, so lanes whose offset overflows the local window fall back
    to accumulate-writeback."""
    T, C = partials.shape
    G = group_size
    n_groups = T // G
    gp = partials.reshape(n_groups, G, C)
    gs = seg_ids.reshape(n_groups, G)
    first = gs[:, :1]
    local = gs - first  # (n_groups, G) >= 0
    in_window = local < G
    local_c = jnp.clip(local, 0, G - 1)
    onehot = jax.nn.one_hot(local_c, G, dtype=partials.dtype)
    onehot = onehot * in_window[..., None].astype(partials.dtype)
    if monoid.matmul_ok:
        seg_tot = jnp.einsum("ngs,ngc->nsc", onehot, gp)  # (n_groups, G, C)
    else:
        # masked reduce over lanes: slot s of group n combines the lanes
        # whose local slot is s (identity elsewhere)
        expanded = jnp.where(onehot.transpose(0, 2, 1)[..., None] > 0,
                             gp[:, None, :, :], monoid.identity)
        seg_tot = monoid.reduce(expanded, 2)  # (n_groups, G slots, C)
    # writeback: local slot s of group n targets global segment first[n]+s
    targets = jnp.clip(first + jnp.arange(G)[None, :], 0, num_segments - 1)
    out = monoid.seg_reduce(seg_tot.reshape(-1, C), targets.reshape(-1),
                            num_segments)
    # overflow lanes (rare: segment-id jumps > G inside one group)
    ov = monoid.seg_reduce(
        jnp.where((~in_window)[..., None], gp, monoid.identity).reshape(-1, C),
        jnp.clip(gs, 0, num_segments - 1).reshape(-1),
        num_segments,
    )
    return monoid.combine(out, ov)


@partial(jax.jit, static_argnames=("num_segments", "group_size", "entry"))
def _dispatch_spec(partials, seg_ids, *, num_segments, group_size, entry):
    from .schedule import call_spec_fn

    return call_spec_fn(entry, partials, seg_ids, num_segments, group_size)


def segment_group_reduce(
    partials: jax.Array,  # (T, C) per-lane partial results
    seg_ids: jax.Array,  # (T,) int32 non-decreasing segment ids
    num_segments: int,
    group_size: int = 32,
    strategy: "GroupReduceStrategy | str" = GroupReduceStrategy.SEGMENT,
    op: "str | Monoid | None" = None,
) -> jax.Array:
    """Executable spec of grouped reduction with explicit group structure.

    ``strategy`` may be a :class:`GroupReduceStrategy`, the name of any
    registered strategy, or a registry entry; dispatch goes through the
    strategy registry, so user strategies registered with
    ``repro.core.register_strategy`` run here unchanged.  ``op`` selects
    the reduction monoid ('add' default, 'max', 'min', or a
    :class:`Monoid`); strategies registered with their own
    ``combine``/``identity`` refuse a conflicting ``op``.  Mathematically
    equals ``segment_sum`` for SEGMENT/ACCUMULATE under the add monoid;
    see the per-strategy ``spec_*`` docstrings for the contracts.
    """
    from .schedule import get_strategy

    T = partials.shape[0]
    if T % group_size:
        raise ValueError(f"T={T} not a multiple of group_size={group_size}")
    entry = get_strategy(strategy, op=op)
    return _dispatch_spec(partials, seg_ids, num_segments=num_segments,
                          group_size=group_size, entry=entry)


def group_writeback_counts(seg_ids, group_size: int):
    """Analytic model input: distinct segments per group = number of
    writebacks a SEGMENT-strategy group performs. Drives the selector's
    napkin math and the Table-1/2 benchmarks."""
    T = seg_ids.shape[0]
    G = group_size
    gs = seg_ids.reshape(T // G, G)
    changes = jnp.concatenate(
        [jnp.ones((gs.shape[0], 1), jnp.int32),
         (gs[:, 1:] != gs[:, :-1]).astype(jnp.int32)], axis=1)
    return jnp.sum(changes, axis=1)


def group_waste_fraction(row_lengths, group_size: int) -> float:
    """Paper challenge (1): fraction of lanes wasted when rows shorter than
    the group still occupy a full group (zero-extension padding waste)."""
    import numpy as np

    lengths = np.asarray(row_lengths)
    lengths = lengths[lengths > 0]
    if lengths.size == 0:
        return 0.0
    padded = group_size * np.ceil(lengths / group_size)
    return float(1.0 - lengths.sum() / padded.sum())
