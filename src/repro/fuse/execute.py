"""Plan execution — route each :class:`~repro.fuse.ir.Launch` through
the library surface that realizes it.

``run_plan`` threads the chain value through the launches: ``spmm``
anchors go through ``repro.sparse.spmm`` (the differentiable scheduled
kernel, the launch's merged epilogue attached), ``grouped_matmul``
anchors through ``kernels.ops.grouped_matmul`` (differentiable,
epilogued), ``segment_reduce`` through ``repro.sparse.segment_reduce``,
``combine`` through the jnp monoid scatter (:func:`moe_combine` — kept
in XLA for differentiability), and unfused ``ewise`` launches apply
their epilogue spec in XLA.  Because every Pallas path already carries a
custom VJP, a planned chain is differentiable end to end.

``run_chain_ref`` is the parity oracle: the *unfused spec composition*,
each node executed separately through the pure-jnp references — what
every plan of the same chain must match within dtype tolerance.

Operands travel in ``params`` — a per-chain-node list of dicts (aligned
with the chain; see the builders in ``repro.fuse.ir``):

=================  =======================================================
node kind          recognized params keys
=================  =======================================================
spmm               ``a`` (CSR/GroupedCOO/ELL), optional ``w`` (dense
                   weight: the launch computes ``A @ (x @ w)``)
grouped_matmul     ``tile_experts``, ``weights``, optional ``token_tile``
                   / ``f_tile`` / ``d_tile``
segment_reduce     ``seg_ids``, ``num_segments``
combine            ``topi``, ``topv``, ``num_tokens``
ewise              ``bias`` / ``residual`` arrays for its epilogue flags
=================  =======================================================
"""
from __future__ import annotations

import jax.numpy as jnp

from .ir import FusePlan, Launch

__all__ = ["moe_combine", "run_chain_ref", "run_plan"]


def moe_combine(y, topi, topv, num_tokens: int, op: str = "sum"):
    """Gate-weighted expert→token combine under the named monoid.

    ``y`` (S, D) routed-slot outputs, ``topi`` (S,) destination token of
    each slot, ``topv`` (S,) gate weight.  'sum' is the standard MoE
    combine; 'min' takes the elementwise min over a token's routed
    experts (untouched tokens → 0, matching sum's zero-init); 'mean'
    averages over the routed experts.  Pure jnp scatters — the combine
    stays differentiable in ``y`` and ``topv``."""
    d = y.shape[-1]
    y = y.astype(jnp.float32) * topv[:, None].astype(jnp.float32)
    flat_i = topi.reshape(-1)
    if op == "sum":
        return jnp.zeros((num_tokens, d), jnp.float32).at[flat_i].add(y)
    if op == "min":
        out = jnp.full((num_tokens, d), jnp.inf,
                       jnp.float32).at[flat_i].min(y)
        return jnp.where(jnp.isinf(out), 0.0, out)
    if op == "mean":
        tot = jnp.zeros((num_tokens, d), jnp.float32).at[flat_i].add(y)
        cnt = jnp.zeros((num_tokens, 1), jnp.float32).at[flat_i].add(
            jnp.ones((y.shape[0], 1), jnp.float32))
        return tot / jnp.maximum(cnt, 1.0)
    raise ValueError(f"moe_combine op {op!r}; one of sum/min/mean")


def _ewise_bias(bias, params):
    """Bias operand of an *unfused* elementwise pass.  A 1-D feature
    bias broadcasts as (1, F); a 2-D per-expert (E, F) bias (the
    grouped_matmul operand the fused kernel indexes per tile via its
    expert map) is expanded to per-row (T, F) using the chain's routing
    params."""
    if bias is None:
        return None
    if bias.ndim == 1:
        return jnp.reshape(bias, (1, -1))
    for p in params:
        if p and p.get("tile_experts") is not None:
            return jnp.repeat(bias[p["tile_experts"]],
                              p.get("token_tile", 128), axis=0)
    return bias


def _epilogue_operands(launch: Launch, params):
    """Collect the launch epilogue's array operands from its members
    (whichever fused node declared the bias / residual supplies it)."""
    bias = residual = None
    for i in launch.members:
        p = params[i] or {}
        if p.get("bias") is not None:
            bias = p["bias"]
        if p.get("residual") is not None:
            residual = p["residual"]
    return bias, residual


def _run_launch(launch: Launch, cur, params, interpret: bool):
    a = launch.anchor
    p = params[launch.anchor_idx] or {}
    ep = launch.epilogue
    bias, residual = _epilogue_operands(launch, params)

    if a.kind == "spmm":
        from ..sparse import spmm

        x = cur if p.get("w") is None else cur @ p["w"]
        return spmm(p["a"], x, schedule=a.schedule or "auto",
                    bias=bias, residual=residual,
                    epilogue=None if ep.is_noop else ep,
                    interpret=interpret)
    if a.kind == "grouped_matmul":
        from ..kernels.ops import grouped_matmul

        return grouped_matmul(
            cur, p["tile_experts"], p["weights"], bias=bias, epilogue=ep,
            token_tile=p.get("token_tile", 128),
            f_tile=p.get("f_tile", 128), d_tile=p.get("d_tile", 128),
            interpret=interpret)
    if a.kind == "segment_reduce":
        from ..sparse import segment_reduce

        return segment_reduce(p["seg_ids"], cur, p["num_segments"],
                              schedule=a.schedule, op=a.op,
                              interpret=interpret)
    if a.kind == "combine":
        return moe_combine(cur, p["topi"], p["topv"], p["num_tokens"],
                           op=a.op)
    # unfused elementwise launch: the epilogue spec runs in XLA
    return ep.apply(cur, bias=_ewise_bias(bias, params),
                    residual=residual)


def run_plan(plan: FusePlan, x, params, *, interpret: bool = True):
    """Execute a plan: ``params`` is the per-chain-node operand list
    (``len(params) == len(plan.chain)``)."""
    assert len(params) == len(plan.chain), (len(params), len(plan.chain))
    cur = x
    for launch in plan.launches:
        cur = _run_launch(launch, cur, params, interpret)
    return cur


def _run_node_ref(node, cur, p, params):
    """One node of the unfused spec composition (pure jnp / ref paths)."""
    import jax

    p = p or {}
    if node.kind == "spmm":
        from ..kernels import ops as kops

        x = cur if p.get("w") is None else cur @ p["w"]
        out = kops.spmm(p["a"], x, impl="ref")
        return out if node.epilogue.is_noop else node.epilogue.apply(out)
    if node.kind == "grouped_matmul":
        from ..kernels.ops import grouped_matmul_ref

        return grouped_matmul_ref(cur, p["tile_experts"], p["weights"],
                                  epilogue=node.epilogue,
                                  token_tile=p.get("token_tile", 128))
    if node.kind == "segment_reduce":
        seg, n = p["seg_ids"], p["num_segments"]
        data = cur.astype(jnp.float32)
        if node.op == "sum":
            return jax.ops.segment_sum(data, seg, num_segments=n)
        if node.op == "max":
            return jax.ops.segment_max(data, seg, num_segments=n)
        if node.op == "min":
            return jax.ops.segment_min(data, seg, num_segments=n)
        tot = jax.ops.segment_sum(data, seg, num_segments=n)
        cnt = jax.ops.segment_sum(jnp.ones((data.shape[0], 1)), seg,
                                  num_segments=n)
        return tot / jnp.maximum(cnt, 1.0)
    if node.kind == "combine":
        return moe_combine(cur, p["topi"], p["topv"], p["num_tokens"],
                           op=node.op)
    return node.epilogue.apply(cur, bias=_ewise_bias(p.get("bias"),
                                                     params),
                               residual=p.get("residual"))


def run_chain_ref(chain, x, params):
    """The unfused spec composition — every node its own pure-jnp pass.
    This is the oracle every plan of ``chain`` must match."""
    cur = x
    for node, p in zip(chain, params):
        cur = _run_node_ref(node, cur, p, params)
    return cur
