"""Atomic parallelism — the paper's design-space model (Sgap §3).

An SpMM algorithm point is ``{<x sparse-work, c col>, r}``:

* ``split``      what the sparse-work unit is: ``nnz`` or ``row``;
* ``x``          minimal sparse data per thread: ``g`` units, ``1`` unit, or
                 ``1/g`` of a unit (g threads collaborate on one unit);
* ``c``          minimal dense columns per thread (coarsen factor);
* ``r``          reduction parallelism — how many threads synchronize per
                 reduction step (the paper's group size).

Legality rules (paper §3.3, Fig. 8):

1. ``<1/g nnz, ...>`` and ``<..., 1/c col>`` with nnz split are illegal: a
   non-zero must be multiplied by at least one whole dense element.
2. ``{<1/g row, x col>, r}`` with ``r < g`` is illegal: parallel reduction
   has a single writeback thread, so the sync width must cover the row
   group.
3. ``<1/g row, 1/c col>`` is illegal: resource parallelism may multiply
   only one element of the atomic parallelism.

The mapping to TPU kernel schedules lives in
:meth:`repro.core.schedule.Schedule.from_point` — see DESIGN.md §2/§3 for
the semantics of each field on TPU; :func:`to_schedule` is kept as a thin
compatibility wrapper.

DA-SpMM's space embeds as:
    EB+PR = {<1 nnz, c col>, 32}     EB+SR = {<32 nnz, c col>, 1}
    RB+PR = {<1/32 row, c col>, 32}  RB+SR = {<1 row, c col>, 1}
"""
from __future__ import annotations

import dataclasses
import itertools
from fractions import Fraction
from typing import Iterable, List

from .schedule import Schedule

__all__ = [
    "AtomicParallelism",
    "KernelSchedule",
    "is_legal",
    "enumerate_space",
    "to_schedule",
    "DA_SPMM_POINTS",
]

REDUCTION_PARALLELISMS = (1, 2, 4, 8, 16, 32)

# Deprecated alias: the stringly-typed KernelSchedule was folded into the
# unified Schedule object (DESIGN.md §3); the constructor signature is
# unchanged, so existing call sites keep working.
KernelSchedule = Schedule


@dataclasses.dataclass(frozen=True)
class AtomicParallelism:
    """One point ``{<x split, c col>, r}`` in the design space."""

    split: str  # 'nnz' | 'row'
    x: Fraction  # minimal sparse data: Fraction(g), Fraction(1), Fraction(1, g)
    c: int  # dense columns per thread (>= 1)
    r: int  # reduction parallelism

    def __post_init__(self):
        if self.split not in ("nnz", "row"):
            raise ValueError(f"split must be 'nnz' or 'row', got {self.split}")
        object.__setattr__(self, "x", Fraction(self.x))
        if self.c < 1:
            raise ValueError("fractional dense columns are expressed via "
                             "split='row' collaboration, not c < 1")

    def __str__(self):
        return f"{{<{self.x} {self.split}, {self.c} col>, {self.r}}}"


def is_legal(p: AtomicParallelism) -> bool:
    """Whether the parallelism point satisfies the paper's legality
    rules (no fractional nnz; row collaboration covered by the sync
    width) — the filter ``enumerate_legal`` applies to the raw grid."""
    # Rule 1: no fractional nnz.
    if p.split == "nnz" and p.x < 1:
        return False
    # Rule 2: row collaboration (1/g row) forces parallel reduction whose
    # sync width must cover the g collaborators.
    if p.split == "row" and p.x < 1 and p.r < 1 / p.x:
        return False
    # Rule 3 is structurally unrepresentable here (c >= 1 enforced), kept
    # for documentation parity with the paper.
    if p.r not in REDUCTION_PARALLELISMS:
        return False
    return True


def enumerate_space(
    g_values: Iterable[int] = (1, 2, 4, 8, 16, 32),
    c_values: Iterable[int] = (1, 2, 4, 8),
    r_values: Iterable[int] = REDUCTION_PARALLELISMS,
) -> List[AtomicParallelism]:
    """All legal points over the given tunable ranges (deduplicated)."""
    xs = set()
    for g in g_values:
        xs.add(Fraction(g))
        xs.add(Fraction(1, g))
    points = set()
    for split, x, c, r in itertools.product(("nnz", "row"), xs, c_values, r_values):
        p = AtomicParallelism(split, x, c, r)
        if is_legal(p):
            points.add(p)
    return sorted(points, key=lambda p: (p.split, p.x, p.c, p.r))


# The four DA-SpMM algorithms (paper §3.3), row-major variants.
DA_SPMM_POINTS = {
    "EB+PR": AtomicParallelism("nnz", Fraction(1), 4, 32),
    "EB+SR": AtomicParallelism("nnz", Fraction(32), 4, 1),
    "RB+PR": AtomicParallelism("row", Fraction(1, 32), 4, 32),
    "RB+SR": AtomicParallelism("row", Fraction(1), 4, 1),
}


def to_schedule(
    p: AtomicParallelism,
    *,
    lane_width: int = 128,
    base_nnz_tile: int = 256,
    base_row_tile: int = 8,
) -> Schedule:
    """Deprecated: use :meth:`Schedule.from_point`."""
    return Schedule.from_point(p, lane_width=lane_width,
                               base_nnz_tile=base_nnz_tile,
                               base_row_tile=base_row_tile)
