"""Benchmark harness — one function per paper table (Sgap Tables 1-5) plus
beyond-paper benches. Prints ``name,us_per_call,derived`` CSV; ``--json``
additionally emits a machine-readable ``{name: {us_per_call, derived}}``
file (the ``BENCH_<tag>.json`` trajectory CI tracks).

    PYTHONPATH=src python -m benchmarks.run [--full] [--json BENCH_ci.json]

``REPRO_BENCH_ITERS`` caps per-measurement timing iterations (CI smoke
sets it low to stay inside its time budget).
"""
import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger matrices (slower, closer to paper scale)")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table3,table4,table5,"
                         "moe,moe_tuner,selector")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write {name: {us_per_call, derived}} JSON")
    args = ap.parse_args()
    quick = not args.full

    from . import beyond, tables

    benches = {
        "table1": lambda: tables.table1_group_size(quick),
        "table2": lambda: tables.table2_segment_vs_atomic(quick),
        "table3": lambda: tables.table3_new_vs_original(quick),
        "table4": lambda: tables.table4_tuning(quick),
        "table5": lambda: tables.table5_dynamic_choice(quick),
        "moe": lambda: beyond.moe_dispatch(quick),
        "moe_tuner": lambda: beyond.moe_tuner_gap(quick),
        "selector": lambda: beyond.selector_quality(quick),
    }
    wanted = args.only.split(",") if args.only else list(benches)
    unknown = [w for w in wanted if w not in benches]
    if unknown:
        ap.error(f"unknown bench(es) {unknown}; have {sorted(benches)}")

    print("name,us_per_call,derived")
    results = {}
    ok = True
    for name in wanted:
        try:
            for row in benches[name]():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
                results[row[0]] = {"us_per_call": float(row[1]),
                                   "derived": str(row[2])}
            sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            ok = False
            # the ERROR row goes to the CSV (so graders see it in-band)
            # AND to stderr with the full traceback (so CI logs show
            # *where* it failed instead of a swallowed repr)
            print(f"{name},NaN,ERROR:{e!r}")
            print(f"{name},NaN,ERROR:{e!r}", file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
            sys.stderr.flush()
            results[name] = {"us_per_call": None, "derived": f"ERROR:{e!r}"}
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, sort_keys=True)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
