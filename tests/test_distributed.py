"""Distributed tests: run in a subprocess with 8 forced host devices so the
main pytest process keeps its single-device view.  The forced-device
environment (and the device-count assertion) lives in
``conftest.run_distributed`` — snippets here contain only the test.
"""
import jax
import pytest

from conftest import run_distributed as _run

if not hasattr(jax.sharding, "AxisType"):
    pytest.skip("jax.sharding.AxisType unavailable in this jax version",
                allow_module_level=True)


DISTRIBUTED_SPMM = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.sparse import random_csr, GroupedCOO
from repro.sparse.distributed import spmm_shard_map
from repro.kernels import ref

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
n_rows, n_cols = 64, 48
csr = random_csr(n_rows, n_cols, density=0.05, seed=0)
g = GroupedCOO.fromcsr(csr, 8)  # nnz padded to a multiple of 8
b = jax.random.normal(jax.random.PRNGKey(0), (n_cols, 16))
want = np.asarray(ref.spmm_coo_ref(g.rows, g.cols, g.vals, b, n_rows))
for mode in ("nnz_ar", "nnz_rs"):
    got = np.asarray(spmm_shard_map(g.rows, g.cols, g.vals, b,
                                    n_rows=n_rows, mesh=mesh, axis="data",
                                    mode=mode))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    print(mode, "OK")

# row mode: pre-partition rows locally
rows_per = n_rows // 8
import numpy as onp
rows_np = onp.asarray(g.rows); cols_np = onp.asarray(g.cols); vals_np = onp.asarray(g.vals)
buckets = [[] for _ in range(8)]
for r, c, v in zip(rows_np, cols_np, vals_np):
    buckets[min(int(r) // rows_per, 7)].append((int(r) % rows_per if r < 8*rows_per else r - 7*rows_per, c, v))
width = max(len(bk) for bk in buckets)
lr = onp.zeros((8, width), onp.int32); lc = onp.zeros((8, width), onp.int32)
lv = onp.zeros((8, width), onp.float32)
for i, bk in enumerate(buckets):
    for j, (r, c, v) in enumerate(bk):
        lr[i, j], lc[i, j], lv[i, j] = r, c, v
got = np.asarray(spmm_shard_map(jnp.asarray(lr.reshape(-1)),
                                jnp.asarray(lc.reshape(-1)),
                                jnp.asarray(lv.reshape(-1)), b,
                                n_rows=n_rows, mesh=mesh, axis="data",
                                mode="row"))
np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
print("row OK")
"""


MOE_EP = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, smoke_config
from repro.models.moe import apply_moe, init_moe, ShardingCtx

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
# capacity_factor large enough that no token is dropped in either layout,
# so expert parallelism must match the single-shard result exactly.
cfg = smoke_config(ARCHS["qwen3-moe-235b-a22b"]).scaled(capacity_factor=4.0)
p = init_moe(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model))
ref_out, ref_aux = apply_moe(cfg, p, x, None)
ctx = ShardingCtx(mesh=mesh, data_axes=("data",), model_axis="model")
with mesh:
    out, aux = jax.jit(lambda p, x: apply_moe(cfg, p, x, ctx))(p, x)
close = np.isclose(np.asarray(out), np.asarray(ref_out), rtol=1e-3,
                   atol=1e-3).all(axis=-1).mean()
assert close > 0.999, close
print("moe EP OK, agreement", close)
"""


SEQ_SHARDED_DECODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, smoke_config
from repro.models import get_model
from repro.distributed.sharding import cache_shardings, param_shardings

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = smoke_config(ARCHS["qwen2-7b"]).scaled(n_kv_heads=2)
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 15), 0,
                                      cfg.vocab_size, jnp.int32)}
logits_ref, cache = api.prefill(params, batch, 32)
tok = jnp.argmax(logits_ref, -1).astype(jnp.int32)
want, _ = api.decode_step(params, cache, tok)

pshard = param_shardings(mesh, jax.eval_shape(api.init, jax.random.PRNGKey(0)))
csh = cache_shardings(mesh, cfg, jax.eval_shape(lambda: cache))
params_s = jax.device_put(params, pshard)
cache_s = jax.device_put(cache, csh)
with mesh:
    got, new_cache = jax.jit(api.decode_step)(params_s, cache_s, tok)
np.testing.assert_allclose(np.asarray(got, np.float32),
                           np.asarray(want, np.float32), rtol=2e-3, atol=2e-3)
print("seq-sharded decode OK; cache seq spec:",
      new_cache["k"].sharding.spec)
"""


@pytest.mark.slow
def test_distributed_spmm_modes():
    out = _run(DISTRIBUTED_SPMM)
    assert "row OK" in out


@pytest.mark.slow
def test_moe_expert_parallel_matches_single():
    out = _run(MOE_EP)
    assert "moe EP OK" in out


@pytest.mark.slow
def test_seq_sharded_kv_decode_matches_single():
    out = _run(SEQ_SHARDED_DECODE)
    assert "seq-sharded decode OK" in out


SEQ_PARALLEL_ATTENTION = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, smoke_config
from repro.models import get_model

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = smoke_config(ARCHS["qwen2-7b"])
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                      cfg.vocab_size, jnp.int32)}
want = float(api.loss(params, batch))
gw = jax.grad(api.loss)(params, batch)

cfg_sp = cfg.scaled(seq_parallel_attn=True)
api_sp = get_model(cfg_sp)
with mesh:
    got = float(jax.jit(api_sp.loss)(params, batch))
    gg = jax.jit(jax.grad(api_sp.loss))(params, batch)
assert abs(got - want) < 2e-3, (got, want)
for a, b in zip(jax.tree.leaves(gw), jax.tree.leaves(gg)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2,
                               atol=2e-3)
print("seq-parallel attention OK, loss", got)
"""


@pytest.mark.slow
def test_seq_parallel_attention_matches_single():
    out = _run(SEQ_PARALLEL_ATTENTION)
    assert "seq-parallel attention OK" in out


ELASTIC_REMESH = """
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import ARCHS, smoke_config
from repro.models import get_model
from repro.checkpoint.manager import CheckpointManager
from repro.train.optimizer import AdamW, constant_schedule
from repro.train.train_step import init_state, make_train_step
from repro.distributed.fault_tolerance import plan_remesh

cfg = smoke_config(ARCHS["qwen2-7b"])
api = get_model(cfg)
opt = AdamW(lr=constant_schedule(1e-3))
step = jax.jit(make_train_step(api, opt))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      cfg.vocab_size, jnp.int32)}

# phase 1: train on a (4, 2) mesh, checkpoint
mesh1 = jax.make_mesh((4, 2), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
state = init_state(api, opt, jax.random.PRNGKey(0))
state = jax.device_put(state, NamedSharding(mesh1, P()))
with mesh1:
    for _ in range(3):
        state, m = step(state, batch)
loss_before = float(m["loss"])
tmp = tempfile.mkdtemp()
mgr = CheckpointManager(tmp, async_save=False)
mgr.save(3, state)

# phase 2: "lose" half the fleet -> re-mesh to (2, 2) on 4 devices and
# restore the same checkpoint under the new topology
shape = plan_remesh(n_healthy_hosts=1, chips_per_host=4, model_parallel=2)
assert shape == (2, 2), shape
devs = np.asarray(jax.devices()[:4]).reshape(2, 2)
mesh2 = jax.sharding.Mesh(devs, ("data", "model"))
restored, step_no = mgr.restore(
    jax.tree.map(jnp.zeros_like, state),
    shardings=jax.tree.map(lambda _: NamedSharding(mesh2, P()), state))
assert step_no == 3
with mesh2:
    restored, m2 = step(restored, batch)
assert int(restored.opt.step) == 4
# same params + same batch -> the post-restore loss must equal a
# continuation on the original mesh
with mesh1:
    cont, m1 = step(state, batch)
assert abs(float(m2["loss"]) - float(m1["loss"])) < 1e-4, (
    float(m2["loss"]), float(m1["loss"]))
print("elastic remesh OK: step", step_no, "->", int(restored.opt.step),
      "loss", float(m2["loss"]))
"""


@pytest.mark.slow
def test_elastic_remesh_checkpoint_restore():
    out = _run(ELASTIC_REMESH)
    assert "elastic remesh OK" in out
