"""Hymba-1.5B [arXiv:2411.13676]: parallel attention + mamba heads."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    conv_kernel=4, ssm_chunk=128,
    norm="rmsnorm", mlp_type="swiglu", rope_theta=1e4,
)
