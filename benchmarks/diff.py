"""Bench-artifact regression diff (ISSUE 3 satellite).

Compares two ``BENCH_<tag>.json`` artifacts (as written by
``benchmarks.run --json``) and exits non-zero when the new run regresses
past a threshold.  Two signals are checked:

* **us_per_call geomeans** per row group (default group: ``table5``):
  geomean over the names both artifacts share; regression when
  ``new/old > 1 + threshold``;
* **derived geomean metrics** — ``derived`` fields carry
  ``<key>_geomean=<x>`` ratios.  Only the *win* ratios
  (``tuned_vs_auto_geomean``, ``tuned_vs_default_geomean`` — higher is
  better) gate, failing when ``new < old * (1 - threshold)``; other
  geomean keys (e.g. the ``*_vs_oracle`` slowdown ratios, where lower
  is better) are reported informationally but never fail.  The tuner
  gaps gate through win ratios rather than absolute wall clock: a ratio
  is measured within one run on one machine, so it survives the
  runner-to-runner CPU variance that makes absolute us comparisons
  across CI runs noisy.

Runs standalone (stdlib only) so CI and local use are the same command:

    python benchmarks/diff.py old.json new.json --threshold 0.10

Missing groups or no shared rows are reported and *skipped*, never
failed — the first run of a fresh benchmark set must stay green.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys

DEFAULT_GROUPS = ("table5",)

# derived geomean keys where higher is better (gateable win ratios);
# anything else matched by the regex — e.g. auto_vs_oracle_geomean, a
# slowdown ratio where LOWER is better — is reported but never gates
GATED_GEOMEAN_KEYS = ("tuned_vs_auto_geomean", "tuned_vs_default_geomean")

_GEOMEAN_RE = re.compile(r"([a-z0-9_/]*geomean)=([-+0-9.eE]+)")


def load_bench(path: str) -> dict:
    """``{name: {us_per_call, derived}}`` as ``benchmarks.run`` wrote it."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise ValueError(f"{path}: expected a JSON object of bench rows")
    return data


def _geomean(xs) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def _us_rows(bench: dict, group: str) -> dict:
    out = {}
    for name, row in bench.items():
        us = (row or {}).get("us_per_call")
        if name.startswith(group) and isinstance(us, (int, float)) and us > 0:
            out[name] = float(us)
    return out


def _derived_geomeans(bench: dict) -> dict:
    """``{row_name/metric: value}`` for every ``*geomean=`` in derived."""
    out = {}
    for name, row in bench.items():
        for key, val in _GEOMEAN_RE.findall(str((row or {}).get("derived"))):
            try:
                v = float(val)
            except ValueError:
                continue
            if v > 0:
                out[f"{name}:{key}"] = v
    return out


def compare(old: dict, new: dict, *, threshold: float = 0.10,
            groups=DEFAULT_GROUPS) -> list:
    """Findings as ``(kind, label, old, new, ratio, regressed)`` tuples.

    kind 'us' ratios are new/old time (higher is worse); kind 'geomean'
    ratios are new/old win ratio (lower is worse); kind 'info' is a
    non-gating derived ratio (direction unknown, e.g. vs-oracle
    slowdowns); kind 'skip' marks a group with no shared rows.
    """
    findings = []
    for group in groups:
        a, b = _us_rows(old, group), _us_rows(new, group)
        shared = sorted(set(a) & set(b))
        if not shared:
            findings.append(("skip", group, None, None, None, False))
            continue
        g_old = _geomean([a[n] for n in shared])
        g_new = _geomean([b[n] for n in shared])
        ratio = g_new / g_old
        findings.append(("us", f"{group} ({len(shared)} rows)",
                         g_old, g_new, ratio, ratio > 1.0 + threshold))
    d_old, d_new = _derived_geomeans(old), _derived_geomeans(new)
    for key in sorted(set(d_old) & set(d_new)):
        ratio = d_new[key] / d_old[key]
        gated = key.rsplit(":", 1)[-1] in GATED_GEOMEAN_KEYS
        findings.append(("geomean" if gated else "info", key,
                         d_old[key], d_new[key], ratio,
                         gated and ratio < 1.0 - threshold))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="previous BENCH json artifact")
    ap.add_argument("new", help="current BENCH json artifact")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional geomean regression that fails "
                         "(default 0.10 = 10%%)")
    ap.add_argument("--groups", default=",".join(DEFAULT_GROUPS),
                    help="comma list of row-name prefixes to diff")
    args = ap.parse_args(argv)

    old = load_bench(args.old)
    new = load_bench(args.new)
    findings = compare(old, new, threshold=args.threshold,
                       groups=tuple(g for g in args.groups.split(",") if g))

    failed = False
    print(f"bench diff: {args.old} -> {args.new} "
          f"(threshold {args.threshold:.0%})")
    for kind, label, a, b, ratio, regressed in findings:
        if kind == "skip":
            print(f"  SKIP  {label}: no shared rows")
            continue
        unit = "us" if kind == "us" else "x"
        verdict = ("REGRESSED" if regressed
                   else "info" if kind == "info" else "ok")
        arrow = "slower" if kind == "us" else "ratio"
        print(f"  {verdict:9s} {label}: {a:.3f}{unit} -> {b:.3f}{unit} "
              f"({ratio:.3f} {arrow})")
        failed |= regressed
    if failed:
        print("bench diff: FAIL (regression past threshold)",
              file=sys.stderr)
        return 1
    print("bench diff: ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
