"""Grouped (expert-segment) matmul Pallas kernel — segment group applied to
MoE dispatch (DESIGN.md §4.1).

MoE expert application is sparse-dense hybrid algebra in the paper's DF
formulation: Q₀ = token→expert routing (sparse), ⊗ = expert GEMM,
⊕ = segment-sum over each expert's token segment. Tokens arrive sorted by
expert and *capacity-padded so every token tile belongs to exactly one
expert* — zero extension again: padding tokens multiply real expert
weights and are masked afterwards.

The tile→expert map is scalar-prefetched so the weight BlockSpec can
select the expert block at DMA-schedule time (the TPU analogue of the
runtime writeback-thread election: the *read* side is decided at runtime
here).

The kernel is a planner-rule target (``repro.fuse``): a ``core.Epilogue``
(per-expert bias / activation / dtype cast) runs on the output block at
the last contraction step, so e.g. the MoE expert GEMM's SiLU is one
launch per tile instead of a GEMM pass plus an XLA elementwise pass.
Residuals are not supported here — there is no natural (T_pad, F)
residual operand in the expert-sorted layout.

Grid: (token_tiles, f_tiles, d_tiles) — contraction axis innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.schedule import Epilogue
from .common import apply_epilogue, split_epilogue_refs, upcast_f32

_NOOP = Epilogue()


def fit_tile(n: int, tile: int) -> int:
    """Largest power-of-two shrink of ``tile`` that divides ``n`` —
    ``grouped_matmul`` requires exact blocking of the D/F axes, and
    halving preserves the power-of-two grid.  Shared by the dispatch
    path (``models.moe``) and the tuner (``tune.moe``) so both agree on
    what a legal tile is."""
    t = max(1, min(tile, n))
    while n % t and t > 1:
        t //= 2
    return t


def _gmm_kernel(epilogue: Epilogue, narrowed: bool,
                emap_ref, x_ref, w_ref, *refs):
    del emap_ref  # consumed by the index maps
    bias_ref, res_ref, out_ref, acc_ref = split_epilogue_refs(
        refs, epilogue, narrowed)
    acc = out_ref if acc_ref is None else acc_ref

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    # narrow (bf16/fp8) storage upcasts here; accumulation is f32
    x, w3 = upcast_f32(x_ref[...], w_ref[...])  # (TT, DT), (1, DT, FT)
    w = w3[0]  # (DT, FT)
    acc[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    if not epilogue.is_noop or narrowed:
        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _finish():
            apply_epilogue(out_ref, epilogue, bias_ref, res_ref, acc_ref)


@functools.partial(
    jax.jit,
    static_argnames=("token_tile", "f_tile", "d_tile", "interpret",
                     "epilogue"),
)
def grouped_matmul(x, tile_experts, weights, *, bias=None,
                   epilogue: Epilogue = _NOOP, token_tile: int = 128,
                   f_tile: int = 128, d_tile: int = 128,
                   interpret: bool = True):
    """x: (T_pad, D) tokens sorted by expert, T_pad % token_tile == 0;
    tile_experts: (T_pad // token_tile,) int32 expert of each token tile;
    weights: (E, D, F); bias: (E, F) per-expert, required iff
    ``epilogue.bias``. Returns (T_pad, F) in ``epilogue.out_dtype``
    (f32 default) with the epilogue fused onto the output block."""
    t_pad, d = x.shape
    e, dw, f = weights.shape
    assert dw == d and t_pad % token_tile == 0
    assert d % d_tile == 0 and f % f_tile == 0
    assert not epilogue.residual, \
        "grouped_matmul has no residual operand (see module docstring)"
    assert epilogue.bias == (bias is not None)
    if bias is not None:
        assert bias.shape == (e, f), (bias.shape, (e, f))

    out_dtype = jnp.dtype(epilogue.out_dtype or jnp.float32)
    narrowed = out_dtype != jnp.float32

    in_specs = [
        pl.BlockSpec((token_tile, d_tile), lambda i, j, k, emap: (i, k)),
        pl.BlockSpec((1, d_tile, f_tile),
                     lambda i, j, k, emap: (emap[i], k, j)),
    ]
    operands = [tile_experts, x, weights]
    if bias is not None:
        in_specs.append(
            pl.BlockSpec((1, f_tile), lambda i, j, k, emap: (emap[i], j)))
        operands.append(bias)

    grid = (t_pad // token_tile, f // f_tile, d // d_tile)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((token_tile, f_tile),
                               lambda i, j, k, emap: (i, j)),
        scratch_shapes=(
            [pltpu.VMEM((token_tile, f_tile), jnp.float32)]
            if narrowed else []
        ),
    )
    return pl.pallas_call(
        functools.partial(_gmm_kernel, epilogue, narrowed),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t_pad, f), out_dtype),
        interpret=interpret,
    )(*operands)
