"""Mamba-2 language model (attention-free): x += mixer(norm(x)) per layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (apply_norm, embed, init_embedding, init_norm,
                     layer_scan, lm_loss_from_features, unembed)
from .mamba2 import (init_mixer, init_mixer_cache, mixer_decode, mixer_fwd)


def init_layer(cfg, key):
    return {"ln": init_norm(cfg, cfg.d_model), "mixer": init_mixer(cfg, key)}


def init_params(cfg, key):
    ke, kl = jax.random.split(key)
    layers = jax.vmap(lambda k: init_layer(cfg, k))(
        jax.random.split(kl, cfg.n_layers))
    return {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model,
                                cfg.param_dtype),
        "layers": layers,
        "final_norm": init_norm(cfg, cfg.d_model),
    }


def forward_features(cfg, params, tokens, ctx=None):
    del ctx
    x = embed(params["embed"], tokens).astype(cfg.compute_dtype)

    def layer(p_l, x):
        return x + mixer_fwd(cfg, p_l["mixer"], apply_norm(cfg, p_l["ln"], x))

    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)

    def step(x, p_l):
        return layer(p_l, x), None

    x, _ = layer_scan(cfg, step, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    return x


def forward(cfg, params, tokens, ctx=None):
    x = forward_features(cfg, params, tokens, ctx)
    return unembed(params["embed"], x), jnp.zeros((), jnp.float32)


def loss_fn(cfg, params, batch, ctx=None):
    x = forward_features(cfg, params, batch["tokens"], ctx)
    return lm_loss_from_features(params["embed"], x[:, :-1],
                                 batch["tokens"][:, 1:], batch.get("mask"))


def init_cache(cfg, batch_size, max_len, dtype=None):
    del max_len  # state models have O(1) cache
    one = init_mixer_cache(cfg, batch_size, dtype)
    return {
        "layers": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg, params, tokens, max_len, ctx=None):
    del max_len, ctx
    x = embed(params["embed"], tokens).astype(cfg.compute_dtype)

    def step(x, p_l):
        h = apply_norm(cfg, p_l["ln"], x)
        out, st = mixer_fwd(cfg, p_l["mixer"], h, return_state=True)
        return x + out, st

    x, states = layer_scan(cfg, step, x, params["layers"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x[:, -1])
    return logits, {"layers": states,
                    "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_step(cfg, params, cache, tokens, ctx=None):
    del ctx
    x = embed(params["embed"], tokens).astype(cfg.compute_dtype)  # (B, D)

    def step(x, inp):
        p_l, cache_l = inp
        h = apply_norm(cfg, p_l["ln"], x)
        out, new_cache = mixer_decode(cfg, p_l["mixer"], cache_l, h)
        return x + out, new_cache

    x, new_layers = layer_scan(cfg, step, x, (params["layers"],
                                              cache["layers"]))
    x = apply_norm(cfg, params["final_norm"], x)
    return unembed(params["embed"], x), {"layers": new_layers,
                                         "pos": cache["pos"] + 1}
