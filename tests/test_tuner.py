"""Tests for the empirical schedule autotuner (ISSUE 2).

Covers the acceptance surface: cache round-trip (tune -> serialize ->
reload -> hit with *zero* measurement calls), fingerprint determinism,
``schedule="tune"`` end-to-end through ``repro.sparse`` against the
reference oracle, tuned-never-loses-to-auto within one measurement
session, calibration strictly lowering cost-model regret, and the
serving-path resolver never measuring.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Schedule,
    as_schedule,
    cost_terms,
    get_cost_weights,
    select_schedule,
    set_cost_weights,
)
from repro.kernels import ref
from repro.sparse import matrix_stats, random_csr, segment_reduce, spmm
from repro.tune import (
    SCHEMA_VERSION,
    ScheduleCache,
    cache_key,
    calibrate,
    cached_or_auto,
    fingerprint,
    model_regret,
    schedule_key,
    tune_schedule,
)

RTOL = ATOL = 2e-5


def _assert_tuned_parity(got, want, sched):
    """Parity check for a *real-measurement* tuned schedule.  The dtype
    axis (DESIGN.md §13) may legitimately pick a narrow value dtype when
    its measured time wins, so which dtype the tuner lands on is
    machine-timing-dependent: f32 results must match the oracle tightly,
    narrow ones within the tuner's default parity-error budget (the same
    norm-relative metric ``_dtype_parity_error`` gates on, with slack
    because the gate probed a different dense operand)."""
    if sched is None or sched.value_dtype is None:
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
        return
    rel = (np.linalg.norm(got - want)
           / (np.linalg.norm(want) + 1e-12))
    assert rel <= 0.10, (sched, rel)


def _mat(seed=0, n=200, density=0.02, skew=1.5):
    return random_csr(n, n, density=density, skew=skew, seed=seed)


def _fake_measure(costs=None):
    """Deterministic, instant objective: seconds from a hash of the
    schedule key (or an explicit table).  Returns (fn, call_log)."""
    calls = []

    def measure(s: Schedule) -> float:
        calls.append(s)
        if costs is not None:
            return costs(s)
        h = sum(ord(c) for c in schedule_key(s))
        return 1e-3 * (1.0 + (h % 97) / 97.0)

    return measure, calls


# ---------------------------------------------------------------------------
# Cache round-trip + determinism
# ---------------------------------------------------------------------------


def test_cache_round_trip_zero_remeasure(tmp_path):
    path = tmp_path / "cache.json"
    csr = _mat()
    measure, calls = _fake_measure()
    res = tune_schedule(csr, 8, cache=ScheduleCache(path), measure=measure)
    assert not res.from_cache and len(calls) > 0
    assert path.exists()

    # fresh cache object, same file: replay must not measure at all
    measure2, calls2 = _fake_measure()
    res2 = tune_schedule(csr, 8, cache=ScheduleCache(path),
                         measure=measure2)
    assert res2.from_cache
    assert calls2 == []
    assert res2.n_measurements == 0
    assert res2.schedule == res.schedule
    assert res2.us_per_call == pytest.approx(res.us_per_call)


def test_fingerprint_deterministic_and_stats_sensitive():
    a = _mat(seed=3)
    b = _mat(seed=3)
    assert fingerprint(a) == fingerprint(b)
    assert cache_key(a, 8) == cache_key(b, 8)
    # the key separates dense-col count; backends are separated by the
    # cache *namespace* (one file per backend+device kind), not the key
    assert cache_key(a, 8) != cache_key(a, 16)
    from repro.tune import default_cache_path

    assert default_cache_path("cpu") != default_cache_path("tpu-v5e")
    # a different sparsity profile gets a different fingerprint
    assert fingerprint(a) != fingerprint(_mat(seed=3, skew=0.0))


def test_tune_deterministic_under_fixed_fingerprint(tmp_path):
    csr = _mat(seed=5)
    r1 = tune_schedule(csr, 4, cache=ScheduleCache(None),
                       measure=_fake_measure()[0])
    r2 = tune_schedule(csr, 4, cache=ScheduleCache(None),
                       measure=_fake_measure()[0])
    assert r1.schedule == r2.schedule
    assert r1.measured == r2.measured


def test_cache_save_merges_concurrent_writers(tmp_path):
    """Two processes sharing one cache file must not drop each other's
    records: save() folds the on-disk state in before rewriting."""
    path = tmp_path / "cache.json"
    a, b = ScheduleCache(path), ScheduleCache(path)
    csr1, csr2 = _mat(seed=1), _mat(seed=2, skew=0.0)
    a.load(), b.load()  # both snapshot the (empty) file up front
    tune_schedule(csr1, 4, cache=a, measure=_fake_measure()[0])
    tune_schedule(csr2, 4, cache=b, measure=_fake_measure()[0])
    fresh = ScheduleCache(path)
    assert cache_key(csr1, 4) in fresh
    assert cache_key(csr2, 4) in fresh


def test_cache_save_interleaved_writers_keep_all_records(tmp_path):
    """Many threads doing load-modify-save on one file concurrently: the
    flock around the merge-and-rewrite means no thread's records are
    lost to an interleaved read-merge-write."""
    import threading

    from repro.core import Schedule
    from repro.tune import TuneRecord

    path = tmp_path / "cache.json"
    n_writers, per_writer = 6, 5
    errors = []

    def writer(i):
        try:
            for j in range(per_writer):
                c = ScheduleCache(path)
                c.put(f"w{i}k{j}", TuneRecord(schedule=Schedule("eb"),
                                              us_per_call=float(i * 10 + j)))
                c.save()
        except Exception as e:  # pragma: no cover - surfacing only
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    fresh = ScheduleCache(path)
    keys = set(fresh.keys())
    want = {f"w{i}k{j}" for i in range(n_writers) for j in range(per_writer)}
    assert keys == want


def test_cache_schema_version_mismatch_drops_records(tmp_path):
    path = tmp_path / "cache.json"
    csr = _mat()
    tune_schedule(csr, 8, cache=ScheduleCache(path),
                  measure=_fake_measure()[0])
    raw = json.loads(path.read_text())
    assert raw["version"] == SCHEMA_VERSION
    raw["version"] = SCHEMA_VERSION + 1
    path.write_text(json.dumps(raw))
    assert len(ScheduleCache(path)) == 0  # stale schema: silently empty


def test_tuned_never_loses_to_auto_in_session():
    """The selector's pick is always in the measured pool, so the tuned
    schedule can never be slower than auto under the session's own
    measurements (the acceptance criterion, minus wall-clock noise)."""
    for seed in (0, 1, 2):
        csr = _mat(seed=seed, skew=float(seed))
        measure, _ = _fake_measure()
        res = tune_schedule(csr, 4, cache=ScheduleCache(None),
                            measure=measure)
        auto = select_schedule(matrix_stats(csr), 4)
        auto_key = schedule_key(auto)
        assert auto_key in res.measured
        assert res.us_per_call <= res.measured[auto_key] + 1e-12


# ---------------------------------------------------------------------------
# schedule="tune" end-to-end
# ---------------------------------------------------------------------------


@pytest.fixture
def tuner_env(tmp_path, monkeypatch):
    """Hermetic tuner environment: tmp cache file, minimal timing work."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_BENCH_ITERS", "1")
    monkeypatch.setenv("REPRO_BENCH_WARMUP", "0")
    return tmp_path


def test_spmm_schedule_tune_matches_oracle(tuner_env):
    csr = _mat(seed=7, n=150, density=0.03)
    b = jax.random.normal(jax.random.PRNGKey(0), (150, 8))
    coo = csr.tocoo()
    want = np.asarray(
        ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, b, csr.shape[0]))
    got = np.asarray(spmm(csr, b, schedule="tune"))
    sched = cached_or_auto(csr, 8)  # what "tune" just persisted
    _assert_tuned_parity(got, want, sched)
    # second call replays the persisted record (same schedule, no search)
    got2 = np.asarray(spmm(csr, b, schedule="tune"))
    _assert_tuned_parity(got2, want, sched)
    # the record landed in the backend's namespace file, derived from
    # REPRO_TUNE_CACHE (tune.json -> tune.<namespace>.json)
    from repro.tune import default_cache_path

    assert default_cache_path().exists()
    assert default_cache_path().name.startswith("tune.")


def test_segment_reduce_schedule_tune_matches_oracle(tuner_env):
    rng = np.random.default_rng(11)
    seg = np.sort(rng.integers(0, 25, 300)).astype(np.int32)
    data = rng.standard_normal((300, 6)).astype(np.float32)
    want = np.asarray(jax.ops.segment_sum(jnp.asarray(data),
                                          jnp.asarray(seg), 25))
    got = np.asarray(segment_reduce(jnp.asarray(seg), jnp.asarray(data), 25,
                                    schedule="tune"))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_as_schedule_tune_requires_matrix(tuner_env):
    with pytest.raises(ValueError):
        as_schedule("tune")
    csr = _mat(seed=9, n=120)
    s = as_schedule("tune", matrix=csr, n_dense_cols=4)
    assert isinstance(s, Schedule)
    # the coercion consulted/populated the same persistent cache
    assert cached_or_auto(csr, 4) == s


def test_cached_or_auto_never_measures(tuner_env):
    csr = _mat(seed=13)
    # miss -> static selector, still zero measurements
    assert cached_or_auto(csr, 4) == select_schedule(matrix_stats(csr), 4)
    measure, calls = _fake_measure()
    tuned = tune_schedule(csr, 4, measure=measure).schedule
    assert calls  # the explicit tune measured
    assert cached_or_auto(csr, 4) == tuned  # ...and the hit replays it


def test_serve_engine_spmm_consults_tuner_cache(tuner_env):
    from repro.serve.engine import ServeEngine

    class _API:  # the sparse path never touches decode
        def init_cache(self, slots, max_len):
            return {}

        def decode_step(self, params, cache, toks):  # pragma: no cover
            raise NotImplementedError

    eng = ServeEngine(_API(), params={}, slots=1)
    csr = _mat(seed=17, n=140, density=0.03)
    b = jax.random.normal(jax.random.PRNGKey(1), (140, 4))
    sched = eng.prepare_sparse(csr, 4)  # tunes ahead of time
    coo = csr.tocoo()
    want = np.asarray(
        ref.spmm_coo_ref(coo.rows, coo.cols, coo.vals, b, csr.shape[0]))
    got = np.asarray(eng.spmm(csr, b))  # request path: replay only
    _assert_tuned_parity(got, want, sched)
    assert sched in eng._sched_memo.values()
    # an equal-fingerprint copy of the matrix replays the same schedule
    # (the memo is keyed by fingerprint, not object identity)
    copy = _mat(seed=17, n=140, density=0.03)
    got2 = np.asarray(eng.spmm(copy, b))
    _assert_tuned_parity(got2, want, sched)


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------


def _synthetic_machine(true_w):
    true_w = np.asarray(true_w, np.float64)

    def measure(csr, sched):
        return float(true_w
                     @ np.asarray(cost_terms(matrix_stats(csr), sched, 4)))

    return measure


def test_calibration_strictly_lowers_regret():
    """On a writeback-dominated synthetic machine the napkin weights
    mispredict; the least-squares fit must strictly lower regret (and,
    with exactly-linear timings, reach the oracle)."""
    mats = [random_csr(256, 256, density=d, skew=s, seed=i)
            for i, (d, s) in enumerate([(0.01, 0.0), (0.02, 1.5),
                                        (0.005, 2.5)])]
    measure = _synthetic_machine([1.0, 0.0, 8.0, 0.1])
    res = calibrate(mats, 4, measure=measure)
    assert res.regret_before > 1.0  # the prior does mispredict here
    assert res.regret_after < res.regret_before  # strictly lower
    assert res.regret_after == pytest.approx(1.0, abs=1e-9)
    assert res.n_samples > 0


def test_calibration_apply_feeds_schedule_auto():
    from repro.tune import collect_samples

    mats = [random_csr(200, 200, density=0.02, skew=s, seed=int(s * 2))
            for s in (0.0, 2.0)]
    measure = _synthetic_machine([1.0, 0.0, 8.0, 0.1])
    try:
        res = calibrate(mats, 4, apply=True, measure=measure)
        assert get_cost_weights() == res.weights
        # with the calibrated weights installed, the model's argmin now
        # matches the synthetic machine's empirical winner everywhere
        samples = collect_samples(mats, 4, measure=measure)
        assert model_regret(samples,
                            get_cost_weights()) == pytest.approx(1.0,
                                                                 abs=1e-9)
        # Schedule.auto runs through the same installed weights
        assert Schedule.auto(matrix_stats(mats[0]), 4) is not None
    finally:
        set_cost_weights(None)
    assert get_cost_weights() == (1.0, 1.0, 2.0, 0.25)


def test_calibration_never_ships_a_worse_fit():
    """If the fit cannot beat the prior on its own data, the prior is
    kept (regret_after <= regret_before always holds)."""
    mats = [random_csr(128, 128, density=0.05, seed=1)]

    def constant_measure(csr, sched):
        return 1.0  # timings carry no signal at all

    res = calibrate(mats, 4, measure=constant_measure)
    assert res.regret_after <= res.regret_before
    assert res.regret_after == pytest.approx(1.0)


def test_set_cost_weights_validation():
    with pytest.raises(ValueError):
        set_cost_weights((1.0, 2.0))
    with pytest.raises(ValueError):
        set_cost_weights((-1.0, 1.0, 1.0, 1.0))
    with pytest.raises(ValueError):
        set_cost_weights((0.0, 0.0, 0.0, 0.0))
    set_cost_weights(None)
