"""Row-split (RB) SpMM Pallas kernel — the paper's ``{<g row, c col>, 1}``
family (parallel reduction: exactly one writeback per row).

Feed format: ELL (per-row padded, see ``formats.ELL``) — padding is the
zero extension the paper legitimizes: padded slots gather B[0] scaled by
0.0 and flow through the vector datapath unpredicated.

Grid: (row_tiles, col_tiles, width_tiles) — width innermost, accumulating
into the same (ROW_TILE × COL_TILE) output block; the fused epilogue
(``core.Epilogue``: bias / activation / residual / dtype cast) runs on
the last width step, when the block holds the fully-reduced row.  Like
the EB kernel's, this epilogue slot is a fusion-planner target
(``repro.fuse`` ``epilogue-fold`` rule, DESIGN.md §10).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schedule import Epilogue
from .common import apply_epilogue, split_epilogue_refs, upcast_f32

_NOOP = Epilogue()


def _spmm_rb_kernel(cols_ref, vals_ref, b_ref, *refs,
                    epilogue: Epilogue, narrowed: bool, quantized: bool):
    if quantized:
        scales_ref, *refs = refs
    bias_ref, res_ref, out_ref, acc_ref = split_epilogue_refs(
        refs, epilogue, narrowed)
    # out_dtype narrowing: accumulate in the f32 scratch, cast only at
    # the final store (out_ref doubles as the accumulator otherwise)
    acc = out_ref if acc_ref is None else acc_ref

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    cols = cols_ref[...]  # (R, Wt)
    # narrow (bf16/fp8) or int8 storage upcasts here; reduction is f32
    vals = upcast_f32(vals_ref[...])  # (R, Wt)
    b = upcast_f32(b_ref[...])  # (K, C)
    if quantized:
        # per-row scales: this cell owns whole rows, so dequant is a
        # broadcast over the width axis before the row reduction
        vals = vals * upcast_f32(scales_ref[...])[:, None]

    r, wt = cols.shape
    gathered = jnp.take(b, cols.reshape(-1), axis=0).reshape(r, wt, -1)
    acc[...] += jnp.sum(vals[..., None] * gathered,
                        axis=1).astype(acc.dtype)

    if not epilogue.is_noop:
        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _epilogue():
            apply_epilogue(out_ref, epilogue, bias_ref, res_ref,
                           acc_ref=acc_ref)


@functools.partial(
    jax.jit,
    static_argnames=("row_tile", "col_tile", "width_tile", "epilogue",
                     "interpret"),
)
def spmm_rb(ecols, evals, b, *, row_tile: int = 8, col_tile: int = 128,
            width_tile: int | None = None, epilogue: Epilogue = _NOOP,
            scales=None, bias=None, residual=None, interpret: bool = True):
    """out (R_pad, N) from ELL arrays (R_pad, W) and dense B (K, N), with
    the fused ``epilogue`` applied per output block on its last width
    step (``bias`` (1, N) / ``residual`` (R_pad, N) per its flags).

    R_pad % row_tile == 0 and N % col_tile == 0 are the wrapper's job
    (``ops.spmm``); W is padded to width_tile here.

    ``scales`` (R_pad,) f32, when given, selects the quantized value
    path (DESIGN.md §13): ``evals`` holds int8 codes dequantized
    ``val * scales[row]`` before the width reduction (padded rows carry
    val 0, so their scale is irrelevant).
    """
    r_pad, w = ecols.shape
    k, n = b.shape
    if width_tile is None:
        width_tile = min(w, 64)
    w_pad = ((w + width_tile - 1) // width_tile) * width_tile
    if w_pad != w:
        pad = w_pad - w
        ecols = jnp.pad(ecols, ((0, 0), (0, pad)))
        evals = jnp.pad(evals, ((0, 0), (0, pad)))
    assert r_pad % row_tile == 0 and n % col_tile == 0

    grid = (r_pad // row_tile, n // col_tile, w_pad // width_tile)
    operands = [ecols, evals, b]
    in_specs = [
        pl.BlockSpec((row_tile, width_tile), lambda i, j, u: (i, u)),
        pl.BlockSpec((row_tile, width_tile), lambda i, j, u: (i, u)),
        pl.BlockSpec((k, col_tile), lambda i, j, u: (0, j)),
    ]
    quantized = scales is not None
    if quantized:
        assert scales.shape == (r_pad,), (scales.shape, r_pad)
        operands.append(scales)
        in_specs.append(pl.BlockSpec((row_tile,), lambda i, j, u: (i,)))
    if epilogue.bias:
        assert bias is not None and bias.shape == (1, n), (n, bias)
        operands.append(bias)
        in_specs.append(pl.BlockSpec((1, col_tile), lambda i, j, u: (0, j)))
    if epilogue.residual:
        assert residual is not None and residual.shape == (r_pad, n)
        operands.append(residual)
        in_specs.append(
            pl.BlockSpec((row_tile, col_tile), lambda i, j, u: (i, j)))
    out_dtype = jnp.dtype(epilogue.out_dtype or jnp.float32)
    narrowed = out_dtype != jnp.float32
    scratch = []
    if narrowed:
        from jax.experimental.pallas import tpu as pltpu

        scratch = [pltpu.VMEM((row_tile, col_tile), jnp.float32)]

    kernel = functools.partial(_spmm_rb_kernel, epilogue=epilogue,
                               narrowed=narrowed, quantized=quantized)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((row_tile, col_tile), lambda i, j, u: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r_pad, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
