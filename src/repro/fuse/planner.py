"""The fusion planner: chain → :class:`~repro.fuse.ir.FusePlan`.

``plan`` walks the chain left to right, growing the current launch while
the rule registry (``repro.fuse.rules``) keeps fusing and opening a new
launch when it refuses — a greedy pass, optimal for straight-line chains
(the only shape the IR expresses: every boundary decision is local to
one launch).

``tune_plan`` is the measured version: fuse/split is a *scheduling*
decision, not just a legality one (a fused epilogue can lose to XLA's
own fusion on tiny tiles), so it searches the per-boundary decision
space on the shared tuner driver — seeded with the maximally-fused and
fully-split plans, hillclimbing single-boundary flips on 3+-node
chains — and persists the winning
:class:`~repro.fuse.ir.FuseDecision` in the schedule cache
(``fuse:``-prefixed keys, same fingerprint machinery as SpMM tuning) —
a repeat call replays with zero measurements.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from .ir import FuseDecision, FusePlan, Launch, chain_sig
from .rules import try_fuse

__all__ = ["plan", "plan_key", "split_all", "tune_plan", "tuned_plan"]


def plan(chain, decision: Optional[FuseDecision] = None) -> FusePlan:
    """Plan a chain.  Without ``decision``, fuse greedily wherever the
    rules allow; with one (e.g. a tuned replay), fuse a boundary only
    when the decision asks *and* the rules allow — legality is never
    overridden by a cached bit."""
    chain = tuple(chain)
    if not chain:
        raise ValueError("empty chain")
    if decision is not None and len(decision.fused) != len(chain) - 1:
        raise ValueError(
            f"decision covers {len(decision.fused)} boundaries, chain "
            f"has {len(chain) - 1}")

    launches: List[Launch] = []
    fused_bits: List[bool] = []
    reasons: List[str] = []
    anchor, anchor_idx = chain[0], 0
    epilogue = chain[0].epilogue
    members = [0]

    def _close():
        launches.append(Launch(anchor=anchor, anchor_idx=anchor_idx,
                               epilogue=epilogue, members=tuple(members)))

    for i in range(1, len(chain)):
        node = chain[i]
        cur = Launch(anchor=anchor, anchor_idx=anchor_idx,
                     epilogue=epilogue, members=tuple(members))
        merged, reason, _rule = try_fuse(cur, node)
        wanted = decision is None or decision.fused[i - 1]
        if merged is not None and wanted:
            epilogue = merged
            members.append(i)
            fused_bits.append(True)
            reasons.append("")
        else:
            _close()
            anchor, anchor_idx = node, i
            epilogue = node.epilogue
            members = [i]
            fused_bits.append(False)
            reasons.append(reason if merged is None
                           else "split by decision")
    _close()
    return FusePlan(chain=chain, launches=tuple(launches),
                    decision=FuseDecision(tuple(fused_bits)),
                    reasons=tuple(reasons))


def split_all(chain) -> FusePlan:
    """The fully-split plan — every node its own launch (the unfused
    baseline ``tune_plan`` measures against)."""
    chain = tuple(chain)
    return plan(chain, FuseDecision((False,) * (len(chain) - 1)))


# ---------------------------------------------------------------------------
# Tuner integration
# ---------------------------------------------------------------------------


def plan_key(chain, x, params) -> str:
    """Cache key of a (chain, workload) pair: the chain signature plus a
    fingerprint of each node's operands — sparse matrices contribute
    their profile fingerprint (two matrices with the same sparsity
    profile share a record), dense operands their shapes."""
    from ..tune.cache import fingerprint

    parts = [chain_sig(chain), "x" + "x".join(str(s) for s in x.shape)]
    for p in params:
        if not p:
            continue
        a = p.get("a")
        if a is not None:
            parts.append(fingerprint(a))
        w = p.get("weights")
        if w is not None:
            parts.append("w" + "x".join(str(s) for s in w.shape))
    return "fuse:" + "|".join(parts)


def tune_plan(chain, x, params, *, cache=None,
              measure: Optional[Callable[[FusePlan], float]] = None,
              warmup: Optional[int] = None, iters: Optional[int] = None,
              backend: Optional[str] = None, interpret: bool = True,
              hill_steps: Optional[int] = None):
    """Measure fuse decisions for this chain on this workload and return
    a :class:`~repro.tune.TuneResult` whose ``.schedule`` is the winning
    :class:`FuseDecision` (feed it back through :func:`plan`).

    The search runs on the shared driver over the
    :class:`~repro.tune.space.FuseBoundaryAxis`: the seeds are the
    maximally-fused and the fully-split plans (identical chains —
    nothing fusable — measure once), and on 3+-node chains the driver's
    hillclimb then flips *individual* boundary bits around the measured
    winner (``hill_steps`` defaults to boundaries − 1, so 1-boundary
    chains keep the classic fused-vs-split duel) — fuse/split is a
    per-boundary scheduling decision, not an all-or-nothing one.  A
    flip is realized through :func:`plan`, so legality is never
    overridden: an illegal fuse realizes back to a split and dedupes
    away.  The winner persists under a ``fuse:`` key (:func:`plan_key`);
    a repeat call replays the cache with zero measurements.  ``measure``
    overrides the objective (``FusePlan -> seconds``) for tests."""
    from ..tune.cache import default_cache
    from ..tune.driver import _replay, drive
    from ..tune.measure import time_fn
    from ..tune.space import FuseBoundaryAxis, SearchContext, SearchSpace

    chain = tuple(chain)
    if cache is None:
        cache = default_cache(backend)
    key = plan_key(chain, x, params)
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    if measure is None:
        from .execute import run_plan

        def measure(p: FusePlan) -> float:
            return time_fn(
                lambda xx: run_plan(p, xx, params, interpret=interpret),
                x, warmup=warmup, iters=iters)

    if hill_steps is None:
        hill_steps = max(0, len(chain) - 2)
    space = SearchSpace(
        (FuseBoundaryAxis(chain),),
        key_fn=lambda p: p.decision.tag,
        dedupe=lambda c, p: p.decision.tag,
        record_of=lambda p: p.decision,
    )
    return drive(space, SearchContext(workload=chain), cache=cache,
                 key=key, measure=measure,
                 seeds=[plan(chain), split_all(chain)],
                 hill_steps=hill_steps)


def tuned_plan(chain, x, params, *, cache=None,
               backend: Optional[str] = None) -> FusePlan:
    """Measurement-free resolver: replay the cached decision for this
    (chain, workload) if one exists, else the greedy maximally-fused
    plan.  Safe on a serving path."""
    from ..tune.cache import default_cache
    from ..tune.driver import _replay

    if cache is None:
        cache = default_cache(backend)
    hit = _replay(cache, plan_key(tuple(chain), x, params))
    if hit is not None:
        return plan(chain, hit.schedule)
    return plan(chain)
