"""Bench-artifact regression diff (ISSUE 3 satellite; ISSUE 4: probe
normalization + N-run trajectory window).

Compares ``BENCH_<tag>.json`` artifacts (as written by
``benchmarks.run --json``) and exits non-zero when the new run regresses
past a threshold.  Signals checked:

* **us_per_call geomeans** per row group (default groups: ``table5``,
  ``beyond/fused_attention_bwd``, ``beyond/fusion_planner``,
  ``beyond/skew``, ``beyond/dist_attention`` and ``beyond/dist_moe``):
  geomean over the names both artifacts share.  When both artifacts
  carry the ``probe/runner_speed`` row (a fixed dense-matmul timing
  baked into every artifact), the geomeans are **normalized by the
  probe** — ``(new/new_probe) / (old/old_probe)`` — so heterogeneous CI
  runner CPUs stop gating on raw machine speed; without a probe on both
  sides the raw ratio gates as before.  Regression when the (normalized)
  ratio exceeds ``1 + threshold``;
* **derived geomean metrics** — ``derived`` fields carry
  ``<key>_geomean=<x>`` ratios.  Only the *win* ratios in
  ``GATED_GEOMEAN_KEYS`` (``tuned_vs_auto_geomean``,
  ``tuned_vs_default_geomean``, ``tuned_vs_static_geomean``,
  ``tuned_vs_fixed_geomean`` — higher is better) gate, failing when
  ``new < old * (1 - threshold)``; other geomean keys are reported
  informationally but never fail — both the ``*_vs_oracle`` slowdown
  ratios (lower is better) and ``fused_vs_unfused_geomean`` (a win
  ratio whose magnitude swings with runner load; see the comment at
  ``GATED_GEOMEAN_KEYS``).  Gated win ratios are measured within one
  run on one machine, so they need no probe;
* **trajectory drift** — with ``--trajectory traj.json``, the previous
  run is the trajectory's last entry *and* the new run is additionally
  gated against the **median of the last N runs' normalized geomeans**
  (``--window``, default 5): a slow drift of +4% per run passes every
  pairwise diff but accumulates past the threshold against the window
  median.  ``--update`` appends the new run and trims to the window, so
  CI keeps one rolling artifact.

Runs standalone (stdlib only) so CI and local use are the same command:

    python benchmarks/diff.py old.json new.json --threshold 0.10
    python benchmarks/diff.py --trajectory traj.json new.json --update

Missing groups, absent probes, or no shared rows are reported and
*skipped*, never failed — the first run of a fresh benchmark set must
stay green.
"""
from __future__ import annotations

import argparse
import json
import math
import re
import sys

# groups whose probe-normalized us geomeans gate: table5 (the paper's
# headline kernels), the fused attention backward (ISSUE 5), the
# fusion planner's fused chains (ISSUE 6), the skew-aware tuner on
# power-law graphs (ISSUE 7), and the distributed collective-mode
# benches (ISSUE 8 — their rows appear in both the smoke lane's
# 1-device artifact and the dist lane's 8-device artifact; each lane
# keeps its own trajectory, so the two never cross-compare).  A group's
# *first* appearance in a trajectory has no shared rows and skips
# green; thereafter a >threshold normalized slowdown fails.
DEFAULT_GROUPS = ("table5", "beyond/fused_attention_bwd",
                  "beyond/fusion_planner", "beyond/skew",
                  "beyond/lowprec", "beyond/dist_attention",
                  "beyond/dist_moe", "beyond/joint_dist",
                  "beyond/fuse_boundary")
DEFAULT_WINDOW = 5
PROBE_ROW = "probe/runner_speed"
TRAJECTORY_VERSION = 1

# derived geomean keys where higher is better (gateable win ratios);
# anything else matched by the regex is reported but never gates — e.g.
# auto_vs_oracle_geomean (a slowdown ratio where LOWER is better) and
# fused_vs_unfused_geomean (a win ratio, but its two sides are multi-
# second kernel timings measured sequentially, so its *magnitude* swings
# ±40% under runner contention even though the >1 win itself is robust).
# tuned_vs_static_geomean (beyond/skew) gates: tuned and static come
# from one measured pool, so the ratio is load-robust like the other
# within-run win ratios.
# tuned_vs_fixed_geomean (beyond/dist_*) gates too: tuned is the
# measured minimum of a pool containing the fixed mode, so the ratio is
# >= 1.0 by construction and load-robust like the other within-run
# win ratios.
GATED_GEOMEAN_KEYS = ("tuned_vs_auto_geomean", "tuned_vs_default_geomean",
                      "tuned_vs_static_geomean", "tuned_vs_fixed_geomean")

_GEOMEAN_RE = re.compile(r"([a-z0-9_/]*geomean)=([-+0-9.eE]+)")


def load_bench(path: str) -> dict:
    """``{name: {us_per_call, derived}}`` as ``benchmarks.run`` wrote it."""
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise ValueError(f"{path}: expected a JSON object of bench rows")
    return data


def load_trajectory(path: str) -> list:
    """List of artifacts, oldest first.  Tolerates a missing file (fresh
    trajectory) and a bare artifact (pre-trajectory BENCH_ci.json used to
    seed the window)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return []
    if isinstance(data, dict) and "runs" in data:
        return list(data["runs"])
    if isinstance(data, dict):
        return [data]  # a bare artifact seeds a 1-run window
    raise ValueError(f"{path}: expected a trajectory or an artifact")


def save_trajectory(path: str, runs: list, window: int) -> None:
    with open(path, "w") as f:
        json.dump({"version": TRAJECTORY_VERSION,
                   "runs": runs[-window:]}, f, indent=1)


def _geomean(xs) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def _us_rows(bench: dict, group: str) -> dict:
    out = {}
    for name, row in bench.items():
        us = (row or {}).get("us_per_call")
        if name.startswith(group) and isinstance(us, (int, float)) and us > 0:
            out[name] = float(us)
    return out


def probe_us(bench: dict) -> float | None:
    us = (bench.get(PROBE_ROW) or {}).get("us_per_call")
    return float(us) if isinstance(us, (int, float)) and us > 0 else None


def _derived_geomeans(bench: dict) -> dict:
    """``{row_name/metric: value}`` for every ``*geomean=`` in derived."""
    out = {}
    for name, row in bench.items():
        for key, val in _GEOMEAN_RE.findall(str((row or {}).get("derived"))):
            try:
                v = float(val)
            except ValueError:
                continue
            if v > 0:
                out[f"{name}:{key}"] = v
    return out


def _group_geomean(bench: dict, group: str, names) -> float | None:
    rows = _us_rows(bench, group)
    vals = [rows[n] for n in names if n in rows]
    return _geomean(vals) if len(vals) == len(list(names)) and vals else None


def compare(old: dict, new: dict, *, threshold: float = 0.10,
            groups=DEFAULT_GROUPS, window: list | None = None) -> list:
    """Findings as ``(kind, label, old, new, ratio, regressed)`` tuples.

    kind 'us' ratios are probe-normalized new/old time (higher is worse);
    kind 'drift' is new vs the window-median baseline (trajectory mode);
    kind 'geomean' ratios are new/old win ratio (lower is worse); kind
    'info' is a non-gating derived ratio; kind 'skip' marks a group with
    no shared rows.
    """
    findings = []
    p_old, p_new = probe_us(old), probe_us(new)
    normalize = p_old is not None and p_new is not None
    if normalize:
        findings.append(("info", f"{PROBE_ROW} (runner speed)",
                         p_old, p_new, p_new / p_old, False))
    for group in groups:
        a, b = _us_rows(old, group), _us_rows(new, group)
        shared = sorted(set(a) & set(b))
        if not shared:
            findings.append(("skip", group, None, None, None, False))
            continue
        g_old = _geomean([a[n] for n in shared])
        g_new = _geomean([b[n] for n in shared])
        ratio = g_new / g_old
        if normalize:
            ratio /= p_new / p_old
        label = (f"{group} ({len(shared)} rows"
                 + (", probe-normalized)" if normalize else ")"))
        findings.append(("us", label, g_old, g_new, ratio,
                         ratio > 1.0 + threshold))
        # trajectory drift: new vs the median of the window's normalized
        # geomeans over the same shared rows
        if window:
            baselines = []
            for run in window:
                g = _group_geomean(run, group, shared)
                p = probe_us(run)
                if g is None:
                    continue
                if normalize:
                    if p is None:
                        # a pre-probe run's raw us is not comparable to
                        # normalized values — skip it, don't poison the
                        # median (the CI seed path hits this)
                        continue
                    g /= p
                baselines.append(g)
            if baselines:
                base = sorted(baselines)[len(baselines) // 2]
                g_norm = g_new / p_new if normalize else g_new
                dr = g_norm / base
                findings.append(
                    ("drift", f"{group} vs {len(baselines)}-run median",
                     base, g_norm, dr, dr > 1.0 + threshold))
    d_old, d_new = _derived_geomeans(old), _derived_geomeans(new)
    for key in sorted(set(d_old) & set(d_new)):
        ratio = d_new[key] / d_old[key]
        gated = key.rsplit(":", 1)[-1] in GATED_GEOMEAN_KEYS
        findings.append(("geomean" if gated else "info", key,
                         d_old[key], d_new[key], ratio,
                         gated and ratio < 1.0 - threshold))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", nargs="?", default=None,
                    help="previous BENCH json artifact (omit with "
                         "--trajectory: its last run is the baseline)")
    ap.add_argument("new", help="current BENCH json artifact")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="fractional geomean regression that fails "
                         "(default 0.10 = 10%%)")
    ap.add_argument("--groups", default=",".join(DEFAULT_GROUPS),
                    help="comma list of row-name prefixes to diff")
    ap.add_argument("--trajectory", default=None, metavar="PATH",
                    help="rolling N-run trajectory file: the last run is "
                         "the pairwise baseline and the window median "
                         "gates slow drift")
    ap.add_argument("--window", type=int, default=DEFAULT_WINDOW,
                    help=f"trajectory window size (default "
                         f"{DEFAULT_WINDOW})")
    ap.add_argument("--update", action="store_true",
                    help="append the new run to --trajectory (trimmed to "
                         "the window) after diffing")
    args = ap.parse_args(argv)

    new = load_bench(args.new)
    window: list = []
    if args.trajectory is not None:
        window = load_trajectory(args.trajectory)[-args.window:]
    if args.old is not None:
        old = load_bench(args.old)
    elif window:
        old = window[-1]
    elif args.trajectory is not None:
        # fresh trajectory: nothing to diff against, pass (and seed the
        # window when asked to persist)
        if args.update:
            save_trajectory(args.trajectory, [new], args.window)
            print(f"bench diff: empty trajectory {args.trajectory}; "
                  f"seeded with {args.new}")
        else:
            print(f"bench diff: empty trajectory {args.trajectory}; "
                  f"nothing to diff (pass --update to seed it)")
        return 0
    else:
        ap.error("need an old artifact or --trajectory")

    findings = compare(old, new, threshold=args.threshold,
                       groups=tuple(g for g in args.groups.split(",") if g),
                       window=window)

    failed = False
    baseline = args.old or f"{args.trajectory}[-1]"
    print(f"bench diff: {baseline} -> {args.new} "
          f"(threshold {args.threshold:.0%}"
          + (f", window {len(window)}" if window else "") + ")")
    for kind, label, a, b, ratio, regressed in findings:
        if kind == "skip":
            print(f"  SKIP  {label}: no shared rows")
            continue
        unit = "us" if kind in ("us", "drift") else "x"
        verdict = ("REGRESSED" if regressed
                   else "info" if kind == "info" else "ok")
        arrow = "slower" if kind in ("us", "drift") else "ratio"
        print(f"  {verdict:9s} {label}: {a:.3f}{unit} -> {b:.3f}{unit} "
              f"({ratio:.3f} {arrow})")
        failed |= regressed
    if args.trajectory is not None and args.update:
        save_trajectory(args.trajectory,
                        load_trajectory(args.trajectory) + [new],
                        args.window)
    if failed:
        print("bench diff: FAIL (regression past threshold)",
              file=sys.stderr)
        return 1
    print("bench diff: ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
