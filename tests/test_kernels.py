"""Per-kernel allclose sweeps against the pure-jnp oracles (ref.py).

Sweeps shapes, dtypes, schedules (nnz_tile/row_tile/col_tile/group_size)
and strategies, per the paper's tuning axes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import GroupReduceStrategy, KernelSchedule, segment_group_reduce
from repro.kernels import grouped_matmul, ref, sddmm, segment_reduce, spmm
from repro.kernels.ops import expert_tile_map
from repro.sparse import random_csr

RTOL = 2e-5
ATOL = 2e-5


def _want_spmm(csr, b):
    return np.asarray(spmm(csr, b, impl="ref"))


@pytest.mark.parametrize("density,skew", [(0.02, 0.0), (0.05, 1.5), (0.001, 0.0)])
@pytest.mark.parametrize(
    "sched",
    [
        KernelSchedule("eb", nnz_tile=64, col_tile=8, group_size=8),
        KernelSchedule("eb", nnz_tile=64, col_tile=16, group_size=64),
        KernelSchedule("eb", nnz_tile=128, col_tile=8, group_size=16),
        KernelSchedule("eb", nnz_tile=64, col_tile=8, group_size=32,
                       strategy="accumulate"),
    ],
)
def test_spmm_eb_schedule_sweep(density, skew, sched):
    csr = random_csr(200, 150, density=density, skew=skew, seed=3)
    b = jax.random.normal(jax.random.PRNGKey(0), (150, 37))
    got = np.asarray(spmm(csr, b, sched))
    np.testing.assert_allclose(got, _want_spmm(csr, b), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("n_rows,n_cols,n_dense", [(100, 80, 20), (64, 64, 8), (33, 70, 130)])
@pytest.mark.parametrize("row_tile", [4, 8, 16])
def test_spmm_rb_shape_sweep(n_rows, n_cols, n_dense, row_tile):
    csr = random_csr(n_rows, n_cols, density=0.05, seed=7)
    b = jax.random.normal(jax.random.PRNGKey(1), (n_cols, n_dense))
    sched = KernelSchedule("rb", row_tile=row_tile, col_tile=8)
    got = np.asarray(spmm(csr, b, sched))
    np.testing.assert_allclose(got, _want_spmm(csr, b), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_dtypes(dtype):
    csr = random_csr(96, 96, density=0.03, seed=11)
    csr = type(csr)(indptr=csr.indptr, indices=csr.indices,
                    vals=csr.vals.astype(dtype), shape=csr.shape)
    b = jax.random.normal(jax.random.PRNGKey(2), (96, 16)).astype(dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else RTOL
    got = np.asarray(spmm(csr, b, KernelSchedule("eb", nnz_tile=64,
                                                 col_tile=8, group_size=8)))
    np.testing.assert_allclose(got, _want_spmm(csr, b), rtol=tol, atol=tol)


def test_spmm_empty_rows_and_single_tile():
    # matrix with many empty rows, nnz < one tile
    csr = random_csr(50, 40, density=0.002, seed=13)
    b = jax.random.normal(jax.random.PRNGKey(3), (40, 4))
    got = np.asarray(spmm(csr, b, KernelSchedule("eb", nnz_tile=64,
                                                 col_tile=8, group_size=8)))
    np.testing.assert_allclose(got, _want_spmm(csr, b), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("d", [16, 33, 128])
def test_sddmm(d):
    csr = random_csr(100, 80, density=0.05, seed=5)
    coo = csr.tocoo()
    a = jax.random.normal(jax.random.PRNGKey(2), (100, d))
    b = jax.random.normal(jax.random.PRNGKey(3), (80, d))
    want = np.asarray(ref.sddmm_ref(coo.rows, coo.cols, a, b))
    got = np.asarray(sddmm(coo.rows, coo.cols, a, b, nnz_tile=64))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sddmm_with_scale():
    csr = random_csr(60, 60, density=0.05, seed=6)
    coo = csr.tocoo()
    a = jax.random.normal(jax.random.PRNGKey(4), (60, 24))
    b = jax.random.normal(jax.random.PRNGKey(5), (60, 24))
    want = np.asarray(ref.sddmm_ref(coo.rows, coo.cols, a, b, coo.vals))
    got = np.asarray(sddmm(coo.rows, coo.cols, a, b, coo.vals, nnz_tile=64))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("group_size", [8, 16, 32, 64])
@pytest.mark.parametrize("strategy", ["segment", "accumulate"])
def test_segment_reduce_kernel(group_size, strategy):
    T, C, S = 256, 16, 40
    rng = np.random.default_rng(0)
    seg = np.sort(rng.integers(0, S, T)).astype(np.int32)
    data = rng.standard_normal((T, C)).astype(np.float32)
    want = np.asarray(ref.segment_reduce_ref(jnp.asarray(data),
                                             jnp.asarray(seg), S))
    got = np.asarray(
        segment_reduce(jnp.asarray(seg), jnp.asarray(data), num_segments=S,
                       tile=max(64, group_size), group_size=group_size,
                       strategy=strategy))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("group_size", [2, 4, 8, 16, 32])
def test_segment_group_reduce_spec_matches_segment_sum(group_size):
    T, C, S = 128, 8, 50
    rng = np.random.default_rng(1)
    seg = np.sort(rng.integers(0, S, T)).astype(np.int32)
    data = rng.standard_normal((T, C)).astype(np.float32)
    want = np.asarray(ref.segment_reduce_ref(jnp.asarray(data), jnp.asarray(seg), S))
    got = np.asarray(segment_group_reduce(
        jnp.asarray(data), jnp.asarray(seg), S, group_size=group_size,
        strategy=GroupReduceStrategy.SEGMENT))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_segment_group_parallel_contract():
    """PARALLEL strategy: groups whose lanes share one segment reduce
    exactly; the contract holds when seg ids are constant per group."""
    G, n_groups, C = 8, 6, 4
    seg = np.repeat(np.arange(n_groups), G).astype(np.int32)
    data = np.random.default_rng(2).standard_normal((G * n_groups, C)).astype(np.float32)
    got = np.asarray(segment_group_reduce(
        jnp.asarray(data), jnp.asarray(seg), n_groups, group_size=G,
        strategy=GroupReduceStrategy.PARALLEL))
    want = data.reshape(n_groups, G, C).sum(1)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("gs", [[40, 0, 70, 17], [1, 1, 1, 1], [0, 0, 128, 0]])
def test_grouped_matmul(gs):
    E, D, F, TT = 4, 64, 96, 32
    gs = np.asarray(gs)
    tiles = expert_tile_map(gs, TT)
    if len(tiles) == 0:
        pytest.skip("no tokens")
    t_pad = len(tiles) * TT
    rng = np.random.default_rng(2)
    x = rng.standard_normal((t_pad, D)).astype(np.float32)
    eids = np.repeat(tiles, TT)
    w = rng.standard_normal((E, D, F)).astype(np.float32)
    want = np.asarray(ref.grouped_matmul_ref(jnp.asarray(x), jnp.asarray(eids),
                                             jnp.asarray(w)))
    got = np.asarray(grouped_matmul(jnp.asarray(x), jnp.asarray(tiles),
                                    jnp.asarray(w), token_tile=TT,
                                    f_tile=32, d_tile=32))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
