"""Mixture-of-Experts FFN with segment-group dispatch (DESIGN.md §4.1).

Dispatch is the paper's sparse–dense hybrid algebra: routing matrix
(tokens × experts, top-k sparse) times token activations. The TPU
realization uses per-expert capacity selection (zero extension = capacity
padding), grouped GEMM, and scatter-add + psum writeback — the
segment-group machinery at the collective level.

Two execution paths with identical math:
  * einsum path — what the SPMD dry-run lowers (flop-accurate grouped GEMM
    per local expert);
  * Pallas path — ``kernels.grouped_matmul`` on the capacity-gathered
    tokens (validated in tests, CPU-interpret).

Expert parallelism: under a ``ShardingCtx`` the experts are sharded over
the model axis and tokens over the data axes via ``shard_map``; the psum
over the model axis is the 'atomic' collective writeback (DESIGN.md
changed-assumption 2).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import init_dense


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """How model-internal shard_map regions see the mesh. ``None`` ctx (or
    axes) means single-shard execution (smoke tests)."""

    mesh: object = None
    data_axes: tuple = ()
    model_axis: str | None = None


def init_moe(cfg, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    s = d ** -0.5
    so = f ** -0.5
    return {
        "router": init_dense(k1, d, e, "float32")["w"],
        "wg": (jax.random.normal(k2, (e, d, f)) * s).astype(cfg.param_dtype),
        "wi": (jax.random.normal(k3, (e, d, f)) * s).astype(cfg.param_dtype),
        "wo": (jax.random.normal(k4, (e, f, d)) * so).astype(cfg.param_dtype),
    }


def _capacity(cfg, t_local: int, factor: float | None = None) -> int:
    if factor is None:
        factor = cfg.capacity_factor
    cap = int(t_local * cfg.experts_per_token * factor / cfg.n_experts)
    return min(max(8, cap), t_local)


def _expert_ffn(cfg, x, wg, wi, wo, gates, capacity, use_pallas,
                dispatch=None, combine: str = "sum"):
    """Local computation: x (T, D) tokens; wg/wi/wo (E_loc, D, F)/(E_loc, F,
    D); gates (T, E_loc) combine weights (0 when not routed). Returns the
    partial output (T, D) for these experts.  ``dispatch`` (a
    ``repro.tune.MoeDispatchSchedule``) overrides the static tile
    defaults of the Pallas path; ``None`` keeps them.  ``combine`` is
    the gate-weighted writeback monoid ('sum' / 'min' / 'mean' —
    ``repro.fuse.moe_combine``)."""
    t, d = x.shape
    e_loc = wg.shape[0]
    # per-expert capacity selection: top-C tokens by gate weight. Tokens
    # with gate 0 may be selected when a local expert is under capacity —
    # they contribute 0 (zero extension).
    topv, topi = jax.lax.top_k(gates.T, capacity)  # (E_loc, C)
    xg = jnp.take(x, topi.reshape(-1), axis=0).reshape(e_loc, capacity, d)

    if use_pallas:
        from ..core.schedule import Epilogue
        from ..kernels.grouped_matmul import fit_tile
        from ..kernels.ops import grouped_matmul

        f = wg.shape[-1]
        tt = dispatch.token_tile if dispatch is not None else 128
        dt = fit_tile(d, dispatch.d_tile if dispatch is not None else 128)
        ft = fit_tile(f, dispatch.f_tile if dispatch is not None else 128)
        tile = min(capacity, tt)
        cap_pad = ((capacity + tile - 1) // tile) * tile
        if cap_pad != capacity:
            xg = jnp.pad(xg, ((0, 0), (0, cap_pad - capacity), (0, 0)))
        tiles_per_e = cap_pad // tile
        tile_experts = jnp.repeat(jnp.arange(e_loc, dtype=jnp.int32),
                                  tiles_per_e)
        flat = xg.reshape(e_loc * cap_pad, d)

        def gmm(x_, w_, contract_tile, out_tile, epilogue=Epilogue()):
            return grouped_matmul(x_, tile_experts, w_, token_tile=tile,
                                  d_tile=contract_tile, f_tile=out_tile,
                                  epilogue=epilogue)

        # the up-projections contract D and emit F; the down-projection
        # contracts F and emits D — tiles are passed per role, never
        # inferred from shapes (d == f would make that ambiguous).  The
        # gate projection's SiLU is fused onto the GEMM's output block
        # (the repro.fuse grouped_matmul→ewise chain, pre-planned): one
        # launch per tile instead of a GEMM pass + an XLA silu pass.
        h = gmm(flat, wg, dt, ft,
                epilogue=Epilogue(activation="silu")) * gmm(flat, wi,
                                                            dt, ft)
        y = gmm(h.astype(x.dtype), wo, ft, dt)
        y = y.reshape(e_loc, cap_pad, d)[:, :capacity]
    else:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, wg)) * jnp.einsum(
            "ecd,edf->ecf", xg, wi)
        y = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), wo)

    from ..fuse.execute import moe_combine

    return moe_combine(y.reshape(-1, d), topi.reshape(-1),
                       topv.reshape(-1), t, op=combine)


def _route(cfg, x, router):
    """Router: top-k gates. Returns (gates_dense (T, E) with zeros off the
    top-k, probs (T, E) for the aux loss)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.experts_per_token)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(probs.shape[0])[:, None], topi].set(topv)
    return gates, probs


def _aux_loss(cfg, gates, probs):
    """Switch-style load-balance loss over the local token shard."""
    f = jnp.mean((gates > 0).astype(jnp.float32), axis=0)  # dispatch frac
    p = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(f * p)


def apply_moe(cfg, p, x2d, ctx: ShardingCtx | None = None, *,
              dispatch=None, combine: str = "sum"):
    """x2d: (T, D) tokens (sharded over data axes under ctx). Returns
    (out (T, D), aux_loss scalar).  ``dispatch`` (a
    ``repro.tune.MoeDispatchSchedule``, e.g. from
    :func:`moe_tune_dispatch`) replaces the static token-tile/capacity
    defaults; ``None`` keeps the config's static choice.  ``combine``
    picks the expert→token writeback monoid ('sum' default; 'min' /
    'mean' run the same gate-weighted scatter under those monoids —
    ``repro.fuse.moe_combine``).  Non-additive combines are single-shard
    only: the expert-parallel psum writeback composes additive partials
    and cannot carry a min/mean across shards."""
    use_pallas = cfg.moe_pallas_dispatch
    cap_factor = dispatch.capacity_factor if dispatch is not None else None

    if ctx is None or ctx.mesh is None or ctx.model_axis is None:
        gates, probs = _route(cfg, x2d, p["router"])
        cap = _capacity(cfg, x2d.shape[0], cap_factor)
        out = _expert_ffn(cfg, x2d, p["wg"], p["wi"], p["wo"], gates, cap,
                          use_pallas, dispatch, combine)
        return out.astype(x2d.dtype), _aux_loss(cfg, gates, probs)

    if combine != "sum":
        raise ValueError(
            f"combine={combine!r} requires single-shard execution: the "
            "expert-parallel psum writeback only composes additive "
            "partials")

    mesh = ctx.mesh
    dax, max_ = ctx.data_axes, ctx.model_axis
    t_local = x2d.shape[0] // int(
        functools.reduce(lambda a, b: a * b, (mesh.shape[a] for a in dax), 1))
    cap = _capacity(cfg, t_local, cap_factor)

    # expert-parallel writeback mode (DESIGN.md §12): the tuned dispatch
    # carries the collective the way Schedule carries it for SpMM —
    # 'nnz_ar' is the atomic-style psum (the historical default),
    # 'nnz_rs' reduce-scatters the partial so each model shard finalizes
    # a token slice (1/P of the wire bytes).
    mode = (dispatch.collective if dispatch is not None else None) or "nnz_ar"
    m_size = int(mesh.shape[max_])
    if mode == "nnz_rs" and t_local % m_size:
        raise ValueError(
            f"collective='nnz_rs' needs the local token count ({t_local}) "
            f"divisible by the model axis ({m_size})")
    out_spec = (P(tuple(dax) + (max_,), None) if mode == "nnz_rs"
                else P(dax, None))

    from ..sparse.distributed import shard_map

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(dax, None), P(), P(max_), P(max_), P(max_)),
        out_specs=(out_spec, P()),
    )
    def _sharded(x, router, wg, wi, wo):
        gates, probs = _route(cfg, x, router)  # (T_loc, E) all experts
        e_loc = wg.shape[0]
        m_idx = jax.lax.axis_index(max_)
        sl = m_idx * e_loc
        gates_loc = jax.lax.dynamic_slice(
            gates, (0, sl), (gates.shape[0], e_loc))
        part = _expert_ffn(cfg, x, wg, wi, wo, gates_loc, cap, use_pallas,
                           dispatch)
        if mode == "nnz_rs":
            out = jax.lax.psum_scatter(part, max_, scatter_dimension=0,
                                       tiled=True)
        else:
            out = jax.lax.psum(part, max_)  # atomic collective writeback
        aux = _aux_loss(cfg, gates, probs)
        aux = jax.lax.pmean(aux, dax) if dax else aux
        aux = jax.lax.pmean(aux, max_)
        return out.astype(x.dtype), aux

    return _sharded(x2d, p["router"], p["wg"], p["wi"], p["wo"])


# ---------------------------------------------------------------------------
# Empirical dispatch tuning (repro.tune.moe wired to this model)
# ---------------------------------------------------------------------------


def default_dispatch(cfg):
    """The static dispatch point ``apply_moe(dispatch=None)`` uses: the
    config's capacity factor with 128-wide tiles.  The tuner's baseline —
    a tuned schedule is never slower than this on the measured configs."""
    from ..tune.moe import MoeDispatchSchedule

    return MoeDispatchSchedule(capacity_factor=cfg.capacity_factor)


def expert_lengths_from_gates(gates) -> "jnp.ndarray":
    """Expert-segment histogram of a routing decision: routed tokens per
    expert from the dense (T, E) gate matrix (zeros off the top-k)."""
    return (gates > 0).sum(axis=0)


def balanced_expert_lengths(cfg, t_tokens: int):
    """The histogram a perfectly load-balanced router would produce —
    the tuning default when no observed routing is supplied."""
    import numpy as np

    total = t_tokens * cfg.experts_per_token
    base, extra = divmod(total, cfg.n_experts)
    lengths = np.full(cfg.n_experts, base, np.int64)
    lengths[:extra] += 1
    return lengths


def skewed_expert_lengths(cfg, t_tokens: int, *, a: float = 1.5,
                          seed: int = 0):
    """A Zipf-skewed routing histogram — the representative hot-expert
    workload both ``launch.hillclimb --moe`` and the
    ``beyond/moe_tuner_gap`` benchmark tune (one definition, so the
    offline cache-population tool and the tracked benchmark stay on the
    same cells)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    w = rng.zipf(a, cfg.n_experts).astype(np.float64)
    total = t_tokens * cfg.experts_per_token
    return np.maximum(w / w.sum() * total, 1).astype(np.int64)


def moe_tune_dispatch(cfg, t_tokens: int, *, expert_lengths=None,
                      cache=None, measure=None, warmup=None, iters=None,
                      backend=None, **kw):
    """Empirically tune this config's dispatch schedule for ``t_tokens``
    local tokens (``repro.tune.tune_moe_dispatch`` keyed by the
    expert-segment histogram).  ``expert_lengths`` is the observed
    routed-tokens-per-expert histogram (e.g.
    ``expert_lengths_from_gates``); default assumes balanced routing.
    Returns a :class:`~repro.tune.TuneResult` whose ``.schedule`` plugs
    into ``apply_moe(..., dispatch=...)``; a repeat call with the same
    histogram replays the per-backend cache with zero measurements.

    When no histogram is supplied the balanced assumption stands in —
    and capacity shrinking is withheld (the drop constraint is only
    trustworthy on *observed* routing; a sub-default capacity that is
    free on the balanced histogram drops tokens on a skewed live
    batch)."""
    import numpy as np

    from ..tune.moe import tune_moe_dispatch as _tune

    kw.setdefault("allow_capacity_shrink", expert_lengths is not None)
    kw.setdefault("max_tokens", t_tokens)
    if expert_lengths is None:
        expert_lengths = balanced_expert_lengths(cfg, t_tokens)
    return _tune(np.asarray(expert_lengths), cfg.d_model, cfg.moe_d_ff,
                 dtype=str(cfg.param_dtype), default=default_dispatch(cfg),
                 cache=cache, measure=measure, warmup=warmup, iters=iters,
                 backend=backend, **kw)


def moe_tune_collective(cfg, params, x2d, ctx, *, dispatch=None,
                        cache=None, measure=None, warmup=None, iters=None,
                        backend=None):
    """Tune the expert-parallel writeback collective on a *real* mesh
    (DESIGN.md §12): measures ``apply_moe`` end to end under each
    feasible mode ('nnz_ar' psum vs 'nnz_rs' psum_scatter, when the
    local token count divides the model axis) and persists the winner —
    a :class:`~repro.tune.MoeDispatchSchedule` carrying ``collective`` —
    under a mesh-extent-suffixed key, so replays are measurement-free
    and a different mesh re-tunes.  ``dispatch`` seeds the GEMM tiling
    (default: the config's static point); like the wire mode on SpMM,
    only the collective axis is searched here — the tiling axes belong
    to :func:`moe_tune_dispatch`.

    This lives at the models layer because the objective *is* the model
    op (``repro.tune`` never imports ``repro.models``)."""
    import jax as _jax

    from ..tune.cache import default_cache, fingerprint_from_lengths
    from ..tune.driver import _replay, drive
    from ..tune.measure import time_fn
    from ..tune.moe import moe_schedule_key
    from ..tune.space import CollectiveAxis, SearchContext, SearchSpace

    if ctx is None or ctx.mesh is None or ctx.model_axis is None:
        raise ValueError("moe_tune_collective needs a sharded ctx "
                         "(mesh + model_axis)")
    if cache is None:
        cache = default_cache(backend)
    base = (dispatch or default_dispatch(cfg)).replace(collective=None)
    m_size = int(ctx.mesh.shape[ctx.model_axis])
    t = int(x2d.shape[0])
    d_size = int(functools.reduce(
        lambda a, b: a * b, (ctx.mesh.shape[a] for a in ctx.data_axes), 1))
    t_local = t // d_size

    lengths = balanced_expert_lengths(cfg, t)
    fp = fingerprint_from_lengths(lengths, (cfg.n_experts, cfg.d_model), t)
    key = (f"moedist:{fp}|F{cfg.moe_d_ff}|{moe_schedule_key(base)}"
           f"|mesh:{m_size}")
    hit = _replay(cache, key)
    if hit is not None:
        return hit

    if measure is None:
        def measure(s):
            fn = _jax.jit(
                lambda xx: apply_moe(cfg, params, xx, ctx, dispatch=s)[0])
            return time_fn(fn, x2d, warmup=warmup, iters=iters)

    modes = ["nnz_ar"] + (["nnz_rs"] if t_local % m_size == 0 else [])
    space = SearchSpace((CollectiveAxis(modes),), key_fn=moe_schedule_key)
    ctx_s = SearchContext(axis_size=m_size, workload=lengths)
    return drive(space, ctx_s, cache=cache, key=key, measure=measure,
                 ranked=space.cross(ctx_s, [base]))


def moe_dispatch_schedule(cfg, t_tokens: int, *, expert_lengths=None,
                          cache=None, backend=None):
    """Measurement-free resolver: the tuned dispatch for this config's
    histogram if the cache has one, else the static default.  Safe on a
    serving path — never stalls on a tuning run.  Mirrors
    :func:`moe_tune_dispatch`'s keying: an assumed (``None``) histogram
    resolves only no-shrink records."""
    import numpy as np

    from ..tune.moe import moe_cached_or_default

    observed = expert_lengths is not None
    if expert_lengths is None:
        expert_lengths = balanced_expert_lengths(cfg, t_tokens)
    return moe_cached_or_default(np.asarray(expert_lengths), cfg.d_model,
                                 cfg.moe_d_ff, dtype=str(cfg.param_dtype),
                                 default=default_dispatch(cfg),
                                 cache=cache, backend=backend,
                                 allow_capacity_shrink=observed,
                                 max_tokens=t_tokens)
