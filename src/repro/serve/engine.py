"""Batched serving engine: continuous batching over a fixed-slot KV cache.

Slots hold independent sequences; ``step`` decodes one token for every
active slot with a single jit'd serve_step (the decode path the dry-run
lowers). Finished slots are refilled from the request queue via per-slot
prefill; greedy or temperature sampling.

Sparse side-channel workloads (retrieval adapters, graph features, MoE
routing tables) go through :meth:`ServeEngine.spmm`, which resolves the
schedule from the persistent tuner cache (``repro.tune``) — tuning
happens ahead of time via :meth:`ServeEngine.prepare_sparse` (or
``launch.hillclimb --spmm``); the request path itself *never* runs a
measurement.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16


class ServeEngine:
    def __init__(self, api, params, *, slots: int = 4, max_len: int = 128,
                 temperature: float = 0.0, seed: int = 0,
                 tuner_cache=None):
        self.api = api
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.active: dict[int, dict] = {}  # slot -> {rid, remaining, out}
        self.cache = api.init_cache(slots, max_len)
        self._decode = jax.jit(api.decode_step)
        self.results: dict[int, list[int]] = {}
        self._next_tokens = np.zeros((slots,), np.int32)
        # repro.tune.ScheduleCache (None -> the process default cache);
        # consulted by the sparse side-channel path below.  The memo maps
        # fingerprint cache keys -> tuned Schedule, so it survives operand
        # re-creation and never aliases two different matrices (ids can be
        # reused after GC; fingerprints cannot collide that way).
        self.tuner_cache = tuner_cache
        self._sched_memo: dict[str, object] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    # -- tuned sparse side-channel ----------------------------------------

    def prepare_sparse(self, csr, n_dense_cols: int, *,
                       value_dtypes=None, error_budget=None):
        """Ahead-of-time tuning for a sparse operand this engine will
        serve with: measures (or replays the fingerprint cache) and
        persists the winner, so :meth:`spmm` replays it for free.

        ``value_dtypes`` / ``error_budget`` forward to
        :func:`~repro.tune.tune_schedule`'s dtype axis (DESIGN.md §13):
        pass ``value_dtypes=()`` to pin f32 storage for a
        parity-critical serving path, or a tighter ``error_budget``
        than the tuner's 5% default."""
        from ..tune import cache_key, tune_schedule

        kw = {}
        if value_dtypes is not None:
            kw["value_dtypes"] = value_dtypes
        if error_budget is not None:
            kw["error_budget"] = error_budget
        sched = tune_schedule(csr, n_dense_cols,
                              cache=self.tuner_cache, **kw).schedule
        self._sched_memo[cache_key(csr, n_dense_cols)] = sched
        return sched

    def prepare_dist(self, csr, n_dense_cols: int, *, mesh, axis: str,
                     value_dtypes=None, interpret: bool = True):
        """Ahead-of-time tuning for a *sharded* sparse operand: one
        joint search over local tiling × collective mode × value dtype
        (:func:`~repro.tune.tune_dist_spmm` on the §14 driver), persisted
        under the mesh-extent-suffixed key so
        ``dist_spmm(..., schedule="tune")`` replays it for free on the
        serving path.  ``value_dtypes=()`` pins f32 storage."""
        from ..tune import cache_key, tune_dist_spmm

        kw = {}
        if value_dtypes is not None:
            kw["value_dtypes"] = value_dtypes
        res = tune_dist_spmm(csr, n_dense_cols, mesh=mesh, axis=axis,
                             cache=self.tuner_cache, interpret=interpret,
                             **kw)
        axis_size = int(mesh.shape[axis])
        self._sched_memo[
            f"dist:{cache_key(csr, n_dense_cols)}|mesh:{axis_size}"
        ] = res.schedule
        return res.schedule

    def prepare_moe(self, cfg, t_tokens: int, expert_lengths=None):
        """Ahead-of-time tuning of the MoE dispatch this engine will run:
        measures (or replays the per-backend cache) the token-tile ×
        capacity × (f_tile, d_tile) space for this config's expert
        histogram, so :meth:`moe_dispatch_schedule` replays it for free."""
        from ..models.moe import moe_tune_dispatch

        res = moe_tune_dispatch(cfg, t_tokens,
                                expert_lengths=expert_lengths,
                                cache=self.tuner_cache)
        self._sched_memo[res.key] = res.schedule
        return res.schedule

    def moe_dispatch_schedule(self, cfg, t_tokens: int,
                              expert_lengths=None):
        """Serving-path resolver for ``apply_moe(..., dispatch=...)``:
        per-engine memo, then the persistent per-backend cache, else the
        config's static default — never an inline measurement."""
        import numpy as np

        from ..models.moe import balanced_expert_lengths, moe_dispatch_schedule
        from ..tune.moe import moe_cache_key

        observed = expert_lengths is not None
        lengths = np.asarray(expert_lengths if observed
                             else balanced_expert_lengths(cfg, t_tokens))
        # same keying as moe_tune_dispatch: assumed histograms resolve
        # the no-shrink record only
        key = moe_cache_key(lengths, cfg.d_model, cfg.moe_d_ff,
                            str(cfg.param_dtype), shrink=observed,
                            max_tokens=t_tokens)
        sched = self._sched_memo.get(key)
        if sched is None:
            sched = moe_dispatch_schedule(cfg, t_tokens,
                                          expert_lengths=expert_lengths,
                                          cache=self.tuner_cache)
        return sched

    def spmm(self, a, b):
        """Serving-path SpMM: schedule comes from the per-engine memo,
        then the persistent tuner cache, else the static selector —
        never from an inline measurement (requests must not stall on a
        tuning run).  Cache misses are not memoized, so tuning done
        later (``hillclimb --spmm``, another engine's ``prepare_sparse``)
        is picked up on the next call.  Non-CSR operands have no
        fingerprint; they fall through to the library default, matching
        ``repro.sparse.spmm(..., schedule="auto")``."""
        from ..sparse import spmm as _spmm
        from ..sparse.formats import CSR
        from ..tune import cache_key, cached_or_auto

        if not isinstance(a, CSR):
            return _spmm(a, b, schedule="auto")
        key = cache_key(a, int(b.shape[1]))  # memoized on the CSR
        sched = self._sched_memo.get(key)
        if sched is None:
            sched = cached_or_auto(a, int(b.shape[1]),
                                   cache=self.tuner_cache, key=key)
        return _spmm(a, b, schedule=sched)

    def _slot_prefill(self, slot: int, req: Request):
        """Prefill one slot: run the prompt batched-by-1 and splice the
        per-slot KV into the shared cache."""
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        logits, cache1 = self.api.prefill(self.params, batch, self.max_len)

        def splice(full, one):
            if one.ndim >= 2 and one.shape[1] == 1:  # (L, 1, ...) slot axis
                return jax.lax.dynamic_update_slice_in_dim(
                    full, one.astype(full.dtype), slot, axis=1)
            return full

        self.cache = jax.tree.map(splice, self.cache, cache1)
        # NOTE: per-slot positions require a vector 'pos'; this engine uses
        # synchronized-length prompts per wave (documented limitation).
        self.cache["pos"] = cache1["pos"]
        tok = int(jnp.argmax(logits[0]))
        self.active[slot] = {"rid": req.rid,
                             "remaining": req.max_new_tokens - 1,
                             "out": [tok]}
        self._next_tokens[slot] = tok

    def _fill_slots(self):
        for slot in range(self.slots):
            if slot not in self.active and self.queue:
                self._slot_prefill(slot, self.queue.popleft())

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(
            sub, logits / self.temperature, axis=-1).astype(jnp.int32)

    def step(self):
        """One decode wave across all active slots."""
        self._fill_slots()
        if not self.active:
            return False
        toks = jnp.asarray(self._next_tokens)
        logits, self.cache = self._decode(self.params, self.cache, toks)
        nxt = np.asarray(self._sample(logits))
        for slot, st in list(self.active.items()):
            tok = int(nxt[slot])
            st["out"].append(tok)
            st["remaining"] -= 1
            self._next_tokens[slot] = tok
            if st["remaining"] <= 0:
                self.results[st["rid"]] = st["out"]
                del self.active[slot]
        return True

    def run_to_completion(self, max_steps: int = 1000):
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.results
