"""Sgap core: atomic parallelism (design space), segment group (executable
reduction spec) and the unified Schedule API + reduction-strategy registry
(DESIGN.md §3)."""
from .atomic_parallelism import (  # noqa: F401
    DA_SPMM_POINTS,
    AtomicParallelism,
    KernelSchedule,
    enumerate_space,
    is_legal,
    to_schedule,
)
from .dtypes import (  # noqa: F401
    VALUE_DTYPES,
    Fp8Fallback,
    canonical_value_dtype,
    fp8_supported,
    operand_dtype,
    operand_itemsize,
    storage_dtype,
    value_itemsize,
)
from .schedule import (  # noqa: F401
    ACTIVATIONS,
    COLLECTIVES,
    Epilogue,
    ReductionStrategy,
    Schedule,
    as_schedule,
    attach_pallas_impl,
    available_strategies,
    get_strategy,
    register_strategy,
    schedule_axes,
)
from .segment_group import (  # noqa: F401
    GroupReduceStrategy,
    Monoid,
    SegmentGroup,
    available_monoids,
    get_monoid,
    group_waste_fraction,
    group_writeback_counts,
    make_monoid,
    segment_group_reduce,
    segment_sum_ref,
)
from .selector import (  # noqa: F401
    COST_TERM_NAMES,
    DEFAULT_COST_WEIGHTS,
    WIRE_COST_WEIGHT,
    candidate_schedules,
    collective_cost_terms,
    cost_terms,
    get_cost_weights,
    predict_cost,
    predict_dist_cost,
    select_schedule,
    set_cost_weights,
)
