"""Measurement layer shared by the autotuner and the benchmark harness.

``time_fn`` is the single wall-clock timer in the repo — the paper-table
benchmarks (``benchmarks/_util``) re-export it from here, and the tuner
(``tune.search``) calls it directly, so a tuned number and a benchmarked
number come from the same instrument.  The iteration count is
env-tunable (``REPRO_BENCH_ITERS`` / ``REPRO_BENCH_WARMUP``) so CI smoke
runs can trade variance for wall time.

The schedule runners build a jitted pure-JAX analogue of each kernel
schedule — XLA compiles a genuinely different program per schedule point
(group size, strategy, tiling all change the compiled structure), so
relative effects track the paper's axes; absolute numbers are
backend-specific (DESIGN.md changed assumption 5).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core import GroupReduceStrategy, Schedule, segment_group_reduce
from ..kernels import ref

__all__ = [
    "bench_iters",
    "bench_warmup",
    "time_fn",
    "make_eb_runner",
    "make_rb_runner",
    "make_runner",
    "measure_schedule",
]


def bench_iters(default: int = 7) -> int:
    """Timing iterations per measurement; override with REPRO_BENCH_ITERS
    (CI smoke sets a small value to stay under its time budget)."""
    return max(1, int(os.environ.get("REPRO_BENCH_ITERS", default)))


def bench_warmup(default: int = 2) -> int:
    return max(0, int(os.environ.get("REPRO_BENCH_WARMUP", default)))


def time_fn(fn, *args, warmup: int | None = None,
            iters: int | None = None) -> float:
    """Median seconds/call of a jitted fn (blocks on results).

    ``REPRO_BENCH_ITERS`` / ``REPRO_BENCH_WARMUP`` supply defaults and
    *cap* explicit arguments, so CI smoke bounds total bench time without
    touching call sites."""
    if warmup is None:
        warmup = bench_warmup()
    elif "REPRO_BENCH_WARMUP" in os.environ:
        warmup = min(warmup, bench_warmup())
    if iters is None:
        iters = bench_iters()
    elif "REPRO_BENCH_ITERS" in os.environ:
        iters = max(1, min(iters, bench_iters()))
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# ------------------------------------------------------------------------
# Schedule executor: pure-JAX analogue of each kernel schedule, jitted so
# XLA compiles a genuinely different program per schedule point.
# ------------------------------------------------------------------------


def _dense_b(csr, n_dense):
    return jax.random.normal(jax.random.PRNGKey(0), (csr.shape[1], n_dense))


def make_eb_runner(csr, n_dense, *, group_size: int, strategy: str,
                   nnz_tile: int = 256):
    g = csr.grouped(max(nnz_tile, group_size))
    n_rows = csr.shape[0]

    def run(rows, cols, vals, b):
        partial = vals[:, None].astype(jnp.float32) * jnp.take(
            b.astype(jnp.float32), cols, axis=0)
        if strategy == GroupReduceStrategy.ACCUMULATE.value:
            return jax.ops.segment_sum(partial, rows, num_segments=n_rows)
        # any registered strategy name dispatches through the registry
        return segment_group_reduce(partial, rows, n_rows,
                                    group_size=group_size, strategy=strategy)

    fn = jax.jit(run)
    args = (g.rows, g.cols, g.vals, _dense_b(csr, n_dense))
    return fn, args


def make_rb_runner(csr, n_dense, *, row_tile: int = 8,
                   width: int | None = None):
    ell = csr.ell(row_tile=row_tile, width=width)
    n_rows = csr.shape[0]

    def run(ecols, evals, b):
        return ref.spmm_ell_ref(ecols, evals, b, n_rows)

    fn = jax.jit(run)
    args = (ell.cols, ell.vals, _dense_b(csr, n_dense))
    return fn, args


def make_runner(csr, n_dense: int, sched: Schedule):
    """Runner for an arbitrary :class:`Schedule` (dispatch on kernel)."""
    if sched.kernel == "eb":
        return make_eb_runner(csr, n_dense, group_size=sched.group_size,
                              strategy=sched.strategy,
                              nnz_tile=sched.nnz_tile)
    return make_rb_runner(csr, n_dense, row_tile=sched.row_tile)


def measure_schedule(csr, n_dense: int, sched: Schedule, *,
                     warmup: int | None = None,
                     iters: int | None = None) -> float:
    """Seconds/call of ``sched`` applied to ``csr @ B`` with ``n_dense``
    dense columns — the tuner's objective function."""
    fn, args = make_runner(csr, n_dense, sched)
    return time_fn(fn, *args, warmup=warmup, iters=iters)
