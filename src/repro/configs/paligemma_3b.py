"""PaliGemma-3B [arXiv:2407.07726]: SigLIP stub + Gemma decoder (MQA)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_head=256,
    d_ff=16384, vocab_size=257216,
    norm="rmsnorm", mlp_type="geglu", rope_theta=1e4,
    n_vision_tokens=256,
)
