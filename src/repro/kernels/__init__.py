"""Pallas TPU kernels for the Sgap segment-group machinery.

Each kernel module pairs a ``pl.pallas_call`` + BlockSpec implementation
with the pure-jnp oracle in ``ref.py``; ``ops.py`` holds the jit'd
padding/format wrappers the framework calls.
"""
from . import ref  # noqa: F401
from .fused_attention import (  # noqa: F401
    fused_sparse_attention,
    fused_sparse_attention_bwd,
    sparse_attention_bwd_ref,
    sparse_attention_ref,
)
from .grouped_matmul import grouped_matmul  # noqa: F401
from .ops import sddmm, spmm  # noqa: F401
from .segment_reduce import segment_reduce  # noqa: F401
from .spmm_eb import spmm_eb  # noqa: F401
from .spmm_rb import spmm_rb  # noqa: F401
