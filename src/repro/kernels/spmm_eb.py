"""nnz-split (EB) segment-group SpMM Pallas kernel — the paper's
``{<1 nnz, c col>, r}`` algorithm (Sgap §6.2, Listing 6), TPU-native.

Grid: (col_tiles, nnz_tiles) — nnz innermost so consecutive grid steps
revisit the same output block and accumulation is race-free.

Per grid cell (one ``NNZ_TILE × COL_TILE`` block):
  1. gather dense rows      B[cols]            (zero extension: padded
                                                lanes gather row 0, val 0)
  2. scale by values        P = vals ⊙ B[cols]
  3. segment-group reduce   width-G one-hot MXU reduce + runtime
                            writeback (see kernels/common.py)
  4. on the *last* nnz step of a column block: the fused epilogue
     (bias / activation / residual / dtype cast — DESIGN.md §8), so a
     GCN layer's ``act(A @ XW + b)`` is one kernel instead of three HBM
     round trips.  This epilogue slot is what the fusion planner's
     ``epilogue-fold`` rule targets (``repro.fuse``, DESIGN.md §10):
     ewise chain nodes legal under ``Epilogue.extended`` land here.

VMEM working set per cell:  B block (K × COL_TILE) + partials
(NNZ_TILE × COL_TILE) + out block (n_rows × COL_TILE). The kernel targets
the paper's *balance-intensive* regime (few dense columns), where these
comfortably fit VMEM; ``ops.spmm`` asserts the footprint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.schedule import Epilogue
from .common import (
    apply_epilogue,
    group_reduce_scatter,
    split_epilogue_refs,
    upcast_f32,
)

_NOOP = Epilogue()


def _spmm_eb_kernel(rows_ref, cols_ref, vals_ref, b_ref, *refs,
                    group_size: int, strategy: str, heavy_tiles: int,
                    epilogue: Epilogue, narrowed: bool, quantized: bool):
    if quantized:
        scales_ref, *refs = refs
    bias_ref, res_ref, out_ref, acc_ref = split_epilogue_refs(
        refs, epilogue, narrowed)
    # out_dtype narrowing: accumulate in the f32 scratch, cast only at
    # the final store (out_ref doubles as the accumulator otherwise)
    acc = out_ref if acc_ref is None else acc_ref

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    rows = rows_ref[...]
    cols = cols_ref[...]
    # storage may be narrow (bf16/fp8) or int8 codes — all arithmetic is
    # f32 from here on (the upcast_f32 accumulation contract)
    vals = upcast_f32(vals_ref[...])
    b = upcast_f32(b_ref[...])
    if quantized:
        # per-lane dequant *before* the segment reduce: scales are
        # per-row (segment-aligned), so partials combine exactly as in
        # the f32 kernel and the scatter stays monoid-correct.  Padded
        # lanes gather the pad row's scale with val 0 — still zero.
        vals = vals * jnp.take(upcast_f32(scales_ref[...]), rows)

    gathered = jnp.take(b, cols, axis=0)  # (T, C)
    partial = gathered * vals[:, None]
    if heavy_tiles > 0 and strategy != "parallel":
        # two-level skew layout (DESIGN.md §11): the leading heavy tiles
        # hold single-row groups, so they run the registry's 'parallel'
        # realization — one plain reduce + one read-modify-write per
        # group, the accumulate-style cross-group combine for split rows
        @pl.when(pl.program_id(1) < heavy_tiles)
        def _heavy():
            group_reduce_scatter(rows, partial, acc, group_size,
                                 "parallel")

        @pl.when(pl.program_id(1) >= heavy_tiles)
        def _tail():
            group_reduce_scatter(rows, partial, acc, group_size, strategy)
    else:
        group_reduce_scatter(rows, partial, acc, group_size, strategy)

    if not epilogue.is_noop:
        @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
        def _epilogue():
            apply_epilogue(out_ref, epilogue, bias_ref, res_ref,
                           acc_ref=acc_ref)


@functools.partial(
    jax.jit,
    static_argnames=("n_rows", "nnz_tile", "col_tile", "group_size",
                     "strategy", "heavy_tiles", "epilogue", "interpret"),
)
def spmm_eb(rows, cols, vals, b, *, n_rows: int, nnz_tile: int = 256,
            col_tile: int = 128, group_size: int = 32,
            strategy: str = "segment", heavy_tiles: int = 0,
            epilogue: Epilogue = _NOOP, scales=None,
            bias=None, residual=None, interpret: bool = True):
    """out (n_rows, N) = scatter-reduce over padded COO triplets × B,
    with the fused ``epilogue`` applied to each output block on its last
    reduction step (``bias`` (1, N) and ``residual`` (n_rows, N) are
    required/forbidden per the epilogue's flags).

    Inputs must be pre-padded: len(vals) % nnz_tile == 0 (see
    ``formats.GroupedCOO``) and b.shape[1] % col_tile == 0 (``ops.spmm``
    does the column padding).  ``heavy_tiles`` (static, from a skew
    ``GroupedCOO``'s metadata) marks the leading nnz tiles whose groups
    are single-row by construction: those run the 'parallel' realization
    regardless of ``strategy`` (DESIGN.md §11).

    ``scales`` (n_rows,) f32, when given, selects the quantized value
    path (DESIGN.md §13): ``vals`` holds int8 codes and every lane is
    dequantized ``val * scales[row]`` before the segment reduce.  The
    scale vector stays resident in VMEM across nnz steps (constant index
    map) — the dequant adds no per-nnz HBM traffic.
    """
    nnz_pad = vals.shape[0]
    k, n = b.shape
    assert nnz_pad % nnz_tile == 0 and n % col_tile == 0, (nnz_pad, n)
    grid = (n // col_tile, nnz_pad // nnz_tile)

    operands = [rows, cols, vals, b]
    in_specs = [
        pl.BlockSpec((nnz_tile,), lambda j, i: (i,)),
        pl.BlockSpec((nnz_tile,), lambda j, i: (i,)),
        pl.BlockSpec((nnz_tile,), lambda j, i: (i,)),
        pl.BlockSpec((k, col_tile), lambda j, i: (0, j)),
    ]
    quantized = scales is not None
    if quantized:
        assert scales.shape == (n_rows,), (scales.shape, n_rows)
        operands.append(scales)
        in_specs.append(pl.BlockSpec((n_rows,), lambda j, i: (0,)))
    if epilogue.bias:
        assert bias is not None and bias.shape == (1, n), (n, bias)
        operands.append(bias)
        in_specs.append(pl.BlockSpec((1, col_tile), lambda j, i: (0, j)))
    if epilogue.residual:
        assert residual is not None and residual.shape == (n_rows, n)
        operands.append(residual)
        in_specs.append(
            pl.BlockSpec((n_rows, col_tile), lambda j, i: (0, j)))
    out_dtype = jnp.dtype(epilogue.out_dtype or jnp.float32)
    narrowed = out_dtype != jnp.float32
    scratch = []
    if narrowed:
        from jax.experimental.pallas import tpu as pltpu

        scratch = [pltpu.VMEM((n_rows, col_tile), jnp.float32)]

    kernel = functools.partial(
        _spmm_eb_kernel, group_size=group_size, strategy=strategy,
        heavy_tiles=heavy_tiles, epilogue=epilogue, narrowed=narrowed,
        quantized=quantized)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((n_rows, col_tile), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((n_rows, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
