"""Benchmark utilities: wall-clock timing of jitted callables + the
schedule->executable mapping shared by the paper-table benchmarks.

Timing is XLA-CPU wall clock (this container's only real backend). The
schedule space (nnz-split vs row-split, group size G, strategies, tiling)
is expressed in the compiled program structure, so relative effects track
the paper's axes; absolute numbers are CPU-specific (DESIGN.md changed
assumption 5).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GroupReduceStrategy, segment_group_reduce
from repro.kernels import ref


def time_fn(fn, *args, warmup: int = 2, iters: int = 7) -> float:
    """Median seconds/call of a jitted fn (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# ------------------------------------------------------------------------
# Schedule executor: pure-JAX analogue of each kernel schedule, jitted so
# XLA compiles a genuinely different program per schedule point.
# ------------------------------------------------------------------------


def make_eb_runner(csr, n_dense, *, group_size: int, strategy: str,
                   nnz_tile: int = 256):
    g = csr.grouped(max(nnz_tile, group_size))
    n_rows = csr.shape[0]

    def run(rows, cols, vals, b):
        partial = vals[:, None].astype(jnp.float32) * jnp.take(
            b.astype(jnp.float32), cols, axis=0)
        if strategy == GroupReduceStrategy.ACCUMULATE.value:
            return jax.ops.segment_sum(partial, rows, num_segments=n_rows)
        # any registered strategy name dispatches through the registry
        return segment_group_reduce(partial, rows, n_rows,
                                    group_size=group_size, strategy=strategy)

    fn = jax.jit(run)
    args = (g.rows, g.cols, g.vals,
            jax.random.normal(jax.random.PRNGKey(0), (csr.shape[1], n_dense)))
    return fn, args


def make_rb_runner(csr, n_dense, *, row_tile: int = 8,
                   width: int | None = None):
    ell = csr.ell(row_tile=row_tile, width=width)
    n_rows = csr.shape[0]

    def run(ecols, evals, b):
        return ref.spmm_ell_ref(ecols, evals, b, n_rows)

    fn = jax.jit(run)
    args = (ell.cols, ell.vals,
            jax.random.normal(jax.random.PRNGKey(0), (csr.shape[1], n_dense)))
    return fn, args


def geomean(xs) -> float:
    xs = np.asarray(list(xs), np.float64)
    return float(np.exp(np.mean(np.log(xs))))


def suite(sizes=((4096, 4096),), densities=(0.001, 0.01),
          skews=(0.0, 1.0, 2.0), seed: int = 0):
    """The synthetic matrix suite (stands in for the paper's SuiteSparse
    selection — DESIGN.md changed assumption 5)."""
    from repro.sparse import random_csr

    mats = []
    for (m, n) in sizes:
        for d in densities:
            for s in skews:
                mats.append(((m, n, d, s),
                             random_csr(m, n, density=d, skew=s,
                                        seed=seed + int(s * 10))))
    return mats
